"""Layer-1 Pallas kernels (build-time only; interpret=True on CPU)."""

from .int_round import int_round_stochastic, int_round_deterministic
from .dequant_update import dequant_update
from .fused_linear import fused_linear

__all__ = [
    "int_round_stochastic",
    "int_round_deterministic",
    "dequant_update",
    "fused_linear",
]
