"""Pallas kernel: tiled fused linear layer y = act(x @ w + b).

This is the model-side MXU hot-spot (DESIGN.md §Hardware-Adaptation): where
the paper's PyTorch models rely on cuDNN/cuBLAS, we express the dense
layers as an MXU-tiled Pallas matmul so the whole train step lowers into a
single HLO module with the compression kernels.

Tiling: grid (M/BM, N/BN); each grid step keeps an x-tile (BM x K) and a
w-tile (K x BN) resident in VMEM and accumulates in f32. For the model
sizes in this repo K fits VMEM whole, so no K-loop is needed; the BlockSpec
already expresses the HBM->VMEM schedule a CUDA kernel would do with
threadblock staging.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _kernel(act, x_ref, w_ref, b_ref, o_ref):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _pad(v, axis, mult):
    size = v.shape[axis]
    pad = (-size) % mult
    if pad:
        widths = [(0, 0)] * v.ndim
        widths[axis] = (0, pad)
        v = jnp.pad(v, widths)
    return v


def fused_linear(x, w, b, act="relu"):
    """y = act(x @ w + b) with MXU-tiled Pallas; see ref.fused_linear_ref.

    x: f32[m, k], w: f32[k, n], b: f32[n]; act in {'relu', 'none'} (static).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    xp = _pad(x, 0, BM)
    wp = _pad(w, 1, BN)
    bp = _pad(b, 0, BN)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        functools.partial(_kernel, act),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // BM, np_ // BN),
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((BN,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]
