"""Pallas kernel: fused dequantize + SGD update.

Every worker decodes the aggregated integer message and applies the step in
one pass (paper Alg. 1 lines 12-13):

    x <- x - eta * ( sum_i Int(alpha g_i) ) / (n * alpha)

Fusing the dequantization (divide by n*alpha) with the parameter update
halves HBM traffic vs materializing g_tilde: one read of x, one read of s,
one write of x'. Same 1-D VMEM tiling as int_round.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .int_round import BLOCK, _pad_to_block


def _kernel(n, x_ref, s_ref, alpha_ref, lr_ref, o_ref):
    inv = 1.0 / (n * alpha_ref[0])
    o_ref[...] = x_ref[...] - lr_ref[0] * (s_ref[...] * inv)


def dequant_update(x, s, alpha, lr, n):
    """Fused x - lr * s/(n*alpha); see ref.dequant_update_ref.

    x: f32[d] params, s: f32[d] aggregated ints, alpha: f32[1], lr: f32[1],
    n: static python int (worker count).
    """
    xp, d = _pad_to_block(x)
    sp, _ = _pad_to_block(s)
    grid = xp.shape[0] // BLOCK
    out = pl.pallas_call(
        functools.partial(_kernel, n),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(xp, sp, alpha, lr)
    return out[:d]
