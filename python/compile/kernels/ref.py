"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a matching function here with identical
signature and semantics; pytest (python/tests/) asserts allclose between the
two, and the rust integration tests cross-check the compiled artifacts
against the same math re-implemented in rust (rust/src/compress/intsgd.rs).

The rounding semantics follow the paper exactly:

  Int(t) = floor(t) + Bernoulli(t - floor(t))        (randomized, §2)
         = floor(t + u),  u ~ U[0, 1)                (equivalent form)

  deterministic variant = round-half-to-even (the paper's torch.round).

Values are clipped to [-clip, clip] *after* scaling so that the aggregated
sum of n workers fits the wire integer type (int8/int32 in the paper §5.1).
"""

from __future__ import annotations

import jax.numpy as jnp


def int_round_stochastic_ref(g, u, alpha, clip):
    """Stochastically round alpha*g to integers, clipped to [-clip, clip].

    Args:
      g: f32[d] gradient.
      u: f32[d] uniform-[0,1) randomness (generated outside; see DESIGN.md
         §Hardware-Adaptation — replayable, no per-thread RNG state).
      alpha: f32[1] shared scale.
      clip: f32[1] clip bound (e.g. 127/n for int8 wires).

    Returns: f32[d] holding integer values (kept f32 on the wire format
    boundary; the rust side reinterprets/casts — XLA CPU all-reduce of f32
    integers is exact below 2^24).
    """
    scaled = g * alpha[0]
    rounded = jnp.floor(scaled + u)
    return jnp.clip(rounded, -clip[0], clip[0])


def int_round_deterministic_ref(g, alpha, clip):
    """Deterministic variant: round-half-to-even of alpha*g, clipped."""
    scaled = g * alpha[0]
    rounded = jnp.round(scaled)  # jnp.round == round-half-to-even == torch.round
    return jnp.clip(rounded, -clip[0], clip[0])


def dequant_update_ref(x, s, alpha, lr, n):
    """Fused model update: x <- x - lr * (s / (n * alpha)).

    Args:
      x: f32[d] current parameters (flattened).
      s: f32[d] aggregated integer message sum_i Int(alpha * g_i).
      alpha: f32[1] shared scale used at compression time.
      lr: f32[1] step size eta_k.
      n: python int, number of workers (static).
    """
    return x - lr[0] * (s / (n * alpha[0]))


def fused_linear_ref(x, w, b, act):
    """y = act(x @ w + b); act in {'relu', 'none'} (static)."""
    y = x @ w + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return y
