"""Pallas kernel: the IntSGD compression hot-spot.

scale -> (+uniform) -> floor/round -> clip, elementwise over the flattened
gradient. This is the operator every worker applies every round (paper
Alg. 1 line 8), so it is the L1 hot-spot of the stack.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the flattened gradient is
tiled into BLOCK-sized VMEM-resident chunks via a 1-D grid; each grid step
streams one chunk through the VPU (the op is elementwise, so the roofline is
HBM bandwidth, not MXU). BLOCK = 8 * 128 * 8 keeps the three live operands
(g, u, out) well under 2 MiB of VMEM while amortizing grid overhead.

`alpha` (the shared scale) and `clip` (the per-worker clip bound
(2^{b-1}-1)/n that makes the *aggregate* fit the wire integer type, paper
§5.1) are runtime scalars, so one artifact serves every worker count and
bit width.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowering produces plain HLO with identical
numerics (validated against ref.py by pytest and against the rust mirror by
cargo test).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes x 8 — aligned to the VPU tile, 32 KiB per f32
# operand per grid step.
BLOCK = 8 * 128 * 8


def _stoch_kernel(g_ref, u_ref, alpha_ref, clip_ref, o_ref):
    scaled = g_ref[...] * alpha_ref[0]
    c = clip_ref[0]
    o_ref[...] = jnp.clip(jnp.floor(scaled + u_ref[...]), -c, c)


def _determ_kernel(g_ref, alpha_ref, clip_ref, o_ref):
    scaled = g_ref[...] * alpha_ref[0]
    c = clip_ref[0]
    o_ref[...] = jnp.clip(jnp.round(scaled), -c, c)


def _pad_to_block(v):
    d = v.shape[0]
    pad = (-d) % BLOCK
    if pad:
        v = jnp.pad(v, (0, pad))
    return v, d


_scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
_block_spec = pl.BlockSpec((BLOCK,), lambda i: (i,))


def int_round_stochastic(g, u, alpha, clip):
    """Pallas stochastic integer rounding; see ref.int_round_stochastic_ref.

    g: f32[d], u: f32[d] uniform-[0,1), alpha: f32[1], clip: f32[1].
    Returns f32[d] of integer values in [-clip, clip].
    """
    gp, d = _pad_to_block(g)
    up, _ = _pad_to_block(u)
    grid = gp.shape[0] // BLOCK
    out = pl.pallas_call(
        _stoch_kernel,
        out_shape=jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        grid=(grid,),
        in_specs=[_block_spec, _block_spec, _scalar_spec, _scalar_spec],
        out_specs=_block_spec,
        interpret=True,
    )(gp, up, alpha, clip)
    return out[:d]


def int_round_deterministic(g, alpha, clip):
    """Pallas deterministic integer rounding; see ref.int_round_deterministic_ref."""
    gp, d = _pad_to_block(g)
    grid = gp.shape[0] // BLOCK
    out = pl.pallas_call(
        _determ_kernel,
        out_shape=jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        grid=(grid,),
        in_specs=[_block_spec, _scalar_spec, _scalar_spec],
        out_specs=_block_spec,
        interpret=True,
    )(gp, alpha, clip)
    return out[:d]
