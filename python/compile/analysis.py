"""L1/L2 performance analysis: static inspection of the lowered artifacts.

interpret=True Pallas gives CPU-numpy wallclock, which is NOT a TPU proxy
(DESIGN.md §Hardware-Adaptation), so kernel performance is assessed
structurally:

- **VMEM footprint** per grid step from the BlockSpec tiling (operands +
  outputs resident per step) — must stay under the ~16 MiB/core budget
  with double-buffering headroom.
- **Roofline classification** from the HLO: elementwise kernels are
  HBM-bandwidth-bound (report bytes moved / FLOP), matmul kernels are
  MXU-bound (report FLOPs and utilization at the tile shape).
- **HLO op census** per artifact: fusion count, dot/while/custom-call
  presence (a Mosaic custom-call would mean a non-portable lowering).

Run: `python -m compile.analysis` (after `make artifacts`), or via pytest
(python/tests/test_analysis.py) which asserts the budgets.
"""

from __future__ import annotations

import json
import os
import re
import sys
from collections import Counter

from .kernels.int_round import BLOCK
from .kernels.fused_linear import BM, BN

F32 = 4  # bytes


def vmem_budget_report():
    """Static VMEM accounting per Pallas kernel (bytes per grid step)."""
    reports = {}
    # int_round_stochastic: g, u tiles in; out tile; two scalars
    reports["int_round_stochastic"] = {
        "block": BLOCK,
        "vmem_bytes": 3 * BLOCK * F32 + 2 * F32,
        "operands": ["g[BLOCK]", "u[BLOCK]", "alpha[1]", "clip[1]", "out[BLOCK]"],
        "bound": "HBM bandwidth (elementwise)",
        "bytes_per_elem": 3 * F32,  # read g, read u, write out
        "flops_per_elem": 3,  # mul, add, floor(+clip)
    }
    reports["int_round_deterministic"] = {
        "block": BLOCK,
        "vmem_bytes": 2 * BLOCK * F32 + 2 * F32,
        "operands": ["g[BLOCK]", "alpha[1]", "clip[1]", "out[BLOCK]"],
        "bound": "HBM bandwidth (elementwise)",
        "bytes_per_elem": 2 * F32,
        "flops_per_elem": 3,
    }
    reports["dequant_update"] = {
        "block": BLOCK,
        "vmem_bytes": 3 * BLOCK * F32 + 2 * F32,
        "operands": ["x[BLOCK]", "s[BLOCK]", "alpha[1]", "lr[1]", "out[BLOCK]"],
        "bound": "HBM bandwidth (elementwise)",
        "bytes_per_elem": 3 * F32,
        "flops_per_elem": 3,
    }
    # fused_linear with K resident: x(BM x K), w(K x BN), b(BN), out(BM x BN)
    for name, k in [("fused_linear_k3072", 3072), ("fused_linear_k256", 256)]:
        reports[name] = {
            "block": (BM, BN, k),
            "vmem_bytes": (BM * k + k * BN + BN + BM * BN) * F32,
            "operands": [f"x[{BM},{k}]", f"w[{k},{BN}]", f"b[{BN}]",
                         f"out[{BM},{BN}]"],
            "bound": "MXU (dot)",
            "flops_per_step": 2 * BM * BN * k,
            "mxu_tiles_per_step": (BM // 128) * (BN // 128) * max(1, k // 128),
        }
    return reports


VMEM_LIMIT = 16 * 1024 * 1024  # bytes/core, v4-class


def hlo_census(path: str) -> Counter:
    """Count HLO opcodes in an artifact (text format)."""
    ops = Counter()
    with open(path) as f:
        for line in f:
            line = line.strip()
            # instruction lines look like: `%name = type op(args), ...`
            m = re.match(r"(ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},/ ]+?\s+([a-z][\w\-]*)\(", line)
            if m:
                ops[m.group(2)] += 1
    return ops


def analyze(artifact_dir: str):
    manifest = json.load(open(os.path.join(artifact_dir, "manifest.json")))
    print("== L1 kernel VMEM/roofline budgets ==")
    for name, rep in vmem_budget_report().items():
        frac = rep["vmem_bytes"] / VMEM_LIMIT
        print(f"  {name}: {rep['vmem_bytes']/1024:.0f} KiB/step "
              f"({frac*100:.1f}% of VMEM), bound: {rep['bound']}")
    print("\n== L2 artifact HLO census ==")
    rows = []
    for name, entry in sorted(manifest["artifacts"].items()):
        path = os.path.join(artifact_dir, entry["file"])
        ops = hlo_census(path)
        total = sum(ops.values())
        dots = ops.get("dot", 0)
        fusions = ops.get("fusion", 0)
        custom = ops.get("custom-call", 0)
        whiles = ops.get("while", 0)
        rows.append((name, total, dots, fusions, whiles, custom))
        print(f"  {name}: {total} ops, dot={dots}, fusion={fusions}, "
              f"while={whiles}, custom-call={custom}")
    bad = [r for r in rows if r[5] > 0]
    if bad:
        print("\nWARNING: custom-calls present (non-portable lowering):",
              [r[0] for r in bad])
    return rows


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts")
    analyze(d)
