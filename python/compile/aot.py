"""AOT export: lower every L2 graph to HLO text + write a manifest.

Run as `python -m compile.aot --out-dir ../artifacts` (via `make
artifacts`). Python never runs again after this; the rust binary consumes
artifacts/manifest.json + artifacts/*.hlo.txt through the PJRT C API.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def dtype_name(dt):
    return {jnp.dtype("float32"): "f32", jnp.dtype("int32"): "i32"}[jnp.dtype(dt)]


# Logistic-regression dataset geometry (paper Table 4), n=12 workers,
# minibatch = 5% of the local shard (Appendix C.5). real-sim is served by
# the rust-native sparse path only (a dense [m, d] operand would be ~0.5 GB);
# the dense PJRT artifacts exist as numeric cross-checks for the others.
LOGREG_DATASETS = {
    # name: (N, d, lambda2)
    "a5a": (6414, 123, 5e-4),
    "mushrooms": (8124, 112, 6e-4),
    "w8a": (49749, 300, 1e-4),
}
LOGREG_WORKERS = 12


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"format": 1, "artifacts": {}}

    def export(self, name, fn, in_specs, meta=None, outputs=None):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": dtype_name(s.dtype)}
                for s in in_specs
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        if outputs is not None:
            entry["outputs"] = outputs
        if meta:
            entry.update(meta)
        self.manifest["artifacts"][name] = entry
        print(f"  {name}: {len(text)} chars, {len(in_specs)} inputs", flush=True)

    def export_model(self, name, params_spec, train_fn, eval_fn, data_specs,
                     eval_data_specs, extra_meta=None):
        """Export train/eval steps + matching quantize/dequant artifacts."""
        p_specs = [spec(shape) for (_, shape, _) in params_spec]
        grad_dim = sum(int(jnp.prod(jnp.array(s))) for (_, s, _) in params_spec)
        params_meta = [
            {"name": n, "shape": list(s), "init": init}
            for (n, s, init) in params_spec
        ]
        meta = {
            "kind": "train_step",
            "model": name,
            "param_count": len(p_specs),
            "params": params_meta,
            "grad_dim": grad_dim,
        }
        if extra_meta:
            meta.update(extra_meta)
        self.export(
            f"{name}_train_step", train_fn, p_specs + data_specs,
            meta=meta, outputs=1 + len(p_specs),
        )
        self.export(
            f"{name}_eval_step", eval_fn, p_specs + eval_data_specs,
            meta={"kind": "eval_step", "model": name, "param_count": len(p_specs)},
        )
        d = grad_dim
        self.export(
            f"quantize_stoch_{name}",
            lambda g, u, a, c: M.quantize_stochastic(g, u, a, c),
            [spec([d]), spec([d]), spec([1]), spec([1])],
            meta={"kind": "quantize", "model": name, "stochastic": True,
                  "grad_dim": d},
            outputs=1,
        )
        self.export(
            f"quantize_determ_{name}",
            lambda g, a, c: M.quantize_deterministic(g, a, c),
            [spec([d]), spec([1]), spec([1])],
            meta={"kind": "quantize", "model": name, "stochastic": False,
                  "grad_dim": d},
            outputs=1,
        )

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    ex = Exporter(args.out_dir)

    # -- classifier (stands in for ResNet18/CIFAR-10) ------------------------
    b, d_in, ncls = M.CLS_BATCH, M.CLS_IN, M.CLS_CLASSES
    ex.export_model(
        "classifier", M.classifier_params_spec(),
        M.classifier_train_step, M.classifier_eval_step,
        [spec([b, d_in]), spec([b, ncls])],
        [spec([256, d_in]), spec([256, ncls])],
        extra_meta={"task": "classification", "batch": b, "eval_batch": 256},
    )

    # -- LSTM char LM (stands in for 3-layer LSTM / Wikitext-2) --------------
    ex.export_model(
        "lm", M.lm_params_spec(), M.lm_train_step, M.lm_eval_step,
        [spec([M.LM_BATCH, M.LM_SEQ + 1], I32)],
        [spec([M.LM_BATCH, M.LM_SEQ + 1], I32)],
        extra_meta={"task": "language_modeling", "batch": M.LM_BATCH,
                    "seq": M.LM_SEQ, "vocab": M.LM_VOCAB},
    )

    # -- transformer LM (end-to-end example) ---------------------------------
    ex.export_model(
        "transformer", M.transformer_params_spec(),
        M.transformer_train_step, M.transformer_eval_step,
        [spec([M.TF_BATCH, M.TF_SEQ + 1], I32)],
        [spec([M.TF_BATCH, M.TF_SEQ + 1], I32)],
        extra_meta={"task": "language_modeling", "batch": M.TF_BATCH,
                    "seq": M.TF_SEQ, "vocab": M.TF_VOCAB},
    )

    # -- logistic regression gradients (Fig. 6 cross-checks) -----------------
    for name, (N, d, lam) in LOGREG_DATASETS.items():
        m = N // LOGREG_WORKERS
        tau = max(1, m // 20)
        ex.export(
            f"logreg_grad_{name}",
            lambda x, a, bb, l: (M.logreg_grad(x, a, bb, l),),
            [spec([d]), spec([tau, d]), spec([tau]), spec([1])],
            meta={"kind": "logreg_grad", "dataset": name, "n_total": N,
                  "dim": d, "lambda2": lam, "minibatch": tau,
                  "workers": LOGREG_WORKERS},
            outputs=1,
        )
        ex.export(
            f"logreg_loss_{name}",
            lambda x, a, bb, l: (M.logreg_loss(x, a, bb, l),),
            [spec([d]), spec([tau, d]), spec([tau]), spec([1])],
            meta={"kind": "logreg_loss", "dataset": name},
            outputs=1,
        )

    # -- standalone dequant+update (one per model grad dim) ------------------
    for name, gd in [
        ("classifier", ex.manifest["artifacts"]["classifier_train_step"]["grad_dim"]),
        ("lm", ex.manifest["artifacts"]["lm_train_step"]["grad_dim"]),
        ("transformer", ex.manifest["artifacts"]["transformer_train_step"]["grad_dim"]),
    ]:
        # n (worker count) is static in the kernel signature; bake the
        # default fleet sizes used by the experiments.
        for n in (12, 16):
            ex.export(
                f"dequant_{name}_n{n}",
                lambda x, s, a, lr, n=n: M.dequant_update_step(x, s, a, lr, n),
                [spec([gd]), spec([gd]), spec([1]), spec([1])],
                meta={"kind": "dequant", "model": name, "workers": n,
                      "grad_dim": gd},
                outputs=1,
            )

    ex.finish()


if __name__ == "__main__":
    main()
