"""Layer-2: JAX model definitions (build-time only).

Each model exposes a `*_train_step(*params, x, y) -> (loss, *grads)` pure
function that aot.py lowers to one self-contained HLO module. The rust
coordinator then drives training entirely through PJRT: it owns the
parameter literals, feeds minibatches, receives per-worker gradients, and
runs the compression/collective path on them.

Models
------
- classifier: 3-layer MLP on 32x32x3 inputs (stands in for ResNet18/CIFAR;
  see DESIGN.md substitution table). Dense layers run on the Pallas
  fused_linear kernel via a custom_vjp so the backward pass stays in XLA.
- lm: 2-layer LSTM character language model with tied embeddings (stands in
  for the paper's 3-layer LSTM / Wikitext-2).
- transformer: small pre-LN transformer LM for the end-to-end example.
- logreg_grad: closed-form minibatch gradient of l2-regularized logistic
  regression (paper Appendix C.5 / Fig. 6).
- quantize / dequant wrappers over the L1 kernels, exported per gradient
  dimension so the rust hot path can run compression on-device.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import (
    dequant_update,
    fused_linear,
    int_round_deterministic,
    int_round_stochastic,
)

# ---------------------------------------------------------------------------
# Dense layer: Pallas forward, hand-written VJP (pallas_call has no autodiff
# rule; the backward matmuls lower to plain XLA dots).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _dense2d(x, w, b, act):
    return fused_linear(x, w, b, act)


def _dense2d_fwd(x, w, b, act):
    y = fused_linear(x, w, b, act)
    return y, (x, w, y)


def _dense2d_bwd(act, res, dy):
    x, w, y = res
    if act == "relu":
        dy = dy * (y > 0.0)
    dx = dy @ w.T
    dw = x.T @ dy
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


_dense2d.defvjp(_dense2d_fwd, _dense2d_bwd)


def dense(x, w, b, act="relu"):
    """act(x @ w + b) on the Pallas fused_linear kernel; x may be >2-D."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _dense2d(x2, w, b, act)
    return y.reshape(*lead, w.shape[1])


def softmax_xent(logits, targets_onehot):
    """Mean cross-entropy; numerically stable log-softmax."""
    logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    logp = logits - logz
    return -jnp.mean(jnp.sum(targets_onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# Classifier: MLP on flattened 32x32x3 images (CIFAR-like synthetic data).
# ---------------------------------------------------------------------------

CLS_IN = 3 * 32 * 32
CLS_HIDDEN = (256, 128)
CLS_CLASSES = 10
CLS_BATCH = 32


def classifier_params_spec():
    """[(name, shape, init)] in artifact order."""
    dims = [CLS_IN, *CLS_HIDDEN, CLS_CLASSES]
    spec = []
    for li, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        spec.append((f"w{li}", (din, dout), "glorot"))
        spec.append((f"b{li}", (dout,), "zeros"))
    return spec


def classifier_loss(params, x, y_onehot):
    w0, b0, w1, b1, w2, b2 = params
    h = dense(x, w0, b0, "relu")
    h = dense(h, w1, b1, "relu")
    logits = dense(h, w2, b2, "none")
    return softmax_xent(logits, y_onehot)


def classifier_train_step(*args):
    """(w0,b0,w1,b1,w2,b2, x[B,3072], y[B,10]) -> (loss, 6 grads)."""
    params, (x, y) = args[:-2], args[-2:]
    loss, grads = jax.value_and_grad(classifier_loss)(params, x, y)
    return (loss, *grads)


def classifier_eval_step(*args):
    """(params..., x, y_onehot) -> (loss, accuracy)."""
    params, (x, y) = args[:-2], args[-2:]
    w0, b0, w1, b1, w2, b2 = params
    h = dense(x, w0, b0, "relu")
    h = dense(h, w1, b1, "relu")
    logits = dense(h, w2, b2, "none")
    loss = softmax_xent(logits, y)
    acc = jnp.mean(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y, axis=-1)).astype(jnp.float32)
    )
    return loss, acc


# ---------------------------------------------------------------------------
# LSTM character LM (2 layers, tied embedding / softmax weights).
# ---------------------------------------------------------------------------

LM_VOCAB = 64
LM_EMBED = 96  # == hidden so the softmax can be tied to the embedding
LM_HIDDEN = 96
LM_BATCH = 16
LM_SEQ = 30


def lm_params_spec():
    v, e, h = LM_VOCAB, LM_EMBED, LM_HIDDEN
    spec = [("emb", (v, e), "normal0.1")]
    for li, din in enumerate([e, h]):
        spec.append((f"l{li}_wih", (din, 4 * h), "glorot"))
        spec.append((f"l{li}_whh", (h, 4 * h), "glorot"))
        spec.append((f"l{li}_b", (4 * h,), "zeros"))
    spec.append(("out_b", (v,), "zeros"))
    return spec


def _lstm_cell(x, h, c, wih, whh, b):
    gates = x @ wih + h @ whh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, c


def lm_loss(params, tokens):
    """tokens: i32[B, T+1]; next-char cross-entropy averaged over B*T."""
    emb, w0i, w0h, b0, w1i, w1h, b1, ob = params
    bsz = tokens.shape[0]
    xs = emb[tokens[:, :-1]]  # [B, T, E]
    tgt = tokens[:, 1:]  # [B, T]

    h0 = jnp.zeros((bsz, LM_HIDDEN))
    state0 = (h0, h0, h0, h0)

    def step(state, x_t):
        h1, c1, h2, c2 = state
        h1, c1 = _lstm_cell(x_t, h1, c1, w0i, w0h, b0)
        h2, c2 = _lstm_cell(h1, h2, c2, w1i, w1h, b1)
        return (h1, c1, h2, c2), h2

    _, hs = jax.lax.scan(step, state0, jnp.swapaxes(xs, 0, 1))  # [T, B, H]
    logits = hs @ emb.T + ob  # tied softmax, [T, B, V]
    tgt_t = jnp.swapaxes(tgt, 0, 1)  # [T, B]
    onehot = jax.nn.one_hot(tgt_t, LM_VOCAB)
    return softmax_xent(logits, onehot)


def lm_train_step(*args):
    """(params... x8, tokens i32[B,T+1]) -> (loss, 8 grads)."""
    params, tokens = args[:-1], args[-1]
    loss, grads = jax.value_and_grad(lm_loss)(params, tokens)
    return (loss, *grads)


def lm_eval_step(*args):
    params, tokens = args[:-1], args[-1]
    return (lm_loss(params, tokens),)


# ---------------------------------------------------------------------------
# Transformer LM (end-to-end example model).
# ---------------------------------------------------------------------------

TF_VOCAB = 256
TF_DMODEL = 128
TF_HEADS = 4
TF_LAYERS = 2
TF_BATCH = 8
TF_SEQ = 64


def transformer_params_spec():
    v, d, t = TF_VOCAB, TF_DMODEL, TF_SEQ
    spec = [("emb", (v, d), "normal0.02"), ("pos", (t, d), "normal0.02")]
    for li in range(TF_LAYERS):
        p = f"blk{li}_"
        spec += [
            (p + "ln1_s", (d,), "ones"),
            (p + "ln1_b", (d,), "zeros"),
            (p + "wq", (d, d), "glorot"),
            (p + "wk", (d, d), "glorot"),
            (p + "wv", (d, d), "glorot"),
            (p + "wo", (d, d), "glorot"),
            (p + "ln2_s", (d,), "ones"),
            (p + "ln2_b", (d,), "zeros"),
            (p + "w1", (d, 4 * d), "glorot"),
            (p + "b1", (4 * d,), "zeros"),
            (p + "w2", (4 * d, d), "glorot"),
            (p + "b2", (d,), "zeros"),
        ]
    spec += [("lnf_s", (d,), "ones"), ("lnf_b", (d,), "zeros")]
    return spec


def _layernorm(x, s, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * s + b


def _attention(x, wq, wk, wv, wo):
    bsz, t, d = x.shape
    hd = d // TF_HEADS

    def split(z):
        return jnp.swapaxes(z.reshape(bsz, t, TF_HEADS, hd), 1, 2)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = jnp.swapaxes(out, 1, 2).reshape(bsz, t, d)
    return out @ wo


def transformer_loss(params, tokens):
    """tokens: i32[B, T+1]."""
    it = iter(params)
    emb, pos = next(it), next(it)
    blocks = [[next(it) for _ in range(12)] for _ in range(TF_LAYERS)]
    lnf_s, lnf_b = next(it), next(it)

    x = emb[tokens[:, :-1]] + pos[None, :, :]
    for ln1s, ln1b, wq, wk, wv, wo, ln2s, ln2b, w1, b1, w2, b2 in blocks:
        x = x + _attention(_layernorm(x, ln1s, ln1b), wq, wk, wv, wo)
        h = dense(_layernorm(x, ln2s, ln2b), w1, b1, "relu")
        x = x + dense(h, w2, b2, "none")
    x = _layernorm(x, lnf_s, lnf_b)
    logits = x @ emb.T  # tied head
    onehot = jax.nn.one_hot(tokens[:, 1:], TF_VOCAB)
    return softmax_xent(logits, onehot)


def transformer_train_step(*args):
    params, tokens = args[:-1], args[-1]
    loss, grads = jax.value_and_grad(transformer_loss)(params, tokens)
    return (loss, *grads)


def transformer_eval_step(*args):
    params, tokens = args[:-1], args[-1]
    return (transformer_loss(params, tokens),)


# ---------------------------------------------------------------------------
# Logistic regression (paper Appendix C.5): closed-form minibatch gradient.
# ---------------------------------------------------------------------------


def logreg_grad(x, a, b, lam):
    """grad of (1/m) sum log(1+exp(-b_i a_i^T x)) + lam/2 ||x||^2.

    x: f32[d]; a: f32[m, d]; b: f32[m] in {-1, +1}; lam: f32[1].
    """
    margins = -b * (a @ x)
    # sigma(-z) = 1/(1+exp(z)) evaluated stably
    coeff = -b * jax.nn.sigmoid(margins)
    return (a.T @ coeff) / a.shape[0] + lam[0] * x


def logreg_loss(x, a, b, lam):
    margins = -b * (a @ x)
    return jnp.mean(jnp.logaddexp(0.0, margins)) + 0.5 * lam[0] * jnp.sum(x * x)


# ---------------------------------------------------------------------------
# Compression wrappers (exported per flattened gradient dimension).
# ---------------------------------------------------------------------------


def quantize_stochastic(g, u, alpha, clip):
    return (int_round_stochastic(g, u, alpha, clip),)


def quantize_deterministic(g, alpha, clip):
    return (int_round_deterministic(g, alpha, clip),)


def dequant_update_step(x, s, alpha, lr, n):
    return (dequant_update(x, s, alpha, lr, n),)
