"""Perf-structure tests: VMEM budgets and HLO portability of artifacts."""

import os

import pytest

from compile import analysis

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_all_kernels_fit_vmem_with_double_buffering():
    for name, rep in analysis.vmem_budget_report().items():
        # double buffering needs 2x the working set resident
        assert 2 * rep["vmem_bytes"] <= analysis.VMEM_LIMIT, (
            name,
            rep["vmem_bytes"],
        )


def test_elementwise_kernels_are_bandwidth_bound():
    reps = analysis.vmem_budget_report()
    for name in ("int_round_stochastic", "int_round_deterministic", "dequant_update"):
        r = reps[name]
        # arithmetic intensity well below 1 FLOP/byte => bandwidth bound
        assert r["flops_per_elem"] / r["bytes_per_elem"] < 1.0


def test_fused_linear_mxu_aligned():
    reps = analysis.vmem_budget_report()
    for name, r in reps.items():
        if name.startswith("fused_linear"):
            bm, bn, _ = r["block"]
            assert bm % 128 == 0 and bn % 128 == 0  # MXU tile alignment
            assert r["mxu_tiles_per_step"] >= 1


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts`",
)
def test_artifacts_portable_no_custom_calls():
    rows = analysis.analyze(ART)
    assert rows, "no artifacts found"
    for name, total, _dots, _fus, _wh, custom in rows:
        assert custom == 0, f"{name} contains custom-calls (Mosaic lowering?)"
        assert total > 0, f"{name} parsed to zero ops"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts`",
)
def test_train_steps_contain_dots():
    # artifacts are *pre-optimization* HLO (fusion happens inside the PJRT
    # compile on the rust side), so we assert on the dots, not fusions
    rows = {r[0]: r for r in analysis.analyze(ART)}
    for model in ("classifier", "lm", "transformer"):
        name = f"{model}_train_step"
        _, total, dots, _fusions, _, _ = rows[name]
        assert dots >= 2, f"{name}: expected matmuls in fwd+bwd"
        assert total > 100, f"{name}: suspiciously small module"
