"""L2 model tests: shapes, gradient correctness, optimization sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def glorot(key, shape):
    if len(shape) == 1:
        return jnp.zeros(shape)
    lim = np.sqrt(6.0 / (shape[0] + shape[1]))
    return jax.random.uniform(key, shape, minval=-lim, maxval=lim)


def init_params(spec, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(spec))
    out = []
    for key, (_, shape, init) in zip(keys, spec):
        if init == "zeros":
            out.append(jnp.zeros(shape))
        elif init == "ones":
            out.append(jnp.ones(shape))
        elif init.startswith("normal"):
            std = float(init[len("normal"):])
            out.append(std * jax.random.normal(key, shape))
        else:
            out.append(glorot(key, shape))
    return out


class TestClassifier:
    def setup_method(self):
        self.params = init_params(M.classifier_params_spec(), 1)
        k = jax.random.PRNGKey(2)
        self.x = jax.random.normal(k, (M.CLS_BATCH, M.CLS_IN))
        y = jax.random.randint(jax.random.PRNGKey(3), (M.CLS_BATCH,), 0, 10)
        self.y = jax.nn.one_hot(y, M.CLS_CLASSES)

    def test_shapes(self):
        out = M.classifier_train_step(*self.params, self.x, self.y)
        assert len(out) == 1 + len(self.params)
        assert out[0].shape == ()
        for g, p in zip(out[1:], self.params):
            assert g.shape == p.shape

    def test_initial_loss_near_log10(self):
        loss = M.classifier_train_step(*self.params, self.x, self.y)[0]
        assert abs(float(loss) - np.log(10)) < 0.5

    def test_loss_decreases_under_sgd(self):
        params = self.params
        first = None
        for _ in range(20):
            out = M.classifier_train_step(*params, self.x, self.y)
            loss, grads = out[0], out[1:]
            if first is None:
                first = float(loss)
            params = [p - 0.1 * g for p, g in zip(params, grads)]
        assert float(loss) < first - 0.3

    def test_grads_match_autodiff_of_loss(self):
        out = M.classifier_train_step(*self.params, self.x, self.y)
        grads_direct = jax.grad(
            lambda ps: M.classifier_loss(ps, self.x, self.y)
        )(self.params)
        for a, b in zip(out[1:], grads_direct):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_eval_step(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (256, M.CLS_IN))
        y = jax.nn.one_hot(
            jax.random.randint(jax.random.PRNGKey(5), (256,), 0, 10), 10
        )
        loss, acc = M.classifier_eval_step(*self.params, x, y)
        assert 0.0 <= float(acc) <= 1.0


class TestLM:
    def setup_method(self):
        self.params = init_params(M.lm_params_spec(), 10)
        self.tokens = jax.random.randint(
            jax.random.PRNGKey(11), (M.LM_BATCH, M.LM_SEQ + 1), 0, M.LM_VOCAB
        )

    def test_shapes(self):
        out = M.lm_train_step(*self.params, self.tokens)
        assert len(out) == 1 + len(self.params)
        for g, p in zip(out[1:], self.params):
            assert g.shape == p.shape

    def test_initial_loss_near_log_vocab(self):
        loss = M.lm_train_step(*self.params, self.tokens)[0]
        assert abs(float(loss) - np.log(M.LM_VOCAB)) < 0.5

    def test_loss_decreases_under_sgd(self):
        params = self.params
        first = None
        step = jax.jit(M.lm_train_step)
        # uniform-random tokens: the only signal is memorizing the batch,
        # so the drop is small but must be strictly positive and material.
        for _ in range(60):
            out = step(*params, self.tokens)
            loss, grads = out[0], out[1:]
            if first is None:
                first = float(loss)
            params = [p - 2.0 * g for p, g in zip(params, grads)]
        assert float(loss) < first - 0.1, (first, float(loss))


class TestTransformer:
    def setup_method(self):
        self.params = init_params(M.transformer_params_spec(), 20)
        self.tokens = jax.random.randint(
            jax.random.PRNGKey(21), (M.TF_BATCH, M.TF_SEQ + 1), 0, M.TF_VOCAB
        )

    def test_shapes(self):
        out = M.transformer_train_step(*self.params, self.tokens)
        assert len(out) == 1 + len(self.params)
        for g, p in zip(out[1:], self.params):
            assert g.shape == p.shape

    def test_initial_loss_near_log_vocab(self):
        loss = M.transformer_train_step(*self.params, self.tokens)[0]
        assert abs(float(loss) - np.log(M.TF_VOCAB)) < 1.0

    def test_loss_decreases_under_sgd(self):
        params = self.params
        first = None
        for _ in range(15):
            out = M.transformer_train_step(*params, self.tokens)
            loss, grads = out[0], out[1:]
            if first is None:
                first = float(loss)
            params = [p - 0.5 * g for p, g in zip(params, grads)]
        assert float(loss) < first - 0.2


class TestLogreg:
    def setup_method(self):
        k = jax.random.PRNGKey(30)
        self.m, self.d = 40, 17
        self.a = jax.random.normal(k, (self.m, self.d))
        self.b = jnp.sign(jax.random.normal(jax.random.PRNGKey(31), (self.m,)))
        self.x = 0.1 * jax.random.normal(jax.random.PRNGKey(32), (self.d,))
        self.lam = jnp.array([1e-3])

    def test_grad_matches_autodiff(self):
        auto = jax.grad(lambda x: M.logreg_loss(x, self.a, self.b, self.lam))(self.x)
        closed = M.logreg_grad(self.x, self.a, self.b, self.lam)
        np.testing.assert_allclose(closed, auto, rtol=1e-5, atol=1e-6)

    def test_grad_matches_finite_differences(self):
        g = M.logreg_grad(self.x, self.a, self.b, self.lam)
        eps = 1e-4
        for j in range(0, self.d, 5):
            e = jnp.zeros(self.d).at[j].set(eps)
            fd = (
                M.logreg_loss(self.x + e, self.a, self.b, self.lam)
                - M.logreg_loss(self.x - e, self.a, self.b, self.lam)
            ) / (2 * eps)
            # f32 forward differences are noisy; the autodiff cross-check
            # above is the tight one.
            np.testing.assert_allclose(g[j], fd, rtol=1e-2, atol=1e-3)

    def test_gd_converges(self):
        x = self.x
        for _ in range(200):
            x = x - 0.5 * M.logreg_grad(x, self.a, self.b, self.lam)
        gnorm = float(jnp.linalg.norm(M.logreg_grad(x, self.a, self.b, self.lam)))
        assert gnorm < 1e-2
