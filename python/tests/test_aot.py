"""AOT pipeline tests: manifest consistency and HLO text sanity.

Runs against the artifacts/ directory if `make artifacts` has been run;
otherwise these tests are skipped (they re-validate outputs, not the
exporter logic, which test_kernels/test_model already cover).
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_format():
    m = load()
    assert m["format"] == 1
    assert len(m["artifacts"]) >= 20


def test_all_files_exist_and_parse_as_hlo():
    m = load()
    for name, entry in m["artifacts"].items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_train_steps_have_grad_outputs():
    m = load()
    for name, entry in m["artifacts"].items():
        if entry.get("kind") == "train_step":
            assert entry["outputs"] == 1 + entry["param_count"]
            total = sum(
                int(__import__("math").prod(p["shape"]) or 1)
                for p in entry["params"]
            )
            assert total == entry["grad_dim"], name


def test_quantize_dims_match_models():
    m = load()
    arts = m["artifacts"]
    for model in ("classifier", "lm", "transformer"):
        gd = arts[f"{model}_train_step"]["grad_dim"]
        assert arts[f"quantize_stoch_{model}"]["inputs"][0]["shape"] == [gd]
        assert arts[f"quantize_determ_{model}"]["inputs"][0]["shape"] == [gd]
        for n in (12, 16):
            assert arts[f"dequant_{model}_n{n}"]["inputs"][0]["shape"] == [gd]


def test_input_dtypes_recorded():
    m = load()
    for name, entry in m["artifacts"].items():
        for inp in entry["inputs"]:
            assert inp["dtype"] in ("f32", "i32"), name


def test_param_specs_have_known_inits():
    m = load()
    for entry in m["artifacts"].values():
        for p in entry.get("params", []):
            assert p["init"] in ("glorot", "zeros", "ones") or p["init"].startswith(
                "normal"
            )
