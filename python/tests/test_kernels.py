"""L1 kernel tests: Pallas kernels vs pure-jnp oracles (ref.py).

Includes hypothesis sweeps over shapes/scales so the BlockSpec padding path
(d not a multiple of BLOCK) and degenerate scales are exercised.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dequant_update,
    fused_linear,
    int_round_deterministic,
    int_round_stochastic,
)
from compile.kernels import ref
from compile.kernels.int_round import BLOCK


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


def uni(key, shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape)


def s1(v):
    return jnp.array([v], jnp.float32)


# ---------------------------------------------------------------------------
# int_round_stochastic
# ---------------------------------------------------------------------------


class TestIntRoundStochastic:
    def test_matches_ref(self):
        g, u = rand(0, (5000,)), uni(1, (5000,))
        a, c = s1(37.5), s1(127.0)
        np.testing.assert_array_equal(
            int_round_stochastic(g, u, a, c),
            ref.int_round_stochastic_ref(g, u, a, c),
        )

    def test_outputs_are_integers(self):
        g, u = rand(2, (777,), 10.0), uni(3, (777,))
        out = np.asarray(int_round_stochastic(g, u, s1(3.3), s1(1e9)))
        np.testing.assert_array_equal(out, np.floor(out))

    def test_clip_bound_respected(self):
        g, u = rand(4, (1024,), 100.0), uni(5, (1024,))
        out = np.asarray(int_round_stochastic(g, u, s1(50.0), s1(7.0)))
        assert out.max() <= 7.0 and out.min() >= -7.0

    def test_unbiased_over_uniform_draws(self):
        # E_u[floor(a*g + u)] == a*g  (Lemma 1, eq. 3), estimated by
        # averaging over many uniform draws for a handful of fixed values.
        g = jnp.array([0.3, -1.7, 2.5, 0.0, -0.49], jnp.float32)
        a = s1(1.0)
        draws = []
        for k in range(4000):
            u = uni(1000 + k, g.shape)
            draws.append(np.asarray(int_round_stochastic(g, u, a, s1(1e9))))
        mean = np.stack(draws).mean(axis=0)
        np.testing.assert_allclose(mean, np.asarray(g), atol=0.03)

    def test_variance_bound(self):
        # Var[Int(t)] <= 1/4 per coordinate (Lemma 1, eq. 4).
        g = rand(6, (64,))
        a = s1(5.0)
        draws = np.stack([
            np.asarray(int_round_stochastic(g, uni(2000 + k, g.shape), a, s1(1e9)))
            for k in range(2000)
        ])
        var = draws.var(axis=0) / float(a[0]) ** 2 * float(a[0]) ** 2  # int-domain var
        assert (var <= 0.25 + 0.02).all()

    def test_exact_integers_pass_through(self):
        g = jnp.arange(-5, 6).astype(jnp.float32)
        u = uni(7, g.shape)
        out = int_round_stochastic(g, u, s1(1.0), s1(100.0))
        np.testing.assert_array_equal(out, g)

    @settings(max_examples=20, deadline=None)
    @given(
        d=st.integers(1, 3 * BLOCK + 17),
        seed=st.integers(0, 2**16),
        alpha=st.floats(1e-3, 1e3),
    )
    def test_hypothesis_shapes_and_scales(self, d, seed, alpha):
        g, u = rand(seed, (d,), 2.0), uni(seed + 1, (d,))
        a, c = s1(alpha), s1(127.0)
        np.testing.assert_array_equal(
            int_round_stochastic(g, u, a, c),
            ref.int_round_stochastic_ref(g, u, a, c),
        )


# ---------------------------------------------------------------------------
# int_round_deterministic
# ---------------------------------------------------------------------------


class TestIntRoundDeterministic:
    def test_matches_ref(self):
        g = rand(8, (4097,), 5.0)
        a, c = s1(12.25), s1(127.0)
        np.testing.assert_array_equal(
            int_round_deterministic(g, a, c),
            ref.int_round_deterministic_ref(g, a, c),
        )

    def test_half_to_even(self):
        # torch.round / jnp.round semantics: .5 rounds to the even integer.
        g = jnp.array([0.5, 1.5, 2.5, -0.5, -1.5], jnp.float32)
        out = int_round_deterministic(g, s1(1.0), s1(100.0))
        np.testing.assert_array_equal(out, [0.0, 2.0, 2.0, -0.0, -2.0])

    @settings(max_examples=15, deadline=None)
    @given(d=st.integers(1, 2 * BLOCK + 5), seed=st.integers(0, 2**16))
    def test_hypothesis_shapes(self, d, seed):
        g = rand(seed, (d,), 3.0)
        a, c = s1(7.7), s1(31.0)
        np.testing.assert_array_equal(
            int_round_deterministic(g, a, c),
            ref.int_round_deterministic_ref(g, a, c),
        )


# ---------------------------------------------------------------------------
# dequant_update
# ---------------------------------------------------------------------------


class TestDequantUpdate:
    def test_matches_ref(self):
        x, s = rand(9, (9999,)), jnp.round(rand(10, (9999,), 20.0))
        a, lr = s1(3.0), s1(0.05)
        np.testing.assert_allclose(
            dequant_update(x, s, a, lr, 16),
            ref.dequant_update_ref(x, s, a, lr, 16),
            rtol=1e-5, atol=1e-6,
        )

    def test_zero_message_is_noop(self):
        x = rand(11, (500,))
        out = dequant_update(x, jnp.zeros(500), s1(2.0), s1(0.1), 8)
        np.testing.assert_array_equal(out, x)

    def test_recovers_average_gradient(self):
        # With alpha -> huge, quantization is exact and the update equals
        # plain distributed SGD: x - lr * mean_i(g_i).
        n, d = 4, 300
        gs = [rand(20 + i, (d,)) for i in range(n)]
        a = s1(1e6)
        msgs = [
            int_round_deterministic(g, a, s1(1e30)) for g in gs
        ]
        ssum = sum(msgs)
        x = rand(30, (d,))
        out = dequant_update(x, ssum, a, s1(0.1), n)
        expect = x - 0.1 * sum(gs) / n
        np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(d=st.integers(1, BLOCK + 333), n=st.integers(1, 64),
           seed=st.integers(0, 2**16))
    def test_hypothesis(self, d, n, seed):
        x, s = rand(seed, (d,)), jnp.round(rand(seed + 1, (d,), 50.0))
        a, lr = s1(2.5), s1(0.01)
        np.testing.assert_allclose(
            dequant_update(x, s, a, lr, n),
            ref.dequant_update_ref(x, s, a, lr, n),
            rtol=1e-5, atol=1e-6,
        )


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


class TestFusedLinear:
    @pytest.mark.parametrize("act", ["relu", "none"])
    def test_matches_ref(self, act):
        x, w, b = rand(40, (57, 90)), rand(41, (90, 33)), rand(42, (33,))
        np.testing.assert_allclose(
            fused_linear(x, w, b, act),
            ref.fused_linear_ref(x, w, b, act),
            rtol=1e-5, atol=1e-5,
        )

    def test_exact_tile_sizes(self):
        x, w, b = rand(43, (128, 64)), rand(44, (64, 256)), rand(45, (256,))
        np.testing.assert_allclose(
            fused_linear(x, w, b, "relu"),
            ref.fused_linear_ref(x, w, b, "relu"),
            rtol=1e-5, atol=1e-5,
        )

    def test_relu_nonnegative(self):
        x, w, b = rand(46, (17, 19)), rand(47, (19, 23)), rand(48, (23,))
        assert (np.asarray(fused_linear(x, w, b, "relu")) >= 0).all()

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 200), k=st.integers(1, 150), n=st.integers(1, 200),
           seed=st.integers(0, 2**16))
    def test_hypothesis_shapes(self, m, k, n, seed):
        x, w, b = rand(seed, (m, k)), rand(seed + 1, (k, n)), rand(seed + 2, (n,))
        np.testing.assert_allclose(
            fused_linear(x, w, b, "none"),
            ref.fused_linear_ref(x, w, b, "none"),
            rtol=1e-4, atol=1e-4,
        )
