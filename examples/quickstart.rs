//! Quickstart: distributed IntSGD over the full three-layer stack, in the
//! form it should take — one typed `Session` per algorithm.
//!
//! Trains the MLP classifier on synthetic CIFAR-like data with 4 simulated
//! workers, comparing full-precision SGD against IntSGD with the int8
//! wire. Gradients are computed by the AOT-compiled JAX/Pallas train step
//! through PJRT; compression, aggregation and optimization run in rust.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Ends with a traced run: the same Session, with the telemetry knobs
//! on — phase spans land in a Chrome trace and the metrics are one
//! `curl` away (DESIGN.md §11).

use intsgd::api::{Backend, CompressorSpec, ModelSpec, Pipeline, Session, StagedAlgo};
use intsgd::config::Config;
use intsgd::coordinator::net_driver::quad_factories;
use intsgd::experiments::common::{setup, task_session, Task};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::new();
    cfg.set_kv("workers=4")?;
    if let Ok(dir) = std::env::var("INTSGD_ARTIFACTS") {
        cfg.set_kv(&format!("artifacts={dir}"))?;
    }
    let s = setup(&cfg, 40, 0.1);

    for algo in ["sgd_ar", "intsgd_random8"] {
        let spec = CompressorSpec::parse(algo)?;
        let mut session = task_session(Task::Classifier, &spec, &s, 0.9, 1e-8, 0, &cfg)?;
        session.run(s.rounds)?;

        println!("\n=== {algo} ({}) ===", spec.paper_name());
        println!("round  train_loss  wire_bytes/worker  comm_model_ms");
        for r in session.records().iter().step_by(8) {
            println!(
                "{:>5}  {:>10.4}  {:>17}  {:>13.4}",
                r.round, r.train_loss, r.wire_bytes_per_worker, r.comm_seconds * 1e3
            );
        }
        let last = session.finish().records.last().unwrap().clone();
        println!(
            "final: loss {:.4}, per-round comm {:.4} ms (modeled, 100 Gb/s cluster)",
            last.train_loss,
            last.comm_seconds * 1e3
        );
    }
    println!("\nIntSGD ships 4x fewer bytes with the same convergence — the paper's headline.");

    // --- a traced run: same front door, telemetry on --------------------
    // trace_path() journals every phase span (encode/reduce/drain/decode,
    // per block) and writes chrome://tracing JSON at finish();
    // metrics_listen() serves Prometheus text for the session's lifetime.
    let (n, d) = (4, 1 << 14);
    let mut traced = Session::builder()
        .world(n)
        .model(ModelSpec::blocks(vec![d / 2, d / 2]))
        .sources(quad_factories(n, d, 42, 0.01))
        .compressor(CompressorSpec::parse("intsgd_random8")?)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .pipeline(Pipeline::Streamed)
        .lr(0.2)
        .trace_path("quickstart_trace.json")
        .metrics_listen("127.0.0.1:0")
        .build()?;
    let addr = traced.metrics_addr().expect("endpoint bound");
    traced.run(16)?;
    traced.finish();
    println!(
        "\ntraced 16 streamed rounds -> quickstart_trace.json \
         (open in chrome://tracing; metrics served on http://{addr}/metrics \
         while the session lived)"
    );
    Ok(())
}
