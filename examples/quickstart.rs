//! Quickstart: distributed IntSGD over the full three-layer stack.
//!
//! Trains the MLP classifier on synthetic CIFAR-like data with 4 simulated
//! workers, comparing full-precision SGD against IntSGD with the int8
//! wire. Gradients are computed by the AOT-compiled JAX/Pallas train step
//! through PJRT; compression, aggregation and optimization run in rust.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::IdentitySgd;
use intsgd::coordinator::{
    BatchSpec, Coordinator, GradientSource, LrSchedule, PjrtWorker, TrainConfig,
    WorkerPool,
};
use intsgd::data::{shard_iid, CifarLike};
use intsgd::netsim::Network;
use intsgd::runtime::{init_params, Runtime};
use intsgd::scaling::MovingAverageRule;

fn main() -> Result<()> {
    let n = 4; // simulated workers
    let rounds = 40;
    let artifact_dir =
        std::env::var("INTSGD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // inspect the manifest for the classifier model
    let rt = Runtime::open(&artifact_dir)?;
    let meta = rt.meta("classifier_train_step").expect("run `make artifacts`").clone();
    println!(
        "model: classifier ({} params over {} arrays)",
        meta.grad_dim,
        meta.params.len()
    );

    // shared synthetic dataset, one iid shard per worker
    let data = Arc::new(CifarLike::generate(2048, 512, 1.2, 0));
    let batch = meta.extra_usize("batch").unwrap_or(32);

    for algo in ["sgd_fp32", "intsgd_random_int8"] {
        // spawn the worker pool: each thread owns its own PJRT client
        let shards = shard_iid(data.train_count(), n, 1);
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> =
            shards
                .into_iter()
                .enumerate()
                .map(|(i, indices)| {
                    let data = Arc::clone(&data);
                    let dir = artifact_dir.clone();
                    let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                        Box::new(move || {
                            Box::new(
                                PjrtWorker::new(
                                    &dir,
                                    "classifier",
                                    BatchSpec::Classifier { data, indices, batch },
                                    100 + i as u64,
                                )
                                .expect("worker"),
                            )
                        });
                    f
                })
                .collect();
        let mut pool = WorkerPool::spawn(factories);

        // leader state: params from the manifest init specs
        let init: Vec<f32> = init_params(&meta.params, 42).concat();
        let block_dims: Vec<usize> = meta.params.iter().map(|p| p.numel()).collect();
        let mut coord = Coordinator::new(init, block_dims, Network::paper_cluster());

        // phased compressor behind the round engine: encode runs on the
        // worker threads, reduce + decode on this (leader) thread
        let compressor: Box<dyn intsgd::compress::PhasedCompressor> =
            match algo {
                "sgd_fp32" => Box::new(IdentitySgd::allreduce()),
                _ => Box::new(IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int8,
                    Box::new(MovingAverageRule::default_paper()),
                    n,
                    7,
                )),
            };
        let mut engine = intsgd::compress::RoundEngine::new(compressor);

        let cfg = TrainConfig {
            rounds,
            start_round: 0,
            schedule: LrSchedule::constant(0.1),
            momentum: 0.9,
            weight_decay: 1e-4,
            eval_every: 0,
        };
        let res = coord.train(&mut pool, &mut engine, &cfg, None);
        pool.shutdown();

        println!("\n=== {algo} ===");
        println!("round  train_loss  wire_bytes/worker  comm_model_ms");
        for r in res.records.iter().step_by(8) {
            println!(
                "{:>5}  {:>10.4}  {:>17}  {:>13.4}",
                r.round,
                r.train_loss,
                r.wire_bytes_per_worker,
                r.comm_seconds * 1e3
            );
        }
        let last = res.records.last().unwrap();
        println!(
            "final: loss {:.4}, per-round comm {:.4} ms (modeled, 100 Gb/s cluster)",
            last.train_loss,
            last.comm_seconds * 1e3
        );
    }
    println!("\nIntSGD ships 4x fewer bytes with the same convergence — the paper's headline.");
    Ok(())
}
