//! End-to-end validation driver (DESIGN.md §4 "E2E"): train a transformer
//! language model for a few hundred steps through the complete stack —
//! Pallas fused-linear kernels inside the JAX train step, AOT-lowered to
//! HLO, executed by per-worker PJRT clients, gradients compressed with
//! IntSGD int8, aggregated as integers, applied by the rust leader — and
//! log the loss curve to results/e2e_transformer.csv. The run is wired
//! through the typed `api::Session` builder (DESIGN.md §8).
//!
//!   make artifacts && cargo run --release --example train_transformer
//!
//! Env/args: STEPS (default 300), WORKERS (default 4).

use std::sync::Arc;

use anyhow::Result;

use intsgd::api::{CompressorSpec, ModelSpec, Session, SourceFactory};
use intsgd::coordinator::{BatchSpec, LrSchedule, PjrtEvaluator, PjrtWorker};
use intsgd::data::MarkovText;
use intsgd::metrics::Csv;
use intsgd::runtime::{init_params, lit_i32, Runtime};
use intsgd::util::Rng;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> Result<()> {
    let steps = env_usize("STEPS", 300);
    let n = env_usize("WORKERS", 4);
    let artifact_dir =
        std::env::var("INTSGD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let rt = Runtime::open(&artifact_dir)?;
    let meta = rt.meta("transformer_train_step").expect("run `make artifacts`").clone();
    let vocab = meta.extra_usize("vocab").unwrap_or(256);
    let batch = meta.extra_usize("batch").unwrap_or(8);
    let seq = meta.extra_usize("seq").unwrap_or(64);
    println!(
        "transformer LM: {} params, vocab {vocab}, batch {batch}, seq {seq}, {n} workers, {steps} steps",
        meta.grad_dim
    );

    // corpus with real structure so the loss curve means something
    let text = Arc::new(MarkovText::generate(vocab, 400_000, 40_000, 0.05, 0));
    println!(
        "corpus entropy rate {:.3} nats (Bayes-optimal loss); uniform = {:.3}",
        text.entropy_rate(),
        (vocab as f64).ln()
    );

    let shard_len = text.train.len() / n;
    let factories: Vec<SourceFactory> = (0..n)
        .map(|i| {
            let shard: Arc<Vec<u32>> =
                Arc::new(text.train[i * shard_len..(i + 1) * shard_len].to_vec());
            let dir = artifact_dir.clone();
            let f: SourceFactory = Box::new(move || {
                Box::new(
                    PjrtWorker::new(
                        &dir,
                        "transformer",
                        BatchSpec::Lm { tokens: shard, batch, seq },
                        500 + i as u64,
                    )
                    .expect("worker"),
                )
            });
            f
        })
        .collect();

    let mut evaluator = PjrtEvaluator::new(&artifact_dir, "transformer")?;
    let test = Arc::clone(&text);
    let mut eval_rng = Rng::new(999);
    let eval_hook = move |params: &[f32]| -> (f64, f64) {
        let w = MarkovText::batch_windows(&test.test, batch, seq, &mut eval_rng);
        let data = vec![lit_i32(&w, &[batch, seq + 1]).unwrap()];
        match evaluator.eval(params, data) {
            Ok(outs) => (outs[0] as f64, 0.0),
            Err(_) => (f64::NAN, 0.0),
        }
    };

    let init: Vec<f32> = init_params(&meta.params, 7).concat();
    let layout: Vec<Vec<usize>> = meta.params.iter().map(|p| p.shape.clone()).collect();
    let mut session = Session::builder()
        .world(n)
        .model(ModelSpec::with_params(init, layout))
        .sources(factories)
        .compressor(CompressorSpec::parse("intsgd_random8")?)
        .seed(13)
        .schedule(LrSchedule {
            base: 0.5,
            warmup_rounds: steps / 20,
            milestones: vec![(steps * 2 / 3, 0.1)],
        })
        .momentum(0.9)
        .weight_decay(1e-4)
        .eval_every((steps / 20).max(1))
        .eval_hook(Box::new(eval_hook))
        .build()?;

    // Wall-time report for the run summary (clippy.toml wall-clock rule).
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    session.run(steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let res = session.finish();

    let mut csv = Csv::create(
        "results/e2e_transformer.csv",
        &["step", "train_loss", "eval_loss", "alpha", "comm_ms"],
    )?;
    let mut evals = res.evals.iter().peekable();
    for r in &res.records {
        let el = match evals.peek() {
            Some(&&(er, l, _)) if er == r.round => {
                evals.next();
                l
            }
            _ => f64::NAN,
        };
        csv.rowf(&[
            r.round as f64,
            r.train_loss,
            el,
            r.alpha,
            r.comm_seconds * 1e3,
        ])?;
    }
    csv.flush()?;

    println!("\nstep  train_loss  eval_loss");
    let mut evals = res.evals.iter();
    let mut last_eval = f64::NAN;
    for r in res.records.iter() {
        if let Some(&(er, l, _)) = evals.clone().next() {
            if er == r.round {
                last_eval = l;
                evals.next();
            }
        }
        if r.round % (steps / 15).max(1) == 0 {
            println!("{:>4}  {:>10.4}  {:>9.4}", r.round, r.train_loss, last_eval);
        }
    }
    let first = res.records.first().unwrap().train_loss;
    let last = res.records.last().unwrap().train_loss;
    let entropy = text.entropy_rate();
    println!(
        "\nloss {first:.3} -> {last:.3} over {steps} steps ({wall:.1}s wall); \
         Bayes floor {entropy:.3}"
    );
    println!("wrote results/e2e_transformer.csv");
    assert!(
        last < first - 0.2,
        "e2e training did not make progress: {first} -> {last}"
    );
    Ok(())
}
