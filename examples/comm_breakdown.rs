//! Systems example: what each algorithm actually puts on the wire, and
//! what that costs on the modeled 100 Gb/s cluster (the paper's Table 1
//! "supports all-reduce" column made quantitative). Every compressor is
//! built through the typed `api::CompressorSpec` registry — the same
//! front door every `Session` run uses.
//!
//!   cargo run --release --example comm_breakdown

use anyhow::Result;

use intsgd::api::CompressorSpec;
use intsgd::compress::RoundEngine;
use intsgd::coordinator::{BlockInfo, RoundCtx};
use intsgd::netsim::Network;
use intsgd::util::Rng;

fn main() -> Result<()> {
    let n = 16;
    // a ResNet18-ish layout: a few big matrices + small vectors
    let layout: Vec<Vec<usize>> = vec![
        vec![512, 4608],
        vec![512],
        vec![512, 2048],
        vec![512],
        vec![1000, 512],
        vec![1000],
    ];
    let numels: Vec<usize> = layout.iter().map(|s| s.iter().product()).collect();
    let d: usize = numels.iter().sum();
    println!("gradient: {d} coordinates over {} blocks, {n} workers\n", layout.len());

    let mut rng = Rng::new(0);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.02)).collect();
    let ctx = RoundCtx {
        round: 3,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: layout
            .iter()
            .map(|s| BlockInfo {
                dim: s.iter().product::<usize>(),
                step_norm_sq: 1e-4 / layout.len() as f64,
            })
            .collect(),
    };

    // the registry ids of the paper's Table 1 comparison set
    let algos = [
        "sgd_ar", "sgd_ag", "intsgd_random8", "heuristic8", "qsgd", "natsgd",
        "powersgd", "topk", "signsgd",
    ];

    let net = Network::paper_cluster();
    println!(
        "{:<26} {:>12} {:>8} {:>12} {:>14} {:>12}",
        "algorithm", "bytes/worker", "vs fp32", "primitive", "comm model", "overhead"
    );
    for (i, id) in algos.iter().enumerate() {
        let spec = CompressorSpec::parse(id)?;
        let mut engine =
            RoundEngine::new(spec.build(n, &layout, 0.9, 1e-8, 1 + i as u64)?);
        let r = engine.round_sequential(&grads, &ctx);
        let bytes = r.wire_bytes_per_worker();
        let comm = net.comm_seconds(&r.comm, n);
        let prim = format!("{:?}", r.comm[0].primitive);
        println!(
            "{:<26} {:>12} {:>7.1}x {:>12} {:>11.3} ms {:>9.2} ms",
            spec.paper_name(),
            bytes,
            d as f64 * 4.0 / bytes as f64,
            prim,
            comm * 1e3,
            (r.encode_seconds + r.decode_seconds) * 1e3,
        );
    }
    println!(
        "\nAll-gather pays (n-1)x bandwidth; the all-reduce-compatible\n\
         compressors (IntSGD, PowerSGD) are the only ones that cut wire\n\
         bytes AND keep the cheap collective — the paper's Table 1 point."
    );
    Ok(())
}
