//! Heterogeneous-data example (paper Appendix A.2 / C.5): why plain
//! integer compression blows up on heterogeneous shards and how IntDIANA
//! fixes it by compressing gradient *differences*.
//!
//! Note: IntDIANA is its own optimizer loop (shift-compressed full-batch
//! rounds over `optim::IntDiana`), not a round-engine compressor — it is
//! the one example that deliberately does NOT run through `api::Session`,
//! whose facade covers the synchronous data-parallel round structure.
//!
//!   cargo run --release --example logreg_diana

use anyhow::Result;

use intsgd::data::{synth_dataset, DATASETS};
use intsgd::optim::{Estimator, IntDiana};

fn main() -> Result<()> {
    let spec = &DATASETS[0]; // a5a geometry: N=6414, d=123
    let workers = 12;
    let rounds = 300;
    println!(
        "dataset {} (N={}, d={}, lambda={:.0e}), {} heterogeneous shards",
        spec.name, spec.n_examples, spec.dim, spec.lambda2, workers
    );

    let ds = synth_dataset(spec, 11);
    let shards = ds.shards(workers);
    let global = ds.global();

    // reference optimum by pooled gradient descent
    let mut x = vec![0.0f32; spec.dim];
    for _ in 0..2000 {
        let g = global.grad(&x);
        for (xi, &gi) in x.iter_mut().zip(&g) {
            *xi -= 1.0 * gi;
        }
    }
    let f_star = global.loss(&x);
    println!("f* = {f_star:.6}\n");

    let m = shards[0].examples();
    let tau = (m / 20).max(1);
    let runs: Vec<(&str, Estimator, bool, usize)> = vec![
        ("IntGD (no shifts)", Estimator::Gd, false, 0),
        ("IntDIANA", Estimator::Gd, true, 0),
        ("VR-IntDIANA (L-SVRG)", Estimator::LSvrg { p: tau as f64 / m as f64 }, true, tau),
    ];

    for (name, est, shifts, mb) in runs {
        let mut opt = IntDiana::new(workers, spec.dim, 0.5, est, shifts, 3);
        let (xf, recs) = opt.run(
            &shards,
            vec![0.0f32; spec.dim],
            rounds,
            mb,
            &global,
            f_star,
            rounds / 10,
        );
        println!("=== {name} ===");
        println!("round  objective_gap   max_agg_int   bits/coord");
        for r in &recs {
            println!(
                "{:>5}  {:>13.3e}  {:>12}  {:>10.1}",
                r.round, r.objective, r.max_abs_int, r.agg_bits_per_coord
            );
        }
        let gap = global.loss(&xf) - f_star;
        println!("final gap {gap:.3e}\n");
    }
    println!(
        "IntGD's integers explode as x -> x* (alpha ~ 1/||dx|| against a\n\
         non-vanishing local gradient); IntDIANA's differences g_i - h_i\n\
         shrink with the steps, keeping the wire a few bits per coordinate."
    );
    Ok(())
}
