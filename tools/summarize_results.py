#!/usr/bin/env python3
"""Summarize results/*.csv into the paper-style tables.

Usage: python tools/summarize_results.py [results_dir]

Reads the CSVs written by the experiment drivers (`repro exp ...`) and
prints compact tables mirroring the paper's figures — handy after a
long run, and usable as a plotting frontend (each block is a tidy
dataframe-shaped CSV already).
"""

import csv
import math
import os
import sys
from collections import defaultdict


def mean(xs):
    return sum(xs) / len(xs) if xs else float("nan")


def std(xs):
    if len(xs) < 2:
        return 0.0
    m = mean(xs)
    return math.sqrt(sum((x - m) ** 2 for x in xs) / (len(xs) - 1))


def load(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def fnum(s):
    try:
        return float(s)
    except (TypeError, ValueError):
        return float("nan")


def summarize_curves(path, metric="train_loss", probe=(5, 20, 60, -1)):
    rows = load(path)
    by = defaultdict(list)
    for r in rows:
        by[(r["algo"], r["seed"])].append(fnum(r[metric]))
    algos = sorted({a for a, _ in by})
    print(f"  {'algo':<18}" + "".join(f"{('r'+str(p)) if p>=0 else 'final':>12}" for p in probe))
    for a in algos:
        seeds = [v for (aa, _), v in by.items() if aa == a]
        cols = []
        for p in probe:
            vals = [s[p] for s in seeds if len(s) > abs(p)]
            cols.append(f"{mean(vals):>12.4f}")
        print(f"  {a:<18}" + "".join(cols))


def summarize_table(path, metric_cols):
    rows = load(path)
    by = defaultdict(lambda: defaultdict(list))
    for r in rows:
        for c in metric_cols:
            by[r.get("paper_name", r["algo"])][c].append(fnum(r[c]))
    width = max(len(a) for a in by) + 2
    print(f"  {'algorithm':<{width}}" + "".join(f"{c:>16}" for c in metric_cols))
    for a, cols in by.items():
        cells = "".join(
            f"{mean(v):>9.3f}±{std(v):<6.3f}" for v in (cols[c] for c in metric_cols)
        )
        print(f"  {a:<{width}}{cells}")


def summarize_fig6(path):
    rows = load(path)
    final = {}
    for r in rows:
        key = (r["dataset"], r["algo"], r["seed"])
        final[key] = r  # last row per key wins (rounds ascending)
    agg = defaultdict(list)
    for (ds, algo, _), r in final.items():
        agg[(ds, algo)].append((fnum(r["objective_gap"]), fnum(r["max_abs_int"]), fnum(r["agg_bits"])))
    print(f"  {'dataset':<12}{'algo':<14}{'gap':>12}{'max_int':>10}{'bits':>8}")
    for (ds, algo), vals in sorted(agg.items()):
        gaps = [v[0] for v in vals]
        ints = [v[1] for v in vals]
        bits = [v[2] for v in vals]
        print(f"  {ds:<12}{algo:<14}{mean(gaps):>12.3e}{max(ints):>10.0f}{mean(bits):>8.1f}")


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    sections = [
        ("Figure 1 (classifier)", "fig1_classifier.csv", lambda p: summarize_curves(p)),
        ("Figure 1 (LM)", "fig1_lm.csv", lambda p: summarize_curves(p)),
        ("Figure 2", "fig2_comm_times.csv", _fig2),
        ("Table 2", "table2_classifier.csv", lambda p: summarize_table(p, ["test_acc", "overhead_ms", "comm_ms", "total_ms"])),
        ("Table 3", "table3_lm.csv", lambda p: summarize_table(p, ["test_loss", "overhead_ms", "comm_ms", "total_ms"])),
        ("Figure 3", "fig3_classifier_curves.csv", lambda p: summarize_curves(p)),
        ("Figure 4", "fig4_lm_curves.csv", lambda p: summarize_curves(p)),
        ("Figure 5", "fig5_classifier.csv", lambda p: summarize_table(p, ["test_loss", "test_acc"])),
        ("Figure 6", "fig6_logreg.csv", summarize_fig6),
        ("Ablation", "ablation_intsgd.csv", lambda p: summarize_table(p, ["test_loss", "test_acc", "max_int"])),
        ("E2E transformer", "e2e_transformer.csv", _e2e),
    ]
    for title, fname, fn in sections:
        path = os.path.join(d, fname)
        print(f"== {title} ==")
        if not os.path.exists(path):
            print(f"  (missing {path}; run the driver)")
            continue
        try:
            fn(path)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"  error summarizing: {e}")
        print()


def _fig2(path):
    rows = load(path)
    print(f"  {'coords':>12}{'fp32 ms':>10}{'int8 ms':>10}{'ratio':>8}")
    for r in rows[:: max(1, len(rows) // 6)]:
        print(
            f"  {int(fnum(r['num_coords'])):>12}{fnum(r['fp32_ms']):>10.3f}"
            f"{fnum(r['int8_ms']):>10.3f}{fnum(r['speedup']):>8.2f}"
        )


def _e2e(path):
    rows = load(path)
    losses = [fnum(r["train_loss"]) for r in rows]
    print(f"  steps {len(rows)}: train loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
