#!/usr/bin/env python3
"""Fail CI when a committed bench baseline regresses.

Usage:
    python3 tools/bench_gate.py --baseline rust/BENCH_net.baseline.json \
        --fresh rust/BENCH_net.json [--threshold 0.15] [--floor-ms 0.05]

Compares the timing leaves of a fresh bench report (written by
`cargo bench --bench bench_compress` / `bench_collective`) against the
committed baseline and exits non-zero when any hot-path value regressed
past the threshold. Improvements and schema drift never fail the gate:
a PR that reshapes a report is expected to refresh the baseline next to
it, and a missing counterpart key is reported but not fatal.

Noise handling, deliberately conservative so the gate stays green on
shared CI runners:

  * Gated leaves are numeric keys ending in `_ms` (measured time) and
    `_bytes_per_coord` (wire occupancy — deterministic, so gated with
    the strict threshold and no noise floor even in smoke mode).
    Config echo columns (d, n, group, hops, bytes totals) and derived
    speedups/ratios are ignored.
  * Values where BOTH sides sit under the floor (default 0.05 ms) are
    skipped: sub-tick timings are scheduler noise, not signal.
  * Smoke-mode reports (`"smoke": true` — single iteration at tiny
    sizes) are gated with a relaxed threshold (default 2.0, i.e. fail
    only past 3x) because a 1-iteration median at d=2^12 jitters far
    beyond any honest regression bound. The strict threshold applies
    to full runs, whose medians at d=2^20 are stable.
  * A smoke/full mismatch between baseline and fresh report skips the
    gate entirely (exit 0, loud message) — comparing the two shapes
    would be meaningless.

Refreshing a baseline after an intentional perf or schema change:

    (cd rust && BENCH_SMOKE=1 cargo bench --bench bench_compress)
    cp rust/BENCH_compress.json rust/BENCH_compress.baseline.json

and likewise for bench_collective -> BENCH_net.baseline.json.

Every invocation ends with one machine-readable line on stdout,

    BENCH_GATE status=<pass|fail|skipped|error> mode=<full|smoke|mismatch|->
        compared=N regressed=N missing=N skipped=N threshold=X worst=X

so CI annotations and the PR driver can grep `^BENCH_GATE ` instead of
parsing the human-oriented prose.
"""

import argparse
import json
import sys


def summary(status, mode="-", compared=0, regressed=0, missing=0, skipped=0,
            threshold=None, worst=None):
    """One machine-readable line, emitted on EVERY exit path.

    CI and the PR driver grep for the `BENCH_GATE ` prefix instead of
    parsing the prose above it; keep the key=value grammar stable.
    """
    thr = f"{threshold:.2f}" if threshold is not None else "-"
    wst = f"{worst:.3f}" if worst is not None else "-"
    print(
        f"BENCH_GATE status={status} mode={mode} compared={compared} "
        f"regressed={regressed} missing={missing} skipped={skipped} "
        f"threshold={thr} worst={wst}"
    )


def is_timing_key(key):
    # `*model*` columns are deterministic netsim-preset functions (already
    # pinned by unit tests), not wall-clock — only measured time is gated.
    return key.endswith("_ms") and "model" not in key


def is_bytes_key(key):
    # Wire-occupancy leaves (`*_bytes_per_coord`): deterministic — a
    # compressor change that widens the wire lane must trip the gate even
    # in smoke mode, so these are compared with the strict threshold and
    # no noise floor.
    return key.endswith("_bytes_per_coord")


def is_gated_key(key):
    return is_timing_key(key) or is_bytes_key(key)


def walk(base, fresh, path, pairs, missing):
    """Collect (path, baseline, fresh) timing pairs from both trees."""
    if isinstance(base, dict) and isinstance(fresh, dict):
        for k in sorted(set(base) | set(fresh)):
            p = f"{path}.{k}" if path else k
            if k not in base or k not in fresh:
                if is_gated_key(k):
                    missing.append(p)
                continue
            walk(base[k], fresh[k], p, pairs, missing)
    elif isinstance(base, list) and isinstance(fresh, list):
        if len(base) != len(fresh):
            missing.append(f"{path}[] (len {len(base)} vs {len(fresh)})")
        for i, (b, f) in enumerate(zip(base, fresh)):
            walk(b, f, f"{path}[{i}]", pairs, missing)
    elif isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        leaf = path.rsplit(".", 1)[-1]
        if is_gated_key(leaf) and not isinstance(base, bool):
            pairs.append((path, float(base), float(fresh)))


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed baseline json")
    ap.add_argument("--fresh", required=True, help="freshly written bench json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="max tolerated relative regression on full runs (0.15 = +15%%)",
    )
    ap.add_argument(
        "--smoke-threshold",
        type=float,
        default=2.0,
        help="relaxed threshold when both reports are smoke runs",
    )
    ap.add_argument(
        "--floor-ms",
        type=float,
        default=0.05,
        help="skip pairs where both sides are under this many ms (noise)",
    )
    args = ap.parse_args()

    try:
        with open(args.baseline) as f:
            base = json.load(f)
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_gate: cannot load reports: {e}", file=sys.stderr)
        summary("error")
        return 2

    base_smoke = bool(base.get("smoke", False))
    fresh_smoke = bool(fresh.get("smoke", False))
    if base_smoke != fresh_smoke:
        print(
            f"bench_gate: smoke mismatch (baseline smoke={base_smoke}, "
            f"fresh smoke={fresh_smoke}) — shapes are not comparable, skipping"
        )
        summary("skipped", mode="mismatch")
        return 0
    threshold = args.smoke_threshold if fresh_smoke else args.threshold

    pairs, missing = [], []
    walk(base, fresh, "", pairs, missing)
    mode = "smoke" if fresh_smoke else "full"
    if not pairs:
        print("bench_gate: no comparable timing keys found", file=sys.stderr)
        summary("error", mode=mode, missing=len(missing))
        return 2

    regressions, compared, skipped = [], 0, 0
    worst = None
    for path, b, f in pairs:
        leaf = path.rsplit(".", 1)[-1]
        if is_bytes_key(leaf):
            # deterministic wire-occupancy leaf: strict threshold, no floor
            compared += 1
            ratio = f / b if b > 0 else float("inf")
            if worst is None or ratio > worst:
                worst = ratio
            if f > b * (1.0 + args.threshold):
                regressions.append((path, b, f, ratio))
            continue
        if b < args.floor_ms and f < args.floor_ms:
            skipped += 1
            continue
        compared += 1
        ratio = f / b if b > 0 else float("inf")
        if worst is None or ratio > worst:
            worst = ratio
        if f > b * (1.0 + threshold):
            regressions.append((path, b, f, ratio))

    print(
        f"bench_gate [{mode}]: {compared} timing keys gated at +{threshold:.0%}, "
        f"{skipped} under the {args.floor_ms} ms noise floor"
    )
    for p in missing:
        print(f"  note: no counterpart for {p} (schema drift — refresh baseline?)")
    if regressions:
        print("bench_gate: REGRESSIONS past the threshold:", file=sys.stderr)
        for path, b, f, ratio in sorted(regressions, key=lambda r: -r[3]):
            print(
                f"  {path}: {b:.3f} -> {f:.3f} ({ratio:.2f}x)",
                file=sys.stderr,
            )
        summary("fail", mode=mode, compared=compared,
                regressed=len(regressions), missing=len(missing),
                skipped=skipped, threshold=threshold, worst=worst)
        return 1
    print("bench_gate: ok — no hot-path regression past the threshold")
    summary("pass", mode=mode, compared=compared, missing=len(missing),
            skipped=skipped, threshold=threshold, worst=worst)
    return 0


if __name__ == "__main__":
    sys.exit(main())
