//! Net parity: the staged collectives over **real TCP sockets** must be
//! bit-identical to the in-process leader fold — for raw IntSGD wire
//! messages, for full engine rounds across the whole compressor zoo, and
//! for end-to-end training.
//!
//! The argument (pinned here, stated in `net::staged`): every staged
//! schedule sums the same n integers per coordinate in a different
//! association order, the accumulator is `i64` throughout, and integer
//! addition is exactly associative — so sockets, frames, and schedule
//! order cannot change a single bit relative to
//! `collective::allreduce_intvec`'s rank-order fold.

use intsgd::collective::allreduce_intvec;
use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::compress::powersgd::BlockShape;
use intsgd::compress::{
    HeuristicIntSgd, IdentitySgd, NatSgd, PhasedCompressor, Pipeline, PowerSgd,
    Qsgd, RoundEngine, SerialReducer, SignSgd, TopK,
};
use intsgd::coordinator::{BlockInfo, Coordinator, RoundCtx, WorkerPool};
use intsgd::coordinator::{LrSchedule, TrainConfig};
use intsgd::net::staged::{ring_allgather_bytes, ring_allreduce_ints, StagedScratch};
use intsgd::net::{StagedAlgo, TcpTransport, TransportReducer};
use intsgd::netsim::Network;
use intsgd::scaling::{BlockRule, MovingAverageRule};
use intsgd::util::Rng;

/// Real IntSGD wire messages: encode each rank's gradient with the
/// paper's clip so partial sums provably fit the int8 wire.
fn intsgd_messages(n: usize, d: usize, seed: u64) -> Vec<IntVec> {
    let clip = i8::MAX as i64 / n as i64;
    let mut root = Rng::new(seed);
    let mut streams: Vec<Rng> = (0..n).map(|i| root.fork(i as u64)).collect();
    let mut grad_rng = Rng::new(seed ^ 0xD1CE);
    (0..n)
        .map(|rank| {
            let grad = grad_rng.normal_vec(d, 1.0);
            let mut widened = Vec::new();
            IntSgd::encode(
                Rounding::Stochastic,
                &grad,
                25.0,
                clip,
                &mut streams[rank],
                &mut widened,
            );
            IntVec::from_i64(&widened, Lanes::I8)
        })
        .collect()
}

#[test]
fn staged_ring_over_tcp_is_bit_identical_to_the_leader_fold() {
    let n = 4;
    let d = 5000;
    let msgs = intsgd_messages(n, d, 0xAB);
    let views: Vec<&IntVec> = msgs.iter().collect();
    let mut want = Vec::new();
    allreduce_intvec(&views, &mut want);

    let mut endpoints = TcpTransport::loopback_mesh(n).expect("mesh");
    let results: Vec<Vec<i64>> = std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .iter_mut()
            .zip(&msgs)
            .map(|(ep, msg)| {
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    for round in 0..2 {
                        ring_allreduce_ints(ep, msg, Lanes::I8, round, &mut scratch, &mut out)
                            .expect("tcp ring");
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, got) in results.iter().enumerate() {
        assert_eq!(got, &want, "rank {rank}");
    }
}

#[test]
fn codec_allgather_over_tcp_roundtrips_every_payload() {
    // the all-gather compressors' byte streams (compress::wire formats)
    // survive the socket verbatim: every rank decodes every rank's bytes
    use intsgd::compress::wire::{
        decode_sign, decode_sparse, encode_sign, encode_sparse,
    };
    let n = 3;
    let d = 200;
    let mut rng = Rng::new(9);
    let payloads: Vec<Vec<u8>> = (0..n)
        .map(|r| {
            if r % 2 == 0 {
                let g = rng.normal_vec(d, 1.0);
                encode_sign(&SignSgd::encode(&g), d)
            } else {
                let entries: Vec<(u32, f32)> = (0..20)
                    .map(|k| (k * 7 + r as u32, rng.normal_f32()))
                    .collect();
                encode_sparse(&entries)
            }
        })
        .collect();
    let mut endpoints = TcpTransport::loopback_mesh(n).expect("mesh");
    let gathered: Vec<Vec<Vec<u8>>> = std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .iter_mut()
            .zip(&payloads)
            .map(|(ep, mine)| {
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    ring_allgather_bytes(ep, mine, 0, &mut scratch, &mut out)
                        .expect("tcp all-gather");
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, got) in gathered.iter().enumerate() {
        assert_eq!(got, &payloads, "rank {rank} gathered set");
        // and the bytes still decode (sign on even origins, sparse on odd)
        for (origin, bytes) in got.iter().enumerate() {
            if origin % 2 == 0 {
                decode_sign(bytes, d).expect("sign decode after transport");
            } else {
                decode_sparse(bytes).expect("sparse decode after transport");
            }
        }
    }
}

// --- full engine rounds over the transport, whole zoo ---------------------

fn ctx_for(round: usize, d: usize, n: usize) -> RoundCtx {
    let dims = [d / 2, d / 4, d / 4];
    let blocks: Vec<BlockInfo> = dims
        .iter()
        .enumerate()
        .map(|(l, &dim)| BlockInfo {
            dim,
            step_norm_sq: 1e-4 / (l + 1) as f64 * (round as f64 + 1.0),
        })
        .collect();
    let step_norm_sq = blocks.iter().map(|b| b.step_norm_sq).sum();
    RoundCtx { round, n, d, lr: 0.1, step_norm_sq, blocks }
}

fn zoo(n: usize, d: usize) -> Vec<(&'static str, Box<dyn Fn() -> Box<dyn PhasedCompressor>>)> {
    let power_layout: Vec<BlockShape> = vec![
        BlockShape { dims: vec![4, d / 8] },
        BlockShape { dims: vec![d / 4] },
        BlockShape { dims: vec![d / 4] },
    ];
    let qsgd_dims = vec![d / 2, d / 4, d / 4];
    vec![
        (
            "sgd_allreduce",
            Box::new(|| Box::new(IdentitySgd::allreduce()) as Box<dyn PhasedCompressor>),
        ),
        (
            "intsgd_random8",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int8,
                    Box::new(MovingAverageRule::default_paper()),
                    n,
                    61,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "intsgd_determ32",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Deterministic,
                    WireInt::Int32,
                    Box::new(MovingAverageRule::default_paper()),
                    n,
                    62,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "intsgd_block8",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int8,
                    Box::new(BlockRule::new(0.9, 1e-8)),
                    n,
                    63,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "heuristic8",
            Box::new(|| Box::new(HeuristicIntSgd::new(8)) as Box<dyn PhasedCompressor>),
        ),
        (
            "qsgd64",
            Box::new(move || {
                Box::new(Qsgd::new(64, qsgd_dims.clone(), n, 64)) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "natsgd",
            Box::new(move || Box::new(NatSgd::new(n, 65)) as Box<dyn PhasedCompressor>),
        ),
        (
            "topk10",
            Box::new(move || Box::new(TopK::new(0.1, n)) as Box<dyn PhasedCompressor>),
        ),
        (
            "ef_signsgd",
            Box::new(move || Box::new(SignSgd::new(n)) as Box<dyn PhasedCompressor>),
        ),
        (
            "powersgd_rank2",
            Box::new(move || {
                Box::new(PowerSgd::new(2, power_layout.clone(), n, 66))
                    as Box<dyn PhasedCompressor>
            }),
        ),
    ]
}

#[test]
fn engine_rounds_over_tcp_match_the_sequential_reference_for_the_zoo() {
    // One TCP mesh serves every compressor in sequence: the integer
    // algorithms aggregate over sockets, the rest exercise the same
    // engine path with the reducer parked — results must equal the
    // sequential reference bit for bit either way.
    let n = 4;
    let d = 96;
    let mut pool = WorkerPool::for_encode(n);
    let mut red =
        TransportReducer::tcp_loopback(n, StagedAlgo::Ring).expect("tcp reducer");
    for (label, mk) in zoo(n, d) {
        let mut seq = RoundEngine::new(mk());
        let mut net = RoundEngine::new(mk());
        let mut rng = Rng::new(0x7C9);
        for round in 0..3 {
            let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.5)).collect();
            let ctx = ctx_for(round, d, n);
            let a = seq.round_sequential(&grads, &ctx);
            let b = net
                .round_parallel_over(&mut pool, &mut red, &grads, &ctx)
                .expect("clean fabric");
            assert_eq!(a.gtilde, b.gtilde, "{label} round {round}: gtilde differs");
            assert_eq!(
                a.max_abs_int, b.max_abs_int,
                "{label} round {round}: max_abs_int differs"
            );
            assert_eq!(
                a.alpha.to_bits(),
                b.alpha.to_bits(),
                "{label} round {round}: alpha differs"
            );
            assert_eq!(
                a.wire_bytes_per_worker(),
                b.wire_bytes_per_worker(),
                "{label} round {round}: wire bytes differ"
            );
        }
    }
    pool.shutdown();
}

#[test]
fn streamed_rounds_match_the_barrier_drivers_bitwise_for_the_zoo() {
    // The double-buffered block pipeline must be invisible in the output:
    // for every compressor, a streamed round equals the sequential
    // reference bit for bit — whether the per-block collectives run on
    // the leader fold (SerialReducer) or over a real transport with the
    // two-level hierarchical schedule. Compressors that cannot stream
    // (dense round 0, multi-pass, all-gather codecs) exercise the
    // fallback: `round_streamed_over` must quietly run the barrier path.
    let n = 4;
    let d = 96;
    let mut pool = WorkerPool::for_encode(n);
    let mut serial = SerialReducer;
    let mut chan =
        TransportReducer::channel_mesh(n, StagedAlgo::TwoLevel { group: 2 });
    for (label, mk) in zoo(n, d) {
        let mut seq = RoundEngine::new(mk());
        let mut str_serial = RoundEngine::new(mk());
        let mut str_chan = RoundEngine::new(mk());
        let mut rng = Rng::new(0x57E0);
        for round in 0..3 {
            let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.5)).collect();
            let ctx = ctx_for(round, d, n);
            let a = seq.round_sequential(&grads, &ctx);
            let b = str_serial
                .round_streamed_over(&mut pool, &mut serial, &grads, &ctx)
                .expect("leader fold cannot fail");
            let c = str_chan
                .round_streamed_over(&mut pool, &mut chan, &grads, &ctx)
                .expect("clean fabric");
            for (tag, r) in [("serial", &b), ("two-level", &c)] {
                assert_eq!(
                    a.gtilde, r.gtilde,
                    "{label} round {round} ({tag}): gtilde differs"
                );
                assert_eq!(
                    a.max_abs_int, r.max_abs_int,
                    "{label} round {round} ({tag}): max_abs_int differs"
                );
                assert_eq!(
                    a.alpha.to_bits(),
                    r.alpha.to_bits(),
                    "{label} round {round} ({tag}): alpha differs"
                );
                assert_eq!(
                    a.wire_bytes_per_worker(),
                    r.wire_bytes_per_worker(),
                    "{label} round {round} ({tag}): wire bytes differ"
                );
            }
        }
    }
    pool.shutdown();
}

#[test]
fn streamed_training_matches_barrier_training_bitwise() {
    // End to end through the coordinator's dispatch: the same run with
    // `pipeline=streamed` (per-block collectives over channels) must
    // reproduce the barrier run exactly — params, losses, diagnostics.
    let n = 4;
    let d = 256;
    let rounds = 10;
    let mk_engine = || {
        RoundEngine::new(Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            29,
        )) as Box<dyn PhasedCompressor>)
    };
    let run = |pipeline: Pipeline| {
        let cfg = TrainConfig {
            rounds,
            schedule: LrSchedule::constant(0.3),
            pipeline,
            ..Default::default()
        };
        let mut pool = quad_pool(n, d);
        let mut coord =
            Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
        let mut engine = mk_engine();
        let mut red = TransportReducer::channel_mesh(n, StagedAlgo::Ring);
        let res = coord.train_over(&mut pool, &mut engine, &mut red, &cfg, None);
        pool.shutdown();
        res
    };
    let barrier = run(Pipeline::Barrier);
    let streamed = run(Pipeline::Streamed);
    assert_eq!(
        barrier.final_params, streamed.final_params,
        "final params diverge"
    );
    for (ra, rb) in barrier.records.iter().zip(&streamed.records) {
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits(), "round {}", ra.round);
        assert_eq!(ra.max_abs_int, rb.max_abs_int, "round {}", ra.round);
        assert_eq!(ra.alpha.to_bits(), rb.alpha.to_bits(), "round {}", ra.round);
        assert_eq!(
            ra.wire_bytes_per_worker, rb.wire_bytes_per_worker,
            "round {}",
            ra.round
        );
    }
}

#[test]
fn halving_reducer_matches_ring_reducer_bitwise() {
    // two transports, two schedules, one answer
    let n = 4;
    let d = 4096;
    let mut pool = WorkerPool::for_encode(n);
    let mk = |seed| {
        RoundEngine::new(Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            seed,
        )) as Box<dyn PhasedCompressor>)
    };
    let mut ring_engine = mk(5);
    let mut halving_engine = mk(5);
    let mut ring = TransportReducer::channel_mesh(n, StagedAlgo::Ring);
    let mut halving = TransportReducer::channel_mesh(n, StagedAlgo::Halving);
    let mut rng = Rng::new(0xFA11);
    for round in 0..3 {
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.5)).collect();
        let ctx = RoundCtx {
            round,
            n,
            d,
            lr: 0.1,
            step_norm_sq: 1e-4,
            blocks: vec![BlockInfo { dim: d, step_norm_sq: 1e-4 }],
        };
        let a = ring_engine
            .round_parallel_over(&mut pool, &mut ring, &grads, &ctx)
            .expect("ring");
        let b = halving_engine
            .round_parallel_over(&mut pool, &mut halving, &grads, &ctx)
            .expect("halving");
        assert_eq!(a.gtilde, b.gtilde, "round {round}");
    }
    pool.shutdown();
}

// --- end-to-end training over the transport -------------------------------

/// The shared deterministic quadratic oracle (same seeds both runs).
fn quad_pool(n: usize, d: usize) -> WorkerPool {
    intsgd::coordinator::net_driver::quad_pool(n, d, 300, 0.01)
}

#[test]
fn training_over_tcp_matches_pool_training_bitwise() {
    // The whole loop — gradients, encode, staged TCP aggregation, decode,
    // optimizer — must reproduce the in-process run exactly: same seeds,
    // same integers, same f32 updates, bit for bit.
    let n = 3;
    let d = 256;
    let rounds = 12;
    let mk_engine = || {
        RoundEngine::new(Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            17,
        )) as Box<dyn PhasedCompressor>)
    };
    let cfg = TrainConfig {
        rounds,
        schedule: LrSchedule::constant(0.3),
        ..Default::default()
    };

    let mut pool_a = quad_pool(n, d);
    let mut coord_a = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_a = mk_engine();
    let res_a = coord_a.train(&mut pool_a, &mut engine_a, &cfg, None);
    pool_a.shutdown();

    let mut pool_b = quad_pool(n, d);
    let mut coord_b = Coordinator::new(vec![0.0; d], vec![d], Network::tcp_loopback());
    let mut engine_b = mk_engine();
    let mut red = TransportReducer::tcp_loopback(n, StagedAlgo::Ring).expect("reducer");
    let res_b = coord_b.train_over(&mut pool_b, &mut engine_b, &mut red, &cfg, None);
    pool_b.shutdown();

    assert_eq!(res_a.final_params, res_b.final_params, "final params diverge");
    for (ra, rb) in res_a.records.iter().zip(&res_b.records) {
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits(), "round {}", ra.round);
        assert_eq!(ra.max_abs_int, rb.max_abs_int, "round {}", ra.round);
        assert_eq!(ra.alpha.to_bits(), rb.alpha.to_bits(), "round {}", ra.round);
    }
    // the transport actually ran: one staged collective per integer round
    assert_eq!(red.calls(), (rounds - 1) as u64);
    assert!(red.wire_seconds() > 0.0, "no wire time recorded");
    // IntSGD int8 partial sums ride the one-byte wire
    assert_eq!(red.last_wire(), Some(Lanes::I8));
}
