//! Multi-job serving over one multiplexed mesh: the isolation and
//! parity guarantees of DESIGN.md §13.
//!
//! 1. **Shared-mesh parity** — two concurrent jobs scheduled by a
//!    [`SessionServer`] over one `MuxTransport::loopback_mesh` each end
//!    bitwise-identical to a solo run of the same job (and the mux solo
//!    run itself matches the in-proc channel backend bitwise — channel
//!    framing is transport plumbing, invisible to the collective).
//! 2. **Interleaving independence** — seeded scheduler jitter produces
//!    different round interleavings; every one of them yields the same
//!    bits (each job's frames ride a private channel with its own
//!    round/seq guard).
//! 3. **Fault isolation** — a seeded kill of one rank in job A makes A
//!    fail over to the survivors while job B's result does not change
//!    by a single bit.

use intsgd::api::{
    Backend, CompressorSpec, FaultSpec, JobSchedule, ModelSpec, Session, SessionBuilder,
    SessionServer, StagedAlgo,
};
use intsgd::coordinator::net_driver::quad_factories;
use intsgd::net::MuxTransport;

const ALGO: StagedAlgo = StagedAlgo::Ring;

/// The shared job shape: what job `seed` trains, regardless of which
/// transport carries its collective.
fn job_builder(n: usize, d: usize, seed: u64) -> SessionBuilder {
    Session::builder()
        .world(n)
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, seed, 0.01))
        .compressor(CompressorSpec::parse("intsgd_random8").expect("spec"))
        .seed(seed ^ 0xA5)
        .lr(0.25)
}

/// Reference run: the job alone on a fresh backend of its own.
fn solo_params(n: usize, d: usize, seed: u64, rounds: usize, backend: Backend) -> Vec<f32> {
    let mut session = job_builder(n, d, seed).backend(backend).build().expect("solo build");
    session.run(rounds).expect("solo run");
    let params = session.params().to_vec();
    session.finish();
    params
}

#[test]
fn two_concurrent_jobs_match_their_solo_runs_bitwise() {
    let (n, d, rounds) = (3, 384, 8);
    let (seed_a, seed_b) = (7, 21);

    // solo references on private single-channel mux meshes ...
    let solo_a = solo_params(n, d, seed_a, rounds, Backend::Mux { algo: ALGO });
    let solo_b = solo_params(n, d, seed_b, rounds, Backend::Mux { algo: ALGO });
    // ... which are themselves bit-identical to the in-proc channel
    // backend: the mux envelope is stripped below the frame guard
    let chan_a = solo_params(n, d, seed_a, rounds, Backend::Channel { algo: ALGO });
    assert_eq!(solo_a, chan_a, "mux solo run differs from the channel backend");

    // the same two jobs, concurrently, over ONE shared two-channel mesh
    let mut mesh = MuxTransport::loopback_mesh(n, 2).expect("shared mesh");
    let mut server = SessionServer::new(JobSchedule::RoundRobin);
    let mut add = |seed: u64, channel: Vec<MuxTransport>, name: &str| {
        let session = job_builder(n, d, seed)
            .backend(Backend::Mux { algo: ALGO })
            .mux_endpoints(channel)
            .build()
            .expect("job build");
        server.add_job(name.to_string(), session, rounds).expect("admit")
    };
    let h_a = add(seed_a, mesh.remove(0), "job-a");
    let h_b = add(seed_b, mesh.remove(0), "job-b");
    server.run_to_completion().expect("both jobs complete");

    assert!(server.is_done(h_a) && server.is_done(h_b));
    assert_eq!(server.params(h_a), &solo_a[..], "job A perturbed by sharing the mesh");
    assert_eq!(server.params(h_b), &solo_b[..], "job B perturbed by sharing the mesh");
}

#[test]
fn any_seeded_jitter_interleaving_yields_the_same_bits() {
    let (n, d, rounds) = (2, 256, 6);
    let (seed_a, seed_b) = (3, 11);
    let solo_a = solo_params(n, d, seed_a, rounds, Backend::Mux { algo: ALGO });
    let solo_b = solo_params(n, d, seed_b, rounds, Backend::Mux { algo: ALGO });

    for jitter in [1u64, 42, 9001] {
        let mut mesh = MuxTransport::loopback_mesh(n, 2).expect("shared mesh");
        let mut server = SessionServer::new(JobSchedule::Jitter { seed: jitter });
        let a = job_builder(n, d, seed_a)
            .backend(Backend::Mux { algo: ALGO })
            .mux_endpoints(mesh.remove(0))
            .build()
            .expect("job a");
        let b = job_builder(n, d, seed_b)
            .backend(Backend::Mux { algo: ALGO })
            .mux_endpoints(mesh.remove(0))
            .build()
            .expect("job b");
        let h_a = server.add_job("job-a", a, rounds).expect("admit a");
        let h_b = server.add_job("job-b", b, rounds).expect("admit b");
        server.run_to_completion().expect("jittered schedule completes");
        assert_eq!(server.params(h_a), &solo_a[..], "jitter seed {jitter} changed job A");
        assert_eq!(server.params(h_b), &solo_b[..], "jitter seed {jitter} changed job B");
    }
}

#[test]
fn a_killed_rank_in_one_job_leaves_the_sibling_job_bit_unchanged() {
    let (n, d, rounds) = (4, 256, 8);
    let (seed_a, seed_b) = (5, 17);
    let solo_b = solo_params(n, d, seed_b, rounds, Backend::Mux { algo: ALGO });

    let mut mesh = MuxTransport::loopback_mesh(n, 2).expect("shared mesh");
    let mut server = SessionServer::new(JobSchedule::RoundRobin);
    // job A: rank 2's transport dies for good at collective round 3 —
    // FaultTransport wraps the mux endpoints, so the death closes A's
    // channel only, never the shared sockets under it
    let a = job_builder(n, d, seed_a)
        .backend(Backend::Mux { algo: ALGO })
        .mux_endpoints(mesh.remove(0))
        .faults(FaultSpec { kill: Some((2, 3)), ..FaultSpec::default() })
        .net_timeout(std::time::Duration::from_millis(2_000))
        .net_retries(16)
        .build()
        .expect("job a");
    let b = job_builder(n, d, seed_b)
        .backend(Backend::Mux { algo: ALGO })
        .mux_endpoints(mesh.remove(0))
        .build()
        .expect("job b");
    let h_a = server.add_job("chaotic", a, rounds).expect("admit a");
    let h_b = server.add_job("clean", b, rounds).expect("admit b");
    server.run_to_completion().expect("failover must keep both jobs running");

    // A failed over: the world shrank and training kept going
    assert!(
        !server.session(h_a).failovers().is_empty(),
        "the kill never fired — the chaos scenario did not happen"
    );
    assert_eq!(server.session(h_a).world(), n - 1, "job A runs on the survivors");
    let recs = server.session(h_a).records();
    let first = recs.first().expect("rounds").train_loss;
    let last = recs.last().expect("rounds").train_loss;
    assert!(last < first, "job A stopped making progress after failover");

    // B never noticed: bitwise-identical to its solo run
    assert_eq!(
        server.params(h_b),
        &solo_b[..],
        "job B's bits changed when its mesh-sharing sibling lost a rank"
    );
    assert!(server.session(h_b).failovers().is_empty(), "job B saw a phantom failover");
}

#[test]
fn mux_endpoint_validation_is_typed() {
    // endpoints demand the Mux backend
    let mut mesh = MuxTransport::loopback_mesh(2, 1).expect("mesh");
    let err = job_builder(2, 64, 1)
        .backend(Backend::Channel { algo: ALGO })
        .mux_endpoints(mesh.remove(0))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("Backend::Mux"), "{err}");

    // endpoint count must match the world
    let mut mesh = MuxTransport::loopback_mesh(3, 1).expect("mesh");
    let err = job_builder(2, 64, 1)
        .backend(Backend::Mux { algo: ALGO })
        .mux_endpoints(mesh.remove(0))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("world"), "{err}");

    // endpoints must arrive rank-ordered
    let mut mesh = MuxTransport::loopback_mesh(2, 1).expect("mesh");
    let mut eps = mesh.remove(0);
    eps.swap(0, 1);
    let err = job_builder(2, 64, 1)
        .backend(Backend::Mux { algo: ALGO })
        .mux_endpoints(eps)
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("rank order"), "{err}");
}
