//! Telemetry integration: the golden Chrome-trace bytes, the Prometheus
//! exposition over a real scrape, and the span journal of a live
//! streamed session (per-block encode/wire overlap, the thing the trace
//! exists to show).

use std::io::{Read as _, Write as _};

use intsgd::api::{Backend, ModelSpec, Pipeline, Session, StagedAlgo};
use intsgd::coordinator::net_driver::quad_factories;
use intsgd::telemetry::{chrome, journal, registry, MetricsServer, Phase, SpanEvent, ALL};
use intsgd::util::json::Json;

fn span(
    phase: Phase,
    start_ns: u64,
    dur_ns: u64,
    round: u32,
    block: u16,
    rank: u16,
) -> SpanEvent {
    SpanEvent { start_ns, dur_ns, round, phase, block, rank }
}

/// A synthetic streamed round: encode b1 is posted while reduce b0 is on
/// the wire, so its span overlaps — the golden bytes pin exactly how the
/// exporter draws that.
fn streamed_round_fixture() -> Vec<SpanEvent> {
    vec![
        span(Phase::Round, 0, 12_000, 1, ALL, ALL),
        span(Phase::Encode, 500, 1_500, 1, 0, ALL),
        span(Phase::Reduce, 2_000, 3_000, 1, 0, ALL),
        span(Phase::Encode, 2_250, 1_750, 1, 1, ALL), // overlaps reduce b0
        span(Phase::Reduce, 2_500, 2_000, 1, 0, 0),
        span(Phase::Reduce, 2_600, 1_900, 1, 0, 1),
        span(Phase::Drain, 5_000, 400, 1, 0, ALL),
        span(Phase::Reduce, 5_500, 2_800, 1, 1, ALL),
        span(Phase::Drain, 8_400, 350, 1, 1, ALL),
        span(Phase::Decode, 9_000, 1_200, 1, ALL, ALL),
    ]
}

#[test]
fn chrome_trace_matches_golden_bytes() {
    let events = streamed_round_fixture();
    let rendered = chrome::render(&events);
    let golden = include_str!("golden/chrome_trace.json");
    assert_eq!(
        rendered, golden,
        "exporter output drifted from tests/golden/chrome_trace.json — if \
         the format change is intentional, regenerate the golden file"
    );
    // and the overlap the fixture encodes is real: encode b1 starts while
    // reduce b0 is still on the wire
    let enc1 = &events[3];
    let red0 = &events[2];
    assert!(enc1.start_ns > red0.start_ns);
    assert!(enc1.start_ns < red0.start_ns + red0.dur_ns);
}

/// The golden family list: every instrument registered in
/// `telemetry/registry.rs` must be named here, literally, and nothing
/// else may be registered. This is the anchor for intlint rule R6 — a
/// new instrument that is not added to this scrape test (and therefore
/// never verified over a real `/metrics` scrape) fails static analysis
/// before it fails in a dashboard.
const FAMILIES: [&str; 28] = [
    "intsgd_rounds_total",
    "intsgd_failovers_total",
    "intsgd_train_loss",
    "intsgd_alpha",
    "intsgd_alpha_min",
    "intsgd_clip_utilization",
    "intsgd_clip_saturated_rounds_total",
    "intsgd_wire_bytes_per_coord",
    "intsgd_wire_bytes_total",
    "intsgd_wire_lane_rounds_total",
    "intsgd_encode_seconds",
    "intsgd_reduce_seconds",
    "intsgd_decode_seconds",
    "intsgd_comm_measured_seconds",
    "intsgd_net_collectives_total",
    "intsgd_net_retries_total",
    "intsgd_net_timeouts_total",
    "intsgd_net_replays_total",
    "intsgd_net_corrupt_total",
    "intsgd_net_stale_frames_total",
    "intsgd_faults_injected_total",
    "intsgd_journal_events_total",
    "intsgd_journal_dropped_total",
    "intsgd_net_backpressure_events_total",
    "intsgd_mux_channels_active",
    "intsgd_mux_queue_depth",
    "intsgd_server_jobs_active",
    "intsgd_server_jobs_completed_total",
];

#[test]
fn registry_families_match_the_golden_list() {
    let registered: Vec<&str> = registry::all().iter().map(|d| d.name).collect();
    for name in FAMILIES {
        assert!(
            registered.contains(&name),
            "golden family {name} is no longer registered — update FAMILIES \
             (and DESIGN.md §12) if the removal is intentional"
        );
    }
    for name in &registered {
        assert!(
            FAMILIES.contains(name),
            "instrument {name} is registered but missing from the golden \
             FAMILIES list — add it here so the scrape test covers it \
             (intlint R6 enforces this statically)"
        );
    }
    assert_eq!(registered.len(), FAMILIES.len(), "duplicate registration");
}

#[test]
fn prometheus_scrape_serves_every_family_and_type() {
    let server = MetricsServer::bind("127.0.0.1:0").expect("bind :0");
    let mut conn = std::net::TcpStream::connect(server.addr()).expect("connect");
    conn.write_all(b"GET /metrics HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
    assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");

    let body = resp.split("\r\n\r\n").nth(1).expect("body");
    for def in registry::all() {
        assert!(
            body.contains(&format!("# HELP {} ", def.name)),
            "scrape is missing HELP for {}",
            def.name
        );
        assert!(
            body.contains(&format!("# TYPE {} ", def.name)),
            "scrape is missing TYPE for {}",
            def.name
        );
    }
    // spot-pin the type mapping the dashboards depend on
    assert!(body.contains("# TYPE intsgd_rounds_total counter"), "{body}");
    assert!(body.contains("# TYPE intsgd_train_loss gauge"), "{body}");
    assert!(body.contains("# TYPE intsgd_encode_seconds histogram"), "{body}");
    assert!(body.contains("# TYPE intsgd_wire_lane_rounds_total counter"), "{body}");
}

/// The one test that owns the process-global journal: a short streamed
/// multi-block run over in-proc channels must journal per-block encode /
/// reduce / drain spans, with the block-k+1 encode overlapping block-k's
/// wire span (the streamed pipeline's whole point).
#[test]
fn streamed_session_journals_per_block_overlap() {
    let trace = std::env::temp_dir()
        .join(format!("intsgd_telemetry_it_{}.json", std::process::id()));
    let n = 3;
    let d = 768;
    let mut session = Session::builder()
        .world(n)
        .model(ModelSpec::blocks(vec![256, 256, 256]))
        .sources(quad_factories(n, d, 7, 0.01))
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .pipeline(Pipeline::Streamed)
        .lr(0.2)
        .trace_path(trace.display().to_string())
        .build()
        .expect("build streamed channel session");
    journal::clear(); // build() enabled the journal; start from empty
    session.run(5).expect("run");
    session.write_trace().expect("write trace");

    let events = journal::snapshot();
    let blocks = |phase: Phase| -> Vec<u16> {
        let mut b: Vec<u16> = events
            .iter()
            .filter(|e| e.phase == phase && e.block != ALL)
            .map(|e| e.block)
            .collect();
        b.sort_unstable();
        b.dedup();
        b
    };
    // round 0 ships dense fp32 over the barrier path; the integer rounds
    // stream all three blocks through encode -> wire -> drain
    assert_eq!(blocks(Phase::Reduce), vec![0, 1, 2], "per-block reduce spans");
    assert_eq!(blocks(Phase::Drain), vec![0, 1, 2], "per-block drain spans");
    let enc = blocks(Phase::Encode);
    assert!(enc.contains(&1) && enc.contains(&2), "per-block encode spans: {enc:?}");

    // overlap: in some round, the encode span for block k+1 starts while
    // the leader-side reduce span for block k is still open
    let overlapping = events.iter().any(|e| {
        e.phase == Phase::Encode
            && e.block != ALL
            && e.block > 0
            && events.iter().any(|r| {
                r.phase == Phase::Reduce
                    && r.rank == ALL
                    && r.round == e.round
                    && r.block + 1 == e.block
                    && r.start_ns >= e.start_ns
                    && r.start_ns <= e.start_ns + e.dur_ns
            })
    });
    assert!(overlapping, "no encode-over-wire overlap span found");

    // the written trace is valid JSON and draws those same spans
    session.finish();
    let text = std::fs::read_to_string(&trace).expect("trace file");
    let doc = Json::parse(&text).expect("valid trace JSON");
    let evs = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    let has = |name: &str| {
        evs.iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some(name))
    };
    assert!(has("encode b1"), "trace should show per-block encode lanes");
    assert!(has("reduce b0"), "trace should show per-block wire lanes");
    let _ = std::fs::remove_file(&trace);

    // the run also fed the static registry through the coordinator
    use intsgd::telemetry::m;
    assert!(m::ROUNDS.get() >= 5, "rounds counter fed");
    assert!(m::BYTES_PER_COORD.get() > 0.0, "bytes-per-coordinate gauge fed");
}
