//! SIMD-vs-scalar kernel parity: every dispatched kernel in
//! `intsgd::simd` must reproduce the scalar spec (`intsgd::simd::scalar`)
//! **bit-for-bit** — integer kernels because integer arithmetic is exact,
//! float kernels because the backends use per-lane-identical IEEE ops and
//! a shared stripe association (DESIGN.md §10).
//!
//! The sweeps exercise exactly the shapes where a vector implementation
//! diverges from its spec if anything is off: d = 0 and d = 1, lengths
//! one below / at / one above every chunk width in play (4, 8, 16), odd
//! remainders, and *unaligned slice starts* (kernels take unaligned
//! loads; slicing a few elements off the front of a buffer must change
//! nothing).
//!
//! Without `--features simd`, the dispatched names re-export the scalar
//! spec, so this suite degenerates to `x == x` — it earns its keep under
//! the CI `simd` job, which runs it once with the vector backend live
//! and once with `INTSGD_FORCE_SCALAR=1`.

use intsgd::simd::{self, scalar};
use intsgd::util::Rng;

/// Lengths that straddle every chunk boundary the backends use (4/8/16
/// lanes per iteration, 64-coordinate scalar fused-fold chunks), plus
/// degenerate and large-odd shapes.
const LENS: &[usize] = &[
    0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129, 1000,
];

/// Slice-start offsets: 0 (aligned with the allocation) and small odd
/// cuts that guarantee misaligned vector loads.
const OFFS: &[usize] = &[0, 1, 3];

fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.uniform() as f32 - 0.5) * scale).collect()
}

fn i8_vec(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| (rng.below(255) as i64 - 127) as i8).collect()
}

#[test]
fn round_stoch_matches_scalar_bitwise() {
    let mut rng = Rng::new(0xA001);
    for &len in LENS {
        for &off in OFFS {
            let g = f32_vec(&mut rng, len + off, 4000.0);
            let g = &g[off..];
            let a = 0.37f32 + rng.uniform() as f32;
            let base = rng.next_u64();
            let j0 = rng.below(1 << 20);
            let mut want = vec![0.0f32; g.len()];
            let mut got = vec![0.0f32; g.len()];
            scalar::round_stoch(g, a, base, j0, &mut want);
            simd::round_stoch(g, a, base, j0, &mut got);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "len={len} off={off} backend={}",
                simd::backend_name()
            );
        }
    }
}

#[test]
fn round_stoch_counter_wraps_like_scalar() {
    // the counter stream must wrap mod 2^64 identically in both domains
    let mut rng = Rng::new(0xA00B);
    let g = f32_vec(&mut rng, 67, 100.0);
    for j0 in [u64::MAX - 100, u64::MAX - 8, u64::MAX - 1] {
        let mut want = vec![0.0f32; g.len()];
        let mut got = vec![0.0f32; g.len()];
        scalar::round_stoch(&g, 1.5, 42, j0, &mut want);
        simd::round_stoch(&g, 1.5, 42, j0, &mut got);
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "j0={j0}"
        );
    }
}

#[test]
fn round_determ_matches_scalar_bitwise() {
    let mut rng = Rng::new(0xA002);
    for &len in LENS {
        for &off in OFFS {
            let g = f32_vec(&mut rng, len + off, 4000.0);
            let g = &g[off..];
            let a = 0.11f32 + rng.uniform() as f32;
            let mut want = vec![0.0f32; g.len()];
            let mut got = vec![0.0f32; g.len()];
            scalar::round_determ(g, a, &mut want);
            simd::round_determ(g, a, &mut got);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "len={len} off={off}"
            );
        }
    }
}

#[test]
fn round_determ_ties_go_to_even() {
    // halfway cases are where round-ties-even implementations diverge:
    // every t = k + 0.5 must land on the even neighbour in all backends
    let g: Vec<f32> = (-16..16).map(|k| k as f32 + 0.5).collect();
    let mut want = vec![0.0f32; g.len()];
    let mut got = vec![0.0f32; g.len()];
    scalar::round_determ(&g, 1.0, &mut want);
    simd::round_determ(&g, 1.0, &mut got);
    assert_eq!(want, got);
    assert_eq!(want[16], 0.0); // 0.5 -> 0
    assert_eq!(want[17], 2.0); // 1.5 -> 2
}

#[test]
fn widening_adds_match_scalar() {
    let mut rng = Rng::new(0xA003);
    for &len in LENS {
        for &off in OFFS {
            let src8 = i8_vec(&mut rng, len + off);
            let src8 = &src8[off..];
            let src32: Vec<i32> = (0..len).map(|_| rng.next_u64() as i32).collect();
            let src64: Vec<i64> = (0..len).map(|_| rng.next_u64() as i64 >> 8).collect();
            let seed: Vec<i64> = (0..len).map(|_| rng.next_u64() as i64 >> 32).collect();

            let mut want = seed.clone();
            let mut got = seed.clone();
            scalar::add_widen_i8(src8, &mut want);
            simd::add_widen_i8(src8, &mut got);
            assert_eq!(want, got, "i8 len={len} off={off}");

            let mut want = seed.clone();
            let mut got = seed.clone();
            scalar::add_widen_i32(&src32, &mut want);
            simd::add_widen_i32(&src32, &mut got);
            assert_eq!(want, got, "i32 len={len}");

            let mut want = seed.clone();
            let mut got = seed.clone();
            scalar::add_i64(&src64, &mut want);
            simd::add_i64(&src64, &mut got);
            assert_eq!(want, got, "i64 len={len}");

            let mut want = vec![0i64; len];
            let mut got = vec![0i64; len];
            scalar::copy_widen_i8(src8, &mut want);
            simd::copy_widen_i8(src8, &mut got);
            assert_eq!(want, got, "copy len={len} off={off}");
        }
    }
}

#[test]
fn sum_ranks_matches_rank_at_a_time_fold() {
    let mut rng = Rng::new(0xA004);
    for &len in LENS {
        for n in [1usize, 2, 3, 16, 127] {
            let msgs: Vec<Vec<i8>> = (0..n).map(|_| i8_vec(&mut rng, len)).collect();
            let views: Vec<&[i8]> = msgs.iter().map(|m| m.as_slice()).collect();
            let mut want = vec![0i64; len];
            for m in &msgs {
                scalar::add_widen_i8(m, &mut want);
            }
            let mut got_scalar = vec![0i64; len];
            scalar::sum_ranks_i8(&views, &mut got_scalar);
            assert_eq!(want, got_scalar, "scalar fused len={len} n={n}");
            let mut got = vec![0i64; len];
            simd::sum_ranks_i8(&views, &mut got);
            assert_eq!(want, got, "dispatched fused len={len} n={n}");
        }
    }
}

#[test]
fn sum_ranks_survives_the_i16_bound_edge() {
    // 128 ranks, every lane at +-127: the cross-rank partial sum hits
    // +-16256, just inside i16 — the widening-bound proof's worst case
    for v in [127i8, -127] {
        let msgs: Vec<Vec<i8>> = (0..simd::SUM_RANKS_MAX).map(|_| vec![v; 50]).collect();
        let views: Vec<&[i8]> = msgs.iter().map(|m| m.as_slice()).collect();
        let mut got = vec![0i64; 50];
        simd::sum_ranks_i8(&views, &mut got);
        assert!(got.iter().all(|&s| s == 128 * v as i64));
    }
}

#[test]
fn decode_matches_scalar_bitwise() {
    let mut rng = Rng::new(0xA005);
    for &len in LENS {
        for &off in OFFS {
            let sum: Vec<i64> = (0..len + off)
                .map(|_| (rng.next_u64() as i64) >> 40) // |s| < 2^24: typical aggregates
                .collect();
            let sum = &sum[off..];
            let inv = 1.0 / (16.0 * (0.01 + rng.uniform()));
            let mut want = vec![0.0f32; sum.len()];
            let mut got = vec![0.0f32; sum.len()];
            scalar::decode_scale_i64(sum, inv, &mut want);
            simd::decode_scale_i64(sum, inv, &mut got);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "len={len} off={off}"
            );
        }
    }
}

#[test]
fn decode_handles_out_of_trick_range_sums() {
    // the AVX2 backend converts via the 2^52 exponent trick, valid only
    // for |x| < 2^51 — these values straddle its guard, including the
    // extremes the guard must catch
    let sum: Vec<i64> = vec![
        0,
        1,
        -1,
        (1 << 51) - 1,
        1 << 51,
        -(1 << 51),
        (1 << 51) + 1,
        i64::MAX,
        i64::MIN,
        i64::MIN + 1,
        (1 << 62) + 12345,
        -(1 << 62) - 12345,
    ];
    for inv in [1.0, 1.0 / 3.0, 1e-9] {
        let mut want = vec![0.0f32; sum.len()];
        let mut got = vec![0.0f32; sum.len()];
        scalar::decode_scale_i64(&sum, inv, &mut want);
        simd::decode_scale_i64(&sum, inv, &mut got);
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "inv={inv}"
        );
    }
}

#[test]
fn norm_folds_match_scalar_bitwise() {
    let mut rng = Rng::new(0xA006);
    for &len in LENS {
        for &off in OFFS {
            let a = f32_vec(&mut rng, len + off, 3.0);
            let b = f32_vec(&mut rng, len + off, 3.0);
            let (a, b) = (&a[off..], &b[off..]);
            assert_eq!(
                scalar::sq_norm(a).to_bits(),
                simd::sq_norm(a).to_bits(),
                "sq_norm len={len} off={off}"
            );
            assert_eq!(
                scalar::sq_diff_norm(a, b).to_bits(),
                simd::sq_diff_norm(a, b).to_bits(),
                "sq_diff_norm len={len} off={off}"
            );
        }
    }
}

#[test]
fn max_abs_matches_scalar_including_type_extremes() {
    let mut rng = Rng::new(0xA007);
    for &len in LENS {
        let mut v8 = i8_vec(&mut rng, len);
        let mut v32: Vec<i32> = (0..len).map(|_| rng.next_u64() as i32).collect();
        // i64::MIN excluded: scalar saturates there by documented
        // contract, pinned separately below
        let mut v64: Vec<i64> = (0..len)
            .map(|_| (rng.next_u64() as i64).max(i64::MIN + 1))
            .collect();
        if len > 2 {
            v8[len / 2] = i8::MIN; // |MIN| = 128 must be exact
            v32[len / 2] = i32::MIN;
            v64[len / 2] = i64::MIN + 1;
        }
        assert_eq!(scalar::max_abs_i8(&v8), simd::max_abs_i8(&v8), "i8 len={len}");
        assert_eq!(scalar::max_abs_i32(&v32), simd::max_abs_i32(&v32), "i32 len={len}");
        assert_eq!(scalar::max_abs_i64(&v64), simd::max_abs_i64(&v64), "i64 len={len}");
    }
}

#[test]
fn max_abs_i64_saturates_at_min() {
    let v = vec![5i64, i64::MIN, -7];
    assert_eq!(scalar::max_abs_i64(&v), i64::MAX);
    assert_eq!(simd::max_abs_i64(&v), i64::MAX);
}

#[test]
fn backend_name_is_coherent_with_feature_state() {
    let name = simd::backend_name();
    if cfg!(feature = "simd") {
        // forced-scalar override or a real vector backend — both valid
        assert!(["scalar", "sse2", "avx2", "neon"].contains(&name), "{name}");
        let forced = std::env::var(simd::FORCE_SCALAR_ENV)
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if forced {
            assert_eq!(name, "scalar");
        }
    } else {
        assert_eq!(name, "scalar");
    }
}
