//! Integration tests across the python/rust boundary: every AOT artifact
//! is executed through PJRT and cross-checked against the rust mirrors.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use intsgd::compress::intsgd::{IntSgd, Rounding};
use intsgd::data::{synth_dataset, DATASETS};
use intsgd::models::{LogReg, SparseMatrix};
use intsgd::runtime::{init_params, lit_f32, Runtime};
use intsgd::util::stats::l2_norm;
use intsgd::util::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn quantize_stoch_artifact_matches_rust_mirror() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt.meta("quantize_stoch_classifier").unwrap().clone();
    let d = meta.grad_dim;
    let mut rng = Rng::new(0);
    let g = rng.normal_vec(d, 1.0);
    let u = rng.uniform_vec(d);
    let alpha = 37.5f32;
    let clip = 7.0f32;

    let exe = rt.load("quantize_stoch_classifier").unwrap();
    let outs = exe
        .run(&[
            lit_f32(&g, &[d]).unwrap(),
            lit_f32(&u, &[d]).unwrap(),
            lit_f32(&[alpha], &[1]).unwrap(),
            lit_f32(&[clip], &[1]).unwrap(),
        ])
        .unwrap();
    let kernel_out = outs[0].to_vec::<f32>().unwrap();

    // rust mirror of the same math: clip(floor(alpha*g + u))
    for j in 0..d {
        let expect = ((g[j] * alpha + u[j]).floor()).clamp(-clip, clip);
        assert_eq!(
            kernel_out[j], expect,
            "coord {j}: kernel {} vs rust {expect} (g={}, u={})",
            kernel_out[j], g[j], u[j]
        );
    }
}

#[test]
fn quantize_determ_artifact_matches_rust_encode() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt.meta("quantize_determ_classifier").unwrap().clone();
    let d = meta.grad_dim;
    let mut rng = Rng::new(1);
    let g = rng.normal_vec(d, 2.0);
    let alpha = 12.25f64;
    let clip = 127i64;

    let exe = rt.load("quantize_determ_classifier").unwrap();
    let outs = exe
        .run(&[
            lit_f32(&g, &[d]).unwrap(),
            lit_f32(&[alpha as f32], &[1]).unwrap(),
            lit_f32(&[clip as f32], &[1]).unwrap(),
        ])
        .unwrap();
    let kernel_out = outs[0].to_vec::<f32>().unwrap();

    let mut ints = Vec::new();
    let mut dummy = Rng::new(0);
    IntSgd::encode(Rounding::Deterministic, &g, alpha, clip, &mut dummy, &mut ints);
    let mut mismatches = 0;
    for j in 0..d {
        if kernel_out[j] as i64 != ints[j] {
            mismatches += 1;
        }
    }
    // f32-vs-f64 scaling may flip exact .5 ties on a handful of coords
    assert!(
        mismatches * 100_000 < d,
        "{mismatches}/{d} mismatches between kernel and rust mirror"
    );
}

#[test]
fn dequant_artifact_applies_update() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt.meta("dequant_classifier_n16").unwrap().clone();
    let d = meta.grad_dim;
    let mut rng = Rng::new(2);
    let x = rng.normal_vec(d, 1.0);
    let s: Vec<f32> = (0..d).map(|_| (rng.below(255) as i64 - 127) as f32).collect();
    let alpha = 3.0f32;
    let lr = 0.05f32;

    let exe = rt.load("dequant_classifier_n16").unwrap();
    let outs = exe
        .run(&[
            lit_f32(&x, &[d]).unwrap(),
            lit_f32(&s, &[d]).unwrap(),
            lit_f32(&[alpha], &[1]).unwrap(),
            lit_f32(&[lr], &[1]).unwrap(),
        ])
        .unwrap();
    let got = outs[0].to_vec::<f32>().unwrap();
    for j in (0..d).step_by(997) {
        let expect = x[j] - lr * s[j] / (16.0 * alpha);
        assert!(
            (got[j] - expect).abs() < 1e-5 * expect.abs().max(1.0),
            "coord {j}: {} vs {expect}",
            got[j]
        );
    }
}

#[test]
fn logreg_grad_artifact_matches_rust_model() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = &DATASETS[0]; // a5a
    let meta = rt.meta("logreg_grad_a5a").unwrap().clone();
    let d = spec.dim;
    let tau = meta.extra_usize("minibatch").unwrap();
    let lam = spec.lambda2 as f32;

    // dense random minibatch
    let mut rng = Rng::new(3);
    let rows: Vec<Vec<f32>> = (0..tau).map(|_| rng.normal_vec(d, 1.0)).collect();
    let b: Vec<f32> = (0..tau)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    let x = rng.normal_vec(d, 0.3);

    // PJRT
    let a_flat: Vec<f32> = rows.concat();
    let exe = rt.load("logreg_grad_a5a").unwrap();
    let outs = exe
        .run(&[
            lit_f32(&x, &[d]).unwrap(),
            lit_f32(&a_flat, &[tau, d]).unwrap(),
            lit_f32(&b, &[tau]).unwrap(),
            lit_f32(&[lam], &[1]).unwrap(),
        ])
        .unwrap();
    let pjrt_grad = outs[0].to_vec::<f32>().unwrap();

    // rust model on the same data
    let model = LogReg {
        a: SparseMatrix::from_dense(&rows, d),
        b,
        lambda: lam as f64,
    };
    let rust_grad = model.grad(&x);

    let scale = l2_norm(&rust_grad).max(1e-9);
    for j in 0..d {
        assert!(
            ((pjrt_grad[j] - rust_grad[j]) as f64).abs() < 1e-4 * scale,
            "coord {j}: pjrt {} vs rust {}",
            pjrt_grad[j],
            rust_grad[j]
        );
    }
}

#[test]
fn logreg_loss_artifact_matches_rust_model() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = &DATASETS[1]; // mushrooms
    let meta = rt.meta("logreg_loss_mushrooms").unwrap().clone();
    let d = spec.dim;
    let tau = meta
        .extra
        .get("inputs")
        .and_then(|i| i.as_arr())
        .and_then(|a| a[1].get("shape"))
        .and_then(|s| s.as_arr())
        .and_then(|s| s[0].as_usize())
        .unwrap();
    let mut rng = Rng::new(4);
    let rows: Vec<Vec<f32>> = (0..tau).map(|_| rng.normal_vec(d, 1.0)).collect();
    let b: Vec<f32> = (0..tau)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    let x = rng.normal_vec(d, 0.3);
    let lam = spec.lambda2 as f32;

    let exe = rt.load("logreg_loss_mushrooms").unwrap();
    let outs = exe
        .run(&[
            lit_f32(&x, &[d]).unwrap(),
            lit_f32(&rows.concat(), &[tau, d]).unwrap(),
            lit_f32(&b, &[tau]).unwrap(),
            lit_f32(&[lam], &[1]).unwrap(),
        ])
        .unwrap();
    let pjrt_loss = outs[0].get_first_element::<f32>().unwrap() as f64;

    let model = LogReg { a: SparseMatrix::from_dense(&rows, d), b, lambda: lam as f64 };
    let rust_loss = model.loss(&x);
    assert!(
        (pjrt_loss - rust_loss).abs() < 1e-4 * rust_loss.max(1.0),
        "pjrt {pjrt_loss} vs rust {rust_loss}"
    );
}

#[test]
fn synth_dataset_runs_through_pjrt_grad() {
    // the synthetic a5a stand-in, densified, flows through the artifact
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = &DATASETS[0];
    let ds = synth_dataset(spec, 5);
    let meta = rt.meta("logreg_grad_a5a").unwrap().clone();
    let tau = meta.extra_usize("minibatch").unwrap();
    let d = spec.dim;

    // densify the first tau rows
    let mut a_flat = vec![0.0f32; tau * d];
    for r in 0..tau {
        let (lo, hi) = (ds.a.indptr[r], ds.a.indptr[r + 1]);
        for k in lo..hi {
            a_flat[r * d + ds.a.indices[k] as usize] = ds.a.values[k];
        }
    }
    let x = vec![0.01f32; d];
    let exe = rt.load("logreg_grad_a5a").unwrap();
    let outs = exe
        .run(&[
            lit_f32(&x, &[d]).unwrap(),
            lit_f32(&a_flat, &[tau, d]).unwrap(),
            lit_f32(&ds.b[..tau], &[tau]).unwrap(),
            lit_f32(&[spec.lambda2 as f32], &[1]).unwrap(),
        ])
        .unwrap();
    let g = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(g.len(), d);
    assert!(g.iter().all(|v| v.is_finite()));
    assert!(l2_norm(&g) > 0.0);
}
