//! Chaos suite: failure is a first-class, tested scenario.
//!
//! The invariants pinned here are the ones IntSGD's convergence proof
//! actually needs (ISSUE 4):
//!
//! 1. **Chaos parity** — end-to-end training over a `FaultTransport`
//!    injecting seeded recoverable faults (drop / duplicate / corrupt /
//!    truncate / delay) is **bitwise-identical** to the fault-free run:
//!    the reducer retries failed collectives from the unchanged rank
//!    messages, and integer collectives are exact, so a retried round IS
//!    the unfaulted round.
//! 2. **Survivor-world parity** — when a rank dies for good, the world
//!    shrinks and training continues; from the failover round on, the
//!    run is bitwise-identical to a fresh run at the smaller n started
//!    from the failover state (alpha-rule round idempotence + the dead
//!    rank leaving the average).
//! 3. **Bit-exact resume** — a v2 checkpoint (params, previous params,
//!    scaling-rule moving average, EF residuals, encoder RNG streams)
//!    restores a run that is bitwise-equal to never having stopped —
//!    including the *stochastic* rounding stream and EF-SignSGD's
//!    residual memory, both of which checkpoint v1 silently dropped.
//!
//! Everything runs over `ChannelTransport` (tier-1: no sockets, fully
//! deterministic); `tests/net_loopback.rs` covers the TCP kill/timeout
//! side.

use std::time::Duration;

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::{PhasedCompressor, Pipeline, RoundEngine, SignSgd};
use intsgd::coordinator::net_driver::quad_pool;
use intsgd::coordinator::{Coordinator, LrSchedule, TrainConfig, TrainResult};
use intsgd::net::{
    ChannelTransport, FaultPlan, FaultTransport, KillAt, StagedAlgo, TransportReducer,
};
use intsgd::netsim::Network;
use intsgd::scaling::MovingAverageRule;

fn intsgd_engine(rounding: Rounding, n: usize, seed: u64) -> RoundEngine {
    RoundEngine::new(Box::new(IntSgd::new(
        rounding,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        seed,
    )))
}

fn cfg(rounds: usize, start_round: usize, lr: f32) -> TrainConfig {
    TrainConfig {
        rounds,
        start_round,
        schedule: LrSchedule::constant(lr),
        ..Default::default()
    }
}

/// Bitwise comparison of two runs' record streams + final params.
fn assert_runs_identical(a: &TrainResult, b: &TrainResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: round counts differ");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round, "{label}");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{label}: loss differs at round {}",
            ra.round
        );
        assert_eq!(
            ra.alpha.to_bits(),
            rb.alpha.to_bits(),
            "{label}: alpha differs at round {}",
            ra.round
        );
        assert_eq!(
            ra.max_abs_int, rb.max_abs_int,
            "{label}: max_abs_int differs at round {}",
            ra.round
        );
        assert_eq!(
            ra.wire_bytes_per_worker, rb.wire_bytes_per_worker,
            "{label}: wire bytes differ at round {}",
            ra.round
        );
    }
    assert_eq!(a.final_params, b.final_params, "{label}: final params diverge");
}

// --- 1. chaos parity -------------------------------------------------------

#[test]
fn chaos_training_under_recoverable_faults_is_bitwise_identical() {
    let n = 3;
    let d = 256;
    let rounds = 12;
    let seed = 500;

    // reference: clean channel fabric
    let mut pool_a = quad_pool(n, d, seed, 0.01);
    let mut coord_a = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_a = intsgd_engine(Rounding::Stochastic, n, 71);
    let mut red_a = TransportReducer::channel_mesh(n, StagedAlgo::Ring);
    let res_a =
        coord_a.train_over(&mut pool_a, &mut engine_a, &mut red_a, &cfg(rounds, 0, 0.3), None);
    pool_a.shutdown();
    assert_eq!(red_a.retries(), 0, "clean fabric must not retry");

    // chaos: the same job over a seeded fault injector
    let mut plan = FaultPlan::clean(0xC0FFEE);
    plan.drop_p = 0.015;
    plan.dup_p = 0.02;
    plan.corrupt_p = 0.03;
    plan.truncate_p = 0.015;
    plan.delay_p = 0.01;
    let mesh = FaultTransport::wrap_mesh(ChannelTransport::mesh(n), &plan, None);
    let mut red_b = TransportReducer::new(mesh, StagedAlgo::Ring);
    red_b.set_timeout(Duration::from_millis(250));
    red_b.set_max_retries(64);
    let mut pool_b = quad_pool(n, d, seed, 0.01);
    let mut coord_b = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_b = intsgd_engine(Rounding::Stochastic, n, 71);
    let res_b =
        coord_b.train_over(&mut pool_b, &mut engine_b, &mut red_b, &cfg(rounds, 0, 0.3), None);
    pool_b.shutdown();

    // the fault plan actually fired, and retry erased every trace of it
    assert!(red_b.retries() > 0, "no fault ever fired — weaken the plan's seed");
    assert!(red_b.stale_skipped() > 0 || red_b.retries() > 0);
    assert!(res_b.failovers.is_empty(), "recoverable faults must not shrink the world");
    assert_runs_identical(&res_a, &res_b, "chaos parity");
}

#[test]
fn chaos_streamed_training_under_recoverable_faults_is_bitwise_identical() {
    // The streamed pipeline under seeded recoverable faults: a per-block
    // collective that faults retries from the unchanged block slots (the
    // encoders for block k+1 keep running meanwhile), so the whole run
    // must land on the clean *barrier* run's exact bits — fault recovery
    // and the pipeline are both invisible in the output.
    let n = 3;
    let d = 256; // two blocks of 128: a real multi-block pipeline
    let rounds = 12;
    let seed = 520;
    let dims = vec![128usize, 128];

    let mut pool_a = quad_pool(n, d, seed, 0.01);
    let mut coord_a =
        Coordinator::new(vec![0.0; d], dims.clone(), Network::paper_cluster());
    let mut engine_a = intsgd_engine(Rounding::Stochastic, n, 73);
    let mut red_a = TransportReducer::channel_mesh(n, StagedAlgo::Ring);
    let res_a =
        coord_a.train_over(&mut pool_a, &mut engine_a, &mut red_a, &cfg(rounds, 0, 0.3), None);
    pool_a.shutdown();

    let mut plan = FaultPlan::clean(0x57EA3);
    plan.drop_p = 0.015;
    plan.dup_p = 0.02;
    plan.corrupt_p = 0.03;
    plan.truncate_p = 0.015;
    let mesh = FaultTransport::wrap_mesh(ChannelTransport::mesh(n), &plan, None);
    let mut red_b = TransportReducer::new(mesh, StagedAlgo::Ring);
    red_b.set_timeout(Duration::from_millis(250));
    red_b.set_max_retries(64);
    let mut pool_b = quad_pool(n, d, seed, 0.01);
    let mut coord_b = Coordinator::new(vec![0.0; d], dims, Network::paper_cluster());
    let mut engine_b = intsgd_engine(Rounding::Stochastic, n, 73);
    let mut streamed_cfg = cfg(rounds, 0, 0.3);
    streamed_cfg.pipeline = Pipeline::Streamed;
    let res_b =
        coord_b.train_over(&mut pool_b, &mut engine_b, &mut red_b, &streamed_cfg, None);
    pool_b.shutdown();

    assert!(red_b.retries() > 0, "no fault ever fired — weaken the plan's seed");
    assert!(res_b.failovers.is_empty(), "recoverable faults must not shrink the world");
    // the pipeline really ran per-block: one collective per block per
    // integer round, vs one per round on the barrier path
    assert_eq!(red_b.calls(), 2 * red_a.calls(), "streamed must reduce per block");
    assert_runs_identical(&res_a, &res_b, "streamed chaos parity");
}

#[test]
fn chaos_streamed_failover_matches_barrier_failover_bitwise() {
    // A rank dies while the pipeline is in flight: the driver must drain
    // the posted encode, park the encoders, and surface the PeerDead so
    // the coordinator fails over — landing on the exact bits of the
    // barrier run killed at the same training round.
    let n = 4;
    let d = 128; // two blocks of 64
    let rounds = 8;
    let seed = 650;
    let lr = 0.3;

    // Collective-id bookkeeping for the kill: round 0 is dense (no
    // collective); the barrier path pays one collective per integer round
    // (training round r -> id r-1), the streamed path one per block
    // (round r -> ids 2(r-1), 2(r-1)+1). Both kills below land in
    // training round 4 — the streamed one during block 0, with block 1's
    // encode already posted.
    let run = |pipeline: Pipeline, kill_id: u32| {
        let mesh = FaultTransport::wrap_mesh(
            ChannelTransport::mesh(n),
            &FaultPlan::clean(7),
            Some((3, KillAt::Round(kill_id))),
        );
        let mut red = TransportReducer::new(mesh, StagedAlgo::Ring);
        red.set_timeout(Duration::from_millis(400));
        let mut pool = quad_pool(n, d, seed, 0.0);
        let mut coord =
            Coordinator::new(vec![0.0; d], vec![64, 64], Network::paper_cluster());
        let mut engine = intsgd_engine(Rounding::Stochastic, n, 83);
        let mut c = cfg(rounds, 0, lr);
        c.pipeline = pipeline;
        let res = coord.train_over(&mut pool, &mut engine, &mut red, &c, None);
        pool.shutdown();
        res
    };
    let barrier = run(Pipeline::Barrier, 3);
    let streamed = run(Pipeline::Streamed, 6);
    assert_eq!(barrier.failovers, vec![(4, 3)]);
    assert_eq!(streamed.failovers, vec![(4, 3)]);
    assert_runs_identical(&barrier, &streamed, "streamed failover parity");
}

/// Seeded fault matrix at the collective level: across a grid of world
/// sizes and fault mixes, the retried staged reduce always lands on the
/// serial fold's exact bits.
#[test]
fn chaos_fault_matrix_reduces_to_the_exact_sum() {
    use intsgd::compress::engine::{Message, PassPlan, RankEncoder, RankMessages};
    use intsgd::compress::engine::{Reducer, SerialReducer};
    use intsgd::compress::intvec::{IntVec, Lanes};
    use intsgd::util::Rng;

    struct Fixed {
        msg: Message,
    }
    impl RankEncoder for Fixed {
        fn encode(&mut self, _grad: &[f32], _plan: &PassPlan) {}
        fn message(&self) -> &Message {
            &self.msg
        }
    }

    for (case, &(n, drop, dup, corrupt, truncate, delay)) in [
        (2usize, 0.04, 0.0, 0.0, 0.0, 0.0), // pure drops
        (3, 0.0, 0.05, 0.0, 0.0, 0.0),      // pure duplicates
        (4, 0.0, 0.0, 0.04, 0.0, 0.0),      // pure corruption
        (3, 0.0, 0.0, 0.0, 0.05, 0.0),      // pure truncation
        (3, 0.0, 0.0, 0.0, 0.0, 0.05),      // pure delays (reorders)
        (4, 0.01, 0.01, 0.01, 0.01, 0.01),  // everything at once
    ]
    .iter()
    .enumerate()
    {
        let d = 200;
        let mut rng = Rng::new(900 + case as u64);
        let encs: Vec<Box<dyn RankEncoder>> = (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(21) as i64 - 10).collect();
                Box::new(Fixed { msg: Message::Ints(IntVec::from_i64(&vals, Lanes::I8)) })
                    as Box<dyn RankEncoder>
            })
            .collect();
        let msgs = RankMessages::new(&encs);
        let mut want = Vec::new();
        SerialReducer.sum_ints(&msgs, &mut want).unwrap();

        let plan = FaultPlan {
            seed: 4242 + case as u64,
            drop_p: drop,
            dup_p: dup,
            corrupt_p: corrupt,
            truncate_p: truncate,
            delay_p: delay,
        };
        let mesh = FaultTransport::wrap_mesh(ChannelTransport::mesh(n), &plan, None);
        let mut red = TransportReducer::new(mesh, StagedAlgo::Ring);
        red.set_timeout(Duration::from_millis(250));
        red.set_max_retries(64);
        let mut got = Vec::new();
        for round in 0..4 {
            red.sum_ints(&msgs, &mut got)
                .unwrap_or_else(|e| panic!("case {case} round {round}: {e}"));
            assert_eq!(got, want, "case {case} round {round}: wrong bits");
        }
    }
}

// --- 2. survivor-world parity ----------------------------------------------

#[test]
fn chaos_failover_matches_a_fresh_run_at_the_smaller_world() {
    let n = 4;
    let d = 128;
    let rounds = 10;
    let kill_training_round = 5; // collective id 4 (round 0 is dense)
    let seed = 600;
    let lr = 0.3;

    // Run A: rank 3 (the last — survivors keep their oracle seeds) dies
    // mid-collective in training round 5; the world shrinks to 3 and the
    // run finishes. Stochastic rounding on purpose: the failover
    // re-encode reuses the round-keyed counter base, so even the random
    // integer streams must line up with the fresh smaller-world run.
    let mesh = FaultTransport::wrap_mesh(
        ChannelTransport::mesh(n),
        &FaultPlan::clean(7),
        Some((3, KillAt::Round(kill_training_round as u32 - 1))),
    );
    let mut red_a = TransportReducer::new(mesh, StagedAlgo::Ring);
    red_a.set_timeout(Duration::from_millis(400));
    let mut pool_a = quad_pool(n, d, seed, 0.0);
    let mut coord_a = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_a = intsgd_engine(Rounding::Stochastic, n, 81);
    let res_a =
        coord_a.train_over(&mut pool_a, &mut engine_a, &mut red_a, &cfg(rounds, 0, lr), None);
    pool_a.shutdown();
    assert_eq!(res_a.failovers, vec![(kill_training_round, 3)]);
    assert_eq!(red_a.world(), n - 1);
    assert_eq!(res_a.records.len(), rounds);

    // Reference prefix: the clean n=4 run up to the failover round is
    // bit-identical to run A's (the fault fires only in round 5), and its
    // snapshot is the state run A failed over FROM.
    let mut pool_p = quad_pool(n, d, seed, 0.0);
    let mut coord_p = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_p = intsgd_engine(Rounding::Stochastic, n, 81);
    let res_p = coord_p.train_over(
        &mut pool_p,
        &mut engine_p,
        &mut TransportReducer::channel_mesh(n, StagedAlgo::Ring),
        &cfg(kill_training_round, 0, lr),
        None,
    );
    pool_p.shutdown();
    for (ra, rp) in res_a.records.iter().zip(&res_p.records) {
        assert_eq!(ra.train_loss.to_bits(), rp.train_loss.to_bits(), "prefix diverges");
    }
    let mut ck = coord_p
        .snapshot(&mut engine_p, kill_training_round as u64)
        .expect("snapshot");
    // the dead rank's per-rank state dies with it: keep the survivors'
    ck.rng_streams.truncate(n - 1);

    // Run B: a fresh 3-rank world resumed from the failover state.
    let mut pool_b = quad_pool(n - 1, d, seed, 0.0);
    let mut coord_b = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_b = intsgd_engine(Rounding::Stochastic, n - 1, 81);
    coord_b
        .restore(&mut engine_b, n - 1, &ck)
        .expect("restore into the survivor world");
    let res_b = coord_b.train_over(
        &mut pool_b,
        &mut engine_b,
        &mut TransportReducer::channel_mesh(n - 1, StagedAlgo::Ring),
        &cfg(rounds, kill_training_round, lr),
        None,
    );
    pool_b.shutdown();

    // from the failover round on, run A IS the fresh smaller-world run
    assert_eq!(res_b.records.len(), rounds - kill_training_round);
    for (ra, rb) in res_a.records[kill_training_round..].iter().zip(&res_b.records) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "survivor parity: loss differs at round {}",
            ra.round
        );
        assert_eq!(
            ra.alpha.to_bits(),
            rb.alpha.to_bits(),
            "survivor parity: alpha differs at round {}",
            ra.round
        );
        assert_eq!(ra.max_abs_int, rb.max_abs_int, "round {}", ra.round);
    }
    assert_eq!(res_a.final_params, res_b.final_params, "survivor worlds diverge");
}

// --- 3. bit-exact resume ----------------------------------------------------

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("intsgd_chaos_{name}_{}", std::process::id()))
}

#[test]
fn chaos_v2_resume_is_bitwise_equal_to_an_uninterrupted_run() {
    // stochastic IntSGD: the hardest case — the alpha rule's moving
    // average AND the per-rank rounding streams must both survive the
    // save/load cycle for the bits to line up
    let n = 3;
    let d = 96;
    let rounds = 12;
    let stop = 6;
    let seed = 700;

    let run = |upto: usize, from: usize, coord: &mut Coordinator, engine: &mut RoundEngine| {
        let mut pool = quad_pool(n, d, seed, 0.0);
        let res = coord.train(&mut pool, engine, &cfg(upto, from, 0.25), None);
        pool.shutdown();
        res
    };

    // A: straight through
    let mut coord_a = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_a = intsgd_engine(Rounding::Stochastic, n, 91);
    let res_a = run(rounds, 0, &mut coord_a, &mut engine_a);

    // B: stop at `stop`, checkpoint THROUGH DISK, resume in fresh objects
    let mut coord_b = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_b = intsgd_engine(Rounding::Stochastic, n, 91);
    let res_b1 = run(stop, 0, &mut coord_b, &mut engine_b);
    let path = tmp("resume");
    coord_b
        .snapshot(&mut engine_b, stop as u64)
        .expect("snapshot")
        .save(&path)
        .expect("save");
    let ck = intsgd::runtime::Checkpoint::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    assert_eq!(ck.round, stop as u64);
    assert!(ck.prev_flat.is_some() && ck.rule_state.is_some());
    assert_eq!(ck.rng_streams.len(), n, "one rounding stream per rank");

    let mut coord_c = Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
    let mut engine_c = intsgd_engine(Rounding::Stochastic, n, 12345); // seed must not matter
    coord_c.restore(&mut engine_c, n, &ck).expect("restore");
    let res_b2 = run(rounds, stop, &mut coord_c, &mut engine_c);

    // stitched B == A, bit for bit
    assert_eq!(res_b1.records.len() + res_b2.records.len(), res_a.records.len());
    for (ra, rb) in res_a
        .records
        .iter()
        .zip(res_b1.records.iter().chain(&res_b2.records))
    {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "resume parity: loss differs at round {}",
            ra.round
        );
        assert_eq!(
            ra.alpha.to_bits(),
            rb.alpha.to_bits(),
            "resume parity: alpha differs at round {} (rule state lost?)",
            ra.round
        );
        assert_eq!(
            ra.max_abs_int, rb.max_abs_int,
            "resume parity: integers differ at round {} (RNG stream lost?)",
            ra.round
        );
    }
    assert_eq!(res_a.final_params, res_b2.final_params, "resumed run diverges");
}

#[test]
fn chaos_v2_resume_restores_error_feedback_residuals() {
    // EF-SignSGD: without the residual section, the resumed run re-starts
    // EF from zero and silently diverges from the uninterrupted one
    let n = 2;
    let d = 64;
    let rounds = 10;
    let stop = 5;
    let seed = 800;

    let mk_engine = || RoundEngine::new(Box::new(SignSgd::new(n)) as Box<dyn PhasedCompressor>);
    let run = |upto: usize, from: usize, coord: &mut Coordinator, engine: &mut RoundEngine| {
        let mut pool = quad_pool(n, d, seed, 0.0);
        let res = coord.train(&mut pool, engine, &cfg(upto, from, 0.2), None);
        pool.shutdown();
        res
    };

    let mut coord_a = Coordinator::new(vec![0.1; d], vec![d], Network::paper_cluster());
    let mut engine_a = mk_engine();
    let res_a = run(rounds, 0, &mut coord_a, &mut engine_a);

    let mut coord_b = Coordinator::new(vec![0.1; d], vec![d], Network::paper_cluster());
    let mut engine_b = mk_engine();
    let _ = run(stop, 0, &mut coord_b, &mut engine_b);
    let ck = coord_b.snapshot(&mut engine_b, stop as u64).expect("snapshot");
    assert_eq!(ck.ef_residuals.len(), n, "one EF residual per rank");
    assert!(
        ck.ef_residuals.iter().any(|m| m.iter().any(|&x| x != 0.0)),
        "EF residuals are all zero — the test would not detect a drop"
    );

    let mut coord_c = Coordinator::new(vec![0.1; d], vec![d], Network::paper_cluster());
    let mut engine_c = mk_engine();
    coord_c.restore(&mut engine_c, n, &ck).expect("restore");
    let res_c = run(rounds, stop, &mut coord_c, &mut engine_c);
    assert_eq!(
        res_a.final_params, res_c.final_params,
        "EF residual was not restored bit-exactly"
    );

    // and dropping the residuals (what v1 did) is OBSERVABLE: the resumed
    // run diverges — this is the regression the v2 format exists to stop
    let mut coord_d = Coordinator::new(vec![0.1; d], vec![d], Network::paper_cluster());
    let mut engine_d = mk_engine();
    let mut stripped = ck.clone();
    stripped.ef_residuals.clear();
    coord_d.restore(&mut engine_d, n, &stripped).expect("restore without EF");
    let res_d = run(rounds, stop, &mut coord_d, &mut engine_d);
    assert_ne!(
        res_a.final_params, res_d.final_params,
        "dropping EF residuals went unnoticed — the parity test is vacuous"
    );
}
