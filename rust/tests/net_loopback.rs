//! TCP-loopback smoke tests (`cargo test -q --test net_loopback`, wired
//! into CI explicitly so the socket path cannot rot behind the in-proc
//! channel default). Everything here opens real sockets; keep the sizes
//! CI-friendly.

use std::time::{Duration, Instant};

use intsgd::collective::allreduce_intvec;
use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::compress::RoundEngine;
use intsgd::coordinator::{Coordinator, LrSchedule, TrainConfig};
use intsgd::net::frame::{encode_frame, expect_frame, FrameHeader, PayloadKind};
use intsgd::net::staged::{ring_allreduce_ints, StagedScratch};
use intsgd::net::{
    FaultPlan, FaultTransport, KillAt, NetError, StagedAlgo, TcpTransport, Transport,
    TransportReducer,
};
use intsgd::netsim::Network;
use intsgd::scaling::MovingAverageRule;
use intsgd::util::Rng;

#[test]
fn net_loopback_mesh_exchanges_frames_between_ranks() {
    let n = 4;
    let mut endpoints = TcpTransport::loopback_mesh(n).expect("mesh");
    std::thread::scope(|s| {
        for (rank, ep) in endpoints.iter_mut().enumerate() {
            s.spawn(move || {
                let mut buf = Vec::new();
                let mut rx = Vec::new();
                for peer in 0..n {
                    if peer == rank {
                        continue;
                    }
                    let payload = [rank as u8; 16];
                    encode_frame(
                        FrameHeader { round: 0, seq: 0, kind: PayloadKind::Bytes, elems: 16 },
                        &payload,
                        &mut buf,
                    );
                    ep.send(peer, &buf).expect("send");
                }
                for peer in 0..n {
                    if peer == rank {
                        continue;
                    }
                    ep.recv(peer, &mut rx).expect("recv");
                    let body = expect_frame(&rx, 0, PayloadKind::Bytes, 16).expect("frame");
                    assert_eq!(body, &[peer as u8; 16]);
                }
            });
        }
    });
}

#[test]
fn net_loopback_staged_ring_multirank() {
    // large enough that chunks exceed typical socket buffers, so the
    // backpressure/pump path is actually exercised
    let n = 4;
    let d = 1 << 18;
    let mut rng = Rng::new(2);
    let msgs: Vec<IntVec> = (0..n)
        .map(|_| {
            let vals: Vec<i64> = (0..d).map(|_| rng.below(63) as i64 - 31).collect();
            IntVec::from_i64(&vals, Lanes::I8)
        })
        .collect();
    let views: Vec<&IntVec> = msgs.iter().collect();
    let mut want = Vec::new();
    allreduce_intvec(&views, &mut want);

    let mut endpoints = TcpTransport::loopback_mesh(n).expect("mesh");
    let results: Vec<Vec<i64>> = std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .iter_mut()
            .zip(&msgs)
            .map(|(ep, msg)| {
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    ring_allreduce_ints(ep, msg, Lanes::I8, 0, &mut scratch, &mut out)
                        .expect("tcp ring");
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, got) in results.iter().enumerate() {
        assert_eq!(got, &want, "rank {rank}");
    }
}

#[test]
fn net_loopback_full_intsgd_training_rounds() {
    let n = 4;
    let d = 512;
    let rounds = 10;
    // noise-free shared quadratic oracle: the loss must strictly decrease
    let mut pool = intsgd::coordinator::net_driver::quad_pool(n, d, 40, 0.0);
    let mut coord = Coordinator::new(vec![0.0; d], vec![d], Network::tcp_loopback());
    let mut engine = RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        8,
    )));
    let mut red = TransportReducer::tcp_loopback(n, StagedAlgo::Ring).expect("reducer");
    let cfg = TrainConfig {
        rounds,
        schedule: LrSchedule::constant(0.4),
        ..Default::default()
    };
    let res = coord.train_over(&mut pool, &mut engine, &mut red, &cfg, None);
    pool.shutdown();
    let first = res.records.first().unwrap().train_loss;
    let last = res.records.last().unwrap().train_loss;
    assert!(last < first, "no progress over TCP: {first} -> {last}");
    assert_eq!(red.calls(), (rounds - 1) as u64, "one collective per int round");
    assert!(red.wire_seconds() > 0.0);
    assert!(res.failovers.is_empty(), "healthy fabric must not fail over");
    // the int8 aggregate budget held on the wire too
    assert!(res.records.iter().all(|r| r.max_abs_int <= 127));
}

#[test]
fn net_loopback_stalled_rank_times_out_typed_not_30s() {
    // a rank that never answers must cost the configured deadline — and
    // surface as NetError::Timeout with the stalled rank named — instead
    // of a generic error after a hard-coded 30 s
    let mut mesh = TcpTransport::loopback_mesh(2).expect("mesh");
    let _silent = mesh.pop().unwrap();
    let mut a = mesh.pop().unwrap();
    a.set_timeout(Duration::from_millis(80));
    // Timing the timeout itself (clippy.toml wall-clock rule).
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let err = a.recv(1, &mut Vec::new()).expect_err("silent peer");
    assert!(matches!(err, NetError::Timeout { rank: 1, .. }), "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "stalled rank burned {:?}",
        t0.elapsed()
    );
}

#[test]
fn net_loopback_rank_kill_fails_over_to_survivors() {
    // kill the last rank mid-training over real sockets: the collective
    // reports PeerDead, the coordinator shrinks the world, and training
    // finishes on the survivors
    let n = 3;
    let d = 256;
    let rounds = 8;
    let mut pool = intsgd::coordinator::net_driver::quad_pool(n, d, 70, 0.0);
    let mut coord = Coordinator::new(vec![0.0; d], vec![d], Network::tcp_loopback());
    let mut engine = RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Deterministic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        9,
    )));
    // collective round ids count int rounds: id 3 <=> training round 4
    let mesh = FaultTransport::wrap_mesh(
        TcpTransport::loopback_mesh(n).expect("mesh"),
        &FaultPlan::clean(4),
        Some((2, KillAt::Round(3))),
    );
    let mut red = TransportReducer::new(mesh, StagedAlgo::Ring);
    red.set_timeout(Duration::from_millis(500));
    let cfg = TrainConfig {
        rounds,
        schedule: LrSchedule::constant(0.3),
        ..Default::default()
    };
    let res = coord.train_over(&mut pool, &mut engine, &mut red, &cfg, None);
    pool.shutdown();
    assert_eq!(res.failovers, vec![(4, 2)], "rank 2 dies in training round 4");
    assert_eq!(red.world(), 2, "the reducer shrank to the survivors");
    assert_eq!(res.records.len(), rounds, "every round completed despite the death");
    let first = res.records.first().unwrap().train_loss;
    let last = res.records.last().unwrap().train_loss;
    assert!(last < first, "survivors made no progress: {first} -> {last}");
}
