//! Wire-codec robustness properties: every `compress::wire` codec now
//! parses bytes that arrived from a socket (`net::TcpTransport`), so the
//! decoders must treat their input as hostile — truncated buffers,
//! max-magnitude lanes, empty vectors, and corrupt counts must produce
//! `Err` (or a shorter-but-valid decode for the length-inferred codecs),
//! **never** a panic or a count-driven giant allocation.
//!
//! The same goes for `net::frame`'s transported frames: the per-peer
//! round/seq guard must classify every adversarial frame — duplicated,
//! reordered, stale, future, tampered — as a typed verdict (`Stale` skip
//! or `NetError::Replay`/`Corrupt`), never accept it as the awaited one.

use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::compress::natsgd::{NatMsg, NatSgd};
use intsgd::compress::qsgd::Qsgd;
use intsgd::compress::signsgd::SignSgd;
use intsgd::compress::wire::{
    decode_int32, decode_int8, decode_nat, decode_qsgd, decode_sign, decode_sparse,
    encode_int32, encode_int8, encode_ints, encode_nat, encode_qsgd, encode_sign,
    encode_sparse, read_varint, BitReader, BitWriter, MAX_BITS_PER_OP,
};
use intsgd::prop_assert;
use intsgd::util::prop::prop_check;
use intsgd::util::Rng;

/// Random lane-extreme integer vector (empty with small probability).
fn adversarial_ints(rng: &mut Rng, lanes: Lanes) -> IntVec {
    let d = rng.usize_below(40); // includes d = 0
    let vals: Vec<i64> = (0..d)
        .map(|_| match rng.below(4) {
            0 => match lanes {
                Lanes::I8 => i8::MIN as i64,
                Lanes::I32 => i32::MIN as i64,
                Lanes::I64 => i32::MIN as i64, // int32 codec ceiling
            },
            1 => match lanes {
                Lanes::I8 => i8::MAX as i64,
                Lanes::I32 => i32::MAX as i64,
                Lanes::I64 => i32::MAX as i64,
            },
            2 => 0,
            _ => rng.below(255) as i64 - 127,
        })
        .collect();
    IntVec::from_i64(&vals, lanes)
}

#[test]
fn int_codecs_roundtrip_at_lane_extremes() {
    prop_check(0x1A7E, 200, |rng| {
        for lanes in [Lanes::I8, Lanes::I32, Lanes::I64] {
            let v = adversarial_ints(rng, lanes);
            let bytes = encode_ints(&v).map_err(|e| e.to_string())?;
            let back = match lanes {
                Lanes::I8 => decode_int8(&bytes),
                _ => decode_int32(&bytes).map_err(|e| e.to_string())?,
            };
            prop_assert!(
                back.to_i64_vec() == v.to_i64_vec(),
                "{lanes:?} roundtrip (d = {})",
                v.len()
            );
        }
        Ok(())
    });
}

#[test]
fn int_codec_truncation_never_panics() {
    prop_check(0x7211, 200, |rng| {
        let v = adversarial_ints(rng, Lanes::I32);
        let bytes = encode_int32(&v).unwrap();
        let cut = rng.usize_below(bytes.len() + 1);
        // 4-aligned prefixes legally decode to a shorter vector; the
        // rest must error — either way, no panic
        if let Ok(back) = decode_int32(&bytes[..cut]) {
            prop_assert!(back.len() <= v.len(), "grew on truncation");
        }
        // int8 has no internal structure: every prefix decodes, shorter
        let b8 = encode_int8(&adversarial_ints(rng, Lanes::I8)).unwrap();
        let cut8 = rng.usize_below(b8.len() + 1);
        prop_assert!(decode_int8(&b8[..cut8]).len() == cut8, "int8 prefix length");
        Ok(())
    });
}

#[test]
fn sparse_roundtrips_and_rejects_every_strict_prefix() {
    prop_check(0x59A2, 150, |rng| {
        let k = rng.usize_below(30); // includes the empty support
        let mut used = std::collections::BTreeSet::new();
        let entries: Vec<(u32, f32)> = (0..k)
            .filter_map(|_| {
                let i = rng.below(1 << 20) as u32;
                used.insert(i).then(|| (i, rng.normal_f32() * 1e6))
            })
            .collect();
        let bytes = encode_sparse(&entries);
        let back = decode_sparse(&bytes).map_err(|e| e.to_string())?;
        let mut want = entries.clone();
        want.sort_unstable_by_key(|&(i, _)| i);
        prop_assert!(back == want, "sparse roundtrip k = {}", entries.len());
        // the codec is self-delimiting: every strict prefix must fail
        let cut = rng.usize_below(bytes.len());
        prop_assert!(
            decode_sparse(&bytes[..cut]).is_err(),
            "prefix {cut}/{} decoded",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn qsgd_roundtrip_and_truncation() {
    prop_check(0x95D1, 100, |rng| {
        let d = 1 + rng.usize_below(300);
        let g = rng.normal_vec(d, 2.0);
        let mut stream = Rng::new(rng.next_u64());
        let mut msg = Vec::new();
        let spans = if d >= 2 {
            let b1 = 1 + rng.usize_below(d - 1); // both buckets nonempty
            Qsgd::spans_of(&[b1, d - b1], d)
        } else {
            Qsgd::spans_of(&[d], d)
        };
        Qsgd::encode_buckets(64, &spans, &g, &mut stream, &mut msg);
        let bytes = encode_qsgd(&msg).map_err(|e| e.to_string())?;
        let back = decode_qsgd(&bytes).map_err(|e| e.to_string())?;
        prop_assert!(back.len() == msg.len(), "bucket count");
        for (a, b) in back.iter().zip(&msg) {
            prop_assert!(a.norm.to_bits() == b.norm.to_bits(), "norm bits");
            prop_assert!(a.levels == b.levels, "levels");
        }
        let cut = rng.usize_below(bytes.len());
        prop_assert!(decode_qsgd(&bytes[..cut]).is_err(), "prefix {cut} decoded");
        Ok(())
    });
}

#[test]
fn hostile_counts_error_instead_of_allocating() {
    // varint counts in the hundreds of millions backed by a 3-byte
    // buffer: the old decoders fed them straight to `with_capacity`
    let huge_count = {
        let mut b = Vec::new();
        intsgd::compress::wire::write_varint(&mut b, u32::MAX as u64);
        b.extend_from_slice(&[1, 2, 3]);
        b
    };
    let err = decode_sparse(&huge_count).expect_err("sparse count");
    assert!(err.to_string().contains("exceeds"), "{err}");
    let err = decode_qsgd(&huge_count).expect_err("qsgd count");
    assert!(err.to_string().contains("exceeds"), "{err}");
    // a plausible outer count with a hostile inner bucket length
    let mut nested = Vec::new();
    intsgd::compress::wire::write_varint(&mut nested, 1);
    intsgd::compress::wire::write_varint(&mut nested, u32::MAX as u64);
    nested.extend_from_slice(&[0u8; 16]);
    let err = decode_qsgd(&nested).expect_err("bucket length");
    assert!(err.to_string().contains("exceeds"), "{err}");
    // a delta that wraps the u64 index accumulator: must be an Err, not a
    // debug-build panic or a release-build wrap to a bogus small index
    let mut wrap = Vec::new();
    intsgd::compress::wire::write_varint(&mut wrap, 2);
    intsgd::compress::wire::write_varint(&mut wrap, 1);
    intsgd::compress::wire::write_varint(&mut wrap, u64::MAX);
    wrap.extend_from_slice(&[0u8; 8]);
    let err = decode_sparse(&wrap).expect_err("index wrap");
    assert!(err.to_string().contains("overflow"), "{err}");
}

#[test]
fn nat_and_sign_roundtrip_and_reject_truncation() {
    prop_check(0xA751, 100, |rng| {
        let d = 1 + rng.usize_below(500);
        let g = rng.normal_vec(d, 1.5);
        // NatSGD
        let mut stream = Rng::new(rng.next_u64());
        let mut msg = NatMsg::default();
        NatSgd::encode_into(&mut stream, &g, &mut msg);
        let bytes = encode_nat(&msg);
        let back = decode_nat(&bytes, d).map_err(|e| e.to_string())?;
        prop_assert!(back.exps == msg.exps && back.signs == msg.signs, "nat roundtrip");
        // a prefix that cannot hold the 9d bits must fail; byte-aligned
        // slack at the end can legally satisfy the reader
        let need = (d * 9).div_ceil(8);
        let cut = rng.usize_below(need);
        if cut * 8 < d * 9 {
            prop_assert!(decode_nat(&bytes[..cut], d).is_err(), "nat prefix {cut}");
        }
        // SignSGD
        let smsg = SignSgd::encode(&g);
        let sbytes = encode_sign(&smsg, d);
        let sback = decode_sign(&sbytes, d).map_err(|e| e.to_string())?;
        prop_assert!(
            sback.scale.to_bits() == smsg.scale.to_bits() && sback.bits == smsg.bits,
            "sign roundtrip"
        );
        let scut = rng.usize_below(sbytes.len());
        if (scut.saturating_sub(4)) * 8 < d {
            prop_assert!(decode_sign(&sbytes[..scut], d).is_err(), "sign prefix {scut}");
        }
        Ok(())
    });
}

#[test]
fn varint_and_bitreader_survive_arbitrary_bytes() {
    prop_check(0xB17E, 300, |rng| {
        let len = rng.usize_below(24);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // read_varint: any byte soup either decodes or errors
        let mut pos = 0usize;
        while pos < bytes.len() {
            if read_varint(&bytes, &mut pos).is_err() {
                break;
            }
        }
        // all-0xFF streams longer than 10 bytes must overflow, not wrap
        let all_ff = vec![0xFFu8; 11];
        let mut p = 0;
        prop_assert!(read_varint(&all_ff, &mut p).is_err(), "varint overflow");
        // BitReader: random pull widths over random bytes never panic;
        // oversized widths and exhausted streams error
        let mut r = BitReader::new(&bytes);
        loop {
            let n = 1 + rng.below(MAX_BITS_PER_OP as u64 + 8) as u32;
            match r.pull(n) {
                Ok(v) => {
                    prop_assert!(n <= MAX_BITS_PER_OP, "oversized pull succeeded");
                    prop_assert!(n == 64 || v < (1u64 << n), "pull exceeded width");
                }
                Err(_) => break,
            }
        }
        Ok(())
    });
}

#[test]
fn bitstream_roundtrips_random_schedules() {
    prop_check(0xB175, 200, |rng| {
        let ops: Vec<(u64, u32)> = (0..rng.usize_below(40))
            .map(|_| {
                let n = 1 + rng.below(MAX_BITS_PER_OP as u64) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &ops {
            w.push(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &ops {
            let got = r.pull(n).map_err(|e| e.to_string())?;
            prop_assert!(got == v, "pull({n}) = {got}, pushed {v}");
        }
        Ok(())
    });
}

// --- transported-frame replay/reorder guard (net::frame) -------------------

#[test]
fn hostile_element_counts_are_typed_corrupt_not_giant_allocs() {
    use intsgd::net::frame::{checksum, decode_frame, HEADER_BYTES};
    use intsgd::net::NetError;
    // A hand-built header promising u32::MAX elements of every lane
    // kind, backed by a 3-byte payload. Before the checked-cast sweep,
    // `elems as usize * width` could wrap on narrow hosts and giant
    // counts could reach allocation; now the shape mismatch must be a
    // typed NetError::Corrupt before any payload interpretation.
    for tag in 0u8..4 {
        let payload = [0u8; 3];
        let mut frame = Vec::with_capacity(HEADER_BYTES + payload.len());
        frame.extend_from_slice(&7u32.to_le_bytes()); // round
        frame.extend_from_slice(&0u32.to_le_bytes()); // seq
        frame.push(tag);
        frame.extend_from_slice(&u32::MAX.to_le_bytes()); // hostile elems
        frame.extend_from_slice(&checksum(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        match decode_frame(&frame) {
            Err(NetError::Corrupt { detail, .. }) => {
                assert!(detail.contains("promises"), "tag {tag}: {detail}");
            }
            other => panic!("hostile count accepted for tag {tag}: {other:?}"),
        }
    }
}

#[test]
fn hostile_lane_tags_are_typed_corrupt() {
    use intsgd::net::frame::{checksum, decode_frame, HEADER_BYTES};
    use intsgd::net::NetError;
    // Every unknown payload-kind tag is rejected as Corrupt before the
    // element count can be interpreted against the wrong lane width.
    for tag in [4u8, 5, 99, 255] {
        let payload = [1u8, 2, 3, 4];
        let mut frame = Vec::with_capacity(HEADER_BYTES + payload.len());
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.push(tag);
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(&checksum(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        match decode_frame(&frame) {
            Err(NetError::Corrupt { detail, .. }) => {
                assert!(detail.contains("kind tag"), "tag {tag}: {detail}");
            }
            other => panic!("unknown tag {tag} accepted: {other:?}"),
        }
    }
}

#[test]
fn wire_bound_violations_are_typed_corrupt() {
    use intsgd::net::frame::pack_partials;
    use intsgd::net::NetError;
    // A partial sum outside the declared wire lane is the encoder-side
    // twin of the hostile-count decode: it must surface as a typed
    // Corrupt naming the lane, never truncate silently onto the wire.
    let mut out = Vec::new();
    for (sums, wire) in [
        (&[i64::from(i8::MAX) + 1][..], Lanes::I8),
        (&[i64::from(i8::MIN) - 1][..], Lanes::I8),
        (&[i64::from(i32::MAX) + 1][..], Lanes::I32),
        (&[i64::from(i32::MIN) - 1][..], Lanes::I32),
    ] {
        match pack_partials(sums, wire, &mut out) {
            Err(NetError::Corrupt { detail, .. }) => {
                assert!(detail.contains("exceeds"), "{wire:?}: {detail}");
            }
            other => panic!("out-of-lane sum packed for {wire:?}: {other:?}"),
        }
    }
}

#[test]
fn frame_guard_rejects_every_adversarial_frame() {
    use intsgd::net::frame::{check_frame, encode_frame, FrameCheck, FrameHeader, PayloadKind};
    use intsgd::net::NetError;
    prop_check(0xF4A3, 300, |rng| {
        let elems = rng.usize_below(64);
        let payload: Vec<u8> = (0..elems).map(|_| rng.below(256) as u8).collect();
        let round = rng.below(1 << 20) as u32;
        let seq = rng.below(64) as u32;
        let mut frame = Vec::new();
        encode_frame(
            FrameHeader { round, seq, kind: PayloadKind::Bytes, elems: elems as u32 },
            &payload,
            &mut frame,
        );
        // the exact frame we await is Fresh
        let v = check_frame(&frame, round, seq, PayloadKind::Bytes, elems)
            .map_err(|e| e.to_string())?;
        prop_assert!(v == FrameCheck::Fresh, "awaited frame misclassified");
        // a duplicate (same round, already-consumed seq) is a typed Replay
        let ahead = seq + 1 + rng.below(4) as u32;
        match check_frame(&frame, round, ahead, PayloadKind::Bytes, elems) {
            Err(NetError::Replay { .. }) => {}
            other => return Err(format!("duplicate accepted: {other:?}")),
        }
        // a frame from a round the receiver already left behind is Stale
        let later = round.wrapping_add(1 + rng.below(1000) as u32);
        let v = check_frame(&frame, later, 0, PayloadKind::Bytes, elems)
            .map_err(|e| e.to_string())?;
        prop_assert!(v == FrameCheck::Stale, "stale frame not skipped");
        // a frame from the future is a Replay error, not a skip
        if round > 0 {
            let earlier = round - 1 - rng.below(round as u64 / 2 + 1) as u32;
            match check_frame(&frame, earlier, seq, PayloadKind::Bytes, elems) {
                Err(NetError::Replay { .. }) => {}
                other => return Err(format!("future frame accepted: {other:?}")),
            }
        }
        // any single-bit flip is caught: Corrupt, Replay, or Stale — but
        // NEVER accepted as the awaited frame
        if !frame.is_empty() {
            let mut bad = frame.clone();
            let at = rng.usize_below(bad.len());
            bad[at] ^= 1u8 << rng.below(8);
            match check_frame(&bad, round, seq, PayloadKind::Bytes, elems) {
                Ok(FrameCheck::Fresh) => {
                    return Err(format!("flipped bit at {at} went undetected"));
                }
                Ok(FrameCheck::Stale) | Err(_) => {}
            }
        }
        // truncation to any strict prefix is rejected
        let cut = rng.usize_below(frame.len());
        prop_assert!(
            check_frame(&frame[..cut], round, seq, PayloadKind::Bytes, elems).is_err(),
            "prefix {cut}/{} accepted",
            frame.len()
        );
        Ok(())
    });
}

#[test]
// The transport spins up per-rank mailbox state and exercises timeout
// machinery — out of scope for the Miri codec slice (CI runs this test
// natively in every job).
#[cfg_attr(miri, ignore)]
fn frame_guard_round_trip_over_a_real_transport() {
    // a duplicated frame injected by FaultTransport over the in-process
    // channel arrives byte-identical and is rejected by seq, not checksum
    use intsgd::net::frame::{
        check_frame, encode_frame, FrameCheck, FrameHeader, PayloadKind,
    };
    use intsgd::net::{ChannelTransport, FaultPlan, FaultTransport, NetError, Transport};
    let mut plan = FaultPlan::clean(77);
    plan.dup_p = 1.0;
    let mut mesh = FaultTransport::wrap_mesh(ChannelTransport::mesh(2), &plan, None);
    let mut b = mesh.pop().unwrap();
    let mut a = mesh.pop().unwrap();
    let mut frame = Vec::new();
    encode_frame(
        FrameHeader { round: 5, seq: 0, kind: PayloadKind::Bytes, elems: 3 },
        &[1, 2, 3],
        &mut frame,
    );
    a.send(1, &frame).unwrap();
    let mut rx = Vec::new();
    b.recv(0, &mut rx).unwrap();
    assert_eq!(
        check_frame(&rx, 5, 0, PayloadKind::Bytes, 3).unwrap(),
        FrameCheck::Fresh
    );
    // the duplicate fails the seq guard once seq 0 is consumed
    b.recv(0, &mut rx).unwrap();
    match check_frame(&rx, 5, 1, PayloadKind::Bytes, 3) {
        Err(NetError::Replay { .. }) => {}
        other => panic!("duplicate accepted: {other:?}"),
    }
}
