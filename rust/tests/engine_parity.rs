//! Golden parity: the parallel engine (encode on worker threads, integer
//! reduce coordinate-chunked across the pool) must be bit-identical to the
//! sequential reference for every compressor in the zoo, across worker
//! counts and rounds — including the per-block alpha path (paper Alg. 2).
//!
//! The guarantee rests on two design rules pinned here: encoders consume
//! only their own state plus the shared plan, and every reduce fold
//! processes each coordinate's ranks in rank order — chunking coordinates
//! across threads cannot change a bit because integer addition is exactly
//! associative (fp32 folds never run chunked).

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::powersgd::BlockShape;
use intsgd::compress::{
    HeuristicIntSgd, IdentitySgd, NatSgd, PhasedCompressor, PowerSgd, Qsgd,
    RoundEngine, SignSgd, TopK,
};
use intsgd::coordinator::{BlockInfo, RoundCtx, WorkerPool};
use intsgd::scaling::{BlockRule, MovingAverageRule, Prop3Rule};
use intsgd::util::Rng;

/// Block dims used for every multi-block case (they tile `d`).
fn block_dims(d: usize) -> Vec<usize> {
    assert!(d >= 8 && d % 4 == 0);
    vec![d / 2, d / 4, d / 4]
}

fn ctx_for(round: usize, d: usize, n: usize, blocked: bool) -> RoundCtx {
    let dims = if blocked { block_dims(d) } else { vec![d] };
    let blocks: Vec<BlockInfo> = dims
        .iter()
        .enumerate()
        .map(|(l, &dim)| BlockInfo {
            dim,
            // varies per block and per round so per-block alphas differ
            step_norm_sq: 1e-4 / (l + 1) as f64 * (round as f64 + 1.0),
        })
        .collect();
    let step_norm_sq = blocks.iter().map(|b| b.step_norm_sq).sum();
    RoundCtx { round, n, d, lr: 0.1, step_norm_sq, blocks }
}

/// Run `rounds` rounds through both drivers and require bit-identical
/// results every round (state evolves, so every round must match for the
/// next one to).
fn assert_parity(
    label: &str,
    mk: impl Fn() -> Box<dyn PhasedCompressor>,
    n: usize,
    d: usize,
    blocked: bool,
) {
    let mut seq = RoundEngine::new(mk());
    let mut par = RoundEngine::new(mk());
    let mut pool = WorkerPool::for_encode(n);
    let mut rng = Rng::new(0xE11 + n as u64);
    for round in 0..4 {
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.5)).collect();
        let ctx = ctx_for(round, d, n, blocked);
        let a = seq.round_sequential(&grads, &ctx);
        // the parallel engine encodes in place over the leader's slices,
        // so the gradients are shared read-only, not round-tripped
        let b = par.round_parallel(&mut pool, &grads, &ctx);
        assert_eq!(
            a.gtilde, b.gtilde,
            "{label} n={n} round {round}: gtilde differs"
        );
        assert_eq!(
            a.max_abs_int, b.max_abs_int,
            "{label} n={n} round {round}: max_abs_int differs"
        );
        assert_eq!(
            a.alpha.to_bits(),
            b.alpha.to_bits(),
            "{label} n={n} round {round}: alpha differs"
        );
        assert_eq!(
            a.wire_bytes_per_worker(),
            b.wire_bytes_per_worker(),
            "{label} n={n} round {round}: wire bytes differ"
        );
        assert_eq!(a.comm.len(), b.comm.len(), "{label}: comm schedule length");
        for (ca, cb) in a.comm.iter().zip(&b.comm) {
            assert_eq!(ca.primitive, cb.primitive, "{label}: primitive differs");
        }
    }
    pool.shutdown();
}

fn zoo(n: usize, d: usize) -> Vec<(&'static str, Box<dyn Fn() -> Box<dyn PhasedCompressor>>)> {
    let dims = block_dims(d);
    let qsgd_dims = dims.clone();
    let power_layout: Vec<BlockShape> = vec![
        // a matrix block covering d/2, then two vector blocks
        BlockShape { dims: vec![4, d / 8] },
        BlockShape { dims: vec![d / 4] },
        BlockShape { dims: vec![d / 4] },
    ];
    vec![
        (
            "sgd_allreduce",
            Box::new(|| Box::new(IdentitySgd::allreduce()) as Box<dyn PhasedCompressor>),
        ),
        (
            "sgd_allgather",
            Box::new(|| Box::new(IdentitySgd::allgather()) as Box<dyn PhasedCompressor>),
        ),
        (
            "intsgd_random8",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int8,
                    Box::new(MovingAverageRule::default_paper()),
                    n,
                    41,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "intsgd_determ32",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Deterministic,
                    WireInt::Int32,
                    Box::new(MovingAverageRule::default_paper()),
                    n,
                    42,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "intsgd_prop3",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int32,
                    Box::new(Prop3Rule),
                    n,
                    43,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "intsgd_block8",
            Box::new(move || {
                Box::new(IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int8,
                    Box::new(BlockRule::new(0.9, 1e-8)),
                    n,
                    44,
                )) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "intsgd_switch8",
            Box::new(move || {
                let mut c = IntSgd::new(
                    Rounding::Stochastic,
                    WireInt::Int8,
                    Box::new(MovingAverageRule::default_paper()),
                    n,
                    45,
                );
                c.use_switch = true;
                Box::new(c) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "heuristic8",
            Box::new(|| Box::new(HeuristicIntSgd::new(8)) as Box<dyn PhasedCompressor>),
        ),
        (
            "qsgd64",
            Box::new(move || {
                Box::new(Qsgd::new(64, qsgd_dims.clone(), n, 46)) as Box<dyn PhasedCompressor>
            }),
        ),
        (
            "natsgd",
            Box::new(move || Box::new(NatSgd::new(n, 47)) as Box<dyn PhasedCompressor>),
        ),
        (
            "topk10",
            Box::new(move || Box::new(TopK::new(0.1, n)) as Box<dyn PhasedCompressor>),
        ),
        (
            "ef_signsgd",
            Box::new(move || Box::new(SignSgd::new(n)) as Box<dyn PhasedCompressor>),
        ),
        (
            "powersgd_rank2",
            Box::new(move || {
                Box::new(PowerSgd::new(2, power_layout.clone(), n, 48))
                    as Box<dyn PhasedCompressor>
            }),
        ),
    ]
}

#[test]
fn parallel_engine_is_bit_identical_for_the_whole_zoo() {
    let d = 96; // block dims [48, 24, 24]; powersgd matrix 4 x 12
    for &n in &[1usize, 4, 7] {
        for (label, mk) in zoo(n, d) {
            assert_parity(label, mk.as_ref(), n, d, true);
        }
    }
}

#[test]
fn parity_holds_without_block_layout_too() {
    // single-block ctx (blocks = [d]): the scalar-alpha path
    let d = 64;
    for &n in &[1usize, 4] {
        for (label, mk) in zoo(n, d) {
            assert_parity(label, mk.as_ref(), n, d, false);
        }
    }
}

#[test]
fn per_block_alphas_differ_and_still_match() {
    // sanity that the Alg. 2 path is actually exercised: BlockRule with
    // distinct per-block step norms produces a non-uniform alpha vector
    // (reported alpha = min), and the parallel path reproduces it exactly.
    let n = 4;
    let d = 96;
    let mut seq = RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(BlockRule::new(0.9, 1e-8)),
        n,
        7,
    )) as Box<dyn PhasedCompressor>);
    let mut par = RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(BlockRule::new(0.9, 1e-8)),
        n,
        7,
    )) as Box<dyn PhasedCompressor>);
    let mut pool = WorkerPool::for_encode(n);
    let mut rng = Rng::new(99);
    for round in 1..4 {
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.5)).collect();
        let ctx = ctx_for(round, d, n, true);
        let a = seq.round_sequential(&grads, &ctx);
        let b = par.round_parallel(&mut pool, &grads, &ctx);
        assert_eq!(a.gtilde, b.gtilde, "round {round}");
        assert!(a.alpha.is_finite() && a.alpha > 0.0);
    }
    pool.shutdown();
}

#[test]
fn chunked_pool_reduce_is_bit_identical_at_large_d() {
    // d large enough that round_parallel's integer reduce actually fans
    // out across the worker threads (the small-d cases above fold inline).
    // Integer addition is exactly associative, so the chunked fold must
    // reproduce the sequential rank-order fold bit for bit.
    let n = 4;
    let d = 1 << 16;
    let mk = |seed: u64| {
        RoundEngine::new(Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            seed,
        )) as Box<dyn PhasedCompressor>)
    };
    let mut seq = mk(21);
    let mut par = mk(21);
    let mut pool = WorkerPool::for_encode(n);
    let mut rng = Rng::new(0xBEEF);
    for round in 0..3 {
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.5)).collect();
        let blocks: Vec<BlockInfo> = vec![
            BlockInfo { dim: d / 2, step_norm_sq: 1e-4 },
            BlockInfo { dim: d / 2, step_norm_sq: 2e-4 },
        ];
        let ctx = RoundCtx { round, n, d, lr: 0.1, step_norm_sq: 3e-4, blocks };
        let a = seq.round_sequential(&grads, &ctx);
        let b = par.round_parallel(&mut pool, &grads, &ctx);
        assert_eq!(a.gtilde, b.gtilde, "round {round}: gtilde differs");
        assert_eq!(a.max_abs_int, b.max_abs_int, "round {round}");
    }
    pool.shutdown();
}
