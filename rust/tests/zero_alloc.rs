//! Steady-state zero-allocation: after warmup, an IntSGD round — encode,
//! reduce, decode — touches the allocator exactly zero times, through both
//! engine drivers.
//!
//! This pins the whole recycling chain at once: typed `IntVec` message
//! buffers, the `Arc::make_mut` plan geometry, the reused integer
//! aggregate, the `RoundArena` round outputs (returned via
//! `RoundEngine::reclaim`), and the worker pool's fixed-slot mailboxes
//! (an mpsc channel would allocate a node per send).
//!
//! The file contains a single test: the counter is process-global, so a
//! concurrently running sibling test would pollute the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::{PhasedCompressor, RankMessages, Reducer, RoundEngine, SerialReducer};
use intsgd::net::{NetError, UNKNOWN_RANK, UNKNOWN_ROUND};
use intsgd::scaling::MovingAverageRule;
use intsgd::coordinator::{BlockInfo, RoundCtx, WorkerPool};
use intsgd::util::Rng;

struct CountingAllocator;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn allocations() -> usize {
    ALLOCATIONS.load(Ordering::SeqCst)
}

fn engine(n: usize, seed: u64) -> RoundEngine {
    RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        seed,
    )) as Box<dyn PhasedCompressor>)
}

#[test]
fn steady_state_intsgd_rounds_allocate_nothing() {
    // telemetry ON for the whole measurement: the span journal records
    // into a ring pre-allocated here, and every instrument is a static
    // atomic — the windows below prove the round hot path stays
    // allocation-free with observability enabled, which is the journal's
    // design contract (telemetry::journal docs)
    intsgd::telemetry::journal::enable(intsgd::telemetry::journal::DEFAULT_CAPACITY);

    let n = 4;
    // large enough that the parallel driver's integer reduce fans out
    // across the pool threads (instead of the small-d inline path)
    let d = 1 << 16;
    let mut rng = Rng::new(0x2E20);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.1)).collect();
    let blocks = vec![
        BlockInfo { dim: d / 2, step_norm_sq: 1e-4 },
        BlockInfo { dim: d / 2, step_norm_sq: 3e-4 },
    ];
    let mut ctx = RoundCtx { round: 0, n, d, lr: 0.1, step_norm_sq: 4e-4, blocks };

    // --- sequential driver ------------------------------------------------
    let mut seq = engine(n, 11);
    // warmup: the dense round 0 plus enough int rounds to size every
    // buffer (messages, aggregate, plan geometry, arena outputs)
    for round in 0..5 {
        ctx.round = round;
        let r = seq.round_sequential(&grads, &ctx);
        seq.reclaim(r);
    }
    let before = allocations();
    for round in 5..25 {
        ctx.round = round;
        let r = seq.round_sequential(&grads, &ctx);
        assert_eq!(r.gtilde.len(), d);
        seq.reclaim(r);
    }
    let seq_allocs = allocations() - before;
    assert_eq!(
        seq_allocs, 0,
        "sequential steady-state rounds hit the allocator {seq_allocs} times"
    );

    // --- parallel driver (worker pool: encode + chunked reduce) -----------
    let mut par = engine(n, 11);
    let mut pool = WorkerPool::for_encode(n);
    for round in 0..5 {
        ctx.round = round;
        let r = par.round_parallel(&mut pool, &grads, &ctx);
        par.reclaim(r);
    }
    let before = allocations();
    for round in 5..25 {
        ctx.round = round;
        let r = par.round_parallel(&mut pool, &grads, &ctx);
        assert_eq!(r.gtilde.len(), d);
        par.reclaim(r);
    }
    let par_allocs = allocations() - before;
    pool.shutdown();
    assert_eq!(
        par_allocs, 0,
        "parallel steady-state rounds hit the allocator {par_allocs} times"
    );

    // --- block-less contexts (the normalized whole-gradient path) ---------
    let mut ctx_plain = RoundCtx {
        round: 0,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 4e-4,
        blocks: vec![],
    };
    let mut plain = engine(n, 11);
    for round in 0..5 {
        ctx_plain.round = round;
        let r = plain.round_sequential(&grads, &ctx_plain);
        plain.reclaim(r);
    }
    let before = allocations();
    for round in 5..25 {
        ctx_plain.round = round;
        let r = plain.round_sequential(&grads, &ctx_plain);
        assert_eq!(r.gtilde.len(), d);
        plain.reclaim(r);
    }
    let plain_allocs = allocations() - before;
    assert_eq!(
        plain_allocs, 0,
        "block-less steady-state rounds hit the allocator {plain_allocs} times"
    );

    // --- erroring-then-succeeding rounds (failure must not leak) -----------
    // A reducer that fails its first call (a transport fault that retry
    // could not fix): the engine must surface the error WITHOUT stranding
    // its buffers — the encoders stay parked, the arena keeps its pooled
    // outputs, and the rounds after the error are still allocation-free.
    struct FailFirst {
        remaining_failures: usize,
    }
    impl Reducer for FailFirst {
        fn sum_ints(
            &mut self,
            msgs: &RankMessages,
            out: &mut Vec<i64>,
        ) -> Result<(), NetError> {
            if self.remaining_failures > 0 {
                self.remaining_failures -= 1;
                return Err(NetError::Timeout {
                    rank: UNKNOWN_RANK,
                    round: UNKNOWN_ROUND,
                });
            }
            SerialReducer.sum_ints(msgs, out)
        }
    }
    let mut err_engine = engine(n, 11);
    let mut err_pool = WorkerPool::for_encode(n);
    let mut red = FailFirst { remaining_failures: 1 };
    for round in 0..5 {
        ctx.round = round;
        // round 1 is the first to reach the reducer (round 0 is dense)
        match err_engine.round_parallel_over(&mut err_pool, &mut red, &grads, &ctx) {
            Ok(r) => err_engine.reclaim(r),
            Err(e) => {
                assert!(matches!(e, NetError::Timeout { .. }), "{e}");
                assert_eq!(round, 1, "exactly the first integer round fails");
            }
        }
    }
    let before = allocations();
    for round in 5..25 {
        ctx.round = round;
        let r = err_engine
            .round_parallel_over(&mut err_pool, &mut red, &grads, &ctx)
            .expect("no more injected failures");
        assert_eq!(r.gtilde.len(), d);
        err_engine.reclaim(r);
    }
    let err_allocs = allocations() - before;
    err_pool.shutdown();
    assert_eq!(
        err_allocs, 0,
        "steady state after an erroring round hit the allocator {err_allocs} times \
         (the failed round leaked buffers)"
    );

    // --- streamed driver (double-buffered block pipeline) -------------------
    // The pipeline adds its own reused state — the per-rank block slots
    // (both parities), the per-block aggregate scratch, and the drained
    // whole-round sum. After warmup a streamed round must be exactly as
    // allocation-free as the barrier drivers it is bit-identical to.
    let mut str_engine = engine(n, 11);
    let mut str_pool = WorkerPool::for_encode(n);
    let mut str_red = SerialReducer;
    for round in 0..5 {
        ctx.round = round;
        let r = str_engine
            .round_streamed_over(&mut str_pool, &mut str_red, &grads, &ctx)
            .expect("serial reducer cannot fail");
        str_engine.reclaim(r);
    }
    let before = allocations();
    for round in 5..25 {
        ctx.round = round;
        let r = str_engine
            .round_streamed_over(&mut str_pool, &mut str_red, &grads, &ctx)
            .expect("serial reducer cannot fail");
        assert_eq!(r.gtilde.len(), d);
        str_engine.reclaim(r);
    }
    let str_allocs = allocations() - before;
    str_pool.shutdown();
    assert_eq!(
        str_allocs, 0,
        "streamed steady-state rounds hit the allocator {str_allocs} times"
    );

    // --- dispatched kernels, driven directly --------------------------------
    // The kernel layer's own contract (DESIGN.md §10): every dispatched
    // kernel runs on caller buffers plus fixed-size stack scratch. The
    // rounds above already exercised them indirectly (and warmed the
    // one-time backend detection, which reads the environment); this
    // drives each one explicitly so a future backend cannot smuggle in a
    // heap temporary without tripping the counter.
    use intsgd::simd;
    let g: Vec<f32> = grads[0].clone();
    let h: Vec<f32> = grads[1].clone();
    let msgs: Vec<Vec<i8>> = (0..8)
        .map(|r| g.iter().map(|&x| (x as i64 % 100 + r) as i8).collect())
        .collect();
    let views: Vec<&[i8]> = msgs.iter().map(|m| m.as_slice()).collect();
    let mut f32_out = vec![0.0f32; d];
    let mut i64_acc = vec![0i64; d];
    let src32: Vec<i32> = (0..d as i32).collect();
    let src64: Vec<i64> = (0..d as i64).collect();
    let before = allocations();
    let mut sink = 0.0f64;
    let mut isink = 0i64;
    for _ in 0..10 {
        simd::round_stoch(&g, 7.5, 0x5EED, 0, &mut f32_out);
        simd::round_determ(&g, 7.5, &mut f32_out);
        simd::add_widen_i8(views[0], &mut i64_acc);
        simd::add_widen_i32(&src32, &mut i64_acc);
        simd::add_i64(&src64, &mut i64_acc);
        simd::copy_widen_i8(views[1], &mut i64_acc);
        simd::sum_ranks_i8(&views, &mut i64_acc);
        simd::decode_scale_i64(&src64, 1.0 / 48.0, &mut f32_out);
        sink += simd::sq_norm(&g) + simd::sq_diff_norm(&g, &h);
        isink += simd::max_abs_i8(views[0])
            + simd::max_abs_i32(&src32)
            + simd::max_abs_i64(&i64_acc);
    }
    let kernel_allocs = allocations() - before;
    assert!(sink.is_finite() && isink >= 0);
    assert_eq!(
        kernel_allocs, 0,
        "dispatched kernels ({}) hit the allocator {kernel_allocs} times",
        simd::backend_name()
    );

    // --- telemetry instruments, driven directly -----------------------------
    // The rounds above journaled spans and fed counters as a side effect;
    // this drives every instrument kind explicitly so a future instrument
    // cannot smuggle a heap temporary (string label, map node, lazy init)
    // onto the hot path without tripping the counter.
    use intsgd::compress::Lanes;
    use intsgd::telemetry::{journal, m, Phase, ALL};
    let alphas = [0.25f64, 0.5];
    let before = allocations();
    for i in 0..1_000u64 {
        m::ROUNDS.inc();
        m::WIRE_BYTES.add(i);
        m::TRAIN_LOSS.set(i as f64 * 0.5);
        m::ENCODE_SECONDS.record_secs(1e-6 * i as f64);
        m::ALPHA_BLOCK.set_all(&alphas);
        m::WIRE_LANE.bump(Lanes::I8);
        let t = journal::start();
        journal::record(Phase::Encode, i as u32, (i % 4) as u16, ALL, t);
    }
    let telemetry_allocs = allocations() - before;
    assert_eq!(
        telemetry_allocs, 0,
        "telemetry instruments hit the allocator {telemetry_allocs} times \
         (counters/gauges/histograms/journal must be allocation-free)"
    );
}
