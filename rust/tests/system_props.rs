//! Cross-cutting system properties: invariants that span modules
//! (compressor zoo x collectives x coordinator), all pure-rust (no PJRT),
//! exercised with the in-tree property harness.

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::powersgd::BlockShape;
use intsgd::compress::{
    average, DistributedCompressor, HeuristicIntSgd, IdentitySgd, NatSgd,
    PhasedCompressor, PowerSgd, Qsgd, RoundEngine, SignSgd, TopK,
};
use intsgd::coordinator::{
    BlockInfo, Coordinator, GradientSource, LrSchedule, RoundCtx, TrainConfig,
    WorkerPool,
};
use intsgd::netsim::Network;
use intsgd::scaling::MovingAverageRule;
use intsgd::util::prop::prop_check;
use intsgd::util::stats::{l2_norm, l2_norm_sq};
use intsgd::util::Rng;

fn ctx(round: usize, d: usize, n: usize, step_sq: f64) -> RoundCtx {
    RoundCtx {
        round,
        n,
        d,
        lr: 0.1,
        step_norm_sq: step_sq,
        blocks: vec![BlockInfo { dim: d, step_norm_sq: step_sq }],
    }
}

fn all_compressors(n: usize, d: usize) -> Vec<Box<dyn DistributedCompressor>> {
    vec![
        Box::new(IdentitySgd::allreduce()),
        Box::new(IdentitySgd::allgather()),
        Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            1,
        )),
        Box::new(IntSgd::new(
            Rounding::Deterministic,
            WireInt::Int32,
            Box::new(MovingAverageRule::default_paper()),
            n,
            2,
        )),
        Box::new(HeuristicIntSgd::new(8)),
        Box::new(Qsgd::new(64, vec![], n, 3)),
        Box::new(NatSgd::new(n, 4)),
        Box::new(PowerSgd::new(1, vec![BlockShape { dims: vec![d] }], n, 5)),
        Box::new(TopK::new(0.5, n)),
        Box::new(SignSgd::new(n)),
    ]
}

#[test]
fn every_compressor_produces_finite_output_of_right_dim() {
    prop_check(0xD1, 25, |rng| {
        let n = 1 + rng.usize_below(8);
        let d = 1 + rng.usize_below(400);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let sigma = 10f32.powf(rng.range(-3.0, 2.0) as f32);
                rng.normal_vec(d, sigma)
            })
            .collect();
        let c = ctx(1, d, n, rng.uniform() * 0.1 + 1e-9);
        for comp in all_compressors(n, d).iter_mut() {
            let r = comp.round(&grads, &c);
            if r.gtilde.len() != d {
                return Err(format!("{}: wrong dim", comp.name()));
            }
            if !r.gtilde.iter().all(|v| v.is_finite()) {
                return Err(format!("{}: non-finite output", comp.name()));
            }
            if r.wire_bytes_per_worker() == 0 {
                return Err(format!("{}: zero wire bytes", comp.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn unbiased_compressors_estimate_the_average() {
    // IntSGD(random), QSGD, NatSGD are unbiased: averaging round outputs
    // over repetitions converges to the true mean gradient.
    let n = 4;
    let d = 60;
    let mut rng = Rng::new(7);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
    let avg = average(&grads);
    let reps = 600;

    let mut cases: Vec<(String, Box<dyn DistributedCompressor>)> = vec![
        (
            "intsgd".into(),
            Box::new(IntSgd::new(
                Rounding::Stochastic,
                WireInt::Int32,
                Box::new(MovingAverageRule::default_paper()),
                n,
                8,
            )),
        ),
        ("qsgd".into(), Box::new(Qsgd::new(64, vec![], n, 9))),
        ("natsgd".into(), Box::new(NatSgd::new(n, 10))),
    ];
    for (name, comp) in cases.iter_mut() {
        let mut acc = vec![0.0f64; d];
        for rep in 0..reps {
            // advance the round per rep: IntSGD's stochastic base is keyed
            // by round (a re-encode of the SAME round is deliberately
            // bit-identical — the failover invariant), so fresh draws per
            // rep require fresh rounds, exactly as in a real run
            let c = ctx(1 + rep, d, n, 1e-3);
            let r = comp.round(&grads, &c);
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        let mean: Vec<f32> = acc.iter().map(|&a| (a / reps as f64) as f32).collect();
        let err = l2_norm(
            &mean.iter().zip(&avg).map(|(&m, &a)| m - a).collect::<Vec<_>>(),
        );
        let scale = l2_norm(&avg).max(1.0);
        assert!(err < 0.1 * scale, "{name}: bias {err} vs scale {scale}");
    }
}

#[test]
fn allreduce_compatible_flag_matches_paper_table1() {
    let n = 2;
    let d = 8;
    let expect: Vec<(bool, &str)> = vec![
        (true, "sgd_allreduce"),
        (true, "sgd_allgather"), // fp32 is trivially summable
        (true, "intsgd"),
        (true, "intsgd"),
        (true, "heuristic"),
        (false, "qsgd"),
        (false, "natsgd"),
        (true, "powersgd"),
        (false, "topk"),
        (false, "signsgd"),
    ];
    for (comp, (ar, tag)) in all_compressors(n, d).iter().zip(expect) {
        assert_eq!(
            comp.supports_allreduce(),
            ar,
            "{} (~{tag}) allreduce flag",
            comp.name()
        );
    }
}

#[test]
fn intsgd_training_tracks_uncompressed_on_quadratic() {
    // End-to-end (no PJRT): distributed quadratic optimization with int8
    // IntSGD reaches the same optimum as uncompressed SGD.
    //
    // The shards are iid (every worker sees the same center plus noise) —
    // the setting the paper's deep-learning experiments are in. Under
    // *heterogeneous* shards plain IntSGD stalls (local gradients don't
    // vanish at x*, alpha grows as steps shrink, clipping crushes the
    // update) — exactly the Appendix A.2 pathology that IntDIANA fixes;
    // `optim::intdiana::tests::intdiana_bounded_integers_vs_intgd_blowup`
    // pins that behaviour.
    struct Quad {
        center: Vec<f32>,
        rng: Rng,
    }
    impl GradientSource for Quad {
        fn dim(&self) -> usize {
            self.center.len()
        }
        fn grad(&mut self, params: &[f32], _round: usize) -> (f32, Vec<f32>) {
            let g: Vec<f32> = params
                .iter()
                .zip(&self.center)
                .map(|(&x, &c)| x - c + 0.05 * self.rng.normal_f32())
                .collect();
            let loss = 0.5 * l2_norm_sq(&g) as f32;
            (loss, g)
        }
    }
    let d = 100;
    let n = 4;
    let mk_pool = || {
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> =
            (0..n)
                .map(|i| {
                    let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                        Box::new(move || {
                            // shared center = iid shards; per-worker noise
                            let center = Rng::new(300).normal_vec(d, 1.0);
                            let rng = Rng::new(400 + i as u64);
                            Box::new(Quad { center, rng }) as Box<dyn GradientSource>
                        });
                    f
                })
                .collect();
        WorkerPool::spawn(factories)
    };
    let cfg = TrainConfig {
        rounds: 300,
        schedule: LrSchedule::constant(0.3),
        ..Default::default()
    };

    let run = |comp: Box<dyn PhasedCompressor>| {
        let mut pool = mk_pool();
        let mut coord =
            Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
        let mut engine = RoundEngine::new(comp);
        let res = coord.train(&mut pool, &mut engine, &cfg, None);
        pool.shutdown();
        res.final_params
    };
    let x_sgd = run(Box::new(IdentitySgd::allreduce()));
    let x_int = run(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        11,
    )));
    let dist = l2_norm(
        &x_sgd.iter().zip(&x_int).map(|(&a, &b)| a - b).collect::<Vec<_>>(),
    );
    assert!(dist < 0.2, "IntSGD endpoint {dist} away from SGD's");
}

#[test]
fn compressed_bytes_never_exceed_fp32() {
    prop_check(0xB17E5, 25, |rng| {
        let n = 1 + rng.usize_below(6);
        let d = 64 + rng.usize_below(2000);
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let c = ctx(2, d, n, 1e-4);
        for comp in all_compressors(n, d).iter_mut() {
            let name = comp.name();
            if name.starts_with("sgd") {
                continue;
            }
            let r = comp.round(&grads, &c);
            let fp32 = d * 4;
            if r.wire_bytes_per_worker() > fp32 + 64 {
                return Err(format!(
                    "{name}: {} bytes > fp32's {fp32}",
                    r.wire_bytes_per_worker()
                ));
            }
        }
        Ok(())
    });
}

#[test]
#[should_panic(expected = "worker result")]
fn pool_panics_cleanly_when_worker_dies() {
    struct Dying;
    impl GradientSource for Dying {
        fn dim(&self) -> usize {
            1
        }
        fn grad(&mut self, _p: &[f32], _r: usize) -> (f32, Vec<f32>) {
            panic!("injected worker failure");
        }
    }
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> =
        vec![Box::new(|| Box::new(Dying) as _)];
    let mut pool = WorkerPool::spawn(factories);
    let _ = pool.compute_round(&[0.0], 0);
}
