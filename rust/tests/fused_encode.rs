//! Fused-encoder golden parity: the typed, fused scale → round → clip →
//! pack pass (`compress::intsgd::encode_blocks`) must be bit-identical to
//! a naive scale-then-round-then-clip reference, for both roundings, both
//! wire lane widths, and across block layouts.
//!
//! The reference below is written in the most literal style possible —
//! one coordinate at a time, widened i64 output — precisely so it cannot
//! share a bug with the chunked, lane-typed production path.

use intsgd::compress::intsgd::{IntSgd, Rounding};
use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::compress::BlockSpan;
use intsgd::prop_assert;
use intsgd::util::prop::prop_check;
use intsgd::util::rng::splitmix64_at;
use intsgd::util::Rng;

/// The paper's rounding, spelled out coordinate by coordinate.
fn naive_reference(
    rounding: Rounding,
    grad: &[f32],
    blocks: &[BlockSpan],
    alphas: &[f64],
    clip: i64,
    base: u64,
) -> Vec<i64> {
    const SCALE: f32 = 1.0 / (1u32 << 24) as f32;
    let mut out = Vec::with_capacity(grad.len());
    for (span, &alpha) in blocks.iter().zip(alphas) {
        let a = alpha as f32;
        for (k, &g) in grad[span.range()].iter().enumerate() {
            let t = g * a;
            let rounded = match rounding {
                Rounding::Stochastic => {
                    let j = (span.offset + k) as u64;
                    let u = (splitmix64_at(base, j) >> 40) as f32 * SCALE;
                    (t + u).floor()
                }
                Rounding::Deterministic => t.round_ties_even(),
            };
            // clamp in the integer domain: the widened bound itself, not
            // its f32 rounding (for clip > 2^24 the two can differ —
            // `clip_clamp_is_integer_exact_at_the_i32_boundary` below).
            // `as i64` saturates and maps NaN to 0, matching the
            // production `WireLane::of_rounded` contract.
            out.push((rounded as i64).clamp(-clip, clip));
        }
    }
    out
}

/// A random tiling of [0, d) into 1..=4 blocks.
fn random_layout(rng: &mut Rng, d: usize) -> Vec<BlockSpan> {
    let nblocks = 1 + rng.usize_below(4.min(d));
    let mut cuts: Vec<usize> = (0..nblocks - 1).map(|_| 1 + rng.usize_below(d - 1)).collect();
    cuts.push(0);
    cuts.push(d);
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2)
        .map(|w| BlockSpan { offset: w[0], dim: w[1] - w[0] })
        .collect()
}

#[test]
fn fused_encode_matches_naive_reference() {
    prop_check(0xF05ED, 60, |rng| {
        let d = 1 + rng.usize_below(2000);
        let sigma = 10f32.powf(rng.range(-3.0, 2.0) as f32);
        let grad = rng.normal_vec(d, sigma);
        let blocks = random_layout(rng, d);
        let alphas: Vec<f64> =
            blocks.iter().map(|_| 10f64.powf(rng.range(-2.0, 3.0))).collect();
        let base = rng.next_u64();
        for rounding in [Rounding::Stochastic, Rounding::Deterministic] {
            for (clip, lanes) in [
                (127i64, Lanes::I8),
                (i32::MAX as i64 / 4, Lanes::I32),
                // the SwitchML-widest escape hatch (clip exactly
                // representable in f32, like the production bounds)
                (1i64 << 40, Lanes::I64),
            ] {
                let mut fused = IntVec::new(lanes);
                intsgd::compress::intsgd::encode_blocks(
                    rounding, &blocks, &alphas, clip, &grad, base, &mut fused,
                );
                let reference =
                    naive_reference(rounding, &grad, &blocks, &alphas, clip, base);
                prop_assert!(
                    fused.len() == reference.len(),
                    "length {} vs {} ({rounding:?}, {lanes:?})",
                    fused.len(),
                    reference.len()
                );
                for j in 0..reference.len() {
                    prop_assert!(
                        fused.get(j) == reference[j],
                        "coord {j}: fused {} vs naive {} \
                         ({rounding:?}, {lanes:?}, d={d})",
                        fused.get(j),
                        reference[j]
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn block_layout_is_transparent_under_equal_alphas() {
    // The counter-based uniform stream is indexed by absolute coordinate,
    // so splitting the gradient into blocks (with one shared alpha) cannot
    // change a single integer.
    prop_check(0xB10C, 40, |rng| {
        let d = 8 + rng.usize_below(1500);
        let grad = rng.normal_vec(d, 1.0);
        let alpha = 10f64.powf(rng.range(-1.0, 2.0));
        let base = rng.next_u64();
        let whole = vec![BlockSpan { offset: 0, dim: d }];
        let split = random_layout(rng, d);
        let alphas_whole = vec![alpha];
        let alphas_split = vec![alpha; split.len()];
        for rounding in [Rounding::Stochastic, Rounding::Deterministic] {
            let mut a = IntVec::new(Lanes::I8);
            let mut b = IntVec::new(Lanes::I8);
            intsgd::compress::intsgd::encode_blocks(
                rounding, &whole, &alphas_whole, 127, &grad, base, &mut a,
            );
            intsgd::compress::intsgd::encode_blocks(
                rounding, &split, &alphas_split, 127, &grad, base, &mut b,
            );
            prop_assert!(
                a == b,
                "block layout changed the encode ({rounding:?}, d={d}, \
                 {} blocks)",
                split.len()
            );
        }
        Ok(())
    });
}

#[test]
fn clip_clamp_is_integer_exact_at_the_i32_boundary() {
    // clip = i32::MAX/4 = 536870911 is not f32-representable; `clip as
    // f32` rounds UP to 536870912.0. A rounded value of exactly that
    // f32 passed the old f32-domain clamp one past the proved wire
    // bound; the integer-domain clamp must pin it to the bound itself.
    let clip = i32::MAX as i64 / 4;
    let g = clip as f32; // 536870912.0 — one past the true bound
    let blocks = vec![BlockSpan { offset: 0, dim: 1 }];
    for (sign, want) in [(1.0f32, clip), (-1.0, -clip)] {
        let mut out = IntVec::new(Lanes::I32);
        intsgd::compress::intsgd::encode_blocks(
            Rounding::Deterministic,
            &blocks,
            &[1.0],
            clip,
            &[sign * g],
            0,
            &mut out,
        );
        assert_eq!(out.get(0), want);
    }
}

#[test]
fn clip_bound_holds_for_unrepresentable_clips() {
    // Satellite audit of `encode_span`'s clip handling: sweep clip
    // bounds that are deliberately NOT f32-representable (odd values
    // above 2^24, for the i32 and i64 lanes) with gradients straddling
    // the boundary, and assert no encoded value ever exceeds the
    // *widened* bound — the wire-fit proof the reducer relies on.
    prop_check(0xC11F, 80, |rng| {
        let shift = 25 + rng.usize_below(30) as u32;
        let clip = (1i64 << shift) + 1 + 2 * rng.below(1 << 20) as i64;
        let lanes = Lanes::for_bound(clip);
        let d = 64;
        let grad: Vec<f32> = (0..d)
            .map(|_| {
                let sign = if rng.bernoulli(0.5) { 1.0f32 } else { -1.0 };
                // near the bound (where the f32 rounding of clip bites),
                // or well past it (plain saturation)
                let mag = if rng.bernoulli(0.5) {
                    clip as f32 + rng.range(-1e4, 1e4) as f32
                } else {
                    clip as f32 * (1.0 + rng.uniform_f32())
                };
                sign * mag
            })
            .collect();
        let blocks = vec![BlockSpan { offset: 0, dim: d }];
        let base = rng.next_u64();
        for rounding in [Rounding::Stochastic, Rounding::Deterministic] {
            let mut out = IntVec::new(lanes);
            intsgd::compress::intsgd::encode_blocks(
                rounding, &blocks, &[1.0], clip, &grad, base, &mut out,
            );
            for j in 0..d {
                let v = out.get(j);
                prop_assert!(
                    v.abs() <= clip,
                    "coord {j}: |{v}| exceeds clip {clip} ({rounding:?}, {lanes:?})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn reference_api_matches_naive_reference() {
    // `IntSgd::encode` (the Pallas-kernel mirror shape) draws its counter
    // base from the stream; replaying the same stream must reproduce it.
    prop_check(0xA91, 30, |rng| {
        let d = 1 + rng.usize_below(500);
        let grad = rng.normal_vec(d, 1.0);
        let alpha = 10f64.powf(rng.range(-1.0, 2.0));
        let clip = 1 << 20;
        let seed = rng.next_u64();
        for rounding in [Rounding::Stochastic, Rounding::Deterministic] {
            let mut stream = Rng::new(seed);
            let mut out = Vec::new();
            IntSgd::encode(rounding, &grad, alpha, clip, &mut stream, &mut out);
            let base = match rounding {
                Rounding::Stochastic => Rng::new(seed).next_u64(),
                Rounding::Deterministic => 0,
            };
            let blocks = vec![BlockSpan { offset: 0, dim: d }];
            let reference =
                naive_reference(rounding, &grad, &blocks, &[alpha], clip, base);
            prop_assert!(out == reference, "reference API drifted ({rounding:?})");
        }
        Ok(())
    });
}
