//! Full-stack integration: the coordinator drives real PJRT workers over
//! the AOT artifacts for every model and a representative set of
//! compressors, asserting learning progress and accounting invariants.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use std::sync::Arc;

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::{HeuristicIntSgd, IdentitySgd, PhasedCompressor, RoundEngine};
use intsgd::coordinator::{
    BatchSpec, Coordinator, GradientSource, LrSchedule, PjrtEvaluator, PjrtWorker,
    TrainConfig, WorkerPool,
};
use intsgd::data::{shard_iid, CifarLike, MarkovText};
use intsgd::netsim::Network;
use intsgd::runtime::{init_params, lit_f32, Runtime};
use intsgd::scaling::MovingAverageRule;

fn artifacts_ready() -> bool {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: run `make artifacts` first");
        false
    }
}

fn classifier_pool(n: usize, data: &Arc<CifarLike>, batch: usize) -> WorkerPool {
    let shards = shard_iid(data.train_count(), n, 1);
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, indices)| {
            let data = Arc::clone(data);
            let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                Box::new(move || {
                    Box::new(
                        PjrtWorker::new(
                            "artifacts",
                            "classifier",
                            BatchSpec::Classifier { data, indices, batch },
                            10 + i as u64,
                        )
                        .expect("worker"),
                    )
                });
            f
        })
        .collect();
    WorkerPool::spawn(factories)
}

fn train_classifier(
    comp: Box<dyn PhasedCompressor>,
    n: usize,
    rounds: usize,
) -> (f64, f64, Vec<intsgd::coordinator::RoundRecord>) {
    let rt = Runtime::open("artifacts").unwrap();
    let meta = rt.meta("classifier_train_step").unwrap().clone();
    let data = Arc::new(CifarLike::generate(512, 128, 1.2, 0));
    let mut pool = classifier_pool(n, &data, meta.extra_usize("batch").unwrap());
    let init: Vec<f32> = init_params(&meta.params, 42).concat();
    let block_dims: Vec<usize> = meta.params.iter().map(|p| p.numel()).collect();
    let mut coord = Coordinator::new(init, block_dims, Network::paper_cluster());
    let cfg = TrainConfig {
        rounds,
        start_round: 0,
        schedule: LrSchedule::constant(0.1),
        momentum: 0.9,
        weight_decay: 1e-4,
        eval_every: 0,
    };
    let mut engine = RoundEngine::new(comp);
    let res = coord.train(&mut pool, &mut engine, &cfg, None);
    pool.shutdown();
    let first = res.records[..3].iter().map(|r| r.train_loss).sum::<f64>() / 3.0;
    let lastn = &res.records[res.records.len() - 3..];
    let last = lastn.iter().map(|r| r.train_loss).sum::<f64>() / 3.0;
    (first, last, res.records)
}

#[test]
fn classifier_learns_with_identity_sgd() {
    if !artifacts_ready() {
        return;
    }
    let (first, last, _) = train_classifier(Box::new(IdentitySgd::allreduce()), 2, 25);
    assert!(last < first - 0.3, "loss {first:.3} -> {last:.3}");
}

#[test]
fn classifier_learns_with_intsgd_int8() {
    if !artifacts_ready() {
        return;
    }
    let comp = Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        2,
        7,
    ));
    let (first, last, recs) = train_classifier(comp, 2, 25);
    assert!(last < first - 0.3, "loss {first:.3} -> {last:.3}");
    // int8 wire accounting: 1 byte/coordinate after the exact first round
    let d = recs[1].wire_bytes_per_worker;
    assert_eq!(d, 820_874);
    // aggregates stayed in the int8 budget
    assert!(recs.iter().all(|r| r.max_abs_int <= 127));
}

#[test]
fn intsgd_tracks_sgd_loss_closely() {
    if !artifacts_ready() {
        return;
    }
    let (_, sgd_last, _) = train_classifier(Box::new(IdentitySgd::allreduce()), 2, 30);
    let int8 = Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        2,
        7,
    ));
    let (_, int_last, _) = train_classifier(int8, 2, 30);
    // the paper's Fig. 1: IntSGD matches full precision
    assert!(
        (int_last - sgd_last).abs() < 0.35,
        "IntSGD {int_last:.3} vs SGD {sgd_last:.3}"
    );
}

#[test]
fn heuristic_int8_loses_small_gradients() {
    if !artifacts_ready() {
        return;
    }
    let (first, last, _) = train_classifier(Box::new(HeuristicIntSgd::new(8)), 2, 25);
    // it still moves, but the quantization floor is visible in the rate;
    // this asserts the run completes and records the coarse alpha
    assert!(last <= first + 0.1, "diverged: {first} -> {last}");
}

#[test]
fn lm_learns_through_pjrt() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let meta = rt.meta("lm_train_step").unwrap().clone();
    let vocab = meta.extra_usize("vocab").unwrap();
    let batch = meta.extra_usize("batch").unwrap();
    let seq = meta.extra_usize("seq").unwrap();
    let text = Arc::new(MarkovText::generate(vocab, 50_000, 5_000, 0.08, 0));
    let n = 2;
    let shard_len = text.train.len() / n;
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> = (0..n)
        .map(|i| {
            let shard: Arc<Vec<u32>> =
                Arc::new(text.train[i * shard_len..(i + 1) * shard_len].to_vec());
            let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                Box::new(move || {
                    Box::new(
                        PjrtWorker::new(
                            "artifacts",
                            "lm",
                            BatchSpec::Lm { tokens: shard, batch, seq },
                            20 + i as u64,
                        )
                        .expect("worker"),
                    )
                });
            f
        })
        .collect();
    let mut pool = WorkerPool::spawn(factories);
    let init: Vec<f32> = init_params(&meta.params, 3).concat();
    let block_dims: Vec<usize> = meta.params.iter().map(|p| p.numel()).collect();
    let mut coord = Coordinator::new(init, block_dims, Network::paper_cluster());
    let mut engine = RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        5,
    )));
    let cfg = TrainConfig {
        rounds: 200,
        start_round: 0,
        schedule: LrSchedule::constant(1.25),
        momentum: 0.9,
        weight_decay: 0.0,
        eval_every: 0,
    };
    let res = coord.train(&mut pool, &mut engine, &cfg, None);
    pool.shutdown();
    let first = res.records[0].train_loss;
    let last = res.records.last().unwrap().train_loss;
    // uniform entropy is ln(64) = 4.16; Markov structure is learnable
    assert!(last < first - 0.1, "LM loss {first:.3} -> {last:.3}");
}

#[test]
fn eval_step_reports_loss_and_accuracy() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let meta = rt.meta("classifier_train_step").unwrap().clone();
    let mut evaluator = PjrtEvaluator::new("artifacts", "classifier").unwrap();
    let params: Vec<f32> = init_params(&meta.params, 42).concat();
    let data = CifarLike::generate(64, 256, 1.2, 1);
    let (x, y) = data.test_batch(0, 256);
    let outs = evaluator
        .eval(
            &params,
            vec![
                lit_f32(&x, &[256, data.dim]).unwrap(),
                lit_f32(&y, &[256, data.classes]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 2);
    let (loss, acc) = (outs[0], outs[1]);
    assert!((loss - (10f32).ln()).abs() < 0.7, "init loss {loss}");
    assert!((0.0..=1.0).contains(&acc));
}
