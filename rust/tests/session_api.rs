//! The `api::Session` front door: builder-validation matrix,
//! `CompressorSpec` round-trips over the whole zoo, and — the load-bearing
//! guarantee of the redesign — **bitwise parity** between `Session::run`
//! and the legacy `Coordinator::train` path it replaced.

use intsgd::api::{
    Backend, CompressorSpec, FaultSpec, ModelSpec, Session, SessionBuilder, StagedAlgo,
    ZOO,
};
use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::RoundEngine;
use intsgd::coordinator::net_driver::{quad_factories, quad_pool};
use intsgd::coordinator::{Coordinator, LrSchedule, TrainConfig};
use intsgd::netsim::Network;
use intsgd::scaling::BlockRule;

fn quad_builder(n: usize, d: usize) -> SessionBuilder {
    Session::builder()
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, 100, 0.0))
}

// ---------------------------------------------------------------------
// builder-validation matrix: misconfiguration fails at build(), before
// any thread or socket exists
// ---------------------------------------------------------------------

#[test]
fn build_rejects_missing_and_mismatched_geometry() {
    let err = Session::builder()
        .model(ModelSpec::flat(8))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("gradient sources"), "{err}");

    let err = quad_builder(2, 8).build().map(|_| ()).err();
    assert!(err.is_none(), "a 2-rank quad session must build");

    let err = quad_builder(2, 8).world(3).build().unwrap_err().to_string();
    assert!(err.contains("disagrees"), "{err}");

    let err = Session::builder()
        .sources(quad_factories(2, 8, 1, 0.0))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("needs a model"), "{err}");

    // init params must tile the layout
    let err = Session::builder()
        .model(ModelSpec::with_params(vec![0.0; 7], vec![vec![8]]))
        .sources(quad_factories(2, 8, 1, 0.0))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("tile"), "{err}");
}

#[test]
fn build_rejects_int8_wire_overflow() {
    // 128 workers cannot provably sum clipped int8 messages within i8
    let err = Session::builder()
        .model(ModelSpec::flat(16))
        .sources(quad_factories(128, 16, 1, 0.0))
        .compressor(CompressorSpec::parse("intsgd_random8").unwrap())
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("overflow"), "{err}");
    // the spec itself validates the same bound without any construction
    assert!(CompressorSpec::parse("intsgd_random8").unwrap().validate(128).is_err());
    assert!(CompressorSpec::parse("intsgd_random8").unwrap().validate(127).is_ok());
}

#[test]
fn build_rejects_non_pow2_halving() {
    let err = quad_builder(3, 8)
        .backend(Backend::Channel { algo: StagedAlgo::Halving })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("power-of-two"), "{err}");
    // pow2 world is fine
    quad_builder(4, 8)
        .backend(Backend::Channel { algo: StagedAlgo::Halving })
        .build()
        .unwrap()
        .finish();
}

#[test]
fn build_rejects_bad_fault_knobs() {
    // probabilities out of range
    let err = quad_builder(2, 8)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .faults(FaultSpec { drop: 1.5, ..FaultSpec::default() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("[0, 1]"), "{err}");
    // a negative probability must not read as "no chaos" even when the
    // knobs sum to zero — it reaches validate() and errors
    let err = quad_builder(2, 8)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .faults(FaultSpec { drop: -0.3, dup: 0.3, ..FaultSpec::default() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("[0, 1]"), "{err}");
    // probabilities summing past 1
    let err = quad_builder(2, 8)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .faults(FaultSpec { drop: 0.6, dup: 0.6, ..FaultSpec::default() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("sum"), "{err}");
    // kill target outside the world
    let err = quad_builder(2, 8)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .faults(FaultSpec { kill: Some((9, 0)), ..FaultSpec::default() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("outside the world"), "{err}");
    // faults need a transport to wrap
    let err = quad_builder(2, 8)
        .faults(FaultSpec { corrupt: 0.1, ..FaultSpec::default() })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("transport"), "{err}");
}

#[test]
fn build_rejects_checkpoint_and_switch_misconfig() {
    let err = quad_builder(2, 8).checkpoint_every(5).build().unwrap_err().to_string();
    assert!(err.contains("checkpoint_path"), "{err}");
    // the INA switch simulator aggregates leader-side; a transport backend
    // would be silently bypassed
    let err = quad_builder(2, 8)
        .compressor(CompressorSpec::parse("intsgd_switch8").unwrap())
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("switch"), "{err}");
}

// ---------------------------------------------------------------------
// CompressorSpec registry
// ---------------------------------------------------------------------

#[test]
fn whole_zoo_parses_builds_and_round_trips() {
    let layout = vec![vec![4, 8], vec![16]];
    for id in ZOO {
        let spec = CompressorSpec::parse(id).unwrap_or_else(|e| panic!("{id}: {e}"));
        assert_eq!(&spec.to_string(), id, "Display must round-trip the id");
        assert_eq!(CompressorSpec::parse(&spec.to_string()).unwrap(), spec);
        // every zoo spec constructs for a small world over a shaped layout
        let comp = spec.build(4, &layout, 0.9, 1e-8, 7).unwrap_or_else(|e| panic!("{id}: {e}"));
        drop(comp);
    }
}

#[test]
fn unknown_algorithm_gets_a_suggestion() {
    let err = CompressorSpec::parse("intsgd_random88").unwrap_err().to_string();
    assert!(err.contains("did you mean"), "{err}");
}

// ---------------------------------------------------------------------
// bitwise parity: Session::run == legacy Coordinator::train
// ---------------------------------------------------------------------

/// The legacy wiring, written out by hand exactly as every pre-Session
/// call site did it.
fn legacy_run(n: usize, d: usize, blocks: Vec<usize>, rounds: usize) -> intsgd::coordinator::TrainResult {
    let mut pool = quad_pool(n, d, 100, 0.0);
    let mut coord = Coordinator::new(vec![0.0; d], blocks, Network::paper_cluster());
    let mut engine = RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(BlockRule::new(0.9, 1e-8)),
        n,
        42,
    )));
    let cfg = TrainConfig {
        rounds,
        start_round: 0,
        schedule: LrSchedule::constant(0.4),
        momentum: 0.9,
        weight_decay: 1e-4,
        eval_every: 0,
    };
    let res = coord.train(&mut pool, &mut engine, &cfg, None);
    pool.shutdown();
    res
}

fn session_for_parity(n: usize, d: usize, blocks: Vec<usize>) -> Session {
    Session::builder()
        .world(n)
        .model(ModelSpec::blocks(blocks))
        .sources(quad_factories(n, d, 100, 0.0))
        .compressor(CompressorSpec::parse("intsgd_block8").unwrap())
        .seed(42)
        .lr(0.4)
        .momentum(0.9)
        .weight_decay(1e-4)
        .build()
        .unwrap()
}

fn assert_records_equal(
    a: &[intsgd::coordinator::RoundRecord],
    b: &[intsgd::coordinator::RoundRecord],
) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.alpha.to_bits(), y.alpha.to_bits(), "round {}", x.round);
        assert_eq!(x.max_abs_int, y.max_abs_int, "round {}", x.round);
        assert_eq!(x.wire_bytes_per_worker, y.wire_bytes_per_worker, "round {}", x.round);
    }
}

#[test]
fn session_run_is_bitwise_identical_to_legacy_train() {
    let (n, d, rounds) = (3, 48, 60);
    let blocks = vec![16, 24, 8];

    let legacy = legacy_run(n, d, blocks.clone(), rounds);

    let mut session = session_for_parity(n, d, blocks);
    session.run(rounds).unwrap();
    let new = session.finish();

    assert_records_equal(&legacy.records, &new.records);
    let a: Vec<u32> = legacy.final_params.iter().map(|x| x.to_bits()).collect();
    let b: Vec<u32> = new.final_params.iter().map(|x| x.to_bits()).collect();
    assert_eq!(a, b, "final parameters must be bit-identical");
}

#[test]
fn stepping_equals_running() {
    // momentum on: this pins that per-step driving keeps optimizer state
    let (n, d) = (2, 32);
    let mut run_all = session_for_parity(n, d, vec![d]);
    run_all.run(40).unwrap();
    let a = run_all.finish();

    let mut stepped = session_for_parity(n, d, vec![d]);
    for _ in 0..40 {
        stepped.step().unwrap();
    }
    assert_eq!(stepped.round(), 40);
    let b = stepped.finish();

    assert_records_equal(&a.records, &b.records);
    assert_eq!(
        a.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        b.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}

#[test]
fn transport_backends_match_the_pool_backend_bitwise() {
    // staged collectives are exactly associative integer sums: the same
    // session over the channel transport must reproduce the in-process
    // fold bit for bit (sockets are covered by tests/net_loopback.rs)
    let (n, d, rounds) = (3, 40, 25);
    let mut pool_run = session_for_parity(n, d, vec![d]);
    pool_run.run(rounds).unwrap();
    let want = pool_run.finish();

    for algo in [StagedAlgo::Ring] {
        let mut over_wire = Session::builder()
            .model(ModelSpec::blocks(vec![d]))
            .sources(quad_factories(n, d, 100, 0.0))
            .compressor(CompressorSpec::parse("intsgd_block8").unwrap())
            .seed(42)
            .lr(0.4)
            .momentum(0.9)
            .weight_decay(1e-4)
            .backend(Backend::Channel { algo })
            .network(Network::paper_cluster())
            .build()
            .unwrap();
        over_wire.run(rounds).unwrap();
        assert!(over_wire.wire_stats().unwrap().collectives > 0);
        let got = over_wire.finish();
        assert_eq!(
            want.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{algo:?}"
        );
    }
}

#[test]
fn snapshot_resume_is_bit_exact() {
    let dir = std::env::temp_dir().join(format!("intsgd_session_api_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");
    let path = path.to_str().unwrap();

    // one uninterrupted run
    let (n, d) = (2, 24);
    let mut straight = session_for_parity(n, d, vec![d]);
    straight.run(30).unwrap();
    let want = straight.finish();

    // run 15, snapshot, resume into a FRESH session, run 15 more
    let mut first = session_for_parity(n, d, vec![d]);
    first.run(15).unwrap();
    first.save_checkpoint(path).unwrap();
    drop(first.finish());

    let mut second = session_for_parity(n, d, vec![d]);
    second.resume_from(path).unwrap();
    assert_eq!(second.round(), 15);
    second.run(15).unwrap();
    let got = second.finish();

    // stochastic IntSGD through disk: params only match if the encoder
    // RNG streams and scaling-rule state travelled with the checkpoint.
    // (Momentum restarts at a resume — legacy semantics — so compare
    // against a straight run whose momentum also restarted at round 15.)
    let mut reference = session_for_parity(n, d, vec![d]);
    reference.run(15).unwrap();
    reference.save_checkpoint(path).unwrap();
    reference.resume_from(path).unwrap();
    reference.run(15).unwrap();
    let reference = reference.finish();
    assert_eq!(
        reference.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        got.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "a resumed fresh session must match an in-place resumed session bitwise"
    );
    // and the resumed run really is the back half of the schedule
    assert_eq!(got.records.len(), 15);
    assert_eq!(got.records.first().unwrap().round, 15);
    assert_eq!(got.records.last().unwrap().round, 29);
    assert!(want.records.last().unwrap().train_loss.is_finite());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_every_writes_periodic_snapshots() {
    let dir = std::env::temp_dir().join(format!("intsgd_session_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("periodic.ckpt");
    let path_s = path.to_str().unwrap().to_string();

    let (n, d) = (2, 16);
    let mut session = Session::builder()
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, 100, 0.0))
        .compressor(CompressorSpec::parse("intsgd_random8").unwrap())
        .lr(0.3)
        .checkpoint_every(4)
        .checkpoint_path(path_s.clone())
        .build()
        .unwrap();
    session.run(10).unwrap();
    session.finish();

    let ck = intsgd::runtime::Checkpoint::load(&path_s).unwrap();
    // rounds 0..10 with every-4 snapshots: written after rounds 3 and 7,
    // i.e. positioned at round 8 for a resume
    assert_eq!(ck.round, 8);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_hook_and_observer_fire_on_schedule() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let calls = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&calls);
    let mut session = Session::builder()
        .model(ModelSpec::flat(8))
        .sources(quad_factories(2, 8, 3, 0.0))
        .compressor(CompressorSpec::parse("sgd_ar").unwrap())
        .lr(0.2)
        .eval_every(3)
        .eval_hook(Box::new(move |_p| {
            c.fetch_add(1, Ordering::Relaxed);
            (1.25, 0.5)
        }))
        .build()
        .unwrap();

    #[derive(Default)]
    struct Count {
        rounds: usize,
        evals: usize,
    }
    impl intsgd::api::RoundObserver for Count {
        fn on_round(
            &mut self,
            _r: &intsgd::api::RoundRecord,
            _b: &intsgd::api::RoundBreakdown,
        ) {
            self.rounds += 1;
        }
        fn on_eval(&mut self, _round: usize, loss: f64, acc: f64) {
            assert_eq!((loss, acc), (1.25, 0.5));
            self.evals += 1;
        }
    }
    let mut obs = Count::default();
    session.run_observed(10, &mut obs).unwrap();
    assert_eq!(obs.rounds, 10);
    assert_eq!(obs.evals, 3);
    assert_eq!(calls.load(Ordering::Relaxed), 3);
    assert_eq!(session.evals(), &[(2, 1.25, 0.5), (5, 1.25, 0.5), (8, 1.25, 0.5)]);
    session.finish();
}

#[test]
fn faulty_transport_session_converges_and_reports() {
    // seeded recoverable chaos through the front door: training result
    // identical in value terms (chaos-parity proper is tests/chaos.rs)
    let (n, d, rounds) = (3, 64, 12);
    let mut clean = Session::builder()
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, 7, 0.0))
        .compressor(CompressorSpec::parse("intsgd_random8").unwrap())
        .seed(5)
        .lr(0.4)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .build()
        .unwrap();
    clean.run(rounds).unwrap();
    let want = clean.finish();

    let mut chaotic = Session::builder()
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, 7, 0.0))
        .compressor(CompressorSpec::parse("intsgd_random8").unwrap())
        .seed(5)
        .lr(0.4)
        .backend(Backend::Channel { algo: StagedAlgo::Ring })
        .faults(FaultSpec { corrupt: 0.02, dup: 0.02, ..FaultSpec::default() })
        .net_timeout(std::time::Duration::from_millis(300))
        .net_retries(64)
        .build()
        .unwrap();
    chaotic.run(rounds).unwrap();
    let got = chaotic.finish();
    assert_eq!(
        want.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        got.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "retried faults must not change a single bit"
    );
}
