//! A lightweight Rust lexer: just enough to separate *code* from
//! *comments and string contents* so the rules never fire on a banned
//! token inside a string literal or a doc comment.
//!
//! The output keeps column alignment: every stripped character is
//! replaced by a space in the `code` channel, so byte offsets into
//! `code` line up with the original source and excerpts stay readable.

/// One source line, split into channels.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code with comments and string/char contents blanked to spaces.
    /// String delimiters themselves are kept so tokens do not merge.
    pub code: String,
    /// Concatenated comment text on this line (line, block, and doc
    /// comments), without the `//` / `/*` markers.
    pub comment: String,
    /// The raw line, verbatim (for excerpts).
    pub raw: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    LineComment,
    Block(u32),
    Str,
    RawStr(u32),
    Char,
}

/// Lex a whole file into per-line code/comment channels.
pub fn clean(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0usize;

    // Push `c` to the raw channel and to either code or comment.
    macro_rules! emit {
        (code $c:expr) => {{
            cur.raw.push($c);
            cur.code.push($c);
        }};
        (blank $c:expr) => {{
            cur.raw.push($c);
            cur.code.push(' ');
        }};
        (comment $c:expr) => {{
            cur.raw.push($c);
            cur.code.push(' ');
            cur.comment.push($c);
        }};
    }

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            // A newline ends the line in every state; multi-line
            // constructs carry their state into the next line.
            if state == State::LineComment {
                state = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    emit!(blank c);
                    emit!(blank '/');
                    i += 2;
                    // skip doc-comment markers so `comment` is the text
                    while chars.get(i) == Some(&'/') || chars.get(i) == Some(&'!') {
                        emit!(blank chars[i]);
                        i += 1;
                    }
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    emit!(blank c);
                    emit!(blank '*');
                    i += 2;
                } else if c == '"' {
                    // Possibly the opening quote of a raw string whose
                    // `r#`-prefix we already emitted as code.
                    let hashes = raw_prefix_hashes(&cur.code);
                    if let Some(n) = hashes {
                        state = State::RawStr(n);
                    } else {
                        state = State::Str;
                    }
                    emit!(code c);
                    i += 1;
                } else if c == '\'' {
                    // Char literal vs lifetime: after `'`, a backslash
                    // means a char escape; a closing quote two ahead
                    // means a plain char; otherwise it is a lifetime.
                    let next = chars.get(i + 1);
                    let after = chars.get(i + 2);
                    if next == Some(&'\\') || (next.is_some() && after == Some(&'\'')) {
                        state = State::Char;
                        emit!(code c);
                        i += 1;
                    } else {
                        emit!(code c);
                        i += 1;
                    }
                } else {
                    emit!(code c);
                    i += 1;
                }
            }
            State::LineComment => {
                emit!(comment c);
                i += 1;
            }
            State::Block(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    emit!(blank '*');
                    emit!(blank '/');
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    emit!(comment c);
                    emit!(comment '*');
                    state = State::Block(depth + 1);
                    i += 2;
                } else {
                    emit!(comment c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    emit!(blank c);
                    if let Some(&esc) = chars.get(i + 1) {
                        if esc != '\n' {
                            emit!(blank esc);
                            i += 1;
                        }
                    }
                    i += 1;
                } else if c == '"' {
                    emit!(code c);
                    state = State::Code;
                    i += 1;
                } else {
                    emit!(blank c);
                    i += 1;
                }
            }
            State::RawStr(n) => {
                if c == '"' && closes_raw(&chars, i, n) {
                    emit!(code c);
                    for _ in 0..n {
                        emit!(code '#');
                    }
                    i += 1 + n as usize;
                    state = State::Code;
                } else {
                    emit!(blank c);
                    i += 1;
                }
            }
            State::Char => {
                if c == '\\' {
                    emit!(blank c);
                    if chars.get(i + 1).is_some() {
                        emit!(blank chars[i + 1]);
                        i += 1;
                    }
                    i += 1;
                } else if c == '\'' {
                    emit!(code c);
                    state = State::Code;
                    i += 1;
                } else {
                    emit!(blank c);
                    i += 1;
                }
            }
        }
    }
    if !cur.raw.is_empty() {
        lines.push(cur);
    }
    lines
}

/// If the code emitted so far ends with a raw-string prefix (`r`, `r#`,
/// `br##`, ...), return the hash count; the caller just saw the `"`.
fn raw_prefix_hashes(code_so_far: &str) -> Option<u32> {
    let b = code_so_far.as_bytes();
    let mut i = b.len();
    let mut hashes = 0u32;
    while i > 0 && b[i - 1] == b'#' {
        hashes += 1;
        i -= 1;
    }
    if i == 0 || b[i - 1] != b'r' {
        return None;
    }
    i -= 1;
    // `r` must itself start a token (`br"` is also a raw string).
    if i > 0 && b[i - 1] == b'b' {
        i -= 1;
    }
    if i > 0 && is_ident_byte(b[i - 1]) {
        return None; // identifier ending in r, e.g. `var"` can't occur
    }
    Some(hashes)
}

/// Does the `"` at `chars[i]` close a raw string with `n` hashes?
fn closes_raw(chars: &[char], i: usize, n: u32) -> bool {
    (1..=n as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets where `token` occurs in `code` as a whole word (the
/// characters on both sides, if any, are not identifier characters).
pub fn token_positions(code: &str, token: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (pos, _) in code.match_indices(token) {
        let before_ok = match code[..pos].chars().next_back() {
            Some(c) => !is_ident_char(c),
            None => true,
        };
        let after_ok = match code[pos + token.len()..].chars().next() {
            Some(c) => !is_ident_char(c),
            None => true,
        };
        if before_ok && after_ok {
            out.push(pos);
        }
    }
    out
}

/// Does `code` contain `token` as a whole word?
pub fn has_token(code: &str, token: &str) -> bool {
    !token_positions(code, token).is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_leave_code_channel() {
        let lines = clean("let x = \"unsafe stuff\"; // unsafe note\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(lines[0].comment.contains("unsafe note"));
    }

    #[test]
    fn block_comments_span_lines() {
        let lines = clean("a /* one\nunsafe two */ b\n");
        assert!(lines[0].code.contains('a'));
        assert!(!has_token(&lines[1].code, "unsafe"));
        assert!(lines[1].code.contains('b'));
        assert!(lines[1].comment.contains("unsafe two"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let lines = clean("let s = r#\"panic!(\"x\")\"#;\nlet t = 1;\n");
        assert!(!lines[0].code.contains("panic"));
        assert!(lines[1].code.contains("let t = 1;"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lines = clean("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'y';\n");
        assert!(lines[0].code.contains("fn f<'a>"));
        assert!(lines[1].code.contains("let c ="));
        assert!(!lines[1].code.contains('y'));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("unsafely()", "unsafe"));
        assert!(!has_token("an_unsafe_flag", "unsafe"));
        // `GaugeVec::new` must not register as `Vec::new`
        assert!(token_positions("GaugeVec::new()", "Vec::new").is_empty());
        assert!(!token_positions("std::vec::Vec::new()", "Vec::new").is_empty());
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let lines = clean("let s = \"a\\\"unsafe\\\"b\"; let k = 2;\n");
        assert!(!has_token(&lines[0].code, "unsafe"));
        assert!(lines[0].code.contains("let k = 2;"));
    }
}
