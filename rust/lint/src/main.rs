//! `intlint` CLI: scan the tree, print findings, end with the
//! greppable `INTLINT status=...` line, exit non-zero on any unwaived
//! violation. `--json` writes the machine-readable report to stdout
//! instead (the summary line still goes to stderr so CI can grep it
//! either way).

use std::process::ExitCode;

const USAGE: &str = "\
intlint — repo-invariant static analysis for the intsgd tree

USAGE:
  intlint [--json] [--root <repo-root>] [--list-waivers]

  --json          print the machine-readable report to stdout
  --root <path>   repo root (default: walk up from cwd to find rust/src)
  --list-waivers  print every spent waiver with its reason

Rules R1-R6 and the waiver grammar are documented in DESIGN.md §12.
Exit status: 0 when every finding is waived, 1 otherwise.
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json = false;
    let mut list_waivers = false;
    let mut root_arg: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => json = true,
            "--list-waivers" => list_waivers = true,
            "--root" => {
                i += 1;
                match args.get(i) {
                    Some(p) => root_arg = Some(p.clone()),
                    None => {
                        eprintln!("--root expects a path\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
        i += 1;
    }

    let Some(root) = intlint::find_root(root_arg.as_deref()) else {
        eprintln!("intlint: could not locate a repo root containing rust/src");
        return ExitCode::from(2);
    };
    let report = match intlint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("intlint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    if json {
        print!("{}", report.to_json());
    } else {
        for f in &report.findings {
            if !f.waived {
                println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
                println!("    {}", f.excerpt);
            }
        }
        if list_waivers {
            for f in &report.findings {
                if f.waived {
                    println!(
                        "waived {}:{}: [{}] reason=\"{}\"",
                        f.file, f.line, f.rule, f.reason
                    );
                }
            }
        }
    }
    // The summary goes to both streams: stdout for humans, stderr so
    // `--json` runs can still grep it without parsing the report.
    let summary = report.summary_line();
    if !json {
        println!("{summary}");
    }
    eprintln!("{summary}");

    if report.violations() == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
