//! `intlint` — repo-invariant static analysis for the intsgd tree.
//!
//! Six rules, each the static twin of a dynamic test (DESIGN.md §12):
//!
//! | rule | invariant                                   | dynamic twin          |
//! |------|---------------------------------------------|-----------------------|
//! | R1   | `unsafe` carries a `// SAFETY:` argument    | Miri job              |
//! | R2   | hot-path modules never allocate             | tests/zero_alloc.rs   |
//! | R3   | no narrowing `as` in decode paths           | tests/wire_props.rs   |
//! | R4   | socket-reachable code never panics          | tests/chaos.rs        |
//! | R5   | intrinsics only under `#[target_feature]`   | tests/kernel_parity.rs|
//! | R6   | every instrument is pinned in the scrape    | tests/telemetry.rs    |
//!
//! Violations are waivable inline — `// intlint: allow(R2,
//! reason="...")` — and the binary prints a greppable `INTLINT
//! status=...` line mirroring `tools/bench_gate.py`.

pub mod lex;
pub mod rules;

use std::fmt::Write as _;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation (waived or not) at a source line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id, `"R1"`..`"R6"`.
    pub rule: &'static str,
    /// Repo-relative path (`rust/src/...`).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable statement of the violation.
    pub message: String,
    /// The offending source line, trimmed.
    pub excerpt: String,
    /// An inline waiver covers this finding.
    pub waived: bool,
    /// The waiver's mandatory `reason="..."`.
    pub reason: String,
}

/// The result of a full tree scan.
#[derive(Debug, Default)]
pub struct Report {
    /// Files scanned under `rust/src`.
    pub files: usize,
    /// All findings, waived ones included, ordered by (file, line).
    pub findings: Vec<Finding>,
}

/// Rule ids in reporting order.
pub const RULES: &[&str] = &["R1", "R2", "R3", "R4", "R5", "R6"];

impl Report {
    /// Unwaived violations (what fails the build).
    pub fn violations(&self) -> usize {
        self.findings.iter().filter(|f| !f.waived).count()
    }

    /// Waivers spent (the budget the summary prints).
    pub fn waivers(&self) -> usize {
        self.findings.iter().filter(|f| f.waived).count()
    }

    /// The greppable one-line summary, mirroring `BENCH_GATE status=`.
    pub fn summary_line(&self) -> String {
        format!(
            "INTLINT status={} rules={} violations={} waivers={} files={}",
            if self.violations() == 0 { "ok" } else { "fail" },
            RULES.len(),
            self.violations(),
            self.waivers(),
            self.files,
        )
    }

    /// Machine-readable report for the CI artifact (std-only JSON).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        let _ = write!(
            out,
            "  \"status\": \"{}\",\n  \"files\": {},\n  \"violations\": {},\n  \"waivers\": {},\n",
            if self.violations() == 0 { "ok" } else { "fail" },
            self.files,
            self.violations(),
            self.waivers(),
        );
        out.push_str("  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"waived\": {}, \
                 \"message\": \"{}\", \"excerpt\": \"{}\", \"reason\": \"{}\"}}{}\n",
                f.rule,
                json_escape(&f.file),
                f.line,
                f.waived,
                json_escape(&f.message),
                json_escape(&f.excerpt),
                json_escape(&f.reason),
                if i + 1 == self.findings.len() { "" } else { "," },
            );
        }
        out.push_str("  ]\n}\n");
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Lint one file's source; `rel` is its path relative to `rust/src/`
/// (scope decisions — hot module, decode path — key off it).
pub fn analyze_file(rel: &str, src: &str) -> Vec<Finding> {
    let lines = lex::clean(src);
    let ctx = rules::FileCtx::new(rel, &lines);
    let mut findings = rules::run_file_rules(&ctx);
    rules::apply_waivers(&lines, &mut findings);
    findings
}

/// R6 across the registry and its golden scrape test; waivers come from
/// the registry source.
pub fn analyze_r6(registry_src: &str, test_src: &str) -> Vec<Finding> {
    let mut findings = rules::r6_registry_coverage(registry_src, test_src);
    let lines = lex::clean(registry_src);
    rules::apply_waivers(&lines, &mut findings);
    findings
}

/// Walk `root/rust/src/**/*.rs` (sorted, deterministic) plus the R6
/// pair, and produce the full report.
pub fn run(root: &Path) -> io::Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    walk(&src_root, &mut files)?;
    files.sort();

    let mut report = Report::default();
    let mut registry_src = None;
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .expect("walked under src_root")
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)?;
        report.findings.extend(analyze_file(&rel, &src));
        if rel == "telemetry/registry.rs" {
            registry_src = Some(src);
        }
        report.files += 1;
    }
    if let Some(registry_src) = registry_src {
        let test_path = root.join("rust").join("tests").join("telemetry.rs");
        let test_src = std::fs::read_to_string(test_path)?;
        report.findings.extend(analyze_r6(&registry_src, &test_src));
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the repo root: `--root` wins; otherwise walk up from the
/// current directory looking for `rust/src`.
pub fn find_root(explicit: Option<&str>) -> Option<PathBuf> {
    if let Some(r) = explicit {
        let p = PathBuf::from(r);
        return p.join("rust").join("src").is_dir().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        if cur.join("rust").join("src").is_dir() {
            return Some(cur);
        }
        if !cur.pop() {
            return None;
        }
    }
}
