//! The six repo-invariant rules (DESIGN.md §12).
//!
//! Each rule is a pure function over a lexed file: it sees only the
//! code channel (comments and string contents already blanked by
//! [`crate::lex`]), plus the masks computed here — `#[cfg(test)]` /
//! `macro_rules!` regions, and "cold" delimiter groups for R2.

use crate::lex::{self, has_token, token_positions, Line};
use crate::Finding;

/// Hot-path modules for R2 (paths relative to `rust/src/`). `simd/` is
/// matched by prefix below. The list mirrors `tests/zero_alloc.rs`.
const HOT_MODULES: &[&str] = &[
    "compress/engine.rs",
    "compress/intsgd.rs",
    "net/staged.rs",
    "net/frame.rs",
    "net/reducer.rs",
    "net/poll/sys.rs",
    "net/poll/conn.rs",
    "telemetry/journal.rs",
    "telemetry/registry.rs",
];

/// Files whose decode paths parse attacker-controlled bytes: R3 (no
/// narrowing `as`) and R4 (no panics) apply here.
fn in_r3_scope(rel: &str) -> bool {
    rel.starts_with("net/") || rel == "compress/wire.rs" || rel == "compress/intvec.rs"
}

fn in_r4_scope(rel: &str) -> bool {
    rel.starts_with("net/") || rel == "compress/wire.rs"
}

fn is_hot(rel: &str) -> bool {
    HOT_MODULES.contains(&rel) || rel.starts_with("simd/")
}

/// Delimiter groups opened on a line carrying one of these markers are
/// "cold": error construction, assertion, and panic paths may allocate
/// (the round loop never reaches them on success).
const COLD_MARKERS: &[&str] = &[
    "Err(",
    "map_err",
    "ok_or",
    "unwrap_or_else",
    "unwrap_or(",
    "expect_err",
    "panic!",
    "unreachable!",
    "assert!",
    "assert_eq!",
    "assert_ne!",
    "debug_assert",
];

/// Per-file derived context shared by the rules.
pub struct FileCtx<'a> {
    pub rel: &'a str,
    pub lines: &'a [Line],
    /// Line is inside a `#[cfg(test)]` item or a `macro_rules!` body.
    pub exempt: Vec<bool>,
    /// Per-line, per-byte (into `code`): inside a cold delimiter group.
    pub cold: Vec<Vec<bool>>,
}

impl<'a> FileCtx<'a> {
    pub fn new(rel: &'a str, lines: &'a [Line]) -> Self {
        FileCtx { rel, lines, exempt: exempt_mask(lines), cold: cold_masks(lines) }
    }
}

/// Mark lines inside `#[cfg(test)]` items and `macro_rules!` bodies.
fn exempt_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut stack: Vec<bool> = Vec::new();
    let mut pending = false;
    for (idx, line) in lines.iter().enumerate() {
        if stack.iter().any(|&e| e) {
            mask[idx] = true;
        }
        let code = &line.code;
        if code.contains("#[cfg(test)]")
            || code.contains("#[cfg(all(test")
            || code.contains("#[cfg(any(test")
            || has_token(code, "macro_rules")
        {
            pending = true;
        }
        let mut group = 0i32;
        for c in code.chars() {
            match c {
                '(' | '[' => group += 1,
                ')' | ']' => group -= 1,
                '{' => {
                    let parent = stack.last().copied().unwrap_or(false);
                    let e = parent || pending;
                    if pending {
                        pending = false;
                    }
                    if e {
                        mask[idx] = true;
                    }
                    stack.push(e);
                }
                '}' => {
                    stack.pop();
                }
                // an attribute that ends in an item-free statement
                // (`#[cfg(test)] use ...;`) never opens a body
                ';' if group <= 0 => pending = false,
                _ => {}
            }
        }
    }
    mask
}

/// Per-byte cold mask for every line (see [`COLD_MARKERS`]).
fn cold_masks(lines: &[Line]) -> Vec<Vec<bool>> {
    let mut out = Vec::with_capacity(lines.len());
    let mut stack: Vec<bool> = Vec::new();
    for line in lines {
        let code = &line.code;
        let line_cold = COLD_MARKERS.iter().any(|m| code.contains(m));
        let mut mask = vec![false; code.len()];
        for (pos, c) in code.char_indices() {
            match c {
                '(' | '[' | '{' => {
                    let parent = stack.last().copied().unwrap_or(false);
                    stack.push(parent || line_cold);
                }
                ')' | ']' | '}' => {
                    stack.pop();
                }
                _ => {}
            }
            let now = stack.last().copied().unwrap_or(false);
            for b in mask.iter_mut().skip(pos).take(c.len_utf8()) {
                *b = now;
            }
        }
        out.push(mask);
    }
    out
}

fn finding(ctx: &FileCtx, rule: &'static str, idx: usize, message: String) -> Finding {
    Finding {
        rule,
        file: format!("rust/src/{}", ctx.rel),
        line: idx + 1,
        message,
        excerpt: ctx.lines[idx].raw.trim().to_string(),
        waived: false,
        reason: String::new(),
    }
}

fn comment_has_safety(comment: &str) -> bool {
    let lower = comment.to_lowercase();
    lower.contains("safety:") || lower.contains("# safety")
}

/// R1: every `unsafe` block/fn/impl is immediately preceded by (or
/// carries) a `// SAFETY:` comment. A covered `unsafe` line extends its
/// coverage to a directly following `unsafe` line (back-to-back blocks
/// under one argument).
pub fn r1_safety_comments(ctx: &FileCtx) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut covered_unsafe: Vec<bool> = vec![false; ctx.lines.len()];
    for idx in 0..ctx.lines.len() {
        if ctx.exempt[idx] || !has_token(&ctx.lines[idx].code, "unsafe") {
            continue;
        }
        let mut covered = comment_has_safety(&ctx.lines[idx].comment);
        if !covered {
            let mut j = idx;
            while j > 0 {
                j -= 1;
                let l = &ctx.lines[j];
                let t = l.code.trim();
                if t.is_empty() || t.starts_with("#[") || t.starts_with("#!") {
                    if comment_has_safety(&l.comment) {
                        covered = true;
                        break;
                    }
                    continue;
                }
                covered = covered_unsafe[j];
                break;
            }
        }
        if covered {
            covered_unsafe[idx] = true;
        } else {
            findings.push(finding(
                ctx,
                "R1",
                idx,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            ));
        }
    }
    findings
}

/// R2: no allocation calls in hot-path modules outside cold groups —
/// the static twin of `tests/zero_alloc.rs`.
pub fn r2_hot_path_alloc(ctx: &FileCtx) -> Vec<Finding> {
    if !is_hot(ctx.rel) {
        return Vec::new();
    }
    // (token, needs word boundary before the token)
    const BANNED: &[(&str, bool)] = &[
        ("Vec::new", true),
        ("String::new", true),
        ("Box::new", true),
        (".collect(", false),
        (".collect::<", false),
        (".to_vec(", false),
        (".to_owned(", false),
        (".to_string(", false),
        (".clone()", false),
        ("format!", true),
        ("vec![", true),
    ];
    let mut findings = Vec::new();
    for (idx, line) in ctx.lines.iter().enumerate() {
        if ctx.exempt[idx] {
            continue;
        }
        for &(tok, bounded) in BANNED {
            let positions = if bounded {
                bounded_positions(&line.code, tok)
            } else {
                line.code.match_indices(tok).map(|(p, _)| p).collect()
            };
            for pos in positions {
                if ctx.cold[idx].get(pos).copied().unwrap_or(false) {
                    continue;
                }
                findings.push(finding(
                    ctx,
                    "R2",
                    idx,
                    format!("allocation in hot-path module: `{tok}`"),
                ));
            }
        }
    }
    findings
}

/// Positions of `tok` in `code` where the preceding char is not an
/// identifier char (so `GaugeVec::new` never matches `Vec::new`, while
/// `std::vec::Vec::new` does).
fn bounded_positions(code: &str, tok: &str) -> Vec<usize> {
    code.match_indices(tok)
        .filter(|&(pos, _)| match code[..pos].chars().next_back() {
            Some(c) => !(c.is_alphanumeric() || c == '_'),
            None => true,
        })
        .map(|(pos, _)| pos)
        .collect()
}

const NARROW_TARGETS: &[&str] = &["u8", "i8", "u16", "i16", "u32", "i32", "usize"];

/// R3: no `as` casts to a narrower integer type in the hostile-input
/// decode scope — use `util::cast` instead.
pub fn r3_narrowing_casts(ctx: &FileCtx) -> Vec<Finding> {
    if !in_r3_scope(ctx.rel) {
        return Vec::new();
    }
    let mut findings = Vec::new();
    for (idx, line) in ctx.lines.iter().enumerate() {
        if ctx.exempt[idx] {
            continue;
        }
        for pos in token_positions(&line.code, "as") {
            let rest = line.code[pos + 2..].trim_start();
            let target = if rest.is_empty() {
                // rustfmt can wrap `as\n    u32` on long expressions
                ctx.lines
                    .get(idx + 1)
                    .map(|l| leading_ident(l.code.trim_start()))
                    .unwrap_or_default()
            } else {
                leading_ident(rest)
            };
            if NARROW_TARGETS.contains(&target.as_str()) {
                findings.push(finding(
                    ctx,
                    "R3",
                    idx,
                    format!("narrowing `as {target}` in decode scope — use util::cast"),
                ));
            }
        }
    }
    findings
}

fn leading_ident(s: &str) -> String {
    s.chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect()
}

/// R4: no `unwrap`/`expect`/explicit panic in library code that parses
/// socket bytes. (Panicking indexing is Miri's job — DESIGN.md §12.)
pub fn r4_no_panic_decode(ctx: &FileCtx) -> Vec<Finding> {
    if !in_r4_scope(ctx.rel) {
        return Vec::new();
    }
    const BANNED: &[(&str, bool)] = &[
        (".unwrap()", false),
        (".expect(", false),
        ("panic!", true),
        ("unreachable!", true),
        ("todo!", true),
        ("unimplemented!", true),
    ];
    let mut findings = Vec::new();
    for (idx, line) in ctx.lines.iter().enumerate() {
        if ctx.exempt[idx] {
            continue;
        }
        for &(tok, bounded) in BANNED {
            let hit = if bounded {
                !bounded_positions(&line.code, tok).is_empty()
            } else {
                line.code.contains(tok)
            };
            if hit {
                findings.push(finding(
                    ctx,
                    "R4",
                    idx,
                    format!("panic path in socket-reachable code: `{tok}`"),
                ));
            }
        }
    }
    findings
}

/// R5: `core::arch` intrinsics only under `#[target_feature]` (in
/// `simd/x86.rs`) or behind the dispatch front door `simd/mod.rs`;
/// nothing outside `simd/` touches intrinsics at all.
pub fn r5_intrinsic_hygiene(ctx: &FileCtx) -> Vec<Finding> {
    let mut findings = Vec::new();
    if !ctx.rel.starts_with("simd/") {
        for (idx, line) in ctx.lines.iter().enumerate() {
            if ctx.exempt[idx] {
                continue;
            }
            if line.code.contains("core::arch")
                || line.code.contains("std::arch")
                || !bounded_positions(&line.code, "_mm").is_empty()
            {
                findings.push(finding(
                    ctx,
                    "R5",
                    idx,
                    "core::arch intrinsics outside simd/ — go through the dispatch front door"
                        .to_string(),
                ));
            }
        }
        return findings;
    }
    if ctx.rel != "simd/x86.rs" {
        // mod.rs is the sanctioned front door; neon.rs targets baseline
        // aarch64 NEON; scalar.rs has no intrinsics by construction.
        return findings;
    }
    // x86.rs: every fn whose body touches AVX2/AVX-512 intrinsics must
    // carry #[target_feature] (SSE2 `_mm_...` is x86_64 baseline).
    for (idx, line) in ctx.lines.iter().enumerate() {
        if ctx.exempt[idx] || !has_token(&line.code, "fn") {
            continue;
        }
        let Some((open, close)) = brace_span(ctx.lines, idx) else { continue };
        let body_has_wide = (open..=close).any(|k| {
            let c = &ctx.lines[k].code;
            c.contains("_mm256_") || c.contains("_mm512_")
        });
        if !body_has_wide {
            continue;
        }
        let mut has_tf = false;
        let mut j = idx;
        while j > 0 {
            j -= 1;
            let t = ctx.lines[j].code.trim();
            if t.is_empty() {
                continue;
            }
            if t.starts_with("#[") {
                if t.contains("target_feature") {
                    has_tf = true;
                }
                continue;
            }
            break;
        }
        if !has_tf {
            findings.push(finding(
                ctx,
                "R5",
                idx,
                "fn uses AVX2/AVX-512 intrinsics without #[target_feature]".to_string(),
            ));
        }
    }
    findings
}

/// The `{`..`}` span of the body starting at or after `start` (line
/// indexes of the opening and closing brace lines).
pub fn brace_span(lines: &[Line], start: usize) -> Option<(usize, usize)> {
    let mut depth = 0i32;
    let mut group = 0i32;
    let mut open_line = None;
    for (idx, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '(' | '[' => group += 1,
                ')' | ']' => group -= 1,
                '{' => {
                    if open_line.is_none() {
                        open_line = Some(idx);
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        if let Some(open) = open_line {
                            return Some((open, idx));
                        }
                    }
                }
                // an item that ends before any body opens (a trait
                // method signature, a `use`) has no span; `;` inside
                // `[u8; 4]` and the like does not count
                ';' if open_line.is_none() && group <= 0 => return None,
                _ => {}
            }
        }
    }
    None
}

/// R6: every instrument registered in `telemetry/registry.rs` appears
/// literally in the Prometheus golden scrape test, so a new metric
/// cannot ship unpinned.
pub fn r6_registry_coverage(registry_src: &str, test_src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (idx, raw) in registry_src.lines().enumerate() {
        let mut rest = raw;
        while let Some(p) = rest.find("name: \"") {
            let tail = &rest[p + 7..];
            let Some(q) = tail.find('"') else { break };
            let name = &tail[..q];
            if name.starts_with("intsgd_") && !test_src.contains(name) {
                findings.push(Finding {
                    rule: "R6",
                    file: "rust/src/telemetry/registry.rs".to_string(),
                    line: idx + 1,
                    message: format!(
                        "instrument `{name}` is not pinned in rust/tests/telemetry.rs"
                    ),
                    excerpt: raw.trim().to_string(),
                    waived: false,
                    reason: String::new(),
                });
            }
            rest = &tail[q..];
        }
    }
    findings
}

/// Run R1–R5 on one lexed file.
pub fn run_file_rules(ctx: &FileCtx) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(r1_safety_comments(ctx));
    out.extend(r2_hot_path_alloc(ctx));
    out.extend(r3_narrowing_casts(ctx));
    out.extend(r4_no_panic_decode(ctx));
    out.extend(r5_intrinsic_hygiene(ctx));
    out
}

/// Parse and apply `// intlint: allow(...)` waivers to `findings`.
pub fn apply_waivers(lines: &[Line], findings: &mut [Finding]) {
    let spans = waiver_spans(lines);
    for f in findings.iter_mut() {
        for w in &spans {
            if w.rules.iter().any(|r| r == f.rule) && (w.start..=w.end).contains(&(f.line - 1)) {
                f.waived = true;
                f.reason.clone_from(&w.reason);
                break;
            }
        }
    }
}

struct WaiverSpan {
    rules: Vec<String>,
    start: usize,
    end: usize,
    reason: String,
}

/// Waiver grammar: `// intlint: allow(R2, R3, reason="...")`. A
/// trailing waiver covers its own line; a standalone waiver covers the
/// next code line — or, when that line opens a `fn` (skipping
/// attributes), the whole fn body. A waiver without a `reason` is
/// invalid and waives nothing.
fn waiver_spans(lines: &[Line]) -> Vec<WaiverSpan> {
    let mut spans = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some((rules, reason)) = parse_waiver(&line.comment) else { continue };
        if !line.code.trim().is_empty() {
            spans.push(WaiverSpan { rules, start: idx, end: idx, reason });
            continue;
        }
        // standalone: skip blanks and attributes to the governed item
        let mut j = idx + 1;
        let mut item = None;
        while j < lines.len() {
            let t = lines[j].code.trim();
            if t.is_empty() || t.starts_with("#[") || t.starts_with("#!") {
                j += 1;
                continue;
            }
            item = Some(j);
            break;
        }
        let Some(item) = item else { continue };
        let end = if has_token(&lines[item].code, "fn") {
            brace_span(lines, item).map(|(_, close)| close).unwrap_or(item)
        } else {
            item
        };
        spans.push(WaiverSpan { rules, start: idx, end, reason });
    }
    spans
}

/// Parse one waiver comment; `None` if absent or malformed (no reason).
pub fn parse_waiver(comment: &str) -> Option<(Vec<String>, String)> {
    let p = comment.find("intlint: allow(")?;
    let rest = &comment[p + "intlint: allow(".len()..];
    let reason_at = rest.find("reason=\"")?;
    let after = &rest[reason_at + "reason=\"".len()..];
    let endq = after.find('"')?;
    let reason = after[..endq].to_string();
    let rules: Vec<String> = rest[..reason_at]
        .split(',')
        .map(str::trim)
        .filter(|t| t.len() == 2 && t.starts_with('R') && t[1..].chars().all(|c| c.is_ascii_digit()))
        .map(str::to_string)
        .collect();
    if rules.is_empty() {
        return None;
    }
    Some((rules, reason))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_of<'a>(rel: &'a str, lines: &'a [Line]) -> FileCtx<'a> {
        FileCtx::new(rel, lines)
    }

    #[test]
    fn waiver_parsing() {
        let (rules, reason) =
            parse_waiver(" intlint: allow(R2, R3, reason=\"export path, off the hot loop\")")
                .unwrap();
        assert_eq!(rules, vec!["R2", "R3"]);
        assert_eq!(reason, "export path, off the hot loop");
        assert!(parse_waiver(" intlint: allow(R2)").is_none(), "reason is mandatory");
        assert!(parse_waiver("nothing here").is_none());
    }

    #[test]
    fn cold_groups_span_lines() {
        let src = "fn f() {\n    Err(NetError::Corrupt {\n        msg: format!(\"x\"),\n    })\n}\n";
        let lines = lex::clean(src);
        let ctx = ctx_of("net/frame.rs", &lines);
        assert!(r2_hot_path_alloc(&ctx).is_empty(), "format! inside Err( is cold");
    }

    #[test]
    fn test_mod_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let v = Vec::new(); v.unwrap() }\n}\n";
        let lines = lex::clean(src);
        let ctx = ctx_of("net/frame.rs", &lines);
        assert!(run_file_rules(&ctx).is_empty());
    }
}
