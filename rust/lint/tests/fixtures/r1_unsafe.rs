//! R1 fixture: SAFETY-comment coverage, the run rule, and the
//! false-positive guards (strings, comments, test mods).

pub fn covered(p: *const u8) -> u8 {
    // SAFETY: p is valid for reads by caller contract.
    unsafe { *p }
}

pub fn run_rule(p: *const u8) -> (u8, u8) {
    // SAFETY: both reads are in bounds by caller contract.
    let a = unsafe { *p };
    let b = unsafe { *p };
    (a, b)
}

/// # Safety
/// Doc-heading style coverage also counts.
pub unsafe fn doc_covered(p: *const u8) -> u8 {
    // SAFETY: forwarded caller contract.
    unsafe { *p }
}

pub fn uncovered(p: *const u8) -> u8 {
    unsafe { *p }
}

pub fn false_positives() -> &'static str {
    let s = "unsafe { inside a string is not code }";
    // a comment mentioning unsafe is not a violation either
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn unsafe_in_tests_is_exempt() {
        let x = 3u8;
        let p = &x as *const u8;
        let _ = unsafe { *p };
    }
}
