//! R5 fixture for simd/x86.rs scope: AVX2 bodies need
//! `#[target_feature]`; SSE2-free scalar helpers do not.

use core::arch::x86_64::*;

/// Safety: caller must ensure AVX2 is available.
#[target_feature(enable = "avx2")]
pub unsafe fn good(a: __m256i, b: __m256i) -> __m256i {
    _mm256_add_epi64(a, b)
}

/// Safety: caller must ensure AVX2 is available.
pub unsafe fn bad(a: __m256i, b: __m256i) -> __m256i {
    _mm256_add_epi64(a, b)
}

pub fn no_intrinsics(x: i64) -> i64 {
    x.wrapping_add(1)
}
