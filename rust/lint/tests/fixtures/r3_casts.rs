//! R3 fixture: narrowing `as` casts in decode scope — one live
//! violation, one waived, widening/float/pointer casts allowed, and the
//! `as_slice` identifier guard.

pub fn narrow(x: u64) -> u32 {
    x as u32
}

pub fn widen(x: u32) -> u64 {
    x as u64
}

pub fn float(x: u64) -> f64 {
    x as f64
}

pub fn pointer(p: &u8) -> *const u8 {
    p as *const u8
}

pub fn waived(x: u64) -> u8 {
    (x & 0xFF) as u8 // intlint: allow(R3, reason="masked to the low byte on this line")
}

pub fn ident_guard(v: &[u8]) -> usize {
    let as_slice = v.len();
    as_slice
}

#[cfg(test)]
mod tests {
    #[test]
    fn casts_in_tests_are_fine() {
        assert_eq!(300u64 as u8, 44);
    }
}
