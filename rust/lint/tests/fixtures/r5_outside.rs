//! R5 fixture: intrinsics outside simd/ — one violation.

use core::arch::x86_64::_mm256_add_epi64;

pub fn nothing_here() {}
