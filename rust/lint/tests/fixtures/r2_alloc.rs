//! R2 fixture: allocations in a hot module — two live violations, one
//! trailing waiver, one fn-scoped waiver, cold groups, and the
//! `GaugeVec::new` / `collect_encode_block` boundary guards.

pub struct GaugeVec;
impl GaugeVec {
    pub const fn new() -> Self {
        GaugeVec
    }
}

pub fn hot(n: usize, data: &[u8]) -> usize {
    let v: Vec<u8> = Vec::new();
    let s = format!("x{n}");
    let _ = (v, s, data);
    n
}

pub fn cold_paths(n: usize) -> Result<usize, String> {
    if n == 0 {
        return Err(format!("empty input of {n} lanes"));
    }
    Ok(n)
}

pub fn waived_inline(data: &[u8]) -> Vec<u8> {
    data.to_vec() // intlint: allow(R2, reason="startup copy, not the round loop")
}

// intlint: allow(R2, reason="constructor; steady state reuses the buffers")
pub fn waived_fn_scope(n: usize) -> Box<Vec<u8>> {
    let inner = vec![0u8; n];
    Box::new(inner)
}

pub fn boundary_guards() -> GaugeVec {
    let pool = Pool;
    pool.collect_encode_block();
    GaugeVec::new()
}

pub struct Pool;
impl Pool {
    pub fn collect_encode_block(&self) {}
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_allocate_freely() {
        let v: Vec<u8> = (0..9u8).collect();
        assert_eq!(v.len(), 9);
    }
}
