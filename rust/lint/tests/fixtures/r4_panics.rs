//! R4 fixture: panic paths in socket-reachable code — three live
//! violations, plus the `.unwrap_or(` and string/comment guards.

pub fn bad_unwrap(o: Option<u8>) -> u8 {
    o.unwrap()
}

pub fn bad_expect(r: Result<u8, ()>) -> u8 {
    r.expect("boom")
}

pub fn bad_panic(kind: u8) -> u8 {
    if kind > 7 {
        panic!("unknown frame kind {kind}");
    }
    kind
}

pub fn guards(r: Result<u8, u8>) -> u8 {
    // talking about .unwrap() in a comment is fine
    let v = r.unwrap_or(0);
    let _ = "panic! and .unwrap() inside a string are fine";
    v
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(Some(3u8).unwrap(), 3);
    }
}
