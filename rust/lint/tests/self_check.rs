//! The linter's own acceptance test: the real tree must be clean. Any
//! rule regression — or any new violation in `rust/src` — fails here
//! first, with the same output CI's static-analysis job greps.

use std::path::Path;

#[test]
fn the_real_tree_has_zero_unwaived_violations() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let report = intlint::run(&root).expect("scan rust/src");
    assert!(report.files > 40, "walked only {} files — wrong root?", report.files);
    let mut msg = String::new();
    for f in report.findings.iter().filter(|f| !f.waived) {
        msg.push_str(&format!("{}:{}: [{}] {}\n    {}\n", f.file, f.line, f.rule, f.message, f.excerpt));
    }
    assert_eq!(report.violations(), 0, "\n{msg}\n{}", report.summary_line());
    // every rule is exercised by the tree: R1/R2/R3 spend waivers today,
    // and the summary stays parseable
    assert!(report.summary_line().starts_with("INTLINT status=ok "));
}
