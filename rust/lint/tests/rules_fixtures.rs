//! Golden fixture tests: one tiny source file per rule with known
//! violations, waiver parsing, and the false-positive guards (strings
//! and comments containing banned tokens, `GaugeVec::new`,
//! `collect_encode_block`, `as_slice`, test mods).

use intlint::{analyze_file, analyze_r6, Finding};

fn by_rule<'a>(findings: &'a [Finding], rule: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.rule == rule).collect()
}

fn unwaived<'a>(findings: &'a [Finding], rule: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.rule == rule && !f.waived).collect()
}

#[test]
fn r1_uncovered_unsafe_is_the_only_violation() {
    let src = include_str!("fixtures/r1_unsafe.rs");
    let findings = analyze_file("coordinator/worker.rs", src);
    let r1 = unwaived(&findings, "R1");
    assert_eq!(r1.len(), 1, "{findings:?}");
    assert!(r1[0].excerpt.contains("unsafe { *p }"));
    // the covered fns, the run rule, the doc-heading style, the string,
    // the comment, and the test mod all stay quiet
    assert_eq!(findings.len(), 1, "{findings:?}");
}

#[test]
fn r2_hot_allocs_flagged_cold_and_waived_allocs_not() {
    let src = include_str!("fixtures/r2_alloc.rs");
    let findings = analyze_file("compress/engine.rs", src);
    let live = unwaived(&findings, "R2");
    assert_eq!(live.len(), 2, "{findings:?}");
    assert!(live.iter().any(|f| f.message.contains("Vec::new")));
    assert!(live.iter().any(|f| f.message.contains("format!")));
    // trailing waiver (.to_vec) + fn-scope waiver (vec![ and Box::new)
    let waived: Vec<_> = by_rule(&findings, "R2").into_iter().filter(|f| f.waived).collect();
    assert_eq!(waived.len(), 3, "{findings:?}");
    assert!(waived.iter().all(|f| !f.reason.is_empty()), "waivers carry reasons");
}

#[test]
fn r3_narrowing_cast_flagged_widening_and_waived_not() {
    let src = include_str!("fixtures/r3_casts.rs");
    let findings = analyze_file("net/frame.rs", src);
    let live = unwaived(&findings, "R3");
    assert_eq!(live.len(), 1, "{findings:?}");
    assert!(live[0].message.contains("as u32"));
    let waived: Vec<_> = by_rule(&findings, "R3").into_iter().filter(|f| f.waived).collect();
    assert_eq!(waived.len(), 1);
    assert!(waived[0].message.contains("as u8"));
}

#[test]
fn r4_panic_paths_flagged_guards_not() {
    let src = include_str!("fixtures/r4_panics.rs");
    let findings = analyze_file("net/tcp.rs", src);
    let live = unwaived(&findings, "R4");
    assert_eq!(live.len(), 3, "{findings:?}");
    assert!(live.iter().any(|f| f.message.contains(".unwrap()")));
    assert!(live.iter().any(|f| f.message.contains(".expect(")));
    assert!(live.iter().any(|f| f.message.contains("panic!")));
}

#[test]
fn r5_intrinsics_outside_simd_flagged() {
    let src = include_str!("fixtures/r5_outside.rs");
    let findings = analyze_file("optim/sgd.rs", src);
    let live = unwaived(&findings, "R5");
    assert_eq!(live.len(), 1, "{findings:?}");
}

#[test]
fn r5_avx2_body_without_target_feature_flagged() {
    let src = include_str!("fixtures/r5_x86.rs");
    let findings = analyze_file("simd/x86.rs", src);
    let live = unwaived(&findings, "R5");
    assert_eq!(live.len(), 1, "{findings:?}");
    assert!(live[0].excerpt.contains("fn bad"));
    // and the Safety: doc comments cover R1 for both unsafe fns
    assert!(by_rule(&findings, "R1").is_empty(), "{findings:?}");
}

#[test]
fn r6_unpinned_instrument_flagged_pinned_and_waived_not() {
    let registry = r#"
        Def { name: "intsgd_rounds_total", help: "rounds" },
        Def { name: "intsgd_missing_total", help: "oops" },
        Def { name: "intsgd_internal_total", help: "x" }, // intlint: allow(R6, reason="internal-only counter")
    "#;
    let test_src = r#"assert!(body.contains("intsgd_rounds_total"));"#;
    let findings = analyze_r6(registry, test_src);
    let live = unwaived(&findings, "R6");
    assert_eq!(live.len(), 1, "{findings:?}");
    assert!(live[0].message.contains("intsgd_missing_total"));
    let waived: Vec<_> = findings.iter().filter(|f| f.waived).collect();
    assert_eq!(waived.len(), 1);
    assert!(waived[0].message.contains("intsgd_internal_total"));
}

#[test]
fn summary_line_is_greppable_and_json_is_wellformed() {
    let src = include_str!("fixtures/r3_casts.rs");
    let report =
        intlint::Report { files: 1, findings: analyze_file("net/frame.rs", src) };
    let line = report.summary_line();
    assert!(line.starts_with("INTLINT status=fail "), "{line}");
    assert!(line.contains("rules=6"), "{line}");
    assert!(line.contains("violations=1"), "{line}");
    assert!(line.contains("waivers=1"), "{line}");
    let json = report.to_json();
    assert!(json.contains("\"status\": \"fail\""), "{json}");
    assert!(json.contains("\"rule\": \"R3\""), "{json}");
    // escaping: excerpts with quotes must not break the document
    assert!(!json.contains("\"excerpt\": \"\"\""), "{json}");
}
