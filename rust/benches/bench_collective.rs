//! Collective data-plane benchmarks: ring all-reduce (f32), exact integer
//! all-reduce (widened i64 vs typed wire lanes) and the INA switch
//! pipeline across message sizes.

use std::time::Instant;

use intsgd::collective::{allreduce_i64, allreduce_intvec, ring_allreduce_f32, InaSwitch};
use intsgd::compress::intsgd::WireInt;
use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::util::stats::median;
use intsgd::util::Rng;

fn bench<F: FnMut() -> f64>(name: &str, iters: usize, mut f: F) {
    f();
    let samples: Vec<f64> = (0..iters).map(|_| f()).collect();
    println!("{name:<36} median {:>9.3} ms", median(&samples) * 1e3);
}

fn main() {
    let n = 16;
    for &d in &[1usize << 16, 1 << 20] {
        let mut rng = Rng::new(0);
        let f32s: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let f32_views: Vec<&[f32]> = f32s.iter().map(|v| v.as_slice()).collect();
        let i64s: Vec<Vec<i64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.below(255) as i64 - 127).collect())
            .collect();
        let views: Vec<&[i64]> = i64s.iter().map(|v| v.as_slice()).collect();

        bench(&format!("ring_allreduce_f32 d=2^{}", d.trailing_zeros()), 5, || {
            let t = Instant::now();
            std::hint::black_box(ring_allreduce_f32(&f32_views));
            t.elapsed().as_secs_f64()
        });
        let mut out = Vec::new();
        bench(&format!("allreduce_i64      d=2^{}", d.trailing_zeros()), 5, || {
            let t = Instant::now();
            allreduce_i64(&views, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
        // same values stored at wire width: an eighth of the read traffic
        let i8s: Vec<IntVec> =
            i64s.iter().map(|v| IntVec::from_i64(v, Lanes::I8)).collect();
        let i8_views: Vec<&IntVec> = i8s.iter().collect();
        bench(&format!("allreduce_int8lane d=2^{}", d.trailing_zeros()), 5, || {
            let t = Instant::now();
            allreduce_intvec(&i8_views, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
        let sw = InaSwitch::default();
        bench(&format!("ina_switch_int32   d=2^{}", d.trailing_zeros()), 5, || {
            let t = Instant::now();
            sw.aggregate_into(&views, WireInt::Int32, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
    }
}
