//! Collective data-plane benchmarks.
//!
//! Part 1 (legacy): ring all-reduce (f32), exact integer all-reduce
//! (widened i64 vs typed wire lanes) and the INA switch pipeline.
//!
//! Part 2 (the `net` subsystem measurement): **leader-fold vs staged-ring
//! vs transport-ring** at d = 2^20, n in {4, 16} — the in-process
//! rank-order fold (`allreduce_intvec`), the staged ring schedule over
//! in-process channels (schedule cost without socket cost), and the same
//! schedule over real loopback TCP sockets (`net::TcpTransport`). All
//! three produce bit-identical aggregates (asserted each iteration); the
//! wall-clock spread between them is what the paper's "tailored for
//! all-reduce" claim costs on a real wire. Results land in
//! `BENCH_net.json` next to the modeled loopback cost
//! (`netsim::Network::tcp_loopback`), so measured-vs-modeled drift is
//! machine-checkable across PRs. `BENCH_SMOKE=1` runs tiny sizes for CI
//! rot-checking.
//!
//! Custom harness: criterion is not in the offline vendor set.

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::time::Instant;

use intsgd::collective::{allreduce_i64, allreduce_intvec, ring_allreduce_f32, InaSwitch};
use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::compress::{PhasedCompressor, Primitive, RoundEngine};
use intsgd::coordinator::{BlockInfo, RoundCtx, WorkerPool};
use intsgd::net::staged::{
    halving_allreduce_ints, ring_allreduce_ints, two_level_allreduce_ints,
    StagedScratch,
};
use intsgd::net::{
    ChannelTransport, MuxTransport, StagedAlgo, TcpTransport, Transport, TransportReducer,
};
use intsgd::netsim::Network;
use intsgd::scaling::MovingAverageRule;
use intsgd::util::json::{self, Json};
use intsgd::util::stats::median;
use intsgd::util::Rng;

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn bench<F: FnMut() -> f64>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let samples: Vec<f64> = (0..iters).map(|_| f()).collect();
    let med = median(&samples);
    println!("{name:<40} median {:>9.3} ms", med * 1e3);
    med
}

/// Part 1: the in-process data-plane kernels (legacy cases).
fn legacy_cases(iters: usize, sizes: &[usize]) {
    let n = 16;
    for &d in sizes {
        let mut rng = Rng::new(0);
        let f32s: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let f32_views: Vec<&[f32]> = f32s.iter().map(|v| v.as_slice()).collect();
        let i64s: Vec<Vec<i64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.below(255) as i64 - 127).collect())
            .collect();
        let views: Vec<&[i64]> = i64s.iter().map(|v| v.as_slice()).collect();

        bench(&format!("ring_allreduce_f32 d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            std::hint::black_box(ring_allreduce_f32(&f32_views));
            t.elapsed().as_secs_f64()
        });
        let mut out = Vec::new();
        bench(&format!("allreduce_i64      d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            allreduce_i64(&views, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
        // same values stored at wire width: an eighth of the read traffic
        let i8s: Vec<IntVec> =
            i64s.iter().map(|v| IntVec::from_i64(v, Lanes::I8)).collect();
        let i8_views: Vec<&IntVec> = i8s.iter().collect();
        bench(&format!("allreduce_int8lane d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            allreduce_intvec(&i8_views, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
        let sw = InaSwitch::default();
        bench(&format!("ina_switch_int32   d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            sw.aggregate_into(&views, WireInt::Int32, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
    }
}

/// One timed staged ring all-reduce across n endpoint threads; returns
/// wall seconds (straggler-inclusive: the scope joins every rank).
fn staged_round<T: Transport>(
    endpoints: &mut [T],
    msgs: &[IntVec],
    states: &mut [(StagedScratch, Vec<i64>)],
    round: u32,
) -> f64 {
    let t = Instant::now();
    std::thread::scope(|s| {
        for ((ep, msg), state) in endpoints.iter_mut().zip(msgs).zip(states.iter_mut()) {
            s.spawn(move || {
                let (scratch, out) = state;
                ring_allreduce_ints(ep, msg, Lanes::I8, round, scratch, out)
                    .expect("staged ring");
            });
        }
    });
    t.elapsed().as_secs_f64()
}

/// Part 2: leader-fold vs staged-ring (channels) vs transport-ring (TCP).
fn net_cases(iters: usize, d: usize, worlds: &[usize]) -> Json {
    let net = Network::tcp_loopback();
    let mut rows = Vec::new();
    for &n in worlds {
        // clipped like IntSGD int8: partial sums provably fit the i8 wire
        let clip = (i8::MAX as usize / n) as u64;
        let mut rng = Rng::new(7);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> = (0..d)
                    .map(|_| rng.below(2 * clip + 1) as i64 - clip as i64)
                    .collect();
                IntVec::from_i64(&vals, Lanes::I8)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        println!("\nstaged vs fold: d = 2^{}, n = {n}", d.trailing_zeros());

        let mut out = Vec::new();
        let fold_s =
            bench(&format!("leader_fold        n={n}"), iters, || {
                let t = Instant::now();
                allreduce_intvec(&views, &mut out);
                std::hint::black_box(&out);
                t.elapsed().as_secs_f64()
            });
        assert_eq!(out, want);

        let mut chan = ChannelTransport::mesh(n);
        let mut chan_states: Vec<(StagedScratch, Vec<i64>)> =
            (0..n).map(|_| Default::default()).collect();
        let mut round = 0u32;
        let chan_s = bench(&format!("staged_ring_chan   n={n}"), iters, || {
            let s = staged_round(&mut chan, &msgs, &mut chan_states, round);
            round += 1;
            s
        });
        assert_eq!(chan_states[0].1, want);

        let mut tcp = TcpTransport::loopback_mesh(n).expect("tcp mesh");
        let mut tcp_states: Vec<(StagedScratch, Vec<i64>)> =
            (0..n).map(|_| Default::default()).collect();
        let mut round = 0u32;
        let tcp_s = bench(&format!("transport_ring_tcp n={n}"), iters, || {
            let s = staged_round(&mut tcp, &msgs, &mut tcp_states, round);
            round += 1;
            s
        });
        assert_eq!(tcp_states[0].1, want);

        // modeled loopback cost of the same transfer (d bytes/worker, i8)
        let model_s = net.primitive_seconds(Primitive::AllReduce, d, n);
        println!(
            "modeled tcp_loopback all-reduce: {:.3} ms (measured/modeled {:.2})",
            model_s * 1e3,
            tcp_s / model_s.max(1e-12)
        );
        rows.push(obj(vec![
            ("d", num(d as f64)),
            ("n", num(n as f64)),
            ("leader_fold_ms", num(fold_s * 1e3)),
            ("staged_ring_channel_ms", num(chan_s * 1e3)),
            ("transport_ring_tcp_ms", num(tcp_s * 1e3)),
            ("tcp_model_ms", num(model_s * 1e3)),
            ("tcp_measured_over_model", num(tcp_s / model_s.max(1e-12))),
        ]));
    }
    Json::Arr(rows)
}

/// One timed staged all-reduce under any of the three schedules.
fn staged_round_algo<T: Transport>(
    endpoints: &mut [T],
    msgs: &[IntVec],
    states: &mut [(StagedScratch, Vec<i64>)],
    round: u32,
    algo: &str,
    group: usize,
    wire: Lanes,
) -> f64 {
    let t = Instant::now();
    std::thread::scope(|s| {
        for ((ep, msg), state) in endpoints.iter_mut().zip(msgs).zip(states.iter_mut()) {
            s.spawn(move || {
                let (scratch, out) = state;
                match algo {
                    "ring" => ring_allreduce_ints(ep, msg, wire, round, scratch, out),
                    "halving" => {
                        halving_allreduce_ints(ep, msg, wire, round, scratch, out)
                    }
                    "two_level" => two_level_allreduce_ints(
                        ep, msg, wire, round, group, scratch, out,
                    ),
                    _ => unreachable!("unknown schedule"),
                }
                .expect("staged collective");
            });
        }
    });
    t.elapsed().as_secs_f64()
}

/// Part 3: schedule scaling past the flat-ring wall — ring vs
/// halving-doubling vs two-level hierarchical over in-process channels at
/// growing world sizes. Every exact all-reduce moves the same total
/// payload (~2(n-1)d wire bytes — conservation); what the hierarchy buys
/// is the hop count, O(n) on the flat ring vs O(log n) for the others,
/// which is exactly the latency wall the channel mesh exposes (no
/// bandwidth cost in-process, schedule cost only). `worlds` pairs each n
/// with the two-level group size g (ranks per simulated "node").
fn scaling_cases(iters: usize, d: usize, worlds: &[(usize, usize)]) -> Json {
    let mut rows = Vec::new();
    for &(n, group) in worlds {
        // wide enough values to be honest work, i32 partials provably fit
        let mut rng = Rng::new(23);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(2001) as i64 - 1000).collect();
                IntVec::from_i64(&vals, Lanes::I32)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        println!(
            "\nschedule scaling: d = 2^{}, n = {n}, group = {group}",
            d.trailing_zeros()
        );

        let mut algo_s = Vec::new();
        for algo in ["ring", "halving", "two_level"] {
            let mut mesh = ChannelTransport::mesh(n);
            let mut states: Vec<(StagedScratch, Vec<i64>)> =
                (0..n).map(|_| Default::default()).collect();
            let mut round = 0u32;
            let s = bench(&format!("{algo:<18} n={n}"), iters, || {
                let s = staged_round_algo(
                    &mut mesh, &msgs, &mut states, round, algo, group, Lanes::I32,
                );
                round += 1;
                s
            });
            assert_eq!(states[0].1, want, "{algo} n={n}: wrong bits");
            algo_s.push(s);
        }

        let log2 = |x: usize| x.trailing_zeros() as usize;
        let bytes_total = 2 * (n - 1) * d * Lanes::I32.bytes();
        rows.push(obj(vec![
            ("d", num(d as f64)),
            ("n", num(n as f64)),
            ("group", num(group as f64)),
            ("wire_bytes_total", num(bytes_total as f64)),
            ("ring_ms", num(algo_s[0] * 1e3)),
            ("halving_ms", num(algo_s[1] * 1e3)),
            ("two_level_ms", num(algo_s[2] * 1e3)),
            ("ring_hops", num((2 * (n - 1)) as f64)),
            ("halving_hops", num((2 * log2(n)) as f64)),
            ("two_level_hops", num((2 + 2 * log2(n / group)) as f64)),
        ]));
    }
    Json::Arr(rows)
}

/// Part 4: full engine rounds, streamed pipeline vs barrier, IntSGD int8
/// over a `ChannelTransport` ring reducer — the tentpole's acceptance
/// measurement. Bit-parity is asserted every round; the wall-clock ratio
/// and the overlap-aware vs sequential model columns are *reported* (the
/// CI smoke runs at tiny d where the split is expected to lose — the
/// full-size run is where streamed must win).
fn pipeline_cases(iters: usize, d: usize) -> Json {
    let n = 16;
    let nblocks = 8usize;
    let mut rng = Rng::new(11);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let dims: Vec<usize> = vec![d / nblocks; nblocks];
    let mk = || {
        RoundEngine::new(Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            21,
        )) as Box<dyn PhasedCompressor>)
    };
    let mut barrier = mk();
    let mut streamed = mk();
    let mut pool = WorkerPool::for_encode(n);
    let mut red_b = TransportReducer::channel_mesh(n, StagedAlgo::Ring);
    let mut red_s = TransportReducer::channel_mesh(n, StagedAlgo::Ring);
    println!(
        "\nstreamed vs barrier engine rounds: d = 2^{}, n = {n}, {nblocks} blocks \
         (ChannelTransport ring)",
        d.trailing_zeros()
    );

    let (mut wall_b, mut wall_s) = (Vec::new(), Vec::new());
    let (mut enc, mut dec) = (Vec::new(), Vec::new());
    for round in 0..iters + 2 {
        let ctx = RoundCtx {
            round,
            n,
            d,
            lr: 0.1,
            step_norm_sq: 1e-4,
            blocks: dims
                .iter()
                .map(|&dim| BlockInfo { dim, step_norm_sq: 1e-4 / nblocks as f64 })
                .collect(),
        };
        let t = Instant::now();
        let rb = barrier
            .round_parallel_over(&mut pool, &mut red_b, &grads, &ctx)
            .expect("barrier round");
        let tb = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let rs = streamed
            .round_streamed_over(&mut pool, &mut red_s, &grads, &ctx)
            .expect("streamed round");
        let ts = t.elapsed().as_secs_f64();
        assert_eq!(rb.gtilde, rs.gtilde, "pipeline parity broke at round {round}");
        // rounds 0 (dense) and 1 (buffers first sized) are warmup
        if round >= 2 {
            wall_b.push(tb);
            wall_s.push(ts);
            enc.push(rs.encode_seconds);
            dec.push(rs.decode_seconds);
        }
        barrier.reclaim(rb);
        streamed.reclaim(rs);
    }
    pool.shutdown();

    // wire occupancy: bytes per coordinate per worker at the lane the
    // partial sums actually shipped on (int8 + clipped sums -> 1.0; a
    // compressor change that silently widens the lane shows up here and
    // trips the bench gate)
    let bytes_per_coord = red_s
        .last_wire()
        .map(|l| l.bytes() as f64)
        .expect("streamed rounds used the wire");

    let (b_med, s_med) = (median(&wall_b), median(&wall_s));
    let (e_med, d_med) = (median(&enc), median(&dec));
    // the overlap-aware model next to the sequential one, anchored on the
    // loopback preset (the closest fabric with a calibrated alpha-beta)
    let net = Network::tcp_loopback();
    let model_b = net.barrier_round_seconds(e_med, d_med, d, n);
    let model_s = net.streamed_round_seconds(e_med, d_med, d, n, nblocks);
    println!(
        "barrier  round {:>9.3} ms  (modeled loopback {:>9.3} ms)",
        b_med * 1e3,
        model_b * 1e3
    );
    println!(
        "streamed round {:>9.3} ms  (modeled loopback {:>9.3} ms)",
        s_med * 1e3,
        model_s * 1e3
    );
    println!(
        "streamed/barrier wall ratio: {:.2} (< 1 means the pipeline wins)",
        s_med / b_med.max(1e-12)
    );
    obj(vec![
        ("d", num(d as f64)),
        ("n", num(n as f64)),
        ("blocks", num(nblocks as f64)),
        ("barrier_ms", num(b_med * 1e3)),
        ("streamed_ms", num(s_med * 1e3)),
        ("streamed_over_barrier", num(s_med / b_med.max(1e-12))),
        ("model_barrier_ms", num(model_b * 1e3)),
        ("model_streamed_ms", num(model_s * 1e3)),
        ("wire_bytes_per_coord", num(bytes_per_coord)),
    ])
}

/// Part 5: multi-job serving capacity — 1 vs many concurrent staged
/// rings over ONE shared multiplexed mesh (`net::poll`), each job on its
/// own logical channel of the same sockets. Bit-parity per job is
/// asserted every pass; the per-job round rate and the wire occupancy
/// (i8 payload + the 8-byte mux envelope per frame, per coordinate) are
/// the numbers the serve-smoke CI gate watches.
fn mux_cases(iters: usize, d: usize, n: usize, job_counts: &[usize]) -> Json {
    let clip = (i8::MAX as usize / n) as u64;
    let mut rows = Vec::new();
    for &jobs in job_counts {
        let mut rng = Rng::new(31);
        let per_job: Vec<Vec<IntVec>> = (0..jobs)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        let vals: Vec<i64> = (0..d)
                            .map(|_| rng.below(2 * clip + 1) as i64 - clip as i64)
                            .collect();
                        IntVec::from_i64(&vals, Lanes::I8)
                    })
                    .collect()
            })
            .collect();
        let wants: Vec<Vec<i64>> = per_job
            .iter()
            .map(|msgs| {
                let views: Vec<&IntVec> = msgs.iter().collect();
                let mut want = Vec::new();
                allreduce_intvec(&views, &mut want);
                want
            })
            .collect();
        println!(
            "\nmux serving: d = 2^{}, n = {n}, {jobs} concurrent job(s), one mesh",
            d.trailing_zeros()
        );

        let mut mesh = MuxTransport::loopback_mesh(n, jobs).expect("mux mesh");
        let mut states: Vec<Vec<(StagedScratch, Vec<i64>)>> = (0..jobs)
            .map(|_| (0..n).map(|_| Default::default()).collect())
            .collect();
        let mut round = 0u32;
        let s = bench(&format!("mux_ring jobs={jobs:<3}   n={n}"), iters, || {
            let t = Instant::now();
            std::thread::scope(|scope| {
                for ((eps, msgs), job_states) in
                    mesh.iter_mut().zip(&per_job).zip(states.iter_mut())
                {
                    for ((ep, msg), state) in
                        eps.iter_mut().zip(msgs).zip(job_states.iter_mut())
                    {
                        scope.spawn(move || {
                            let (scratch, out) = state;
                            ring_allreduce_ints(ep, msg, Lanes::I8, round, scratch, out)
                                .expect("mux ring");
                        });
                    }
                }
            });
            round += 1;
            t.elapsed().as_secs_f64()
        });
        for (j, job_states) in states.iter().enumerate() {
            assert_eq!(job_states[0].1, wants[j], "job {j}: wrong bits over the mux");
        }
        // analytic occupancy (deterministic, so the gate holds it exactly):
        // the ring ships 2(n-1) chunks of d/n i8 coords per rank, each in
        // one mux envelope of 8 bytes
        let frames_per_rank = 2 * (n - 1);
        let bytes_per_coord = frames_per_rank as f64 / n as f64
            * Lanes::I8.bytes() as f64
            + frames_per_rank as f64 * 8.0 / d as f64;
        rows.push(obj(vec![
            ("jobs", num(jobs as f64)),
            ("n", num(n as f64)),
            ("d", num(d as f64)),
            ("round_ms", num(s * 1e3)),
            ("rounds_per_sec_per_job", num(1.0 / s.max(1e-12))),
            ("mux_bytes_per_coord", num(bytes_per_coord)),
        ]));
    }
    Json::Arr(rows)
}

fn main() {
    let smoke = smoke();
    let (iters, d_net, legacy_sizes): (usize, usize, Vec<usize>) = if smoke {
        (1, 1 << 12, vec![1 << 12])
    } else {
        (5, 1 << 20, vec![1 << 16, 1 << 20])
    };
    if smoke {
        println!("BENCH_SMOKE: tiny sizes, 1 iteration (CI rot check only)\n");
    }
    legacy_cases(iters, &legacy_sizes);
    let cases = net_cases(iters, d_net, &[4, 16]);
    // schedule scaling: pow2 worlds (halving), group divides n (two-level)
    let (d_scale, scale_worlds): (usize, Vec<(usize, usize)>) = if smoke {
        (1 << 10, vec![(4, 2), (8, 2)])
    } else {
        (1 << 16, vec![(16, 4), (64, 8), (128, 8)])
    };
    let scaling = scaling_cases(iters, d_scale, &scale_worlds);
    let pipeline = pipeline_cases(iters, d_net);
    let mux = mux_cases(iters, d_net, 4, &[1, 4]);
    let report = obj(vec![
        ("bench", Json::Str("bench_collective".into())),
        ("smoke", Json::Bool(smoke)),
        ("net", cases),
        ("scaling", scaling),
        ("pipeline", pipeline),
        ("mux", mux),
    ]);
    let path = "BENCH_net.json";
    std::fs::write(path, json::to_string(&report)).expect("write bench report");
    println!("\nwrote {path}");
}
