//! Collective data-plane benchmarks.
//!
//! Part 1 (legacy): ring all-reduce (f32), exact integer all-reduce
//! (widened i64 vs typed wire lanes) and the INA switch pipeline.
//!
//! Part 2 (the `net` subsystem measurement): **leader-fold vs staged-ring
//! vs transport-ring** at d = 2^20, n in {4, 16} — the in-process
//! rank-order fold (`allreduce_intvec`), the staged ring schedule over
//! in-process channels (schedule cost without socket cost), and the same
//! schedule over real loopback TCP sockets (`net::TcpTransport`). All
//! three produce bit-identical aggregates (asserted each iteration); the
//! wall-clock spread between them is what the paper's "tailored for
//! all-reduce" claim costs on a real wire. Results land in
//! `BENCH_net.json` next to the modeled loopback cost
//! (`netsim::Network::tcp_loopback`), so measured-vs-modeled drift is
//! machine-checkable across PRs. `BENCH_SMOKE=1` runs tiny sizes for CI
//! rot-checking.
//!
//! Custom harness: criterion is not in the offline vendor set.

use std::collections::BTreeMap;
use std::time::Instant;

use intsgd::collective::{allreduce_i64, allreduce_intvec, ring_allreduce_f32, InaSwitch};
use intsgd::compress::intsgd::WireInt;
use intsgd::compress::intvec::{IntVec, Lanes};
use intsgd::compress::Primitive;
use intsgd::net::staged::{ring_allreduce_ints, StagedScratch};
use intsgd::net::{ChannelTransport, TcpTransport, Transport};
use intsgd::netsim::Network;
use intsgd::util::json::{self, Json};
use intsgd::util::stats::median;
use intsgd::util::Rng;

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn bench<F: FnMut() -> f64>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let samples: Vec<f64> = (0..iters).map(|_| f()).collect();
    let med = median(&samples);
    println!("{name:<40} median {:>9.3} ms", med * 1e3);
    med
}

/// Part 1: the in-process data-plane kernels (legacy cases).
fn legacy_cases(iters: usize, sizes: &[usize]) {
    let n = 16;
    for &d in sizes {
        let mut rng = Rng::new(0);
        let f32s: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let f32_views: Vec<&[f32]> = f32s.iter().map(|v| v.as_slice()).collect();
        let i64s: Vec<Vec<i64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.below(255) as i64 - 127).collect())
            .collect();
        let views: Vec<&[i64]> = i64s.iter().map(|v| v.as_slice()).collect();

        bench(&format!("ring_allreduce_f32 d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            std::hint::black_box(ring_allreduce_f32(&f32_views));
            t.elapsed().as_secs_f64()
        });
        let mut out = Vec::new();
        bench(&format!("allreduce_i64      d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            allreduce_i64(&views, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
        // same values stored at wire width: an eighth of the read traffic
        let i8s: Vec<IntVec> =
            i64s.iter().map(|v| IntVec::from_i64(v, Lanes::I8)).collect();
        let i8_views: Vec<&IntVec> = i8s.iter().collect();
        bench(&format!("allreduce_int8lane d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            allreduce_intvec(&i8_views, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
        let sw = InaSwitch::default();
        bench(&format!("ina_switch_int32   d=2^{}", d.trailing_zeros()), iters, || {
            let t = Instant::now();
            sw.aggregate_into(&views, WireInt::Int32, &mut out);
            std::hint::black_box(&out);
            t.elapsed().as_secs_f64()
        });
    }
}

/// One timed staged ring all-reduce across n endpoint threads; returns
/// wall seconds (straggler-inclusive: the scope joins every rank).
fn staged_round<T: Transport>(
    endpoints: &mut [T],
    msgs: &[IntVec],
    states: &mut [(StagedScratch, Vec<i64>)],
    round: u32,
) -> f64 {
    let t = Instant::now();
    std::thread::scope(|s| {
        for ((ep, msg), state) in endpoints.iter_mut().zip(msgs).zip(states.iter_mut()) {
            s.spawn(move || {
                let (scratch, out) = state;
                ring_allreduce_ints(ep, msg, Lanes::I8, round, scratch, out)
                    .expect("staged ring");
            });
        }
    });
    t.elapsed().as_secs_f64()
}

/// Part 2: leader-fold vs staged-ring (channels) vs transport-ring (TCP).
fn net_cases(iters: usize, d: usize, worlds: &[usize]) -> Json {
    let net = Network::tcp_loopback();
    let mut rows = Vec::new();
    for &n in worlds {
        // clipped like IntSGD int8: partial sums provably fit the i8 wire
        let clip = (i8::MAX as usize / n) as u64;
        let mut rng = Rng::new(7);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> = (0..d)
                    .map(|_| rng.below(2 * clip + 1) as i64 - clip as i64)
                    .collect();
                IntVec::from_i64(&vals, Lanes::I8)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        println!("\nstaged vs fold: d = 2^{}, n = {n}", d.trailing_zeros());

        let mut out = Vec::new();
        let fold_s =
            bench(&format!("leader_fold        n={n}"), iters, || {
                let t = Instant::now();
                allreduce_intvec(&views, &mut out);
                std::hint::black_box(&out);
                t.elapsed().as_secs_f64()
            });
        assert_eq!(out, want);

        let mut chan = ChannelTransport::mesh(n);
        let mut chan_states: Vec<(StagedScratch, Vec<i64>)> =
            (0..n).map(|_| Default::default()).collect();
        let mut round = 0u32;
        let chan_s = bench(&format!("staged_ring_chan   n={n}"), iters, || {
            let s = staged_round(&mut chan, &msgs, &mut chan_states, round);
            round += 1;
            s
        });
        assert_eq!(chan_states[0].1, want);

        let mut tcp = TcpTransport::loopback_mesh(n).expect("tcp mesh");
        let mut tcp_states: Vec<(StagedScratch, Vec<i64>)> =
            (0..n).map(|_| Default::default()).collect();
        let mut round = 0u32;
        let tcp_s = bench(&format!("transport_ring_tcp n={n}"), iters, || {
            let s = staged_round(&mut tcp, &msgs, &mut tcp_states, round);
            round += 1;
            s
        });
        assert_eq!(tcp_states[0].1, want);

        // modeled loopback cost of the same transfer (d bytes/worker, i8)
        let model_s = net.primitive_seconds(Primitive::AllReduce, d, n);
        println!(
            "modeled tcp_loopback all-reduce: {:.3} ms (measured/modeled {:.2})",
            model_s * 1e3,
            tcp_s / model_s.max(1e-12)
        );
        rows.push(obj(vec![
            ("d", num(d as f64)),
            ("n", num(n as f64)),
            ("leader_fold_ms", num(fold_s * 1e3)),
            ("staged_ring_channel_ms", num(chan_s * 1e3)),
            ("transport_ring_tcp_ms", num(tcp_s * 1e3)),
            ("tcp_model_ms", num(model_s * 1e3)),
            ("tcp_measured_over_model", num(tcp_s / model_s.max(1e-12))),
        ]));
    }
    Json::Arr(rows)
}

fn main() {
    let smoke = smoke();
    let (iters, d_net, legacy_sizes): (usize, usize, Vec<usize>) = if smoke {
        (1, 1 << 12, vec![1 << 12])
    } else {
        (5, 1 << 20, vec![1 << 16, 1 << 20])
    };
    if smoke {
        println!("BENCH_SMOKE: tiny sizes, 1 iteration (CI rot check only)\n");
    }
    legacy_cases(iters, &legacy_sizes);
    let cases = net_cases(iters, d_net, &[4, 16]);
    let report = obj(vec![
        ("bench", Json::Str("bench_collective".into())),
        ("smoke", Json::Bool(smoke)),
        ("net", cases),
    ]);
    let path = "BENCH_net.json";
    std::fs::write(path, json::to_string(&report)).expect("write bench report");
    println!("\nwrote {path}");
}
