//! Figure 5 regeneration bench: a reduced beta x epsilon sensitivity grid
//! on the classifier task. Full protocol: `repro exp fig5 rounds=600`.

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use intsgd::config::Config;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP bench_fig5: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::new();
    for kv in [
        "workers=2",
        "rounds=8",
        "seeds=1",
        "eval_every=4",
        "train_examples=512",
        "test_examples=256",
        "task=classifier",
        "out_dir=results/bench",
    ] {
        cfg.set_kv(kv).unwrap();
    }
    let t = std::time::Instant::now();
    intsgd::experiments::run("fig5", &cfg).expect("fig5");
    println!("bench_fig5 (abbreviated): {:.1}s total", t.elapsed().as_secs_f64());
}
