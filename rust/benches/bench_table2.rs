//! Table 2 regeneration bench: abbreviated end-to-end runs of all seven
//! algorithms on the classifier task, printing the paper-style table.
//! Full protocol: `repro exp table2 workers=16 rounds=600 seeds=3`.

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use intsgd::config::Config;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP bench_table2: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::new();
    for kv in [
        "workers=2",
        "rounds=10",
        "seeds=1",
        "eval_every=5",
        "train_examples=512",
        "test_examples=256",
        "out_dir=results/bench",
    ] {
        cfg.set_kv(kv).unwrap();
    }
    let t = std::time::Instant::now();
    intsgd::experiments::run("table2", &cfg).expect("table2");
    println!("bench_table2 (abbreviated): {:.1}s total", t.elapsed().as_secs_f64());
}
