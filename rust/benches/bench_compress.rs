//! Compression hot-path microbenchmarks (the §Perf L3 instrument).
//!
//! Part 1 measures per-round encode+reduce+decode wall time of every
//! compressor at the classifier gradient size (d = 820,874), n = 16
//! workers — the quantity behind the "Computation Overhead" column of
//! Tables 2-3. Part 2 is the parallel-round engine measurement: IntSGD at
//! d = 2^20, n = 4, sequential reference vs encode-on-worker-threads,
//! reporting the wallclock speedup (the refactor's acceptance number).
//! Custom harness: criterion is not in the offline vendor set.

use std::time::Instant;

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::powersgd::BlockShape;
use intsgd::compress::{
    HeuristicIntSgd, IdentitySgd, NatSgd, PhasedCompressor, PowerSgd, Qsgd,
    RoundEngine, SignSgd, TopK,
};
use intsgd::coordinator::{BlockInfo, RoundCtx, WorkerPool};
use intsgd::scaling::MovingAverageRule;
use intsgd::util::stats::median;
use intsgd::util::Rng;

fn bench<F: FnMut() -> f64>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        samples.push(f());
    }
    let med = median(&samples);
    println!(
        "{name:<28} median {:>9.3} ms  min {:>9.3} ms  ({} iters)",
        med * 1e3,
        samples.iter().cloned().fold(f64::INFINITY, f64::min) * 1e3,
        iters
    );
    med
}

fn zoo_rounds() {
    // classifier layout: 3 weight matrices + 3 biases
    let layout: Vec<Vec<usize>> = vec![
        vec![3072, 256],
        vec![256],
        vec![256, 128],
        vec![128],
        vec![128, 10],
        vec![10],
    ];
    let numels: Vec<usize> = layout.iter().map(|s| s.iter().product()).collect();
    let d: usize = numels.iter().sum();
    let n = 16;
    let mut rng = Rng::new(0);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let ctx = RoundCtx {
        round: 2,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: layout
            .iter()
            .map(|s| BlockInfo {
                dim: s.iter().product(),
                step_norm_sq: 1e-4 / 6.0,
            })
            .collect(),
    };
    println!("compression round: d = {d}, n = {n} (per-round wall time, sequential)\n");

    let mk_int = |r, w| {
        IntSgd::new(r, w, Box::new(MovingAverageRule::default_paper()), n, 1)
    };
    let algos: Vec<(&str, Box<dyn PhasedCompressor>)> = vec![
        ("intsgd_random_int8", Box::new(mk_int(Rounding::Stochastic, WireInt::Int8))),
        ("intsgd_determ_int8", Box::new(mk_int(Rounding::Deterministic, WireInt::Int8))),
        ("intsgd_random_int32", Box::new(mk_int(Rounding::Stochastic, WireInt::Int32))),
        ("heuristic_int8", Box::new(HeuristicIntSgd::new(8))),
        ("qsgd_64", Box::new(Qsgd::new(64, numels.clone(), n, 2))),
        ("natsgd", Box::new(NatSgd::new(n, 3))),
        (
            "powersgd_rank2",
            Box::new(PowerSgd::new(
                2,
                layout.iter().map(|s| BlockShape { dims: s.clone() }).collect(),
                n,
                4,
            )),
        ),
        ("topk_1pct", Box::new(TopK::new(0.01, n))),
        ("ef_signsgd", Box::new(SignSgd::new(n))),
        ("sgd_fp32_ring", Box::new(IdentitySgd::allreduce())),
    ];
    for (name, comp) in algos {
        let mut engine = RoundEngine::new(comp);
        bench(name, 5, || {
            let t = Instant::now();
            let r = engine.round_sequential(&grads, &ctx);
            std::hint::black_box(&r.gtilde);
            t.elapsed().as_secs_f64()
        });
    }
}

/// The refactor's acceptance measurement: one IntSGD round at d = 2^20
/// with n = 4 workers, sequential (leader encodes all ranks) vs parallel
/// (each rank encodes on its worker thread).
fn parallel_vs_sequential() {
    let d = 1 << 20;
    let n = 4;
    let mut rng = Rng::new(7);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let ctx = RoundCtx {
        round: 2,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: vec![BlockInfo { dim: d, step_norm_sq: 1e-4 }],
    };
    let mk = || {
        Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            1,
        )) as Box<dyn PhasedCompressor>
    };
    println!("\nparallel round engine: intsgd_random_int8, d = 2^20, n = {n}\n");

    let mut seq = RoundEngine::new(mk());
    let mut seq_encode_samples = Vec::new();
    let seq_wall = bench("round sequential", 9, || {
        let t = Instant::now();
        let r = seq.round_sequential(&grads, &ctx);
        std::hint::black_box(&r.gtilde);
        seq_encode_samples.push(r.encode_seconds); // per-worker share: total / n
        t.elapsed().as_secs_f64()
    });

    let mut par = RoundEngine::new(mk());
    let mut pool = WorkerPool::for_encode(n);
    let mut par_encode_samples = Vec::new();
    let mut owned = grads.clone();
    let par_wall = bench("round parallel (pool)", 9, || {
        let t = Instant::now();
        let r = par.round_parallel(&mut pool, &mut owned, &ctx);
        std::hint::black_box(&r.gtilde);
        par_encode_samples.push(r.encode_seconds); // straggler max across ranks
        t.elapsed().as_secs_f64()
    });
    pool.shutdown();
    // bench() runs one untimed warmup call whose encode sample also lands
    // in the vec; drop it so the encode medians cover the same iterations
    // as the wall-clock medians.
    let seq_encode = median(&seq_encode_samples[1..]);
    let par_encode = median(&par_encode_samples[1..]);

    // the sequential path serializes n encodes on the leader: its encode
    // wallclock is n * (per-worker share); the parallel path pays the
    // straggler max once.
    let seq_encode_wall = seq_encode * n as f64;
    println!(
        "\nencode wallclock: sequential {:.3} ms (n x per-worker share) vs \
         parallel straggler {:.3} ms  => {:.2}x",
        seq_encode_wall * 1e3,
        par_encode * 1e3,
        seq_encode_wall / par_encode.max(1e-12)
    );
    println!(
        "round wallclock:  sequential {:.3} ms vs parallel {:.3} ms  => {:.2}x",
        seq_wall * 1e3,
        par_wall * 1e3,
        seq_wall / par_wall.max(1e-12)
    );
}

fn main() {
    zoo_rounds();
    parallel_vs_sequential();
}
