//! Compression hot-path microbenchmarks (the §Perf L3 instrument).
//!
//! Measures per-round encode+aggregate+decode wall time of every
//! compressor at the classifier gradient size (d = 820,874), n = 16
//! workers — the quantity behind the "Computation Overhead" column of
//! Tables 2-3. Custom harness: criterion is not in the offline vendor set.

use std::time::Instant;

use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::powersgd::BlockShape;
use intsgd::compress::{
    DistributedCompressor, HeuristicIntSgd, IdentitySgd, NatSgd, PowerSgd, Qsgd,
    SignSgd, TopK,
};
use intsgd::coordinator::{BlockInfo, RoundCtx};
use intsgd::scaling::MovingAverageRule;
use intsgd::util::stats::median;
use intsgd::util::Rng;

fn bench<F: FnMut() -> f64>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        samples.push(f());
    }
    println!(
        "{name:<28} median {:>9.3} ms  min {:>9.3} ms  ({} iters)",
        median(&samples) * 1e3,
        samples.iter().cloned().fold(f64::INFINITY, f64::min) * 1e3,
        iters
    );
}

fn main() {
    // classifier layout: 3 weight matrices + 3 biases
    let layout: Vec<Vec<usize>> = vec![
        vec![3072, 256],
        vec![256],
        vec![256, 128],
        vec![128],
        vec![128, 10],
        vec![10],
    ];
    let numels: Vec<usize> = layout.iter().map(|s| s.iter().product()).collect();
    let d: usize = numels.iter().sum();
    let n = 16;
    let mut rng = Rng::new(0);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let ctx = RoundCtx {
        round: 2,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: layout
            .iter()
            .map(|s| BlockInfo {
                dim: s.iter().product(),
                step_norm_sq: 1e-4 / 6.0,
            })
            .collect(),
    };
    println!("compression round: d = {d}, n = {n} (per-round wall time)\n");

    let mk_int = |r, w| {
        IntSgd::new(r, w, Box::new(MovingAverageRule::default_paper()), n, 1)
    };
    let mut algos: Vec<(&str, Box<dyn DistributedCompressor>)> = vec![
        ("intsgd_random_int8", Box::new(mk_int(Rounding::Stochastic, WireInt::Int8))),
        ("intsgd_determ_int8", Box::new(mk_int(Rounding::Deterministic, WireInt::Int8))),
        ("intsgd_random_int32", Box::new(mk_int(Rounding::Stochastic, WireInt::Int32))),
        ("heuristic_int8", Box::new(HeuristicIntSgd::new(8))),
        ("qsgd_64", Box::new(Qsgd::new(64, numels.clone(), n, 2))),
        ("natsgd", Box::new(NatSgd::new(n, 3))),
        (
            "powersgd_rank2",
            Box::new(PowerSgd::new(
                2,
                layout.iter().map(|s| BlockShape { dims: s.clone() }).collect(),
                n,
                4,
            )),
        ),
        ("topk_1pct", Box::new(TopK::new(0.01, n))),
        ("ef_signsgd", Box::new(SignSgd::new(n))),
        ("sgd_fp32_ring", Box::new(IdentitySgd::allreduce())),
    ];
    for (name, comp) in algos.iter_mut() {
        bench(name, 5, || {
            let t = Instant::now();
            let r = comp.round(&grads, &ctx);
            std::hint::black_box(&r.gtilde);
            t.elapsed().as_secs_f64()
        });
    }
}
