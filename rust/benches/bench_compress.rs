//! Compression hot-path microbenchmarks (the §Perf L3 instrument).
//!
//! Part 1 measures per-round wall time AND the per-phase breakdown
//! (encode / reduce / decode, from `RoundResult`) of every compressor at
//! the classifier gradient size, n = 16 workers — the quantity behind the
//! "Computation Overhead" column of Tables 2-3. Part 2 pits the typed
//! zero-allocation hot path against a widened-`i64` baseline that
//! reproduces the pre-typed-buffer data layout (IntSGD int8, d = 2^20,
//! n = 16) — the acceptance measurement of the typed-buffer refactor.
//! Part 3 is the parallel-round engine measurement (sequential reference
//! vs encode-on-worker-threads + chunked reduce).
//!
//! Every number is also written to `BENCH_compress.json` (machine
//! readable, schema documented in DESIGN.md §5) so future PRs have a perf
//! trajectory to compare against. Set `BENCH_SMOKE=1` for a seconds-long
//! CI smoke run (tiny d, 1 iteration) that only keeps the targets honest.
//!
//! Custom harness: criterion is not in the offline vendor set.

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::time::Instant;

use intsgd::collective::allreduce_i64;
use intsgd::compress::intsgd::{IntSgd, Rounding, WireInt};
use intsgd::compress::powersgd::BlockShape;
use intsgd::compress::{
    HeuristicIntSgd, IdentitySgd, NatSgd, PhasedCompressor, PowerSgd, Qsgd,
    RoundEngine, SignSgd, TopK,
};
use intsgd::coordinator::{BlockInfo, RoundCtx, WorkerPool};
use intsgd::netsim::Network;
use intsgd::scaling::MovingAverageRule;
use intsgd::util::json::{self, Json};
use intsgd::util::stats::median;
use intsgd::util::Rng;

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Medians of one benched round configuration, milliseconds.
#[derive(Clone, Copy, Default)]
struct Phases {
    wall: f64,
    encode: f64,
    reduce: f64,
    decode: f64,
}

impl Phases {
    fn json(&self) -> Json {
        obj(vec![
            ("wall_ms", num(self.wall)),
            ("encode_ms", num(self.encode)),
            ("reduce_ms", num(self.reduce)),
            ("decode_ms", num(self.decode)),
        ])
    }
}

fn print_phases(name: &str, p: &Phases, iters: usize) {
    println!(
        "{name:<28} wall {:>9.3} ms  encode {:>9.3} ms  reduce {:>9.3} ms  \
         decode {:>9.3} ms  ({iters} iters)",
        p.wall, p.encode, p.reduce, p.decode
    );
}

/// Run `iters` timed engine rounds (after one warmup) and return the
/// per-phase medians in milliseconds.
fn bench_rounds<F>(iters: usize, mut round: F) -> Phases
where
    F: FnMut() -> (f64, f64, f64, f64), // wall, encode, reduce, decode (s)
{
    round(); // warmup
    let mut wall = Vec::with_capacity(iters);
    let mut enc = Vec::with_capacity(iters);
    let mut red = Vec::with_capacity(iters);
    let mut dec = Vec::with_capacity(iters);
    for _ in 0..iters {
        let (w, e, r, d) = round();
        wall.push(w);
        enc.push(e);
        red.push(r);
        dec.push(d);
    }
    Phases {
        wall: median(&wall) * 1e3,
        encode: median(&enc) * 1e3,
        reduce: median(&red) * 1e3,
        decode: median(&dec) * 1e3,
    }
}

fn zoo_rounds(iters: usize, shrink: usize) -> Json {
    // classifier layout: 3 weight matrices + 3 biases (shrunk in smoke)
    let layout: Vec<Vec<usize>> = vec![
        vec![3072 / shrink, 256 / shrink.min(16)],
        vec![256 / shrink.min(16)],
        vec![256 / shrink.min(16), 128 / shrink.min(16)],
        vec![128 / shrink.min(16)],
        vec![128 / shrink.min(16), 10],
        vec![10],
    ];
    let numels: Vec<usize> = layout.iter().map(|s| s.iter().product()).collect();
    let d: usize = numels.iter().sum();
    let n = 16;
    let net = Network::paper_cluster();
    let mut rng = Rng::new(0);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let ctx = RoundCtx {
        round: 2,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: layout
            .iter()
            .map(|s| BlockInfo {
                dim: s.iter().product(),
                step_norm_sq: 1e-4 / 6.0,
            })
            .collect(),
    };
    println!("compression round: d = {d}, n = {n} (per-phase medians, sequential)\n");

    let mk_int = |r, w| {
        IntSgd::new(r, w, Box::new(MovingAverageRule::default_paper()), n, 1)
    };
    let algos: Vec<(&str, Box<dyn PhasedCompressor>)> = vec![
        ("intsgd_random_int8", Box::new(mk_int(Rounding::Stochastic, WireInt::Int8))),
        ("intsgd_determ_int8", Box::new(mk_int(Rounding::Deterministic, WireInt::Int8))),
        ("intsgd_random_int32", Box::new(mk_int(Rounding::Stochastic, WireInt::Int32))),
        ("heuristic_int8", Box::new(HeuristicIntSgd::new(8))),
        ("qsgd_64", Box::new(Qsgd::new(64, numels.clone(), n, 2))),
        ("natsgd", Box::new(NatSgd::new(n, 3))),
        (
            "powersgd_rank2",
            Box::new(PowerSgd::new(
                2,
                layout.iter().map(|s| BlockShape { dims: s.clone() }).collect(),
                n,
                4,
            )),
        ),
        ("topk_1pct", Box::new(TopK::new(0.01, n))),
        ("ef_signsgd", Box::new(SignSgd::new(n))),
        ("sgd_fp32_ring", Box::new(IdentitySgd::allreduce())),
    ];
    let mut rows = Vec::new();
    for (name, comp) in algos {
        let mut engine = RoundEngine::new(comp);
        let mut comm_model = 0.0;
        let phases = bench_rounds(iters, || {
            let t = Instant::now();
            let r = engine.round_sequential(&grads, &ctx);
            let wall = t.elapsed().as_secs_f64();
            std::hint::black_box(&r.gtilde);
            let out = (wall, r.encode_seconds, r.reduce_seconds, r.decode_seconds);
            comm_model = net.round_breakdown(&r, n).comm_model;
            engine.reclaim(r);
            out
        });
        print_phases(name, &phases, iters);
        let mut row = phases.json();
        if let Json::Obj(m) = &mut row {
            m.insert("name".into(), Json::Str(name.into()));
            m.insert("comm_model_ms".into(), num(comm_model * 1e3));
        }
        rows.push(row);
    }
    obj(vec![
        ("d", num(d as f64)),
        ("n", num(n as f64)),
        ("algos", Json::Arr(rows)),
    ])
}

/// The typed-buffer acceptance measurement: IntSGD int8 at d = 2^20,
/// n = 16, typed fused hot path (sequential and pool-parallel) vs a
/// widened-i64 baseline reproducing the pre-typed data layout (i64
/// message vectors, per-round view slices, i64 reduce reads).
fn hotpath(iters: usize, d: usize) -> Json {
    let n = 16;
    let mut rng = Rng::new(7);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let ctx = RoundCtx {
        round: 2,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: vec![BlockInfo { dim: d, step_norm_sq: 1e-4 }],
    };
    let mk = || {
        Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            1,
        )) as Box<dyn PhasedCompressor>
    };
    println!("\nintsgd int8 hot path: d = {d}, n = {n}\n");

    // --- typed fused path, sequential engine ------------------------------
    let mut seq = RoundEngine::new(mk());
    let mut alpha = 0.0f64;
    let typed_seq = bench_rounds(iters, || {
        let t = Instant::now();
        let r = seq.round_sequential(&grads, &ctx);
        let wall = t.elapsed().as_secs_f64();
        std::hint::black_box(&r.gtilde);
        alpha = r.alpha;
        let out = (wall, r.encode_seconds, r.reduce_seconds, r.decode_seconds);
        seq.reclaim(r);
        out
    });
    print_phases("typed fused (seq)", &typed_seq, iters);

    // --- typed fused path, worker-pool engine -----------------------------
    let mut par = RoundEngine::new(mk());
    let mut pool = WorkerPool::for_encode(n);
    let typed_par = bench_rounds(iters, || {
        let t = Instant::now();
        let r = par.round_parallel(&mut pool, &grads, &ctx);
        let wall = t.elapsed().as_secs_f64();
        std::hint::black_box(&r.gtilde);
        let out = (wall, r.encode_seconds, r.reduce_seconds, r.decode_seconds);
        par.reclaim(r);
        out
    });
    pool.shutdown();
    print_phases("typed fused (pool)", &typed_par, iters);

    // --- widened-i64 baseline (pre-typed-buffer data layout) --------------
    // encode: the reference i64 API (same arithmetic, 8x the lane width);
    // reduce: per-round view vec + i64 reads; decode: identical divide.
    let clip = i8::MAX as i64 / n as i64;
    let mut streams: Vec<Rng> = {
        let mut root = Rng::new(1);
        (0..n).map(|i| root.fork(i as u64)).collect()
    };
    let mut msgs: Vec<Vec<i64>> = vec![Vec::new(); n];
    let mut sum: Vec<i64> = Vec::new();
    let mut gtilde: Vec<f32> = Vec::new();
    let baseline = bench_rounds(iters, || {
        let t0 = Instant::now();
        for (rank, grad) in grads.iter().enumerate() {
            IntSgd::encode(
                Rounding::Stochastic,
                grad,
                alpha,
                clip,
                &mut streams[rank],
                &mut msgs[rank],
            );
        }
        let t1 = Instant::now();
        let views: Vec<&[i64]> = msgs.iter().map(|m| m.as_slice()).collect();
        allreduce_i64(&views, &mut sum);
        let t2 = Instant::now();
        let inv = 1.0 / (n as f64 * alpha);
        gtilde.clear();
        gtilde.extend(sum.iter().map(|&s| (s as f64 * inv) as f32));
        std::hint::black_box(&gtilde);
        let t3 = Instant::now();
        (
            (t3 - t0).as_secs_f64(),
            // per-worker share, mirroring the sequential engine's account
            (t1 - t0).as_secs_f64() / n as f64,
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
        )
    });
    print_phases("widened i64 baseline", &baseline, iters);

    let speedup_seq = baseline.wall / typed_seq.wall.max(1e-9);
    let speedup_par = baseline.wall / typed_par.wall.max(1e-9);
    println!(
        "\nencode+reduce+decode speedup vs widened-i64 baseline: \
         {speedup_seq:.2}x sequential, {speedup_par:.2}x pool-parallel"
    );
    obj(vec![
        ("d", num(d as f64)),
        ("n", num(n as f64)),
        ("typed_sequential", typed_seq.json()),
        ("typed_parallel", typed_par.json()),
        ("widened_baseline", baseline.json()),
        ("speedup_sequential", num(speedup_seq)),
        ("speedup_parallel", num(speedup_par)),
    ])
}

/// The parallel-round engine measurement: sequential (leader encodes all
/// ranks) vs parallel (each rank encodes on its worker thread, integer
/// reduce chunked across the pool) at n = 4.
fn parallel_vs_sequential(iters: usize, d: usize) -> Json {
    let n = 4;
    let mut rng = Rng::new(7);
    let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d, 0.05)).collect();
    let ctx = RoundCtx {
        round: 2,
        n,
        d,
        lr: 0.1,
        step_norm_sq: 1e-4,
        blocks: vec![BlockInfo { dim: d, step_norm_sq: 1e-4 }],
    };
    let mk = || {
        Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(MovingAverageRule::default_paper()),
            n,
            1,
        )) as Box<dyn PhasedCompressor>
    };
    println!("\nparallel round engine: intsgd_random_int8, d = {d}, n = {n}\n");

    let mut seq = RoundEngine::new(mk());
    let seq_phases = bench_rounds(iters, || {
        let t = Instant::now();
        let r = seq.round_sequential(&grads, &ctx);
        let wall = t.elapsed().as_secs_f64();
        std::hint::black_box(&r.gtilde);
        let out = (wall, r.encode_seconds, r.reduce_seconds, r.decode_seconds);
        seq.reclaim(r);
        out
    });
    print_phases("round sequential", &seq_phases, iters);

    let mut par = RoundEngine::new(mk());
    let mut pool = WorkerPool::for_encode(n);
    let par_phases = bench_rounds(iters, || {
        let t = Instant::now();
        let r = par.round_parallel(&mut pool, &grads, &ctx);
        let wall = t.elapsed().as_secs_f64();
        std::hint::black_box(&r.gtilde);
        let out = (wall, r.encode_seconds, r.reduce_seconds, r.decode_seconds);
        par.reclaim(r);
        out
    });
    pool.shutdown();
    print_phases("round parallel (pool)", &par_phases, iters);

    // the sequential path serializes n encodes on the leader: its encode
    // wallclock is n * (per-worker share); the parallel path pays the
    // straggler max once.
    let seq_encode_wall = seq_phases.encode * n as f64;
    println!(
        "\nencode wallclock: sequential {:.3} ms (n x per-worker share) vs \
         parallel straggler {:.3} ms  => {:.2}x",
        seq_encode_wall,
        par_phases.encode,
        seq_encode_wall / par_phases.encode.max(1e-9)
    );
    println!(
        "round wallclock:  sequential {:.3} ms vs parallel {:.3} ms  => {:.2}x",
        seq_phases.wall,
        par_phases.wall,
        seq_phases.wall / par_phases.wall.max(1e-9)
    );
    obj(vec![
        ("d", num(d as f64)),
        ("n", num(n as f64)),
        ("sequential", seq_phases.json()),
        ("parallel", par_phases.json()),
        ("wall_speedup", num(seq_phases.wall / par_phases.wall.max(1e-9))),
    ])
}

/// Median milliseconds of `iters` runs of `f` (after one warmup).
fn bench_ms<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    median(&samples) * 1e3
}

/// Part 4: the kernel layer head-to-head — each dispatched kernel
/// (`intsgd::simd`, whatever backend detection picked) against the
/// scalar spec (`intsgd::simd::scalar`) on the d = 2^20, n = 16 hot
/// shape. GB/s counts bytes read + written by the kernel. Without
/// `--features simd` (or under INTSGD_FORCE_SCALAR) both columns time
/// the same code and the speedup sits at ~1.0 — the rows then serve as
/// the scalar-regression guard for `tools/bench_gate.py`.
fn kernel_rows(iters: usize, d: usize) -> Json {
    use intsgd::simd::{self, scalar};
    let n = 16usize;
    let mut rng = Rng::new(0xBE9C);
    let grad = rng.normal_vec(d, 0.05);
    let grad_b = rng.normal_vec(d, 0.05);
    let msgs: Vec<Vec<i8>> = (0..n)
        .map(|_| (0..d).map(|_| (rng.below(15) as i64 - 7) as i8).collect())
        .collect();
    let views: Vec<&[i8]> = msgs.iter().map(|m| m.as_slice()).collect();
    let sum: Vec<i64> = (0..d).map(|_| rng.below(2000) as i64 - 1000).collect();
    let mut f32_out = vec![0.0f32; d];
    let mut acc = vec![0i64; d];
    println!(
        "\nkernel layer: d = {d}, n = {n}, backend = {} \
         (dispatched vs scalar spec)\n",
        simd::backend_name()
    );

    let mut rows = Vec::new();
    let row = |name: &str, bytes: usize, simd_ms: f64, scalar_ms: f64| {
        let gbps = bytes as f64 / (simd_ms / 1e3).max(1e-12) / 1e9;
        let speedup = scalar_ms / simd_ms.max(1e-9);
        println!(
            "{name:<22} dispatched {simd_ms:>8.3} ms  scalar {scalar_ms:>8.3} ms  \
             {gbps:>7.2} GB/s  {speedup:>5.2}x"
        );
        obj(vec![
            ("name", Json::Str(name.to_string())),
            ("simd_ms", num(simd_ms)),
            ("scalar_ms", num(scalar_ms)),
            ("gbps", num(gbps)),
            ("speedup", num(speedup)),
        ])
    };
    let mut sink = 0.0f64;

    // encode: read 4d bytes of f32, write 4d
    let s = bench_ms(iters, || simd::round_stoch(&grad, 7.5, 0x5EED, 0, &mut f32_out));
    let sc = bench_ms(iters, || scalar::round_stoch(&grad, 7.5, 0x5EED, 0, &mut f32_out));
    rows.push(row("encode_round_stoch", 8 * d, s, sc));

    // reduce: read n*d bytes of i8 + 8d of acc, write 8d
    let s = bench_ms(iters, || simd::sum_ranks_i8(&views, &mut acc));
    let sc = bench_ms(iters, || scalar::sum_ranks_i8(&views, &mut acc));
    rows.push(row("reduce_sum_ranks_i8", (n + 16) * d, s, sc));

    // decode: read 8d bytes of i64, write 4d of f32
    let s = bench_ms(iters, || simd::decode_scale_i64(&sum, 1.0 / 48.0, &mut f32_out));
    let sc = bench_ms(iters, || scalar::decode_scale_i64(&sum, 1.0 / 48.0, &mut f32_out));
    rows.push(row("decode_scale_i64", 12 * d, s, sc));

    // norm fold: read 4d + 4d bytes of f32
    let s = bench_ms(iters, || sink += simd::sq_diff_norm(&grad, &grad_b));
    let sc = bench_ms(iters, || sink += scalar::sq_diff_norm(&grad, &grad_b));
    rows.push(row("norm_sq_diff", 8 * d, s, sc));

    std::hint::black_box((&f32_out, &acc, sink));
    obj(vec![
        ("d", num(d as f64)),
        ("n", num(n as f64)),
        ("backend", Json::Str(simd::backend_name().into())),
        ("rows", Json::Arr(rows)),
    ])
}

fn main() {
    let smoke = smoke();
    let (iters, shrink, d_hot) = if smoke {
        (1, 16, 1 << 12)
    } else {
        (9, 1, 1 << 20)
    };
    if smoke {
        println!("BENCH_SMOKE: tiny sizes, 1 iteration (CI rot check only)\n");
    }
    let zoo = zoo_rounds(if smoke { 1 } else { 5 }, shrink);
    let hot = hotpath(iters, d_hot);
    let par = parallel_vs_sequential(iters, d_hot);
    let kernels = kernel_rows(if smoke { 1 } else { 25 }, d_hot);
    let report = obj(vec![
        ("bench", Json::Str("bench_compress".into())),
        ("smoke", Json::Bool(smoke)),
        ("zoo", zoo),
        ("intsgd_int8_hotpath", hot),
        ("parallel_engine", par),
        ("kernels", kernels),
    ]);
    let path = "BENCH_compress.json";
    std::fs::write(path, json::to_string(&report)).expect("write bench report");
    println!("\nwrote {path}");
}
