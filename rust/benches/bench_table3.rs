//! Table 3 regeneration bench: abbreviated end-to-end runs of all seven
//! algorithms on the LM task, printing the paper-style table.
//! Full protocol: `repro exp table3 workers=16 rounds=600 seeds=3`.

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use intsgd::config::Config;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP bench_table3: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::new();
    for kv in [
        "workers=2",
        "rounds=10",
        "seeds=1",
        "eval_every=5",
        "corpus_len=20000",
        "out_dir=results/bench",
    ] {
        cfg.set_kv(kv).unwrap();
    }
    let t = std::time::Instant::now();
    intsgd::experiments::run("table3", &cfg).expect("table3");
    println!("bench_table3 (abbreviated): {:.1}s total", t.elapsed().as_secs_f64());
}
