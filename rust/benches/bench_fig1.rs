//! Figure 1 regeneration bench: an abbreviated end-to-end run of the
//! fig1 protocol (IntSGD vs Heuristic vs SGD) over the real PJRT path.
//! `cargo bench` keeps this tractable (2 workers, 12 rounds); the full
//! protocol is `repro exp fig1 workers=16 rounds=600 seeds=3`.

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use intsgd::config::Config;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP bench_fig1: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::new();
    for kv in [
        "workers=2",
        "rounds=12",
        "seeds=1",
        "eval_every=6",
        "train_examples=512",
        "test_examples=256",
        "corpus_len=20000",
        "task=classifier",
        "out_dir=results/bench",
    ] {
        cfg.set_kv(kv).unwrap();
    }
    let t = std::time::Instant::now();
    intsgd::experiments::run("fig1", &cfg).expect("fig1");
    println!("bench_fig1 (abbreviated): {:.1}s total", t.elapsed().as_secs_f64());
}
