//! Figure 2 regeneration bench: the FP32-vs-Int8 all-reduce time table
//! from the network cost model (exactly the figure's series).

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use intsgd::config::Config;

fn main() {
    let mut cfg = Config::new();
    cfg.set_kv("workers=16").unwrap();
    cfg.set_kv("out_dir=results/bench").unwrap();
    let t = std::time::Instant::now();
    intsgd::experiments::run("fig2", &cfg).expect("fig2");
    println!("bench_fig2: {:.3}s", t.elapsed().as_secs_f64());
}
