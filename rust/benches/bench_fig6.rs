//! Figure 6 regeneration bench: IntGD vs IntDIANA vs VR-IntDIANA on the
//! a5a-geometry dataset (abbreviated). Full protocol:
//! `repro exp fig6 rounds=400 seeds=3` (all four datasets).

// Benches are an allowed zone for wall-clock reads (clippy.toml).
#![allow(clippy::disallowed_methods)]

use intsgd::config::Config;

fn main() {
    let mut cfg = Config::new();
    for kv in [
        "workers=12",
        "rounds=120",
        "seeds=1",
        "dataset=a5a",
        "fstar_iters=800",
        "out_dir=results/bench",
    ] {
        cfg.set_kv(kv).unwrap();
    }
    let t = std::time::Instant::now();
    intsgd::experiments::run("fig6", &cfg).expect("fig6");
    println!("bench_fig6 (abbreviated): {:.1}s total", t.elapsed().as_secs_f64());
}
