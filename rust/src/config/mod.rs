//! Configuration: `key = value` config files + CLI overrides.
//!
//! The offline vendor set has no clap/serde, so the launcher uses a small
//! layered config system: defaults <- config file (`--config path`) <-
//! `key=value` CLI overrides. Keys are flat dotted names, e.g.
//! `train.lr = 0.1`, `net.bandwidth_gbps = 100`.
//!
//! Two levels of strictness:
//!
//! - the `*_or` getters are lenient (malformed values fall back to the
//!   default) — legacy behaviour, kept for exploratory experiment knobs;
//! - [`Config::parsed`] / [`Config::parsed_or`] are strict: a present but
//!   malformed value is an error, which is what the `Session` front door
//!   uses so misconfiguration fails before any thread or socket exists;
//! - [`Config::validate_keys`] rejects unknown/typo'd keys against a
//!   known-key schema (the `api::keys` lists), with a "did you mean"
//!   suggestion — silent ignoring of a misspelt knob is how a run quietly
//!   becomes a different experiment.

use std::collections::BTreeMap;
use std::str::FromStr;

use anyhow::{anyhow, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    /// Parse `key = value` lines; `#` starts a comment; blank lines ok.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("config line {}: expected key = value", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Config { map })
    }

    pub fn load(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Self::parse(&text)
    }

    /// Apply one `key=value` override (CLI form).
    pub fn set_kv(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("override {kv:?}: expected key=value"))?;
        self.map.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    /// Merge `other` on top of `self`.
    pub fn merge(&mut self, other: Config) {
        self.map.extend(other.map);
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Strict typed access: `Ok(None)` when absent, `Err` when present but
    /// malformed (the lenient `*_or` getters silently fall back instead).
    pub fn parsed<T: FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                anyhow!(
                    "config key {key} = {v:?} is not a valid {}",
                    std::any::type_name::<T>()
                )
            }),
        }
    }

    /// [`Config::parsed`] with a default for the absent case.
    pub fn parsed_or<T: FromStr>(&self, key: &str, default: T) -> Result<T> {
        Ok(self.parsed(key)?.unwrap_or(default))
    }

    /// Reject keys outside `known`, suggesting the closest known key when
    /// one is plausibly a typo. All offenders are reported at once.
    pub fn validate_keys(&self, known: &[&str]) -> Result<()> {
        let mut bad = Vec::new();
        for key in self.keys() {
            if known.contains(&key) {
                continue;
            }
            bad.push(match closest(key, known) {
                Some(s) => format!("unknown config key {key:?}; did you mean {s:?}?"),
                None => format!("unknown config key {key:?}"),
            });
        }
        if bad.is_empty() {
            Ok(())
        } else {
            Err(anyhow!("{}", bad.join("\n")))
        }
    }
}

/// The closest candidate within a plausible-typo distance, for "did you
/// mean" suggestions (shared with the `api` compressor registry).
pub(crate) fn closest<'a>(name: &str, candidates: &[&'a str]) -> Option<&'a str> {
    candidates
        .iter()
        .map(|c| (edit_distance(name, c), *c))
        .min()
        .filter(|&(dist, _)| dist <= 2)
        .map(|(_, c)| c)
}

/// Levenshtein distance. Names are a handful of characters, so the
/// O(|a|·|b|) DP is plenty.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let subst = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + subst);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_typed_access() {
        let c = Config::parse(
            "train.lr = 0.1\n# comment\nworkers = 16  # trailing\nname = fig1\nflag = true\n",
        )
        .unwrap();
        assert_eq!(c.f32_or("train.lr", 0.0), 0.1);
        assert_eq!(c.usize_or("workers", 0), 16);
        assert_eq!(c.str_or("name", ""), "fig1");
        assert!(c.bool_or("flag", false));
        assert_eq!(c.usize_or("missing", 7), 7);
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("a = 1\nb = 2\n").unwrap();
        c.set_kv("b=20").unwrap();
        assert_eq!(c.usize_or("a", 0), 1);
        assert_eq!(c.usize_or("b", 0), 20);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("no equals sign\n").is_err());
        let mut c = Config::new();
        assert!(c.set_kv("noequals").is_err());
    }

    #[test]
    fn strict_getters_error_on_malformed_values() {
        let c = Config::parse("workers = 8\ntimeout = soon\n").unwrap();
        assert_eq!(c.parsed_or::<usize>("workers", 1).unwrap(), 8);
        assert_eq!(c.parsed_or::<usize>("missing", 7).unwrap(), 7);
        assert_eq!(c.parsed::<u64>("missing").unwrap(), None);
        let err = c.parsed::<u64>("timeout").unwrap_err().to_string();
        assert!(err.contains("timeout") && err.contains("soon"), "{err}");
        // the lenient getter still falls back (legacy behaviour)
        assert_eq!(c.u64_or("timeout", 5), 5);
    }

    #[test]
    fn validate_keys_suggests_the_closest_known_key() {
        let known = ["workers", "rounds", "net.timeout_ms"];
        let c = Config::parse("workrs = 8\n").unwrap();
        let err = c.validate_keys(&known).unwrap_err().to_string();
        assert!(
            err.contains("\"workrs\"") && err.contains("did you mean \"workers\""),
            "{err}"
        );
        // far-off garbage gets no absurd suggestion
        let c = Config::parse("zzzzzz = 1\n").unwrap();
        let err = c.validate_keys(&known).unwrap_err().to_string();
        assert!(err.contains("unknown config key") && !err.contains("did you mean"), "{err}");
        // known keys pass
        let c = Config::parse("workers = 8\nrounds = 2\n").unwrap();
        c.validate_keys(&known).unwrap();
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("workers", "workers"), 0);
        assert_eq!(edit_distance("workrs", "workers"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }
}
