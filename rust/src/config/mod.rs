//! Configuration: `key = value` config files + CLI overrides.
//!
//! The offline vendor set has no clap/serde, so the launcher uses a small
//! layered config system: defaults <- config file (`--config path`) <-
//! `key=value` CLI overrides. Keys are flat dotted names, e.g.
//! `train.lr = 0.1`, `net.bandwidth_gbps = 100`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    /// Parse `key = value` lines; `#` starts a comment; blank lines ok.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("config line {}: expected key = value", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Config { map })
    }

    pub fn load(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Self::parse(&text)
    }

    /// Apply one `key=value` override (CLI form).
    pub fn set_kv(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("override {kv:?}: expected key=value"))?;
        self.map.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    /// Merge `other` on top of `self`.
    pub fn merge(&mut self, other: Config) {
        self.map.extend(other.map);
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_typed_access() {
        let c = Config::parse(
            "train.lr = 0.1\n# comment\nworkers = 16  # trailing\nname = fig1\nflag = true\n",
        )
        .unwrap();
        assert_eq!(c.f32_or("train.lr", 0.0), 0.1);
        assert_eq!(c.usize_or("workers", 0), 16);
        assert_eq!(c.str_or("name", ""), "fig1");
        assert!(c.bool_or("flag", false));
        assert_eq!(c.usize_or("missing", 7), 7);
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("a = 1\nb = 2\n").unwrap();
        c.set_kv("b=20").unwrap();
        assert_eq!(c.usize_or("a", 0), 1);
        assert_eq!(c.usize_or("b", 0), 20);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("no equals sign\n").is_err());
        let mut c = Config::new();
        assert!(c.set_kv("noequals").is_err());
    }
}
