//! Network cost model: regenerates the *time* columns of Tables 2-3 and
//! Fig. 2 from the wire schedules the compressors report.
//!
//! We do not have the paper's 8-node/16-GPU InfiniBand testbed, so
//! communication time is modeled with the standard alpha-beta (latency-
//! bandwidth) costs of each collective (Thakur et al.; Sarvotham et al.),
//! parameterized to the paper's hardware (100 Gb/s HDR links, NCCL-style
//! ring collectives, 16 ranks). The qualitative shape the paper's
//! evaluation establishes — all-gather ≫ ring all-reduce, int8 < fp32,
//! per-message overheads dominating small transfers — are properties of
//! these cost functions, not of the absolute constants.
//!
//! Ring all-reduce of B bytes over n ranks:
//!     t = 2 (n-1) alpha + 2 (n-1)/n * B / bw
//! All-gather (every rank receives (n-1) messages of B bytes):
//!     t = (n-1) alpha + (n-1) * B / bw
//! Switch INA (pipelined chunks through one switch hop):
//!     t = 2 alpha + B / bw + chunks * pipeline_overhead
//!
//! Every transfer additionally pays a fixed per-tensor framing overhead,
//! which is what separates "communication" from pure bandwidth in the
//! paper's breakdowns.

use crate::compress::{CommOp, Primitive, RoundResult};

/// Link + topology parameters.
#[derive(Clone, Debug)]
pub struct Network {
    /// Unidirectional per-rank bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Per-hop latency, seconds (alpha term).
    pub latency: f64,
    /// Fixed framing/launch overhead per collective call, seconds.
    pub per_call_overhead: f64,
    /// INA pipeline: integers per chunk and per-chunk overhead.
    pub switch_chunk_ints: usize,
    pub switch_chunk_overhead: f64,
}

impl Network {
    /// Parameters matched to the paper's cluster: 100 Gb/s HDR InfiniBand,
    /// ~2 us port-to-port latency, NCCL launch overhead O(10 us).
    pub fn paper_cluster() -> Self {
        Network {
            bandwidth: 100.0e9 / 8.0, // 100 Gb/s -> bytes/s
            latency: 2.0e-6,
            per_call_overhead: 15.0e-6,
            switch_chunk_ints: 128,
            switch_chunk_overhead: 0.15e-6,
        }
    }

    /// Parameters matched to loopback TCP on a developer machine — the
    /// fabric `net::TcpTransport` actually runs on, so the modeled column
    /// of [`Network::round_breakdown_measured`] can be sanity-checked
    /// against the measured one (`repro net-bench`, `BENCH_net.json`).
    /// Single-stream loopback sustains a few GB/s; the per-message cost
    /// is dominated by syscalls and the transport's poll loop rather
    /// than port-to-port latency, hence the fat alpha terms. These are
    /// order-of-magnitude anchors (loopback varies wildly across
    /// machines and kernels), not calibrated constants — the measured
    /// column exists precisely because they drift.
    pub fn tcp_loopback() -> Self {
        Network {
            bandwidth: 5.0e9, // bytes/s, single-stream memcpy-bound
            latency: 20.0e-6,
            per_call_overhead: 50.0e-6,
            switch_chunk_ints: 128,
            switch_chunk_overhead: 0.15e-6,
        }
    }

    /// Seconds for one collective moving `bytes` per worker across `n`
    /// ranks.
    pub fn primitive_seconds(&self, p: Primitive, bytes: usize, n: usize) -> f64 {
        let b = bytes as f64;
        let nf = n as f64;
        match p {
            Primitive::AllReduce => {
                self.per_call_overhead
                    + 2.0 * (nf - 1.0) * self.latency
                    + 2.0 * (nf - 1.0) / nf * b / self.bandwidth
            }
            Primitive::AllGather => {
                self.per_call_overhead
                    + (nf - 1.0) * self.latency
                    + (nf - 1.0) * b / self.bandwidth
            }
            Primitive::Switch => {
                // each slot is a 4-byte integer in the switch pipeline
                let ints = (bytes / 4).max(1);
                let chunks = ints.div_ceil(self.switch_chunk_ints) as f64;
                self.per_call_overhead
                    + 2.0 * self.latency
                    + b / self.bandwidth
                    + chunks * self.switch_chunk_overhead
            }
        }
    }

    /// Modeled seconds of one *barrier* integer round: encode, then one
    /// all-reduce of all `bytes`, then decode — strictly sequential
    /// phases. The reference the streamed model is compared against.
    pub fn barrier_round_seconds(
        &self,
        encode: f64,
        decode: f64,
        bytes: usize,
        n: usize,
    ) -> f64 {
        encode + self.primitive_seconds(Primitive::AllReduce, bytes, n) + decode
    }

    /// Modeled seconds of one *streamed* integer round: the gradient
    /// moves as `blocks` back-to-back per-block all-reduces while the
    /// encoders fill the next block and the drained blocks decode, so
    /// each pipelined slot costs `max(encode_block, comm_block)` instead
    /// of their sum:
    ///
    ///     t = e_b + (B-1) * max(e_b, c_b) + c_b + decode
    ///
    /// where `e_b = encode / B` and `c_b` is the alpha-beta cost of one
    /// block's all-reduce. The old sequential model over-charged streamed
    /// rounds by the full hidden phase; this is the overlap-aware row the
    /// measured-vs-modeled comparison of `repro net-bench` and
    /// `bench_collective` report for `pipeline=streamed`. Note the split
    /// pays `blocks` per-call overheads, so at small `bytes` the model
    /// (correctly) prefers the barrier.
    pub fn streamed_round_seconds(
        &self,
        encode: f64,
        decode: f64,
        bytes: usize,
        n: usize,
        blocks: usize,
    ) -> f64 {
        assert!(blocks >= 1, "a streamed round needs at least one block");
        let e_b = encode / blocks as f64;
        let c_b =
            self.primitive_seconds(Primitive::AllReduce, bytes.div_ceil(blocks), n);
        e_b + (blocks as f64 - 1.0) * e_b.max(c_b) + c_b + decode
    }

    /// Total modeled time for a round's wire schedule.
    pub fn comm_seconds(&self, schedule: &[CommOp], n: usize) -> f64 {
        schedule
            .iter()
            .map(|op| self.primitive_seconds(op.primitive, op.bytes_per_worker, n))
            .sum()
    }

    /// Full per-phase account of one round: the three measured compute
    /// phases next to the modeled wire time. This is what the compression
    /// benchmarks serialize (`BENCH_compress.json`), so perf trajectories
    /// across PRs compare like with like: encode/reduce/decode are real
    /// wallclock on this machine, `comm_model` is the alpha-beta cost of
    /// the schedule — never double-counted (the in-flight reduce fold is
    /// measured under `reduce` but *charged* to the model, see
    /// `compress::RoundResult`).
    pub fn round_breakdown(&self, result: &RoundResult, n: usize) -> RoundBreakdown {
        self.round_breakdown_measured(result, n, 0.0)
    }

    /// [`Network::round_breakdown`] with the measured-vs-modeled column
    /// filled in: `comm_measured` is real wall-clock spent moving the
    /// round's bytes over an actual transport
    /// (`net::TransportReducer::take_wire_seconds`), sitting next to the
    /// alpha-beta `comm_model` of the same schedule. This is how the cost
    /// model is validated: on the loopback fabric
    /// ([`Network::tcp_loopback`]) the two columns should agree to within
    /// a small factor, and a drift is a model bug, not noise to average
    /// away.
    pub fn round_breakdown_measured(
        &self,
        result: &RoundResult,
        n: usize,
        comm_measured: f64,
    ) -> RoundBreakdown {
        self.round_breakdown_net(result, n, comm_measured, 0)
    }

    /// [`Network::round_breakdown_measured`] plus the transport's
    /// fault/retry account: `comm_retries` is how many collective
    /// attempts were retried this round
    /// (`net::TransportReducer::take_retries`). The model column prices a
    /// fault-free fabric, so on a faulted round the measured column is
    /// *expected* to exceed it by roughly `1 + retries / collectives` —
    /// the breakdown makes that visible instead of letting injected
    /// chaos masquerade as model drift.
    pub fn round_breakdown_net(
        &self,
        result: &RoundResult,
        n: usize,
        comm_measured: f64,
        comm_retries: u64,
    ) -> RoundBreakdown {
        RoundBreakdown {
            encode: result.encode_seconds,
            reduce: result.reduce_seconds,
            decode: result.decode_seconds,
            comm_model: self.comm_seconds(&result.comm, n),
            comm_measured,
            comm_retries,
        }
    }
}

/// Measured + modeled seconds of one compression round, by phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundBreakdown {
    pub encode: f64,
    pub reduce: f64,
    pub decode: f64,
    pub comm_model: f64,
    /// Measured transport wall-clock for the round's collectives (0 when
    /// the round ran on an in-process reducer — the model then stands in
    /// for a fabric that was never exercised).
    pub comm_measured: f64,
    /// Collective attempts retried this round after recoverable faults
    /// (0 on a healthy fabric or an in-process reducer).
    pub comm_retries: u64,
}

impl RoundBreakdown {
    /// Total measured compute overhead (what the "Computation Overhead"
    /// columns of Tables 2-3 report): encode + decode. The reduce fold is
    /// never added on top — for all-gather algorithms it is already
    /// charged inside `decode`, and for all-reduce/INA it stands in for
    /// the data plane that `comm_model` prices (`reduce` here is purely
    /// informational, for the per-phase benchmarks).
    pub fn overhead(&self) -> f64 {
        self.encode + self.decode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn allgather_dominates_allreduce_for_large_messages() {
        let net = Network::paper_cluster();
        let n = 16;
        let bytes = 100 << 20; // 100 MiB
        let ar = net.primitive_seconds(Primitive::AllReduce, bytes, n);
        let ag = net.primitive_seconds(Primitive::AllGather, bytes, n);
        // ring all-reduce moves 2(n-1)/n ~= 2x the data; all-gather moves
        // (n-1) ~= 15x.
        assert!(ag > 5.0 * ar, "ag {ag} vs ar {ar}");
    }

    #[test]
    fn int8_beats_fp32_allreduce() {
        let net = Network::paper_cluster();
        let d = 1_000_000;
        let t8 = net.primitive_seconds(Primitive::AllReduce, d, 16);
        let t32 = net.primitive_seconds(Primitive::AllReduce, 4 * d, 16);
        assert!(t8 < t32 / 2.0, "{t8} vs {t32}");
    }

    #[test]
    fn overheads_dominate_small_messages() {
        let net = Network::paper_cluster();
        let t_small = net.primitive_seconds(Primitive::AllReduce, 64, 16);
        // per-call overhead + latencies should be >90% of the cost
        let wire = 2.0 * 15.0 / 16.0 * 64.0 / net.bandwidth;
        assert!(wire / t_small < 0.1);
    }

    #[test]
    fn monotone_in_bytes_and_ranks() {
        prop_check(0x0E7, 100, |rng| {
            let net = Network::paper_cluster();
            let n = 2 + rng.usize_below(62);
            let b = 1 + rng.usize_below(1 << 24);
            for p in [Primitive::AllReduce, Primitive::AllGather, Primitive::Switch] {
                let t1 = net.primitive_seconds(p, b, n);
                let t2 = net.primitive_seconds(p, b * 2, n);
                prop_assert!(t2 >= t1, "{p:?} not monotone in bytes");
                if p != Primitive::Switch {
                    let t3 = net.primitive_seconds(p, b, n + 1);
                    prop_assert!(t3 >= t1, "{p:?} not monotone in ranks");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn switch_scales_with_single_hop_not_ranks() {
        let net = Network::paper_cluster();
        let b = 1 << 20;
        let t16 = net.primitive_seconds(Primitive::Switch, b, 16);
        let t64 = net.primitive_seconds(Primitive::Switch, b, 64);
        assert_eq!(t16, t64); // INA cost is rank-independent (pipelined)
    }

    #[test]
    fn round_breakdown_accounts_phases_and_model() {
        let net = Network::paper_cluster();
        let r = RoundResult {
            gtilde: vec![],
            comm: vec![CommOp { primitive: Primitive::AllReduce, bytes_per_worker: 1000 }],
            encode_seconds: 1.0,
            reduce_seconds: 2.0,
            decode_seconds: 3.0,
            max_abs_int: 0,
            alpha: 0.0,
        };
        let b = net.round_breakdown(&r, 8);
        // overhead = encode + decode; the reduce fold is either inside
        // decode (all-gather) or priced by the comm model (all-reduce)
        assert_eq!(b.overhead(), 4.0);
        assert_eq!(b.reduce, 2.0);
        let model = net.primitive_seconds(Primitive::AllReduce, 1000, 8);
        assert!((b.comm_model - model).abs() < 1e-15);
        // in-process reducers have no measured wire column
        assert_eq!(b.comm_measured, 0.0);
        assert_eq!(b.comm_retries, 0);
        let m = net.round_breakdown_measured(&r, 8, 0.5);
        assert_eq!(m.comm_measured, 0.5);
        assert!((m.comm_model - model).abs() < 1e-15);
        // fault/retry accounting rides the same breakdown
        let f = net.round_breakdown_net(&r, 8, 0.7, 3);
        assert_eq!(f.comm_retries, 3);
        assert_eq!(f.comm_measured, 0.7);
        assert_eq!(f.overhead(), 4.0);
    }

    #[test]
    fn streamed_model_overlaps_where_barrier_sums() {
        let net = Network::paper_cluster();
        let n = 16;
        // large enough that bandwidth dominates the per-block call
        // overheads (at small d the split is a loss — checked below)
        let bytes = 1 << 26; // 64 MiB int8 wire
        // an encode roughly as expensive as the wire: the pipelined round
        // hides most of one phase under the other
        let comm = net.primitive_seconds(Primitive::AllReduce, bytes, n);
        let encode = comm;
        let decode = 0.1 * comm;
        let barrier = net.barrier_round_seconds(encode, decode, bytes, n);
        let streamed = net.streamed_round_seconds(encode, decode, bytes, n, 16);
        assert!(
            streamed < 0.75 * barrier,
            "no overlap win: streamed {streamed} vs barrier {barrier}"
        );
        // a single block degenerates to the barrier sum exactly
        let one = net.streamed_round_seconds(encode, decode, bytes, n, 1);
        assert!((one - barrier).abs() < 1e-15);
        // the streamed round can never beat its critical path: the wire
        // alone, or encode + decode alone
        let wire_floor = net.primitive_seconds(
            Primitive::AllReduce,
            bytes.div_ceil(16),
            n,
        ) * 16.0;
        assert!(streamed >= wire_floor);
        assert!(streamed >= encode + decode);
        // tiny messages: per-call overheads make many blocks a loss — the
        // model must show it rather than promise free pipelining
        let small = 256;
        let b1 = net.streamed_round_seconds(1e-7, 1e-8, small, n, 1);
        let b32 = net.streamed_round_seconds(1e-7, 1e-8, small, n, 32);
        assert!(b32 > b1, "overhead-dominated split must cost more");
    }

    #[test]
    fn tcp_loopback_preset_is_slower_fabric_than_the_paper_cluster() {
        // loopback has fatter per-call overheads and thinner bandwidth
        // than 100 Gb/s HDR; a large all-reduce must cost more there
        let lo = Network::tcp_loopback();
        let hdr = Network::paper_cluster();
        let b = 1 << 20;
        assert!(
            lo.primitive_seconds(Primitive::AllReduce, b, 4)
                > hdr.primitive_seconds(Primitive::AllReduce, b, 4)
        );
    }

    #[test]
    fn schedule_sums() {
        let net = Network::paper_cluster();
        let ops = vec![
            CommOp { primitive: Primitive::AllReduce, bytes_per_worker: 1000 },
            CommOp { primitive: Primitive::AllGather, bytes_per_worker: 500 },
        ];
        let total = net.comm_seconds(&ops, 8);
        let a = net.primitive_seconds(Primitive::AllReduce, 1000, 8);
        let b = net.primitive_seconds(Primitive::AllGather, 500, 8);
        assert!((total - (a + b)).abs() < 1e-15);
    }
}
