//! Metrics: CSV writers for experiment outputs (results/*.csv) so every
//! table/figure can be regenerated and re-plotted from plain files.

use std::fs::{create_dir_all, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// A simple CSV writer with a fixed header.
pub struct Csv {
    w: BufWriter<File>,
    cols: usize,
}

impl Csv {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
        let f = File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "column count mismatch");
        writeln!(self.w, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        let v: Vec<String> = values.iter().map(|x| format!("{x}")).collect();
        self.row(&v)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

impl Drop for Csv {
    /// `BufWriter` flushes on drop but swallows the error; a driver that
    /// early-returns between rows still gets its partial CSV on disk.
    fn drop(&mut self) {
        let _ = self.w.flush();
    }
}

/// Format seconds as milliseconds with 2 decimals (the paper's unit).
pub fn ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

/// mean ± std formatter used in the table printers.
pub fn pm(values: &[f64]) -> String {
    format!(
        "{:.2} ± {:.2}",
        crate::util::stats::mean(values),
        crate::util::stats::std_dev(values)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv() {
        let dir = std::env::temp_dir().join("intsgd_test_metrics");
        let path = dir.join("t.csv");
        {
            let mut c = Csv::create(&path, &["a", "b"]).unwrap();
            c.rowf(&[1.0, 2.5]).unwrap();
            c.row(&["x".into(), "y".into()]).unwrap();
            c.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\nx,y\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_wrong_arity() {
        let dir = std::env::temp_dir().join("intsgd_test_metrics2");
        let mut c = Csv::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = c.row(&["only one".into()]);
    }

    #[test]
    fn drop_flushes_unflushed_rows() {
        let dir = std::env::temp_dir().join("intsgd_test_metrics3");
        let path = dir.join("t.csv");
        {
            let mut c = Csv::create(&path, &["a"]).unwrap();
            c.rowf(&[7.0]).unwrap();
            // no explicit flush: Drop must push the row to disk
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a\n7\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.06495), "64.95");
        assert_eq!(pm(&[1.0, 2.0, 3.0]), "2.00 ± 1.00");
    }
}
