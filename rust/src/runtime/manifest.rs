//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime, parsed with the in-tree JSON parser.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::Json;

/// Dtype of one artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl InputSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: usize,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub grad_dim: usize,
    /// Raw manifest entry for model-specific fields (batch, seq, vocab...).
    pub extra: Json,
}

impl ArtifactMeta {
    pub fn extra_usize(&self, key: &str) -> Option<usize> {
        self.extra.get(key).and_then(|v| v.as_usize())
    }

    pub fn extra_f64(&self, key: &str) -> Option<f64> {
        self.extra.get(key).and_then(|v| v.as_f64())
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn parse_shape(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = root
            .get("format")
            .and_then(|f| f.as_usize())
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != 1 {
            return Err(anyhow!("unsupported manifest format {format}"));
        }
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(|i| {
                    let shape = parse_shape(
                        i.get("shape").ok_or_else(|| anyhow!("{name}: input shape"))?,
                    )?;
                    let dtype = match i.get("dtype").and_then(|d| d.as_str()) {
                        Some("f32") => Dtype::F32,
                        Some("i32") => Dtype::I32,
                        other => return Err(anyhow!("{name}: bad dtype {other:?}")),
                    };
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            let params = entry
                .get("params")
                .and_then(|p| p.as_arr())
                .map(|arr| {
                    arr.iter()
                        .map(|p| {
                            Ok(ParamSpec {
                                name: p
                                    .get("name")
                                    .and_then(|n| n.as_str())
                                    .ok_or_else(|| anyhow!("param name"))?
                                    .to_string(),
                                shape: parse_shape(
                                    p.get("shape").ok_or_else(|| anyhow!("param shape"))?,
                                )?,
                                init: p
                                    .get("init")
                                    .and_then(|i| i.as_str())
                                    .unwrap_or("glorot")
                                    .to_string(),
                            })
                        })
                        .collect::<Result<Vec<_>>>()
                })
                .transpose()?
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file,
                    kind: entry
                        .get("kind")
                        .and_then(|k| k.as_str())
                        .unwrap_or("")
                        .to_string(),
                    inputs,
                    outputs: entry.get("outputs").and_then(|o| o.as_usize()).unwrap_or(1),
                    param_count: entry
                        .get("param_count")
                        .and_then(|p| p.as_usize())
                        .unwrap_or(0),
                    params,
                    grad_dim: entry.get("grad_dim").and_then(|g| g.as_usize()).unwrap_or(0),
                    extra: entry.clone(),
                },
            );
        }
        Ok(Manifest { artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "artifacts": {
        "m_train_step": {
          "file": "m.hlo.txt", "kind": "train_step", "outputs": 3,
          "param_count": 2, "grad_dim": 8, "batch": 4,
          "inputs": [
            {"shape": [2, 3], "dtype": "f32"},
            {"shape": [2], "dtype": "f32"},
            {"shape": [4, 3], "dtype": "i32"}
          ],
          "params": [
            {"name": "w", "shape": [2, 3], "init": "glorot"},
            {"name": "b", "shape": [2], "init": "zeros"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["m_train_step"];
        assert_eq!(a.file, "m.hlo.txt");
        assert_eq!(a.kind, "train_step");
        assert_eq!(a.outputs, 3);
        assert_eq!(a.param_count, 2);
        assert_eq!(a.grad_dim, 8);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[2].dtype, Dtype::I32);
        assert_eq!(a.inputs[2].shape, vec![4, 3]);
        assert_eq!(a.params[1].init, "zeros");
        assert_eq!(a.extra_usize("batch"), Some(4));
    }

    #[test]
    fn param_numel() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["m_train_step"];
        let total: usize = a.params.iter().map(|p| p.numel()).sum();
        assert_eq!(total, a.grad_dim);
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(Manifest::parse(r#"{"format": 2, "artifacts": {}}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
