//! Checkpointing: save/restore flattened parameters + the training state
//! a **bit-exact resume** needs.
//!
//! Binary format (little-endian), no external deps:
//!
//!   magic "INTSGDCK" | version u32 | round u64 | param_count u64 |
//!   for each param: name_len u32, name bytes, numel u64 |
//!   payload: all params concatenated as f32 LE |
//!   (v2) section_count u32 | per section: tag u8, byte_len u64, bytes |
//!   crc: FNV-1a over payload ++ section records, u64
//!
//! **v2 sections** (all optional; absent = not carried):
//!
//! | tag | contents |
//! |-----|----------|
//! | 1   | previous-round parameters (d x f32) — the scaling rules read `‖x^k − x^{k−1}‖²`, so a resume without `x^{k−1}` changes every later alpha |
//! | 2   | scaling-rule state (f64 array, rule-private encoding: the moving average r_k etc.) |
//! | 3   | per-rank error-feedback residuals (u32 count, then u64 numel + f32s each) — dropping them silently breaks the EF convergence mechanism |
//! | 4   | per-rank encoder RNG streams (u32 count, then 6 x u64 each) — stochastic rounding resumes at the exact draw |
//!
//! v1 files (params only) remain readable; their v2 fields load empty.
//! `tests/chaos.rs` pins that save → load → train is bitwise-equal to an
//! uninterrupted run, including the stochastic-rounding stream.
//!
//! The manifest of names/shapes travels with the file so a checkpoint is
//! rejected when loaded against a different model layout.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

const MAGIC: &[u8; 8] = b"INTSGDCK";
const VERSION: u32 = 2;

const SECT_PREV_PARAMS: u8 = 1;
const SECT_RULE_STATE: u8 = 2;
const SECT_EF_RESIDUALS: u8 = 3;
const SECT_RNG_STREAMS: u8 = 4;

/// One checkpoint in memory.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub round: u64,
    /// (name, numel) per parameter, in flattening order.
    pub layout: Vec<(String, u64)>,
    pub flat: Vec<f32>,
    /// v2: parameters of the previous round (`x^{k-1}`), same layout.
    pub prev_flat: Option<Vec<f32>>,
    /// v2: opaque scaling-rule state (`scaling::AlphaRule::export_state`).
    pub rule_state: Option<Vec<f64>>,
    /// v2: per-rank error-feedback residuals, rank order.
    pub ef_residuals: Vec<Vec<f32>>,
    /// v2: per-rank encoder RNG streams (`util::Rng::export_state`).
    pub rng_streams: Vec<[u64; 6]>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("f32 section of {} bytes is not 4-aligned", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Bounds-checked cursor read over a byte slice.
fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if bytes.len() - *pos < n {
        return Err(anyhow!("truncated checkpoint section data"));
    }
    let s = &bytes[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

impl Checkpoint {
    /// A params-only checkpoint (the v1 shape); fill the v2 fields for a
    /// full-state snapshot (`Coordinator::snapshot` does).
    pub fn new(round: u64, layout: Vec<(String, u64)>, flat: Vec<f32>) -> Result<Self> {
        let total: u64 = layout.iter().map(|(_, n)| n).sum();
        if total as usize != flat.len() {
            return Err(anyhow!(
                "layout totals {total} but params have {}",
                flat.len()
            ));
        }
        Ok(Checkpoint { round, layout, flat, ..Checkpoint::default() })
    }

    /// Serialize the v2 body (params payload + sections) — also the byte
    /// stream the trailing CRC covers.
    fn body(&self) -> Result<Vec<u8>> {
        let mut body = Vec::with_capacity(self.flat.len() * 4 + 64);
        push_f32s(&mut body, &self.flat);
        let mut sections: Vec<(u8, Vec<u8>)> = Vec::new();
        if let Some(prev) = &self.prev_flat {
            if prev.len() != self.flat.len() {
                return Err(anyhow!(
                    "prev params have {} values, params {}",
                    prev.len(),
                    self.flat.len()
                ));
            }
            let mut b = Vec::new();
            push_f32s(&mut b, prev);
            sections.push((SECT_PREV_PARAMS, b));
        }
        if let Some(rule) = &self.rule_state {
            let mut b = Vec::with_capacity(rule.len() * 8);
            for &x in rule {
                b.extend_from_slice(&x.to_le_bytes());
            }
            sections.push((SECT_RULE_STATE, b));
        }
        if !self.ef_residuals.is_empty() {
            let mut b = Vec::new();
            b.extend_from_slice(&(self.ef_residuals.len() as u32).to_le_bytes());
            for mem in &self.ef_residuals {
                b.extend_from_slice(&(mem.len() as u64).to_le_bytes());
                push_f32s(&mut b, mem);
            }
            sections.push((SECT_EF_RESIDUALS, b));
        }
        if !self.rng_streams.is_empty() {
            let mut b = Vec::new();
            b.extend_from_slice(&(self.rng_streams.len() as u32).to_le_bytes());
            for st in &self.rng_streams {
                for w in st {
                    b.extend_from_slice(&w.to_le_bytes());
                }
            }
            sections.push((SECT_RNG_STREAMS, b));
        }
        body.extend_from_slice(&(sections.len() as u32).to_le_bytes());
        for (tag, bytes) in &sections {
            body.push(*tag);
            body.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            body.extend_from_slice(bytes);
        }
        Ok(body)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(path).with_context(|| {
                format!("create checkpoint {path:?}")
            })?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.round.to_le_bytes())?;
        w.write_all(&(self.layout.len() as u64).to_le_bytes())?;
        for (name, numel) in &self.layout {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&numel.to_le_bytes())?;
        }
        let body = self.body()?;
        w.write_all(&body)?;
        w.write_all(&fnv1a(&body).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("{path:?}: not an intsgd checkpoint"));
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        if version != 1 && version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        r.read_exact(&mut b8)?;
        let round = u64::from_le_bytes(b8);
        r.read_exact(&mut b8)?;
        let count = u64::from_le_bytes(b8) as usize;
        let mut layout = Vec::with_capacity(count);
        let mut total = 0u64;
        for _ in 0..count {
            r.read_exact(&mut b4)?;
            let len = u32::from_le_bytes(b4) as usize;
            if len > 4096 {
                return Err(anyhow!("corrupt checkpoint: name length {len}"));
            }
            let mut name = vec![0u8; len];
            r.read_exact(&mut name)?;
            r.read_exact(&mut b8)?;
            let numel = u64::from_le_bytes(b8);
            total += numel;
            layout.push((String::from_utf8(name).context("param name")?, numel));
        }
        // body = payload (v1: that's all) ++ v2 section records; the
        // trailing u64 is the CRC over everything before it
        let mut rest = Vec::new();
        r.read_to_end(&mut rest)?;
        if rest.len() < 8 {
            return Err(anyhow!("truncated checkpoint: no CRC"));
        }
        let (body, crc_bytes) = rest.split_at(rest.len() - 8);
        let crc = u64::from_le_bytes(crc_bytes.try_into().expect("8 bytes"));
        if crc != fnv1a(body) {
            return Err(anyhow!("checkpoint payload CRC mismatch"));
        }
        let payload_len = (total * 4) as usize;
        if body.len() < payload_len {
            return Err(anyhow!(
                "checkpoint body {} bytes, layout promises {payload_len}",
                body.len()
            ));
        }
        let flat = read_f32s(&body[..payload_len])?;
        let mut ck = Checkpoint { round, layout, flat, ..Checkpoint::default() };
        if version == 1 {
            if body.len() != payload_len {
                return Err(anyhow!("v1 checkpoint has trailing bytes"));
            }
            return Ok(ck);
        }
        // v2 sections
        let mut pos = payload_len;
        let nsect = u32::from_le_bytes(take(body, &mut pos, 4)?.try_into().unwrap());
        for _ in 0..nsect {
            let tag = take(body, &mut pos, 1)?[0];
            let len =
                u64::from_le_bytes(take(body, &mut pos, 8)?.try_into().unwrap()) as usize;
            if len > body.len() - pos {
                return Err(anyhow!("section {tag} promises {len} bytes beyond the file"));
            }
            let bytes = take(body, &mut pos, len)?;
            match tag {
                SECT_PREV_PARAMS => {
                    let prev = read_f32s(bytes)?;
                    if prev.len() != ck.flat.len() {
                        return Err(anyhow!(
                            "prev-params section has {} values, params {}",
                            prev.len(),
                            ck.flat.len()
                        ));
                    }
                    ck.prev_flat = Some(prev);
                }
                SECT_RULE_STATE => {
                    if len % 8 != 0 {
                        return Err(anyhow!("rule-state section not 8-aligned"));
                    }
                    ck.rule_state = Some(
                        bytes
                            .chunks_exact(8)
                            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    );
                }
                SECT_EF_RESIDUALS => {
                    let mut p = 0usize;
                    let cnt = u32::from_le_bytes(take(bytes, &mut p, 4)?.try_into().unwrap())
                        as usize;
                    if cnt > 4096 {
                        return Err(anyhow!("EF section claims {cnt} ranks"));
                    }
                    let mut mems = Vec::with_capacity(cnt);
                    for _ in 0..cnt {
                        let numel =
                            u64::from_le_bytes(take(bytes, &mut p, 8)?.try_into().unwrap())
                                as usize;
                        let nbytes = numel
                            .checked_mul(4)
                            .ok_or_else(|| anyhow!("EF numel overflow"))?;
                        mems.push(read_f32s(take(bytes, &mut p, nbytes)?)?);
                    }
                    if p != bytes.len() {
                        return Err(anyhow!("EF section has trailing bytes"));
                    }
                    ck.ef_residuals = mems;
                }
                SECT_RNG_STREAMS => {
                    if len < 4 || (len - 4) % 48 != 0 {
                        return Err(anyhow!("RNG section of {len} bytes is malformed"));
                    }
                    let cnt =
                        u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
                    if cnt * 48 != len - 4 {
                        return Err(anyhow!("RNG section count disagrees with size"));
                    }
                    ck.rng_streams = bytes[4..]
                        .chunks_exact(48)
                        .map(|c| {
                            let mut st = [0u64; 6];
                            for (w, b) in st.iter_mut().zip(c.chunks_exact(8)) {
                                *w = u64::from_le_bytes(b.try_into().unwrap());
                            }
                            st
                        })
                        .collect();
                }
                other => return Err(anyhow!("unknown checkpoint section tag {other}")),
            }
        }
        if pos != body.len() {
            return Err(anyhow!("checkpoint has bytes after the last section"));
        }
        Ok(ck)
    }

    /// Verify compatibility against a manifest layout.
    pub fn check_layout(&self, expected: &[(String, u64)]) -> Result<()> {
        if self.layout != expected {
            return Err(anyhow!(
                "checkpoint layout mismatch: file has {} params, model wants {}",
                self.layout.len(),
                expected.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("intsgd_ck_{name}_{}", std::process::id()))
    }

    fn sample() -> Checkpoint {
        Checkpoint::new(
            42,
            vec![("w".into(), 4), ("b".into(), 2)],
            vec![1.0, -2.0, 3.5, 0.0, 9.0, -0.125],
        )
        .unwrap()
    }

    fn full_sample() -> Checkpoint {
        let mut ck = sample();
        ck.prev_flat = Some(vec![0.5, -1.0, 3.0, 0.25, 8.0, 0.0]);
        ck.rule_state = Some(vec![0.125, 1.0, 41.0]);
        ck.ef_residuals = vec![vec![0.1, -0.2], vec![], vec![7.0]];
        ck.rng_streams = vec![[1, 2, 3, 4, 0, 0], [u64::MAX, 9, 8, 7, 1, 42]];
        ck
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn v2_full_state_roundtrips() {
        let p = tmp("v2");
        let ck = full_sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(p).ok();
    }

    /// Write a file in the original v1 layout by hand and load it: the
    /// "keep v1 readable" guarantee.
    #[test]
    fn v1_files_remain_readable() {
        let p = tmp("v1");
        let ck = sample();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&ck.round.to_le_bytes());
        bytes.extend_from_slice(&(ck.layout.len() as u64).to_le_bytes());
        for (name, numel) in &ck.layout {
            bytes.extend_from_slice(&(name.len() as u32).to_le_bytes());
            bytes.extend_from_slice(name.as_bytes());
            bytes.extend_from_slice(&numel.to_le_bytes());
        }
        let mut payload = Vec::new();
        push_f32s(&mut payload, &ck.flat);
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck, "v1 loads with empty v2 fields");
        assert!(back.prev_flat.is_none() && back.rule_state.is_none());
        assert!(back.ef_residuals.is_empty() && back.rng_streams.is_empty());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_layout_mismatch_at_construction() {
        assert!(Checkpoint::new(0, vec![("w".into(), 3)], vec![0.0; 2]).is_err());
    }

    #[test]
    fn detects_corruption_in_params_and_sections() {
        for (label, ck) in [("v1ish", sample()), ("full", full_sample())] {
            let p = tmp(&format!("corrupt_{label}"));
            ck.save(&p).unwrap();
            let clean = std::fs::read(&p).unwrap();
            // flips inside the CRC-covered body (the layout header is
            // shape-validated, not CRC'd): last section bytes + CRC tail
            for at in [clean.len() - 12, clean.len() - 9, clean.len() - 2] {
                let mut bytes = clean.clone();
                bytes[at] ^= 0xFF;
                std::fs::write(&p, &bytes).unwrap();
                assert!(Checkpoint::load(&p).is_err(), "{label}: flip at {at} accepted");
            }
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn rejects_wrong_magic_and_unknown_section() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();

        // unknown section tag: rebuild a valid file, then bump the tag
        // byte and refresh the CRC
        let ck = sample();
        let mut body = ck.body().unwrap();
        // section count 0 -> forge one bogus empty section
        let cut = body.len() - 4;
        body.truncate(cut);
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(99); // unknown tag
        body.extend_from_slice(&0u64.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&ck.round.to_le_bytes());
        bytes.extend_from_slice(&(ck.layout.len() as u64).to_le_bytes());
        for (name, numel) in &ck.layout {
            bytes.extend_from_slice(&(name.len() as u32).to_le_bytes());
            bytes.extend_from_slice(name.as_bytes());
            bytes.extend_from_slice(&numel.to_le_bytes());
        }
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&fnv1a(&body).to_le_bytes());
        let p = tmp("unknown_sect");
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(err.to_string().contains("unknown"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn check_layout_catches_model_mismatch() {
        let ck = sample();
        assert!(ck.check_layout(&[("w".into(), 4), ("b".into(), 2)]).is_ok());
        assert!(ck.check_layout(&[("w".into(), 4)]).is_err());
    }
}
