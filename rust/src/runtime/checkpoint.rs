//! Checkpointing: save/restore flattened parameters + optimizer round.
//!
//! Binary format (little-endian), no external deps:
//!
//!   magic "INTSGDCK" | version u32 | round u64 | param_count u64 |
//!   for each param: name_len u32, name bytes, numel u64 |
//!   payload: all params concatenated as f32 LE |
//!   crc: FNV-1a over the payload, u64
//!
//! The manifest of names/shapes travels with the file so a checkpoint is
//! rejected when loaded against a different model layout.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

const MAGIC: &[u8; 8] = b"INTSGDCK";
const VERSION: u32 = 1;

/// One checkpoint in memory.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: u64,
    /// (name, numel) per parameter, in flattening order.
    pub layout: Vec<(String, u64)>,
    pub flat: Vec<f32>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn new(round: u64, layout: Vec<(String, u64)>, flat: Vec<f32>) -> Result<Self> {
        let total: u64 = layout.iter().map(|(_, n)| n).sum();
        if total as usize != flat.len() {
            return Err(anyhow!(
                "layout totals {total} but params have {}",
                flat.len()
            ));
        }
        Ok(Checkpoint { round, layout, flat })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(path).with_context(|| {
                format!("create checkpoint {path:?}")
            })?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.round.to_le_bytes())?;
        w.write_all(&(self.layout.len() as u64).to_le_bytes())?;
        for (name, numel) in &self.layout {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&numel.to_le_bytes())?;
        }
        let mut payload = Vec::with_capacity(self.flat.len() * 4);
        for &x in &self.flat {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&payload)?;
        w.write_all(&fnv1a(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("{path:?}: not an intsgd checkpoint"));
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        if version != VERSION {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        r.read_exact(&mut b8)?;
        let round = u64::from_le_bytes(b8);
        r.read_exact(&mut b8)?;
        let count = u64::from_le_bytes(b8) as usize;
        let mut layout = Vec::with_capacity(count);
        let mut total = 0u64;
        for _ in 0..count {
            r.read_exact(&mut b4)?;
            let len = u32::from_le_bytes(b4) as usize;
            if len > 4096 {
                return Err(anyhow!("corrupt checkpoint: name length {len}"));
            }
            let mut name = vec![0u8; len];
            r.read_exact(&mut name)?;
            r.read_exact(&mut b8)?;
            let numel = u64::from_le_bytes(b8);
            total += numel;
            layout.push((String::from_utf8(name).context("param name")?, numel));
        }
        let mut payload = vec![0u8; (total * 4) as usize];
        r.read_exact(&mut payload)?;
        r.read_exact(&mut b8)?;
        let crc = u64::from_le_bytes(b8);
        if crc != fnv1a(&payload) {
            return Err(anyhow!("checkpoint payload CRC mismatch"));
        }
        let flat: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { round, layout, flat })
    }

    /// Verify compatibility against a manifest layout.
    pub fn check_layout(&self, expected: &[(String, u64)]) -> Result<()> {
        if self.layout != expected {
            return Err(anyhow!(
                "checkpoint layout mismatch: file has {} params, model wants {}",
                self.layout.len(),
                expected.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("intsgd_ck_{name}_{}", std::process::id()))
    }

    fn sample() -> Checkpoint {
        Checkpoint::new(
            42,
            vec![("w".into(), 4), ("b".into(), 2)],
            vec![1.0, -2.0, 3.5, 0.0, 9.0, -0.125],
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_layout_mismatch_at_construction() {
        assert!(Checkpoint::new(0, vec![("w".into(), 3)], vec![0.0; 2]).is_err());
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt");
        sample().save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 12] ^= 0xFF; // flip a payload byte
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn check_layout_catches_model_mismatch() {
        let ck = sample();
        assert!(ck.check_layout(&[("w".into(), 4), ("b".into(), 2)]).is_ok());
        assert!(ck.check_layout(&[("w".into(), 4)]).is_err());
    }
}
