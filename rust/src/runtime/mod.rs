//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute them
//! from the rust hot path.
//!
//! `make artifacts` (python, build-time only) writes `artifacts/*.hlo.txt`
//! and `artifacts/manifest.json`; this module parses the manifest with the
//! in-tree JSON parser, compiles each HLO module once on a PJRT CPU
//! client, and exposes typed execution. HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos — see
//! DESIGN.md §2).

pub mod checkpoint;
pub mod manifest;

pub use checkpoint::Checkpoint;
pub use manifest::{ArtifactMeta, Dtype, InputSpec, Manifest, ParamSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A compiled artifact bound to a client.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.meta.name))?;
        // aot.py lowers with return_tuple=True: output is always a tuple
        Ok(out.to_tuple()?)
    }
}

/// One PJRT CPU client + the executables compiled on it. NOT `Send` (the
/// client is Rc-backed): construct inside the thread that uses it.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Open `artifacts/` (or the dir named by INTSGD_ARTIFACTS).
    // The executable cache is keyed lookup only — nothing iterates it, so
    // HashMap's randomized order cannot leak anywhere (clippy.toml).
    #[allow(clippy::disallowed_methods)]
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    /// Default artifact dir: $INTSGD_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("INTSGD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    /// Compile (once) and return the named artifact.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.artifacts.get(name)
    }
}

/// Build an f32 literal of the given dims from a flat slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let numel: usize = dims.iter().product();
    if numel != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    let l = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(l);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(l.reshape(&dims_i64)?)
}

/// Build an i32 literal.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let numel: usize = dims.iter().product();
    if numel != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    let l = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(l);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(l.reshape(&dims_i64)?)
}

/// Fetch an f32 literal as a vec.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Fetch a scalar f32.
pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

/// Glorot-uniform / zeros / ones initialization from the manifest's param
/// specs (matches python/tests/test_model.py::init_params semantics).
pub fn init_params(specs: &[ParamSpec], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = crate::util::Rng::new(seed);
    specs
        .iter()
        .map(|p| {
            let numel: usize = p.shape.iter().product::<usize>().max(1);
            match p.init.as_str() {
                "zeros" => vec![0.0; numel],
                "ones" => vec![1.0; numel],
                s if s.starts_with("normal") => {
                    let std: f32 = s["normal".len()..].parse().unwrap_or(0.02);
                    rng.normal_vec(numel, std)
                }
                _ => {
                    // glorot-uniform over the first two dims
                    if p.shape.len() >= 2 {
                        let fan_in = p.shape[0] as f32;
                        let fan_out: f32 =
                            p.shape[1..].iter().product::<usize>() as f32;
                        let lim = (6.0 / (fan_in + fan_out)).sqrt();
                        (0..numel)
                            .map(|_| rng.range(-lim as f64, lim as f64) as f32)
                            .collect()
                    } else {
                        vec![0.0; numel]
                    }
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_params_respects_specs() {
        let specs = vec![
            ParamSpec { name: "w".into(), shape: vec![10, 20], init: "glorot".into() },
            ParamSpec { name: "b".into(), shape: vec![20], init: "zeros".into() },
            ParamSpec { name: "s".into(), shape: vec![5], init: "ones".into() },
            ParamSpec { name: "e".into(), shape: vec![4, 4], init: "normal0.1".into() },
        ];
        let ps = init_params(&specs, 0);
        assert_eq!(ps[0].len(), 200);
        let lim = (6.0f32 / 30.0).sqrt();
        assert!(ps[0].iter().all(|&x| x.abs() <= lim));
        assert!(ps[0].iter().any(|&x| x != 0.0));
        assert!(ps[1].iter().all(|&x| x == 0.0));
        assert!(ps[2].iter().all(|&x| x == 1.0));
        let std = crate::util::stats::std_dev(
            &ps[3].iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!((std - 0.1).abs() < 0.05, "std {std}");
    }

    #[test]
    fn init_is_deterministic() {
        let specs = vec![ParamSpec {
            name: "w".into(),
            shape: vec![3, 3],
            init: "glorot".into(),
        }];
        assert_eq!(init_params(&specs, 42), init_params(&specs, 42));
    }

    #[test]
    fn lit_helpers_validate_shape() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(lit_i32(&[1, 2], &[2]).is_ok());
    }
}
