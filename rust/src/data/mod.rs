//! Data substrate: synthetic stand-ins for the paper's datasets plus a
//! real LibSVM parser (DESIGN.md substitution table).
//!
//! - `cifar_like`: Gaussian class-conditional 32x32x3 images, 10 classes
//!   (for the ResNet18/CIFAR-10 classification task).
//! - `markov_text`: an order-1 Markov character corpus (for the
//!   LSTM/Wikitext-2 language-modeling task).
//! - `libsvm`: parser for the real LibSVM format + synthetic generators
//!   matched to the Table-4 dataset geometries (a5a, mushrooms, w8a,
//!   real-sim), including a sparse generator for the real-sim scale.
//! - `shard`: index-order (heterogeneous) and shuffled (iid) sharding.

pub mod cifar_like;
pub mod libsvm;
pub mod markov_text;

pub use cifar_like::CifarLike;
pub use libsvm::{synth_dataset, LibsvmDataset, DATASETS};
pub use markov_text::MarkovText;

/// Split `count` example indices into `n` contiguous shards (the paper's
/// heterogeneous split: "the whole dataset is split according to its
/// original indices into n folds").
pub fn shard_contiguous(count: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    (0..n)
        .map(|i| {
            let lo = i * count / n;
            let hi = (i + 1) * count / n;
            lo..hi
        })
        .collect()
}

/// IID sharding: shuffle indices then split contiguously.
pub fn shard_iid(count: usize, n: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..count).collect();
    crate::util::Rng::new(seed).shuffle(&mut idx);
    shard_contiguous(count, n)
        .into_iter()
        .map(|r| idx[r].to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_shards_tile() {
        let shards = shard_contiguous(103, 4);
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[0].start, 0);
        assert_eq!(shards[3].end, 103);
        for w in shards.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }

    #[test]
    fn iid_shards_partition() {
        let shards = shard_iid(100, 3, 0);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }
}
