//! Synthetic character corpus with order-1 Markov structure.
//!
//! Stands in for Wikitext-2 in the language-modeling task: a random
//! row-stochastic transition matrix with controllable entropy gives the
//! LSTM something real to learn (unlike uniform noise) while staying
//! generatable offline.

use crate::util::Rng;

pub struct MarkovText {
    pub vocab: usize,
    pub train: Vec<u32>,
    pub test: Vec<u32>,
    /// The generating transition matrix (row-major), for entropy checks.
    pub transition: Vec<f32>,
}

impl MarkovText {
    /// `concentration` < 1 gives peaky (low-entropy) rows — learnable
    /// structure; large values approach uniform noise.
    pub fn generate(
        vocab: usize,
        train_len: usize,
        test_len: usize,
        concentration: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        // Dirichlet(concentration) rows via normalized Gamma; approximate
        // Gamma(c) with exp(c * log u) shaping for small c (sufficient for
        // a synthetic corpus: rows are peaky and distinct).
        let mut transition = vec![0.0f32; vocab * vocab];
        for r in 0..vocab {
            let mut row: Vec<f64> = (0..vocab)
                .map(|_| {
                    let u: f64 = rng.uniform().max(1e-12);
                    // inverse-CDF-ish shaping: u^(1/c) concentrates mass
                    u.powf(1.0 / concentration)
                })
                .collect();
            let sum: f64 = row.iter().sum();
            for v in &mut row {
                *v /= sum;
            }
            for (j, v) in row.iter().enumerate() {
                transition[r * vocab + j] = *v as f32;
            }
        }
        let sample_chain = |len: usize, rng: &mut Rng| {
            let mut out = Vec::with_capacity(len);
            let mut state = rng.usize_below(vocab);
            for _ in 0..len {
                out.push(state as u32);
                let row = &transition[state * vocab..(state + 1) * vocab];
                let mut u = rng.uniform() as f32;
                let mut next = vocab - 1;
                for (j, &p) in row.iter().enumerate() {
                    if u < p {
                        next = j;
                        break;
                    }
                    u -= p;
                }
                state = next;
            }
            out
        };
        let train = sample_chain(train_len, &mut rng);
        let test = sample_chain(test_len, &mut rng);
        MarkovText { vocab, train, test, transition }
    }

    /// Entropy rate of the generating chain (nats): the Bayes-optimal
    /// next-char loss a perfect model converges to.
    pub fn entropy_rate(&self) -> f64 {
        let v = self.vocab;
        // stationary distribution via power iteration
        let mut pi = vec![1.0f64 / v as f64; v];
        for _ in 0..200 {
            let mut next = vec![0.0f64; v];
            for r in 0..v {
                for c in 0..v {
                    next[c] += pi[r] * self.transition[r * v + c] as f64;
                }
            }
            pi = next;
        }
        let mut h = 0.0;
        for r in 0..v {
            for c in 0..v {
                let p = self.transition[r * v + c] as f64;
                if p > 0.0 {
                    h -= pi[r] * p * p.ln();
                }
            }
        }
        h
    }

    /// Sample a batch of [batch, seq+1] windows (i32 tokens) from `data`.
    pub fn batch_windows(
        data: &[u32],
        batch: usize,
        seq: usize,
        rng: &mut Rng,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let start = rng.usize_below(data.len() - seq - 1);
            out.extend(data[start..start + seq + 1].iter().map(|&t| t as i32));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let t = MarkovText::generate(64, 5000, 500, 0.1, 0);
        assert_eq!(t.train.len(), 5000);
        assert!(t.train.iter().all(|&c| c < 64));
        assert!(t.test.iter().all(|&c| c < 64));
    }

    #[test]
    fn transition_rows_are_stochastic() {
        let t = MarkovText::generate(32, 10, 10, 0.2, 1);
        for r in 0..32 {
            let s: f32 = t.transition[r * 32..(r + 1) * 32].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        }
    }

    #[test]
    fn low_concentration_beats_uniform_entropy() {
        let t = MarkovText::generate(64, 10, 10, 0.05, 2);
        let h = t.entropy_rate();
        let uniform = (64f64).ln();
        assert!(h < 0.8 * uniform, "entropy {h} vs uniform {uniform}");
        assert!(h > 0.0);
    }

    #[test]
    fn empirical_bigrams_match_chain() {
        // the sampled chain should roughly follow the transition matrix
        let t = MarkovText::generate(8, 200_000, 10, 0.3, 3);
        let mut counts = vec![0f64; 64];
        let mut row_tot = vec![0f64; 8];
        for w in t.train.windows(2) {
            counts[w[0] as usize * 8 + w[1] as usize] += 1.0;
            row_tot[w[0] as usize] += 1.0;
        }
        for r in 0..8 {
            for c in 0..8 {
                let emp = counts[r * 8 + c] / row_tot[r].max(1.0);
                let p = t.transition[r * 8 + c] as f64;
                assert!((emp - p).abs() < 0.02, "({r},{c}): {emp} vs {p}");
            }
        }
    }

    #[test]
    fn windows_shape_and_range() {
        let t = MarkovText::generate(64, 1000, 10, 0.1, 4);
        let mut rng = Rng::new(0);
        let b = MarkovText::batch_windows(&t.train, 4, 30, &mut rng);
        assert_eq!(b.len(), 4 * 31);
        assert!(b.iter().all(|&x| (0..64).contains(&x)));
    }
}
