//! Synthetic CIFAR-like classification data.
//!
//! Ten Gaussian class-conditional distributions over 32*32*3 = 3072
//! dimensions: x = mu_c + sigma * eps with well-separated random unit-norm
//! class means. This preserves what the compression experiments actually
//! probe — gradient-scale drift, adaptive-alpha tracking, int8 clipping
//! pressure over a real optimization trajectory — at laptop scale (see
//! DESIGN.md substitution table).

use crate::util::Rng;

pub const DIM: usize = 3 * 32 * 32;
pub const CLASSES: usize = 10;

pub struct CifarLike {
    pub train_x: Vec<f32>, // row-major [train, DIM]
    pub train_y: Vec<u32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<u32>,
    pub dim: usize,
    pub classes: usize,
}

impl CifarLike {
    /// Generate `train` + `test` examples. `margin` scales class-mean
    /// separation relative to the noise (1.0 = moderately hard).
    pub fn generate(train: usize, test: usize, margin: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // random unit-norm class means, scaled
        let means: Vec<Vec<f32>> = (0..CLASSES)
            .map(|_| {
                let mut v = rng.normal_vec(DIM, 1.0);
                let norm = crate::util::stats::l2_norm(&v) as f32;
                for x in &mut v {
                    *x *= margin / norm * (DIM as f32).sqrt() * 0.05;
                }
                v
            })
            .collect();
        let gen = |count: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(count * DIM);
            let mut ys = Vec::with_capacity(count);
            for _ in 0..count {
                let c = rng.usize_below(CLASSES);
                ys.push(c as u32);
                for j in 0..DIM {
                    xs.push(means[c][j] + 0.3 * rng.normal_f32());
                }
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen(train, &mut rng);
        let (test_x, test_y) = gen(test, &mut rng);
        CifarLike { train_x, train_y, test_x, test_y, dim: DIM, classes: CLASSES }
    }

    pub fn train_count(&self) -> usize {
        self.train_y.len()
    }

    /// Copy a batch by indices: (x row-major, one-hot y).
    pub fn batch(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = vec![0.0f32; idx.len() * self.classes];
        for (bi, &i) in idx.iter().enumerate() {
            x.extend_from_slice(&self.train_x[i * self.dim..(i + 1) * self.dim]);
            y[bi * self.classes + self.train_y[i] as usize] = 1.0;
        }
        (x, y)
    }

    /// Test batch by range.
    pub fn test_batch(&self, lo: usize, count: usize) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(count * self.dim);
        let mut y = vec![0.0f32; count * self.classes];
        for bi in 0..count {
            let i = (lo + bi) % self.test_y.len();
            x.extend_from_slice(&self.test_x[i * self.dim..(i + 1) * self.dim]);
            y[bi * self.classes + self.test_y[i] as usize] = 1.0;
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = CifarLike::generate(64, 16, 1.0, 0);
        assert_eq!(d.train_x.len(), 64 * DIM);
        assert_eq!(d.test_x.len(), 16 * DIM);
        assert!(d.train_y.iter().all(|&y| (y as usize) < CLASSES));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = CifarLike::generate(8, 2, 1.0, 7);
        let b = CifarLike::generate(8, 2, 1.0, 7);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
    }

    #[test]
    fn batch_is_onehot() {
        let d = CifarLike::generate(10, 2, 1.0, 1);
        let (x, y) = d.batch(&[0, 3, 5]);
        assert_eq!(x.len(), 3 * DIM);
        assert_eq!(y.len(), 3 * CLASSES);
        for r in 0..3 {
            let row = &y[r * CLASSES..(r + 1) * CLASSES];
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), CLASSES - 1);
        }
    }

    #[test]
    fn classes_are_separable_in_mean() {
        // nearest-class-mean classification on the train set should beat
        // chance by a wide margin
        let d = CifarLike::generate(200, 50, 1.5, 3);
        // estimate class means from train data
        let mut means = vec![vec![0.0f64; DIM]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..d.train_count() {
            let c = d.train_y[i] as usize;
            counts[c] += 1;
            for j in 0..DIM {
                means[c][j] += d.train_x[i * DIM + j] as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..50 {
            let x = &d.test_x[i * DIM..(i + 1) * DIM];
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = x
                        .iter()
                        .zip(&means[a])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2))
                        .sum();
                    let db: f64 = x
                        .iter()
                        .zip(&means[b])
                        .map(|(&xi, &mi)| (xi as f64 - mi).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == d.test_y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 25, "accuracy {correct}/50 should beat chance");
    }
}
