//! LibSVM-format datasets: a real parser plus synthetic generators matched
//! to the paper's Table 4 geometries.
//!
//! The paper's Fig. 6 runs on a5a, mushrooms, w8a and real-sim from the
//! LibSVM repository. Offline we synthesize binary-classification data
//! with the same (N, d, lambda_2) and comparable sparsity from a planted
//! linear model with label noise — which reproduces the phenomenon under
//! study (nonzero local gradients at the global optimum under contiguous
//! sharding). Real files in LibSVM format drop in via `parse`.

use crate::models::{LogReg, SparseMatrix};
use crate::util::Rng;

/// Geometry of one dataset: (name, N, d, lambda2, density).
pub struct DatasetSpec {
    pub name: &'static str,
    pub n_examples: usize,
    pub dim: usize,
    pub lambda2: f64,
    /// Fraction of nonzero features per row.
    pub density: f64,
}

/// Paper Table 4 (density estimated from the real datasets).
pub const DATASETS: &[DatasetSpec] = &[
    DatasetSpec { name: "a5a", n_examples: 6414, dim: 123, lambda2: 5e-4, density: 0.11 },
    DatasetSpec { name: "mushrooms", n_examples: 8124, dim: 112, lambda2: 6e-4, density: 0.19 },
    DatasetSpec { name: "w8a", n_examples: 49749, dim: 300, lambda2: 1e-4, density: 0.039 },
    DatasetSpec { name: "real-sim", n_examples: 72309, dim: 20958, lambda2: 5e-5, density: 0.0025 },
];

pub struct LibsvmDataset {
    pub name: String,
    pub a: SparseMatrix,
    pub b: Vec<f32>,
    pub lambda2: f64,
}

impl LibsvmDataset {
    /// Split into n contiguous heterogeneous shards, each a LogReg model.
    pub fn shards(&self, n: usize) -> Vec<LogReg> {
        super::shard_contiguous(self.a.rows, n)
            .into_iter()
            .map(|r| {
                let mut a = SparseMatrix::new(0, self.a.cols);
                for row in r.clone() {
                    let (lo, hi) = (self.a.indptr[row], self.a.indptr[row + 1]);
                    let entries: Vec<(u32, f32)> = (lo..hi)
                        .map(|k| (self.a.indices[k], self.a.values[k]))
                        .collect();
                    a.push_row(&entries);
                }
                LogReg { a, b: self.b[r].to_vec(), lambda: self.lambda2 }
            })
            .collect()
    }

    /// The pooled global objective.
    pub fn global(&self) -> LogReg {
        LogReg { a: self.a.clone(), b: self.b.clone(), lambda: self.lambda2 }
    }
}

/// Synthesize a dataset matching `spec` from a planted sparse linear model
/// with 10% label noise. Row blocks get slightly shifted feature
/// distributions so contiguous shards are heterogeneous, as in the paper.
pub fn synth_dataset(spec: &DatasetSpec, seed: u64) -> LibsvmDataset {
    let mut rng = Rng::new(seed ^ 0xDA7A);
    let d = spec.dim;
    let planted = rng.normal_vec(d, 1.0);
    let nnz_per_row = ((spec.density * d as f64).round() as usize).max(2).min(d);
    let mut a = SparseMatrix::new(0, d);
    let mut b = Vec::with_capacity(spec.n_examples);
    // 12 latent blocks to induce heterogeneity under contiguous sharding
    let blocks = 12usize;
    let block_bias: Vec<Vec<f32>> = (0..blocks)
        .map(|_| rng.normal_vec(d, 0.5))
        .collect();
    for i in 0..spec.n_examples {
        let blk = i * blocks / spec.n_examples;
        let cols = rng.sample_indices(d, nnz_per_row);
        let entries: Vec<(u32, f32)> = cols
            .iter()
            .map(|&c| (c as u32, rng.normal_f32() + block_bias[blk][c]))
            .collect();
        let mut margin = 0.0f64;
        for &(c, v) in &entries {
            margin += v as f64 * planted[c as usize] as f64;
        }
        let mut label = if margin > 0.0 { 1.0 } else { -1.0 };
        if rng.bernoulli(0.1) {
            label = -label;
        }
        a.push_row(&entries);
        b.push(label);
    }
    LibsvmDataset { name: spec.name.to_string(), a, b, lambda2: spec.lambda2 }
}

/// Parse real LibSVM text: `label idx:val idx:val ...` per line, 1-based
/// indices. Unknown dims grow to the max index seen (or `dim_hint`).
pub fn parse(text: &str, dim_hint: usize, name: &str, lambda2: f64) -> Result<LibsvmDataset, String> {
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut labels = Vec::new();
    let mut max_dim = dim_hint;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let lab: f32 = parts
            .next()
            .ok_or_else(|| format!("line {lineno}: empty"))?
            .parse()
            .map_err(|e| format!("line {lineno}: bad label: {e}"))?;
        // normalize labels to {-1, +1} (some datasets use {0,1} or {1,2})
        labels.push(if lab > 0.0 && lab < 1.5 { 1.0 } else if lab <= 0.0 { -1.0 } else { -1.0 });
        let mut entries = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {lineno}: bad pair {tok:?}"))?;
            let i: usize = i.parse().map_err(|e| format!("line {lineno}: {e}"))?;
            let v: f32 = v.parse().map_err(|e| format!("line {lineno}: {e}"))?;
            if i == 0 {
                return Err(format!("line {lineno}: libsvm indices are 1-based"));
            }
            max_dim = max_dim.max(i);
            entries.push(((i - 1) as u32, v));
        }
        rows.push(entries);
    }
    let mut a = SparseMatrix::new(0, max_dim);
    for r in &rows {
        a.push_row(r);
    }
    Ok(LibsvmDataset { name: name.to_string(), a, b: labels, lambda2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:1.0\n-1 2:2.0\n";
        let ds = parse(text, 0, "toy", 1e-3).unwrap();
        assert_eq!(ds.a.rows, 2);
        assert_eq!(ds.a.cols, 3);
        assert_eq!(ds.b, vec![1.0, -1.0]);
        assert_eq!(ds.a.row_dot(0, &[1.0, 1.0, 1.0]), 1.5);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(parse("+1 0:1.0\n", 0, "bad", 1e-3).is_err());
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let ds = parse("# header\n\n+1 1:1\n", 0, "c", 1e-3).unwrap();
        assert_eq!(ds.a.rows, 1);
    }

    #[test]
    fn synth_matches_spec() {
        let spec = &DATASETS[0]; // a5a
        let ds = synth_dataset(spec, 0);
        assert_eq!(ds.a.rows, spec.n_examples);
        assert_eq!(ds.a.cols, spec.dim);
        let density = ds.a.nnz() as f64 / (spec.n_examples * spec.dim) as f64;
        assert!((density - spec.density).abs() < 0.05, "density {density}");
        assert!(ds.b.iter().all(|&l| l == 1.0 || l == -1.0));
    }

    #[test]
    fn shards_are_heterogeneous() {
        // local optima differ across contiguous shards: grad of shard 0 at
        // the *global* optimum is materially nonzero.
        let spec = &DATASETS[1]; // mushrooms (small)
        let ds = synth_dataset(spec, 1);
        let global = ds.global();
        let mut x = vec![0.0f32; ds.a.cols];
        for _ in 0..300 {
            let g = global.grad(&x);
            for (xi, &gi) in x.iter_mut().zip(&g) {
                *xi -= 0.5 * gi;
            }
        }
        let shards = ds.shards(12);
        let g0 = shards[0].grad(&x);
        let norm: f64 = g0.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(norm > 1e-4, "shard-0 grad at optimum too small: {norm}");
    }

    #[test]
    fn shards_partition_rows() {
        let spec = &DATASETS[0];
        let ds = synth_dataset(spec, 2);
        let shards = ds.shards(12);
        let total: usize = shards.iter().map(|s| s.examples()).sum();
        assert_eq!(total, spec.n_examples);
    }

    #[test]
    fn real_sim_scale_generates_sparse() {
        let spec = &DATASETS[3];
        let ds = synth_dataset(spec, 3);
        assert_eq!(ds.a.cols, 20958);
        // sparse storage keeps this tractable
        assert!(ds.a.nnz() < 6_000_000);
    }
}
