//! l2-regularized logistic regression over CSR sparse data.
//!
//!   f(x) = (1/m) sum_l log(1 + exp(-b_l a_l^T x)) + (lambda/2) ||x||^2
//!
//! Matches `python/compile/model.py::logreg_loss/grad` (labels in {-1,+1});
//! the sparse representation also covers the real-sim-scale dataset that a
//! dense [m, d] operand could not.

/// CSR sparse matrix of examples (rows) x features (cols).
#[derive(Clone, Debug)]
pub struct SparseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseMatrix {
    pub fn new(rows: usize, cols: usize) -> Self {
        SparseMatrix { rows, cols, indptr: vec![0], indices: vec![], values: vec![] }
    }

    /// Append a row given (col, value) pairs (cols need not be sorted).
    pub fn push_row(&mut self, entries: &[(u32, f32)]) {
        for &(c, v) in entries {
            assert!((c as usize) < self.cols);
            self.indices.push(c);
            self.values.push(v);
        }
        self.indptr.push(self.indices.len());
        self.rows += usize::from(self.indptr.len() - 1 > self.rows);
        // keep rows consistent when constructed via new(0, cols)
        self.rows = self.indptr.len() - 1;
    }

    pub fn from_dense(data: &[Vec<f32>], cols: usize) -> Self {
        let mut m = SparseMatrix::new(0, cols);
        for row in data {
            assert_eq!(row.len(), cols);
            let entries: Vec<(u32, f32)> = row
                .iter()
                .enumerate()
                .filter(|&(_, &v)| v != 0.0)
                .map(|(j, &v)| (j as u32, v))
                .collect();
            m.push_row(&entries);
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// row . x
    #[inline]
    pub fn row_dot(&self, r: usize, x: &[f32]) -> f64 {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        let mut acc = 0.0f64;
        for k in lo..hi {
            acc += self.values[k] as f64 * x[self.indices[k] as usize] as f64;
        }
        acc
    }

    /// out += s * row
    #[inline]
    pub fn row_axpy(&self, r: usize, s: f64, out: &mut [f64]) {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        for k in lo..hi {
            out[self.indices[k] as usize] += s * self.values[k] as f64;
        }
    }
}

/// The model: data shard + labels + regularizer.
#[derive(Clone, Debug)]
pub struct LogReg {
    pub a: SparseMatrix,
    /// labels in {-1.0, +1.0}
    pub b: Vec<f32>,
    pub lambda: f64,
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[inline]
fn log1pexp(z: f64) -> f64 {
    // stable log(1 + exp(z))
    if z > 30.0 {
        z
    } else if z < -30.0 {
        z.exp()
    } else {
        z.exp().ln_1p()
    }
}

impl LogReg {
    pub fn dim(&self) -> usize {
        self.a.cols
    }

    pub fn examples(&self) -> usize {
        self.a.rows
    }

    /// Full loss over the shard.
    pub fn loss(&self, x: &[f32]) -> f64 {
        let m = self.examples();
        let mut acc = 0.0;
        for r in 0..m {
            let margin = -(self.b[r] as f64) * self.a.row_dot(r, x);
            acc += log1pexp(margin);
        }
        let reg: f64 =
            x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() * 0.5 * self.lambda;
        acc / m as f64 + reg
    }

    /// Gradient over a subset of rows (all rows when `rows` is None).
    pub fn grad_rows(&self, x: &[f32], rows: Option<&[usize]>) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.dim()];
        let iterate: Box<dyn Iterator<Item = usize>> = match rows {
            Some(rs) => Box::new(rs.iter().copied()),
            None => Box::new(0..self.examples()),
        };
        let mut count = 0usize;
        for r in iterate {
            let br = self.b[r] as f64;
            let margin = -br * self.a.row_dot(r, x);
            let coeff = -br * sigmoid(margin);
            self.a.row_axpy(r, coeff, &mut acc);
            count += 1;
        }
        let inv = 1.0 / count.max(1) as f64;
        acc.iter()
            .zip(x)
            .map(|(&a, &xi)| (a * inv + self.lambda * xi as f64) as f32)
            .collect()
    }

    pub fn grad(&self, x: &[f32]) -> Vec<f32> {
        self.grad_rows(x, None)
    }

    /// Gradient of one example (for L-SVRG).
    pub fn grad_one(&self, x: &[f32], row: usize, out: &mut [f64]) {
        out.fill(0.0);
        let br = self.b[row] as f64;
        let margin = -br * self.a.row_dot(row, x);
        let coeff = -br * sigmoid(margin);
        self.a.row_axpy(row, coeff, out);
        for (o, &xi) in out.iter_mut().zip(x) {
            *o += self.lambda * xi as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy() -> LogReg {
        // two separable points
        let a = SparseMatrix::from_dense(
            &[vec![1.0, 0.0], vec![-1.0, 0.5]],
            2,
        );
        LogReg { a, b: vec![1.0, -1.0], lambda: 0.1 }
    }

    #[test]
    fn loss_at_zero_is_log2() {
        let m = toy();
        assert!((m.loss(&[0.0, 0.0]) - (2.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let mut rng = Rng::new(0);
        let d = 8;
        let rows: Vec<Vec<f32>> = (0..20).map(|_| rng.normal_vec(d, 1.0)).collect();
        let b: Vec<f32> = (0..20)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let m = LogReg { a: SparseMatrix::from_dense(&rows, d), b, lambda: 0.01 };
        let x = rng.normal_vec(d, 0.5);
        let g = m.grad(&x);
        let eps = 1e-4;
        for j in 0..d {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += eps;
            xm[j] -= eps;
            let fd = (m.loss(&xp) - m.loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (g[j] as f64 - fd).abs() < 1e-3,
                "coord {j}: {g:?} vs fd {fd}"
            );
        }
    }

    #[test]
    fn minibatch_grads_average_to_full() {
        let mut rng = Rng::new(1);
        let d = 5;
        let rows: Vec<Vec<f32>> = (0..12).map(|_| rng.normal_vec(d, 1.0)).collect();
        let b: Vec<f32> = (0..12)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let m = LogReg { a: SparseMatrix::from_dense(&rows, d), b, lambda: 0.0 };
        let x = rng.normal_vec(d, 1.0);
        let full = m.grad(&x);
        // average of single-row grads == full grad (lambda = 0)
        let mut acc = vec![0.0f64; d];
        let mut tmp = vec![0.0f64; d];
        for r in 0..12 {
            m.grad_one(&x, r, &mut tmp);
            for (a, &t) in acc.iter_mut().zip(&tmp) {
                *a += t;
            }
        }
        for (a, &f) in acc.iter().zip(&full) {
            assert!((a / 12.0 - f as f64).abs() < 1e-6);
        }
    }

    #[test]
    fn gd_converges_and_gradient_vanishes() {
        let mut rng = Rng::new(2);
        let d = 10;
        let rows: Vec<Vec<f32>> = (0..50).map(|_| rng.normal_vec(d, 1.0)).collect();
        let b: Vec<f32> = rows
            .iter()
            .map(|r| if r[0] + 0.3 * r[1] > 0.0 { 1.0 } else { -1.0 })
            .collect();
        let m = LogReg { a: SparseMatrix::from_dense(&rows, d), b, lambda: 1e-3 };
        let mut x = vec![0.0f32; d];
        for _ in 0..500 {
            let g = m.grad(&x);
            for (xi, &gi) in x.iter_mut().zip(&g) {
                *xi -= 1.0 * gi;
            }
        }
        let gnorm: f64 = m.grad(&x).iter().map(|&v| (v as f64).powi(2)).sum();
        // f32 parameter storage floors the reachable gradient norm
        assert!(gnorm < 1e-4, "grad norm sq {gnorm}");
    }

    #[test]
    fn sparse_matches_dense_path() {
        // rows with explicit zeros compress away but compute identically
        let dense = vec![vec![0.0f32, 2.0, 0.0, -1.0], vec![1.0, 0.0, 0.0, 0.0]];
        let m = SparseMatrix::from_dense(&dense, 4);
        assert_eq!(m.nnz(), 3);
        let x = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(m.row_dot(0, &x), 0.0); // 2*2 + (-1)*4

        assert_eq!(m.row_dot(1, &x), 1.0);
    }
}
