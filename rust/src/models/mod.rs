//! Rust-native model oracles.
//!
//! `logreg` is the workhorse of the paper's Appendix C.5 experiments
//! (Fig. 6) and doubles as the numeric cross-check for the PJRT logistic-
//! regression artifacts (`rust/tests/pjrt_roundtrip.rs`).

pub mod logreg;

pub use logreg::{LogReg, SparseMatrix};
