//! Chrome trace-event exporter: renders the span journal as the JSON
//! the `chrome://tracing` / Perfetto UI loads, so streamed-vs-barrier
//! overlap is *visible* — encode spans for block k+1 drawn on top of the
//! wire span for block k — instead of inferred from summed timings.
//!
//! Format: the "JSON object" flavor of the trace-event spec — an object
//! with a `traceEvents` array of `"ph":"X"` complete events (`ts`/`dur`
//! in microseconds) plus `"ph":"M"` `thread_name` metadata rows naming
//! the lanes.
//!
//! Lane (tid) scheme: leader-side spans (`rank == ALL`) land on one lane
//! per phase (tid = the [`Phase`] discriminant), so the round/encode/
//! reduce/drain/decode rows stack like a flame graph; rank-attributed
//! spans (per-rank collective legs) land on `tid = 16 + rank`, one lane
//! per rank, below the leader lanes.
//!
//! Determinism: events are sorted by `(start_ns, phase, block, rank,
//! round)` and timestamps are formatted from integer nanoseconds
//! (`ts`/`dur` strings are `ns/1000 . ns%1000` — no float formatting),
//! so identical journals render byte-identical files; the golden test
//! pins exactly that.

use std::fmt::Write as _;

use super::journal::{Phase, SpanEvent, ALL};

/// First rank lane; leaves room for the six phase lanes plus headroom.
const RANK_LANE_BASE: u32 = 16;

fn lane(ev: &SpanEvent) -> u32 {
    if ev.rank == ALL {
        ev.phase as u32
    } else {
        RANK_LANE_BASE + ev.rank as u32
    }
}

/// Microseconds with fixed 3-decimal nanosecond precision, formatted
/// from integers (deterministic across platforms).
fn micros(ns: u64) -> String {
    format!("{}.{:03}", ns / 1_000, ns % 1_000)
}

fn event_name(ev: &SpanEvent) -> String {
    if ev.block == ALL {
        ev.phase.name().to_string()
    } else {
        format!("{} b{}", ev.phase.name(), ev.block)
    }
}

/// Render spans as a complete Chrome trace JSON document. The caller
/// passes a [`crate::telemetry::journal::snapshot`] (or a hand-built
/// list, as the golden test does).
pub fn render(events: &[SpanEvent]) -> String {
    let mut evs: Vec<&SpanEvent> = events.iter().collect();
    evs.sort_by_key(|e| (e.start_ns, e.phase, e.block, e.rank, e.round));

    // lanes in use, phase lanes first then ranks ascending
    let mut lanes: Vec<u32> = evs.iter().map(|e| lane(e)).collect();
    lanes.sort_unstable();
    lanes.dedup();

    let mut out = String::with_capacity(64 + 160 * evs.len());
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    for tid in &lanes {
        let name = if *tid < RANK_LANE_BASE {
            match *tid {
                0 => "round".to_string(),
                1 => "compute".to_string(),
                2 => "encode".to_string(),
                3 => "reduce".to_string(),
                4 => "drain".to_string(),
                _ => "decode".to_string(),
            }
        } else {
            format!("rank {}", tid - RANK_LANE_BASE)
        };
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"{name}\"}}}}"
        );
    }
    for ev in &evs {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\"name\":\"{name}\",\
             \"cat\":\"{cat}\",\"ts\":{ts},\"dur\":{dur},\
             \"args\":{{\"round\":{round},\"block\":{block},\"rank\":{rank}}}}}",
            tid = lane(ev),
            name = event_name(ev),
            cat = ev.phase.name(),
            ts = micros(ev.start_ns),
            dur = micros(ev.dur_ns),
            round = ev.round,
            block = ev.block as i64,
            rank = ev.rank as i64,
        );
    }
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn span(phase: Phase, start: u64, dur: u64, block: u16, rank: u16) -> SpanEvent {
        SpanEvent { start_ns: start, dur_ns: dur, round: 3, phase, block, rank }
    }

    #[test]
    fn render_is_valid_json_with_sorted_events() {
        let events = vec![
            span(Phase::Decode, 9_000, 500, ALL, ALL),
            span(Phase::Encode, 1_000, 2_500, 0, ALL),
            span(Phase::Reduce, 3_500, 4_000, 0, ALL),
            span(Phase::Reduce, 3_600, 3_000, 0, 1),
        ];
        let text = render(&events);
        let doc = Json::parse(&text).expect("exporter must emit valid JSON");
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 4 lanes in use (encode/reduce/decode + rank 1) -> 4 metadata
        // rows, then the 4 spans sorted by start time
        assert_eq!(evs.len(), 8);
        let xs: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        let starts: Vec<f64> =
            xs.iter().map(|e| e.get("ts").and_then(Json::as_f64).unwrap()).collect();
        assert_eq!(starts, vec![1.0, 3.5, 3.6, 9.0]);
        // timestamps are integer-formatted us.ns, never float-printed
        assert!(text.contains("\"ts\":1.000,"), "{text}");
        assert!(text.contains("\"dur\":2.500,"), "{text}");
        // leader spans ride the phase lanes; the rank span rides 16+rank
        let tids: Vec<f64> =
            xs.iter().map(|e| e.get("tid").and_then(Json::as_f64).unwrap()).collect();
        assert_eq!(tids, vec![2.0, 3.0, 17.0, 5.0]);
    }

    #[test]
    fn identical_journals_render_identical_bytes() {
        let events = vec![
            span(Phase::Round, 0, 10_000, ALL, ALL),
            span(Phase::Encode, 100, 2_000, 1, ALL),
        ];
        assert_eq!(render(&events), render(&events));
        // order of the input list must not matter
        let mut rev = events.clone();
        rev.reverse();
        assert_eq!(render(&events), render(&rev));
    }
}
