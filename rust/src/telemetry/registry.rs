//! The static instrument registry: every metric the system exports is
//! pre-registered here as a `static` with a `const` constructor, so the
//! round hot path updates plain atomics — no map lookup, no string
//! hashing, no heap. The exporters ([`crate::telemetry::prom`],
//! [`crate::telemetry::chrome`]) iterate [`all`] off the hot path and may
//! allocate freely.
//!
//! Instrument kinds:
//!
//! - [`Counter`] — monotone `u64` (`_total` families).
//! - [`Gauge`] — last-write-wins `f64` (stored as bits in an `AtomicU64`).
//! - [`Histogram`] — log2-bucketed distribution of `u64` samples
//!   (durations in nanoseconds, exported in seconds); bucket index is
//!   `ilog2(value)`, so recording is a shift + two `fetch_add`s.
//! - [`GaugeVec`] — a fixed block-indexed gauge array (the per-block
//!   alpha trajectory) with a high-water `used` mark; blocks past
//!   [`GaugeVec::CAPACITY`] are counted, not stored.
//! - [`LaneCounters`] — one counter per wire lane (i8/i32/i64), exported
//!   as a single family with a `lane` label.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::compress::intvec::Lanes;

/// Monotonically increasing event count.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Self {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// Last-write-wins scalar (an `f64` stored as bits — one atomic store).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Self {
        Gauge { bits: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Log2 bucket count: bucket i holds samples with `ilog2(v) == i`, i.e.
/// `v < 2^(i+1)`. 40 buckets cover 1 ns .. ~18 min — every phase duration
/// this system can produce.
pub const HISTOGRAM_BUCKETS: usize = 40;

/// Log2-bucketed histogram of `u64` samples. The recorded unit is
/// nanoseconds; the Prometheus exporter converts bucket bounds and the
/// sum to seconds (the metric names carry `_seconds`).
pub struct Histogram {
    count: AtomicU64,
    /// Sum of all recorded samples (ns — u64 holds ~584 years of it).
    sum: AtomicU64,
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
}

impl Histogram {
    pub const fn new() -> Self {
        // `AtomicU64` is not `Copy`; build the array element by element
        // via the const-friendly `[const { ... }; N]` form
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: [const { AtomicU64::new(0) }; HISTOGRAM_BUCKETS],
        }
    }

    /// Record one sample. Two `fetch_add`s and an indexed third — no
    /// allocation, no lock.
    #[inline]
    pub fn record(&self, v: u64) {
        let idx = (v.max(1).ilog2() as usize).min(HISTOGRAM_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration given in seconds (stored as nanoseconds).
    #[inline]
    pub fn record_secs(&self, secs: f64) {
        if secs >= 0.0 {
            self.record((secs * 1e9) as u64);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }

    /// Upper bound of bucket i in the recorded unit (ns): `2^(i+1)`.
    pub fn bucket_bound(i: usize) -> u64 {
        1u64 << (i as u32 + 1).min(63)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// A fixed-capacity array of gauges indexed by parameter block — the
/// per-block alpha trajectory. `set_all` records the active block count
/// as a high-water mark; the exporter emits one labeled sample per slot
/// in use. Blocks past the capacity update [`GaugeVec::overflowed`]
/// instead of silently vanishing.
pub struct GaugeVec {
    slots: [Gauge; GaugeVec::CAPACITY],
    used: AtomicUsize,
    overflow: AtomicU64,
}

impl GaugeVec {
    /// Block slots held statically. 64 covers every model layout in the
    /// repo (the transformer has 13 blocks); larger layouts keep the
    /// first 64 and count the rest in `overflowed`.
    pub const CAPACITY: usize = 64;

    pub const fn new() -> Self {
        GaugeVec {
            slots: [const { Gauge::new() }; GaugeVec::CAPACITY],
            used: AtomicUsize::new(0),
            overflow: AtomicU64::new(0),
        }
    }

    /// Store one value per block (zero-alloc: a store per slot).
    #[inline]
    pub fn set_all(&self, values: &[f64]) {
        let n = values.len().min(Self::CAPACITY);
        for (slot, &v) in self.slots[..n].iter().zip(values) {
            slot.set(v);
        }
        if values.len() > Self::CAPACITY {
            self.overflow
                .fetch_add((values.len() - Self::CAPACITY) as u64, Ordering::Relaxed);
        }
        self.used.fetch_max(n, Ordering::Relaxed);
    }

    /// Store one slot (zero-alloc: a single store). Indices past the
    /// capacity update [`GaugeVec::overflowed`] instead of silently
    /// vanishing — same contract as [`GaugeVec::set_all`].
    #[inline]
    pub fn set(&self, i: usize, v: f64) {
        if let Some(slot) = self.slots.get(i) {
            slot.set(v);
            self.used.fetch_max(i + 1, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Slots in use (high-water mark across rounds).
    pub fn used(&self) -> usize {
        self.used.load(Ordering::Relaxed)
    }

    pub fn get(&self, i: usize) -> f64 {
        self.slots[i].get()
    }

    /// Values that had no slot (layouts wider than the capacity).
    pub fn overflowed(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }
}

impl Default for GaugeVec {
    fn default() -> Self {
        GaugeVec::new()
    }
}

/// One counter per wire lane — how often each integer width carried a
/// collective's partial sums (`TransportReducer`'s `partial_sum_lanes`
/// choice, the byte count the paper's all-reduce argument is about).
pub struct LaneCounters {
    pub i8: Counter,
    pub i32: Counter,
    pub i64: Counter,
}

impl LaneCounters {
    pub const fn new() -> Self {
        LaneCounters { i8: Counter::new(), i32: Counter::new(), i64: Counter::new() }
    }

    #[inline]
    pub fn bump(&self, lanes: Lanes) {
        match lanes {
            Lanes::I8 => self.i8.inc(),
            Lanes::I32 => self.i32.inc(),
            Lanes::I64 => self.i64.inc(),
        }
    }
}

impl Default for LaneCounters {
    fn default() -> Self {
        LaneCounters::new()
    }
}

/// The pre-registered instruments, grouped for call-site readability:
/// `m::ROUNDS.inc()` reads like the metric name it feeds.
pub mod m {
    use super::{Counter, Gauge, GaugeVec, Histogram, LaneCounters};

    // -- round progress --------------------------------------------------
    pub static ROUNDS: Counter = Counter::new();
    pub static FAILOVERS: Counter = Counter::new();
    pub static TRAIN_LOSS: Gauge = Gauge::new();

    // -- IntSGD instruments (paper-specific) -----------------------------
    /// Per-block alpha gauge (Alg. 2 trajectory), labeled `block="i"`.
    pub static ALPHA_BLOCK: GaugeVec = GaugeVec::new();
    /// min over blocks — the round's `RoundRecord::alpha`.
    pub static ALPHA_MIN: Gauge = Gauge::new();
    /// `max|sum|` over the aggregate relative to the proved wire bound
    /// `n*clip` — 1.0 means the clip actually bit this round.
    pub static CLIP_UTILIZATION: Gauge = Gauge::new();
    pub static CLIP_SATURATED_ROUNDS: Counter = Counter::new();

    // -- wire accounting -------------------------------------------------
    /// Per-worker payload bytes divided by the gradient dimension — the
    /// headline "1 byte per coordinate" number, per round.
    pub static BYTES_PER_COORD: Gauge = Gauge::new();
    /// Total payload bytes shipped (per-worker bytes × world size).
    pub static WIRE_BYTES: Counter = Counter::new();
    pub static WIRE_LANE: LaneCounters = LaneCounters::new();

    // -- phase durations -------------------------------------------------
    pub static ENCODE_SECONDS: Histogram = Histogram::new();
    pub static REDUCE_SECONDS: Histogram = Histogram::new();
    pub static DECODE_SECONDS: Histogram = Histogram::new();
    /// Measured wall-clock inside staged collectives, per round
    /// (transport backends only).
    pub static COMM_SECONDS: Histogram = Histogram::new();

    // -- transport health (fed from TransportReducer / FaultTransport) ---
    pub static NET_COLLECTIVES: Counter = Counter::new();
    pub static NET_RETRIES: Counter = Counter::new();
    pub static NET_TIMEOUTS: Counter = Counter::new();
    pub static NET_REPLAYS: Counter = Counter::new();
    pub static NET_CORRUPT: Counter = Counter::new();
    pub static NET_STALE_FRAMES: Counter = Counter::new();
    pub static FAULTS_INJECTED: Counter = Counter::new();

    // -- the journal's own health ----------------------------------------
    pub static JOURNAL_EVENTS: Counter = Counter::new();
    pub static JOURNAL_DROPPED: Counter = Counter::new();

    // -- mux runtime / multi-job serving ---------------------------------
    pub static NET_BACKPRESSURE_EVENTS: Counter = Counter::new();
    pub static MUX_CHANNELS_ACTIVE: Gauge = Gauge::new();
    pub static MUX_QUEUE_DEPTH: GaugeVec = GaugeVec::new();
    pub static SERVER_JOBS_ACTIVE: Gauge = Gauge::new();
    pub static SERVER_JOBS_COMPLETED: Counter = Counter::new();
}

/// A registered metric, as the exporters see it.
pub enum Metric {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histogram),
    /// A slot-indexed gauge family; the `&str` is the Prometheus label
    /// the exporter keys each slot by (`block` for per-block alpha,
    /// `channel` for per-channel mux queue depth).
    V(&'static GaugeVec, &'static str),
    L(&'static LaneCounters),
}

pub struct Def {
    /// Prometheus family name (`intsgd_` prefixed, `_total` on counters).
    pub name: &'static str,
    pub help: &'static str,
    pub metric: Metric,
}

/// Every instrument, in export order. Adding an instrument = one static
/// in [`m`] plus one row here; the scrape test pins that the two stay in
/// sync by asserting the family list.
pub fn all() -> &'static [Def] {
    use Metric::{C, G, H, L, V};
    static DEFS: &[Def] = &[
        Def {
            name: "intsgd_rounds_total",
            help: "Completed training rounds.",
            metric: C(&m::ROUNDS),
        },
        Def {
            name: "intsgd_failovers_total",
            help: "World shrinks after a permanent rank death.",
            metric: C(&m::FAILOVERS),
        },
        Def {
            name: "intsgd_train_loss",
            help: "Mean worker training loss of the last round.",
            metric: G(&m::TRAIN_LOSS),
        },
        Def {
            name: "intsgd_alpha",
            help: "Per-block IntSGD scaling alpha (Alg. 2), last round.",
            metric: V(&m::ALPHA_BLOCK, "block"),
        },
        Def {
            name: "intsgd_alpha_min",
            help: "Min alpha over blocks, last round.",
            metric: G(&m::ALPHA_MIN),
        },
        Def {
            name: "intsgd_clip_utilization",
            help: "max|aggregate| over the proved wire bound n*clip, last \
                   integer round (1.0 = the clip saturated).",
            metric: G(&m::CLIP_UTILIZATION),
        },
        Def {
            name: "intsgd_clip_saturated_rounds_total",
            help: "Integer rounds whose aggregate reached the clip bound.",
            metric: C(&m::CLIP_SATURATED_ROUNDS),
        },
        Def {
            name: "intsgd_wire_bytes_per_coord",
            help: "Per-worker payload bytes / gradient dimension, last round.",
            metric: G(&m::BYTES_PER_COORD),
        },
        Def {
            name: "intsgd_wire_bytes_total",
            help: "Total payload bytes shipped (per-worker bytes x world).",
            metric: C(&m::WIRE_BYTES),
        },
        Def {
            name: "intsgd_wire_lane_rounds_total",
            help: "Collectives whose partial sums shipped at each lane width.",
            metric: L(&m::WIRE_LANE),
        },
        Def {
            name: "intsgd_encode_seconds",
            help: "Encode phase duration per round (straggler max).",
            metric: H(&m::ENCODE_SECONDS),
        },
        Def {
            name: "intsgd_reduce_seconds",
            help: "Reduce phase duration per round.",
            metric: H(&m::REDUCE_SECONDS),
        },
        Def {
            name: "intsgd_decode_seconds",
            help: "Leader decode/fold duration per round.",
            metric: H(&m::DECODE_SECONDS),
        },
        Def {
            name: "intsgd_comm_measured_seconds",
            help: "Measured wall-clock inside staged collectives per round.",
            metric: H(&m::COMM_SECONDS),
        },
        Def {
            name: "intsgd_net_collectives_total",
            help: "Staged collectives executed (logical, not attempts).",
            metric: C(&m::NET_COLLECTIVES),
        },
        Def {
            name: "intsgd_net_retries_total",
            help: "Retried collective attempts.",
            metric: C(&m::NET_RETRIES),
        },
        Def {
            name: "intsgd_net_timeouts_total",
            help: "Rank-level timeout errors observed inside attempts.",
            metric: C(&m::NET_TIMEOUTS),
        },
        Def {
            name: "intsgd_net_replays_total",
            help: "Rank-level replay (duplicate-frame) errors observed.",
            metric: C(&m::NET_REPLAYS),
        },
        Def {
            name: "intsgd_net_corrupt_total",
            help: "Rank-level corrupt/truncated-frame errors observed.",
            metric: C(&m::NET_CORRUPT),
        },
        Def {
            name: "intsgd_net_stale_frames_total",
            help: "Stale frames the round/seq guard discarded.",
            metric: C(&m::NET_STALE_FRAMES),
        },
        Def {
            name: "intsgd_faults_injected_total",
            help: "Frames the fault injector tampered with (all kinds).",
            metric: C(&m::FAULTS_INJECTED),
        },
        Def {
            name: "intsgd_journal_events_total",
            help: "Span events recorded into the telemetry journal.",
            metric: C(&m::JOURNAL_EVENTS),
        },
        Def {
            name: "intsgd_journal_dropped_total",
            help: "Journal ring overwrites (oldest span evicted).",
            metric: C(&m::JOURNAL_DROPPED),
        },
        Def {
            name: "intsgd_net_backpressure_events_total",
            help: "Sends that observed a full bounded channel queue.",
            metric: C(&m::NET_BACKPRESSURE_EVENTS),
        },
        Def {
            name: "intsgd_mux_channels_active",
            help: "Mux channels with at least one live endpoint.",
            metric: G(&m::MUX_CHANNELS_ACTIVE),
        },
        Def {
            name: "intsgd_mux_queue_depth",
            help: "Frames queued but unwritten, per mux channel (last send).",
            metric: V(&m::MUX_QUEUE_DEPTH, "channel"),
        },
        Def {
            name: "intsgd_server_jobs_active",
            help: "Jobs currently scheduled by the SessionServer.",
            metric: G(&m::SERVER_JOBS_ACTIVE),
        },
        Def {
            name: "intsgd_server_jobs_completed_total",
            help: "Jobs the SessionServer drove to completion.",
            metric: C(&m::SERVER_JOBS_COMPLETED),
        },
    ];
    DEFS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_log2() {
        let h = Histogram::new();
        h.record(1); // bucket 0 (2^0)
        h.record(2); // bucket 1
        h.record(3); // bucket 1
        h.record(1024); // bucket 10
        h.record(0); // clamped to 1 -> bucket 0
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1 + 2 + 3 + 1024);
        assert_eq!(h.bucket(0), 2);
        assert_eq!(h.bucket(1), 2);
        assert_eq!(h.bucket(10), 1);
        assert_eq!(Histogram::bucket_bound(0), 2);
        assert_eq!(Histogram::bucket_bound(10), 2048);
        // a sample past the last bound lands in the final bucket
        h.record(u64::MAX);
        assert_eq!(h.bucket(HISTOGRAM_BUCKETS - 1), 1);
    }

    #[test]
    fn gauge_vec_tracks_used_and_overflow() {
        let v = GaugeVec::new();
        v.set_all(&[1.5, 2.5]);
        assert_eq!(v.used(), 2);
        assert_eq!(v.get(0), 1.5);
        assert_eq!(v.get(1), 2.5);
        // shrinking layouts keep the high-water mark
        v.set_all(&[9.0]);
        assert_eq!(v.used(), 2);
        assert_eq!(v.get(0), 9.0);
        let wide = vec![0.25; GaugeVec::CAPACITY + 3];
        v.set_all(&wide);
        assert_eq!(v.used(), GaugeVec::CAPACITY);
        assert_eq!(v.overflowed(), 3);
    }

    #[test]
    fn gauge_vec_single_slot_set_tracks_used_and_overflow() {
        let v = GaugeVec::new();
        v.set(3, 7.5);
        assert_eq!(v.used(), 4, "used is a high-water mark over indices");
        assert_eq!(v.get(3), 7.5);
        v.set(0, 1.0);
        assert_eq!(v.used(), 4, "lower slots keep the mark");
        v.set(GaugeVec::CAPACITY, 2.0);
        assert_eq!(v.overflowed(), 1, "out-of-capacity slots are counted");
        assert_eq!(v.used(), 4);
    }

    #[test]
    fn every_def_name_is_unique_and_prefixed() {
        let defs = all();
        for (i, d) in defs.iter().enumerate() {
            assert!(d.name.starts_with("intsgd_"), "{}", d.name);
            assert!(!d.help.is_empty(), "{}", d.name);
            for other in &defs[i + 1..] {
                assert_ne!(d.name, other.name, "duplicate family");
            }
            if let Metric::C(_) | Metric::L(_) = d.metric {
                assert!(d.name.ends_with("_total"), "counter {} needs _total", d.name);
            }
        }
    }
}
