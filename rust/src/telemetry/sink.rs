//! [`TelemetrySink`]: the one [`RoundObserver`] the drivers attach.
//!
//! Before this existed, `net_driver` ran two ad-hoc observers with
//! duplicated per-round accounting (a `WireWatcher` summing wire time
//! and a `BreakdownPrinter` re-reading the same breakdown to print it).
//! The sink does both jobs from a single stream of `on_round` calls:
//! always accumulates the measured/modeled/retry totals the summary
//! lines need, and optionally prints the per-round breakdown table rows
//! (switched on with [`TelemetrySink::begin_table`]). The *registry* is
//! not fed here — `Coordinator::run_round` feeds it for every driver,
//! observer or not — so attaching the sink never double-counts.

use crate::coordinator::{RoundObserver, RoundRecord};
use crate::netsim::RoundBreakdown;

#[derive(Default)]
pub struct TelemetrySink {
    measured: f64,
    retries: u64,
    modeled_int: f64,
    /// `Some(next_row)` while the breakdown table is being printed.
    table_row: Option<usize>,
}

impl TelemetrySink {
    pub fn new() -> Self {
        TelemetrySink::default()
    }

    /// Measured transport wall-clock summed over observed rounds.
    pub fn measured(&self) -> f64 {
        self.measured
    }

    /// Retried collective attempts summed over observed rounds.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Modeled comm seconds summed over observed *integer* rounds
    /// (round 0 ships exact fp32 and is excluded — the
    /// measured-vs-modeled ratio is about the integer wire).
    pub fn modeled_int(&self) -> f64 {
        self.modeled_int
    }

    /// Print the breakdown table header and a row per round from here on.
    pub fn begin_table(&mut self) {
        println!(
            "  {:<8} {:>12} {:>12} {:>12} {:>14} {:>14} {:>8}",
            "round", "encode", "reduce", "decode", "comm_model", "comm_measured", "retries"
        );
        self.table_row = Some(0);
    }
}

impl RoundObserver for TelemetrySink {
    fn on_round(&mut self, rec: &RoundRecord, b: &RoundBreakdown) {
        self.measured += b.comm_measured;
        self.retries += b.comm_retries;
        if rec.round >= 1 {
            self.modeled_int += rec.comm_seconds;
        }
        if let Some(row) = &mut self.table_row {
            println!(
                "  {:<8} {:>12.6} {:>12.6} {:>12.6} {:>14.6} {:>14.6} {:>8}",
                row, b.encode, b.reduce, b.decode, b.comm_model, b.comm_measured,
                b.comm_retries
            );
            *row += 1;
        }
    }

    fn on_failover(&mut self, round: usize, rank: usize) {
        println!("  FAILOVER: rank {rank} died in round {round}; world shrank and trained on");
    }
}
