//! Prometheus text-format exporter: renders the static registry as
//! exposition format 0.0.4 (`# HELP` / `# TYPE` + samples) and serves it
//! over a minimal `std::net` HTTP listener — enough for a real
//! Prometheus scraper or a `curl` in CI, with zero crates.
//!
//! Rendering walks [`registry::all`] off the hot path; the hot path only
//! ever touches the atomics. Histograms are recorded in nanoseconds and
//! exported in seconds (cumulative `_bucket{le=...}` + `_sum` +
//! `_count`, per the exposition spec); `le` bounds are the log2 bucket
//! bounds `2^(i+1) ns`, printed with Rust's `f64` `Display`, which never
//! uses scientific notation — the output is deterministic.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::{self, Histogram, Metric, HISTOGRAM_BUCKETS};

fn render_histogram(out: &mut String, name: &str, h: &Histogram) {
    use std::fmt::Write as _;
    let mut cum = 0u64;
    for i in 0..HISTOGRAM_BUCKETS {
        cum += h.bucket(i);
        let le = Histogram::bucket_bound(i) as f64 / 1e9;
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", h.sum() as f64 / 1e9);
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Render every registered instrument as one exposition document.
pub fn render() -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(8 * 1024);
    for def in registry::all() {
        let kind = match def.metric {
            Metric::C(_) | Metric::L(_) => "counter",
            Metric::G(_) | Metric::V(..) => "gauge",
            Metric::H(_) => "histogram",
        };
        // help strings are written as wrapped literals; re-join them
        let help = def.help.split_whitespace().collect::<Vec<_>>().join(" ");
        let _ = writeln!(out, "# HELP {} {}", def.name, help);
        let _ = writeln!(out, "# TYPE {} {}", def.name, kind);
        match def.metric {
            Metric::C(c) => {
                let _ = writeln!(out, "{} {}", def.name, c.get());
            }
            Metric::G(g) => {
                let _ = writeln!(out, "{} {}", def.name, g.get());
            }
            Metric::H(h) => render_histogram(&mut out, def.name, h),
            Metric::V(v, label) => {
                for i in 0..v.used() {
                    let _ = writeln!(out, "{}{{{label}=\"{i}\"}} {}", def.name, v.get(i));
                }
            }
            Metric::L(l) => {
                let _ = writeln!(out, "{}{{lane=\"i8\"}} {}", def.name, l.i8.get());
                let _ = writeln!(out, "{}{{lane=\"i32\"}} {}", def.name, l.i32.get());
                let _ = writeln!(out, "{}{{lane=\"i64\"}} {}", def.name, l.i64.get());
            }
        }
    }
    out
}

/// A one-thread HTTP/1.0 scrape endpoint: every connection gets the
/// current [`render`] back, whatever the request line says. Binding
/// `127.0.0.1:0` picks a free port ([`MetricsServer::addr`] reports it).
/// The listener thread exits on drop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    pub fn bind(addr: &str) -> io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("intsgd-metrics".into())
            .spawn(move || serve(listener, &stop2))?;
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (with the OS-assigned port when `:0` was asked).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // wake the blocking accept so the thread observes the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve(listener: TcpListener, stop: &AtomicBool) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        if let Ok(mut stream) = conn {
            let _ = handle_conn(&mut stream);
        }
    }
}

fn handle_conn(stream: &mut TcpStream) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    // drain the request head (best effort — the response is the same for
    // every path; a scraper that pipelines more than 4 KiB of headers
    // gets its answer anyway)
    let mut head = [0u8; 4096];
    let mut n = 0;
    while n < head.len() {
        match stream.read(&mut head[n..]) {
            Ok(0) => break,
            Ok(k) => {
                n += k;
                if head[..n].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let body = render();
    let header = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; \
         charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_covers_every_family_with_help_and_type() {
        let text = render();
        for def in registry::all() {
            assert!(
                text.contains(&format!("# HELP {} ", def.name)),
                "missing HELP for {}",
                def.name
            );
            assert!(
                text.contains(&format!("# TYPE {} ", def.name)),
                "missing TYPE for {}",
                def.name
            );
        }
        // histograms carry the spec'd sample suffixes
        assert!(text.contains("intsgd_encode_seconds_bucket{le=\"+Inf\"}"));
        assert!(text.contains("intsgd_encode_seconds_sum "));
        assert!(text.contains("intsgd_encode_seconds_count "));
        // labeled lane family lists all three widths
        for lane in ["i8", "i32", "i64"] {
            assert!(text.contains(&format!("intsgd_wire_lane_rounds_total{{lane=\"{lane}\"}}")));
        }
        // no float ever renders in scientific notation on a sample line
        // (help prose may legitimately hyphenate, e.g. "duplicate-frame")
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(!line.contains("e-"), "scientific notation leaked: {line}");
        }
    }

    #[test]
    fn server_answers_a_scrape() {
        let server = MetricsServer::bind("127.0.0.1:0").expect("bind");
        let mut conn = TcpStream::connect(server.addr()).expect("connect");
        conn.write_all(b"GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("intsgd_rounds_total"), "{resp}");
        drop(server); // the listener thread must join without hanging
    }
}
