//! The span journal: a fixed-capacity ring buffer of phase spans
//! (encode / reduce / drain / decode, per round, per block, per rank)
//! behind a process-global switch.
//!
//! Hot-path contract: when the journal is disabled (the default),
//! [`start`] is one vDSO clock read and [`record`] is one relaxed atomic
//! load — nothing else. When enabled, [`record`] takes an uncontended
//! mutex (a futex word on Linux — no allocation) and writes one
//! [`SpanEvent`] into a ring pre-allocated by [`enable`]; a full ring
//! overwrites the oldest span and bumps `intsgd_journal_dropped_total`.
//! Either way the round loop never touches the allocator, which is
//! exactly what `tests/zero_alloc.rs` pins with the journal switched on.
//!
//! Timestamps are nanoseconds since the journal epoch (the first
//! [`enable`]/[`start`] call), so spans from different threads share one
//! clock and the Chrome exporter can lay them on a common axis.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::registry::m;

/// Which phase of a round a span covers. The discriminants are the
/// Chrome-trace lane order (see [`crate::telemetry::chrome`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Phase {
    /// The whole round, wall to wall.
    Round = 0,
    /// Worker forward/backward (gradient production).
    Compute = 1,
    /// Encode: float gradient -> integer message (straggler span on the
    /// barrier paths; per-block overlap window on the streamed path).
    Encode = 2,
    /// The integer all-reduce (logical collective, retries included).
    Reduce = 3,
    /// Streamed-path drain: folding a finished block's aggregate into
    /// the round sum while later blocks are still on the wire.
    Drain = 4,
    /// Leader decode: integer aggregate -> float step.
    Decode = 5,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Round => "round",
            Phase::Compute => "compute",
            Phase::Encode => "encode",
            Phase::Reduce => "reduce",
            Phase::Drain => "drain",
            Phase::Decode => "decode",
        }
    }
}

/// Span scope marker: "not attributable to one rank" / "not one block".
pub const ALL: u16 = u16::MAX;

/// One recorded phase span. 24 bytes, `Copy` — the ring holds these by
/// value, so recording never chases a pointer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    /// Nanoseconds since the journal epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    pub round: u32,
    pub phase: Phase,
    /// Parameter-block index, or [`ALL`] for whole-round spans.
    pub block: u16,
    /// Rank the span belongs to, or [`ALL`] for leader-side spans.
    pub rank: u16,
}

struct Ring {
    buf: Vec<SpanEvent>,
    cap: usize,
    /// Next write position (wraps).
    head: usize,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) -> bool {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
            true
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            false
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static JOURNAL: Mutex<Option<Ring>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Default ring capacity: 6 phases x 64 blocks x ~170 rounds of streamed
/// spans before the ring wraps — plenty for a trace window, bounded for
/// a long run (~1.5 MiB).
pub const DEFAULT_CAPACITY: usize = 65_536;

// Telemetry is an allowed zone for wall-clock reads (clippy.toml): the
// epoch is the one clock every span timestamp is measured against.
#[allow(clippy::disallowed_methods)]
fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the journal epoch (monotonic, shared by all
/// threads). Cheap: one vDSO `clock_gettime`.
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Switch the journal on with a pre-allocated ring of `capacity` spans.
/// All allocation happens here, off the hot path; re-enabling keeps the
/// existing ring if the capacity already matches, else re-allocates.
pub fn enable(capacity: usize) {
    assert!(capacity > 0, "journal capacity must be positive");
    let _ = epoch(); // pin the epoch before the first span
    let mut guard = JOURNAL.lock().unwrap();
    let keep = matches!(&*guard, Some(r) if r.cap == capacity);
    if !keep {
        *guard = Some(Ring { buf: Vec::with_capacity(capacity), cap: capacity, head: 0 });
    }
    drop(guard);
    ENABLED.store(true, Ordering::Release);
}

/// Stop recording (the ring and its contents are kept for export).
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Timestamp the start of a span. Call unconditionally — it is one clock
/// read — and hand the result to [`record`] when the phase ends.
#[inline]
pub fn start() -> u64 {
    now_ns()
}

/// Close a span opened with [`start`] and journal it (no-op while
/// disabled). `block`/`rank` take [`ALL`] when the span is not scoped to
/// one block / one rank.
#[inline]
pub fn record(phase: Phase, round: u32, block: u16, rank: u16, start_ns: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let dur_ns = now_ns().saturating_sub(start_ns);
    push(SpanEvent { start_ns, dur_ns, round, phase, block, rank });
}

/// Journal a fully-formed span (exporter tests and replay tooling; the
/// engine uses [`record`]).
pub fn push(ev: SpanEvent) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let mut guard = JOURNAL.lock().unwrap();
    if let Some(ring) = guard.as_mut() {
        let fit = ring.push(ev);
        m::JOURNAL_EVENTS.inc();
        if !fit {
            m::JOURNAL_DROPPED.inc();
        }
    }
}

/// Copy out the journal contents in chronological order (oldest first).
/// Allocates — export path only.
pub fn snapshot() -> Vec<SpanEvent> {
    let guard = JOURNAL.lock().unwrap();
    match &*guard {
        Some(ring) => {
            let mut out = Vec::with_capacity(ring.buf.len());
            out.extend_from_slice(&ring.buf[ring.head..]);
            out.extend_from_slice(&ring.buf[..ring.head]);
            out
        }
        None => Vec::new(), // intlint: allow(R2, reason="export path, off the hot round loop")
    }
}

/// Drop every recorded span (the ring's storage is kept).
pub fn clear() {
    let mut guard = JOURNAL.lock().unwrap();
    if let Some(ring) = guard.as_mut() {
        ring.buf.clear();
        ring.head = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(start_ns: u64, round: u32) -> SpanEvent {
        SpanEvent {
            start_ns,
            dur_ns: 10,
            round,
            phase: Phase::Encode,
            block: 0,
            rank: ALL,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_snapshot_is_chronological() {
        let mut ring = Ring { buf: Vec::with_capacity(3), cap: 3, head: 0 };
        assert!(ring.push(ev(1, 1)));
        assert!(ring.push(ev(2, 2)));
        assert!(ring.push(ev(3, 3)));
        // full: the next two pushes evict rounds 1 and 2
        assert!(!ring.push(ev(4, 4)));
        assert!(!ring.push(ev(5, 5)));
        let mut out = Vec::new();
        out.extend_from_slice(&ring.buf[ring.head..]);
        out.extend_from_slice(&ring.buf[..ring.head]);
        let rounds: Vec<u32> = out.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![3, 4, 5]);
    }

    #[test]
    fn disabled_journal_records_nothing() {
        // the global switch defaults to off; record/push must be no-ops
        // (the process-global journal itself is exercised by
        // tests/telemetry.rs, which owns the enable/clear lifecycle)
        if is_enabled() {
            return; // another test in this process enabled it — skip
        }
        record(Phase::Round, 0, ALL, ALL, start());
        assert!(!is_enabled());
    }
}
