//! Observability for every round (DESIGN.md §11): what the parity tests
//! *assert*, this subsystem lets you *watch* — alpha trajectories, clip
//! headroom, wire-lane occupancy, bytes per coordinate, retry storms,
//! and the streamed pipeline's encode/wire overlap — on a live run, with
//! zero crates and zero hot-path allocations.
//!
//! Three layers:
//!
//! - [`registry`] — pre-registered static atomics (counters, gauges,
//!   log2 histograms). The round loop updates them with relaxed atomic
//!   ops; `tests/zero_alloc.rs` runs with telemetry enabled to pin that
//!   the instrumented hot path still never allocates.
//! - [`journal`] — a fixed-capacity ring of phase spans (encode /
//!   reduce / drain / decode, per round / block / rank), off by default,
//!   pre-allocated at [`journal::enable`].
//! - exporters — [`chrome`] renders the journal as `chrome://tracing`
//!   trace-event JSON (the streamed pipeline's overlap becomes visible
//!   lanes); [`prom`] renders the registry as Prometheus text format
//!   0.0.4 and serves it from a `std::net` listener.
//!
//! Wiring: `Coordinator::run_round` calls [`observe_round`] once per
//! completed round (every driver, every backend), the engine drivers and
//! `TransportReducer` record phase spans and transport counters at their
//! own seams, and `api::Session` exposes the knobs
//! (`telemetry.trace_path`, `telemetry.listen`). `repro trace` runs a
//! traced job from the CLI.

pub mod chrome;
pub mod journal;
pub mod prom;
pub mod registry;
pub mod sink;

pub use journal::{Phase, SpanEvent, ALL};
pub use prom::MetricsServer;
pub use registry::m;
pub use sink::TelemetrySink;

/// Everything [`observe_round`] folds into the registry after one
/// completed round. Plain scalars the caller already has — building one
/// is a stack write, keeping the call zero-alloc.
pub struct RoundStats {
    pub train_loss: f64,
    /// Min per-block alpha (the `RoundRecord` scalar).
    pub alpha: f64,
    pub wire_bytes_per_worker: usize,
    /// Gradient dimension (bytes-per-coordinate denominator).
    pub d: usize,
    /// World size this round ran at.
    pub n: usize,
    pub encode_seconds: f64,
    pub reduce_seconds: f64,
    pub decode_seconds: f64,
}

/// Fold one completed round into the static registry. Called by
/// `Coordinator::run_round` for every driver and backend; relaxed atomic
/// stores only.
pub fn observe_round(s: &RoundStats) {
    m::ROUNDS.inc();
    m::TRAIN_LOSS.set(s.train_loss);
    m::ALPHA_MIN.set(s.alpha);
    if s.d > 0 {
        m::BYTES_PER_COORD.set(s.wire_bytes_per_worker as f64 / s.d as f64);
    }
    m::WIRE_BYTES.add(s.wire_bytes_per_worker as u64 * s.n as u64);
    m::ENCODE_SECONDS.record_secs(s.encode_seconds);
    m::REDUCE_SECONDS.record_secs(s.reduce_seconds);
    m::DECODE_SECONDS.record_secs(s.decode_seconds);
}

/// Export the span journal as a Chrome trace-event JSON file (load it in
/// `chrome://tracing` or Perfetto). Snapshot + render + write — call it
/// after the run, not inside it.
pub fn write_trace(path: &str) -> std::io::Result<()> {
    let events = journal::snapshot();
    std::fs::write(path, chrome::render(&events))
}
