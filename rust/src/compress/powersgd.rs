//! PowerSGD (Vogels et al., 2019): rank-r low-rank gradient compression
//! with error feedback and warm-started power iteration.
//!
//! Per round, for each >=2-D parameter block reshaped to an (rows x cols)
//! matrix M_i (gradient + EF memory):
//!
//!   P_i = M_i Q          -> all-reduce mean P       (rows x r)
//!   P^  = orthonormalize(P)
//!   Q_i = M_i^T P^       -> all-reduce mean Q       (cols x r)
//!   approx = P^ Q^T;  e_i <- M_i - approx
//!
//! 1-D blocks (biases, norms) travel uncompressed, as in the reference
//! implementation. Both reductions are plain sums, so PowerSGD keeps
//! all-reduce compatibility — the property Table 1 credits it with — at
//! the cost of EF state and a rank hyperparameter (its footnote (2)).
//!
//! Phase split: this is the zoo's genuinely multi-pass algorithm. Pass 1
//! computes P_i per rank, pass 2 Q_i against the orthonormalized mean,
//! and pass 3 is the rank-local EF update: after the two all-reduces every
//! worker holds P^ and Q^, reconstructs the approximation locally, and
//! subtracts it from its own corrected gradient — no extra communication.

use std::sync::Arc;

use crate::coordinator::RoundCtx;
use crate::util::Rng;

use super::engine::{
    mean_dense_into, Message, PassOutcome, PassPlan, PhasedCompressor, RankEncoder,
    RankMessages, Reducer, RoundArena,
};
use super::{CommOp, ErrorFeedback, Primitive, RoundResult};

/// Shape of one parameter block in the flattened gradient.
#[derive(Clone, Debug)]
pub struct BlockShape {
    pub dims: Vec<usize>,
}

impl BlockShape {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// Matrix view: first dim x rest (None for 1-D blocks).
    pub fn matrix(&self) -> Option<(usize, usize)> {
        if self.dims.len() >= 2 {
            let rows = self.dims[0];
            let cols = self.numel() / rows;
            Some((rows, cols))
        } else {
            None
        }
    }
}

pub struct PowerSgd {
    pub rank: usize,
    layout: Arc<Vec<BlockShape>>,
    /// Warm-started Q per matrix block (shared across workers: it is the
    /// output of the previous round's all-reduce). Arc-shared with the
    /// pass plans; mutated via copy-on-write only when a plan no longer
    /// holds it, i.e. in-place in steady state.
    qs: Arc<Vec<Vec<f32>>>, // cols x r, row-major
    encoders: Vec<Box<dyn RankEncoder>>,
    // -- leader round state ------------------------------------------------
    /// Elementwise mean of the rank messages of the current pass.
    mean: Vec<f32>,
    /// Orthonormalized P^ per matrix block.
    phat: Arc<Vec<Vec<f32>>>,
    gtilde: Vec<f32>,
    bytes: usize,
}

impl PowerSgd {
    pub fn new(rank: usize, layout: Vec<BlockShape>, _n: usize, seed: u64) -> Self {
        assert!(rank >= 1);
        let mut rng = Rng::new(seed);
        let qs: Vec<Vec<f32>> = layout
            .iter()
            .filter_map(|b| b.matrix())
            .map(|(_, cols)| rng.normal_vec(cols * rank, 1.0))
            .collect();
        let nmat = qs.len();
        PowerSgd {
            rank,
            layout: Arc::new(layout),
            qs: Arc::new(qs),
            encoders: Vec::new(),
            mean: Vec::new(),
            phat: Arc::new(vec![Vec::new(); nmat]),
            gtilde: Vec::new(),
            bytes: 0,
        }
    }

    /// Gram-Schmidt orthonormalization of the r columns of a (rows x r)
    /// row-major matrix (same as the reference implementation).
    fn orthonormalize(p: &mut [f32], rows: usize, r: usize) {
        for c in 0..r {
            // subtract projections on previous columns
            for prev in 0..c {
                let mut dot = 0.0f64;
                for i in 0..rows {
                    dot += p[i * r + c] as f64 * p[i * r + prev] as f64;
                }
                for i in 0..rows {
                    p[i * r + c] -= dot as f32 * p[i * r + prev];
                }
            }
            let mut norm = 0.0f64;
            for i in 0..rows {
                norm += (p[i * r + c] as f64).powi(2);
            }
            let norm = norm.sqrt().max(1e-12) as f32;
            for i in 0..rows {
                p[i * r + c] /= norm;
            }
        }
    }

    /// C = A(rows x cols) * B(cols x r), all row-major.
    fn matmul(a: &[f32], b: &[f32], rows: usize, cols: usize, r: usize, out: &mut [f32]) {
        out.fill(0.0);
        // branch-free dense inner loops (dense gradients: a zero-skip
        // branch costs more than it saves — §Perf)
        for i in 0..rows {
            let arow = &a[i * cols..(i + 1) * cols];
            let orow = &mut out[i * r..(i + 1) * r];
            for (k, &aik) in arow.iter().enumerate() {
                let brow = &b[k * r..(k + 1) * r];
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += aik * bb;
                }
            }
        }
    }

    /// C = A^T(cols x rows) * B(rows x r): out is cols x r.
    fn matmul_t(a: &[f32], b: &[f32], rows: usize, cols: usize, r: usize, out: &mut [f32]) {
        out.fill(0.0);
        for i in 0..rows {
            let arow = &a[i * cols..(i + 1) * cols];
            let brow = &b[i * r..(i + 1) * r];
            for (k, &aik) in arow.iter().enumerate() {
                let orow = &mut out[k * r..(k + 1) * r];
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += aik * bb;
                }
            }
        }
    }

    /// Sum the rank messages elementwise into `self.mean` and divide by n.
    fn mean_of(&mut self, msgs: &RankMessages) {
        mean_dense_into(msgs, &mut self.mean);
    }
}

/// One rank's state: EF memory plus the corrected gradient, which
/// persists across the round's passes (pass 2 and the EF pass reuse it),
/// and a scratch buffer for the low-rank approximation image.
struct PowerEncoder {
    r: usize,
    layout: Arc<Vec<BlockShape>>,
    ef: ErrorFeedback,
    corrected: Vec<f32>,
    approx: Vec<f32>,
    msg: Message,
}

impl RankEncoder for PowerEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::PowerP { qs } => {
                let d = grad.len();
                self.ef.corrected_into(grad, &mut self.corrected);
                let r = self.r;
                let layout = Arc::clone(&self.layout);
                let out = self.msg.dense_mut();
                out.clear();
                let mut offset = 0;
                let mut mat = 0;
                for block in layout.iter() {
                    let numel = block.numel();
                    match block.matrix() {
                        // vector blocks travel uncompressed (and bypass EF:
                        // they are exact), straight from the raw gradient
                        None => out.extend_from_slice(&grad[offset..offset + numel]),
                        Some((rows, cols)) => {
                            let start = out.len();
                            out.resize(start + rows * r, 0.0);
                            PowerSgd::matmul(
                                &self.corrected[offset..offset + numel],
                                &qs[mat],
                                rows,
                                cols,
                                r,
                                &mut out[start..],
                            );
                            mat += 1;
                        }
                    }
                    offset += numel;
                }
                assert_eq!(offset, d, "layout must tile the gradient");
            }
            PassPlan::PowerQ { ps } => {
                let r = self.r;
                let layout = Arc::clone(&self.layout);
                let out = self.msg.dense_mut();
                out.clear();
                let mut offset = 0;
                let mut mat = 0;
                for block in layout.iter() {
                    let numel = block.numel();
                    if let Some((rows, cols)) = block.matrix() {
                        let start = out.len();
                        out.resize(start + cols * r, 0.0);
                        PowerSgd::matmul_t(
                            &self.corrected[offset..offset + numel],
                            &ps[mat],
                            rows,
                            cols,
                            r,
                            &mut out[start..],
                        );
                        mat += 1;
                    }
                    offset += numel;
                }
            }
            PassPlan::PowerEf { ps, qs } => {
                // rank-local EF update: reconstruct approx = P^ Q^T from
                // the all-reduced factors; vector blocks are exact, so
                // their approx equals the corrected value (zero residual)
                let d = grad.len();
                let r = self.r;
                let layout = Arc::clone(&self.layout);
                self.approx.clear();
                self.approx.resize(d, 0.0);
                let mut offset = 0;
                let mut mat = 0;
                for block in layout.iter() {
                    let numel = block.numel();
                    match block.matrix() {
                        None => self.approx[offset..offset + numel]
                            .copy_from_slice(&self.corrected[offset..offset + numel]),
                        Some((rows, cols)) => {
                            let p = &ps[mat];
                            let q = &qs[mat];
                            for i in 0..rows {
                                for k in 0..cols {
                                    let mut acc = 0.0f32;
                                    for c in 0..r {
                                        acc += p[i * r + c] * q[k * r + c];
                                    }
                                    self.approx[offset + i * cols + k] = acc;
                                }
                            }
                            mat += 1;
                        }
                    }
                    offset += numel;
                }
                self.ef.store_residual(&self.corrected, &self.approx);
                // nothing to communicate; leave the previous message alone
            }
            _ => panic!("PowerSgd encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }

    // checkpoint v2: the EF residual is the algorithm's convergence-
    // critical state (module docs of compress::error_feedback)
    fn ef_memory(&self) -> Option<&[f32]> {
        Some(self.ef.memory())
    }

    fn set_ef_memory(&mut self, mem: &[f32]) -> bool {
        self.ef.set_memory(mem);
        true
    }
}

impl PhasedCompressor for PowerSgd {
    fn name(&self) -> String {
        format!("powersgd_rank{}", self.rank)
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn make_encoder(&mut self, _rank: usize) -> Box<dyn RankEncoder> {
        Box::new(PowerEncoder {
            r: self.rank,
            layout: Arc::clone(&self.layout),
            ef: ErrorFeedback::new(),
            corrected: Vec::new(),
            approx: Vec::new(),
            msg: Message::Empty,
        })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, _ctx: &RoundCtx) -> PassPlan {
        PassPlan::PowerP { qs: Arc::clone(&self.qs) }
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        plan: &PassPlan,
        ctx: &RoundCtx,
        _red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        let r = self.rank;
        Ok(match plan {
            PassPlan::PowerP { .. } => {
                self.mean_of(msgs);
                self.gtilde.clear();
                self.gtilde.resize(ctx.d, 0.0);
                self.bytes = 0;
                let layout = Arc::clone(&self.layout);
                // steady state: no plan holds phat here, so make_mut is
                // an in-place borrow, not a copy
                let phat = Arc::make_mut(&mut self.phat);
                let mut pos = 0;
                let mut offset = 0;
                let mut mat = 0;
                for block in layout.iter() {
                    let numel = block.numel();
                    match block.matrix() {
                        None => {
                            // uncompressed vector block: the mean IS gtilde
                            self.gtilde[offset..offset + numel]
                                .copy_from_slice(&self.mean[pos..pos + numel]);
                            self.bytes += numel * 4;
                            pos += numel;
                        }
                        Some((rows, cols)) => {
                            let plen = rows * r;
                            let pb = &mut phat[mat];
                            pb.clear();
                            pb.extend_from_slice(&self.mean[pos..pos + plen]);
                            Self::orthonormalize(pb, rows, r);
                            self.bytes += (rows + cols) * r * 4;
                            pos += plen;
                            mat += 1;
                        }
                    }
                    offset += numel;
                }
                assert_eq!(offset, ctx.d, "layout must tile the gradient");
                if mat == 0 {
                    PassOutcome::Done
                } else {
                    PassOutcome::Next(PassPlan::PowerQ { ps: Arc::clone(&self.phat) })
                }
            }
            PassPlan::PowerQ { .. } => {
                self.mean_of(msgs);
                let layout = Arc::clone(&self.layout);
                // the PowerQ plan holds phat (read-only) but not qs, so
                // this too is in-place in steady state
                let qs = Arc::make_mut(&mut self.qs);
                let mut pos = 0;
                let mut offset = 0;
                let mut mat = 0;
                for block in layout.iter() {
                    let numel = block.numel();
                    if let Some((rows, cols)) = block.matrix() {
                        let qlen = cols * r;
                        // warm start for the next round
                        let q = &mut qs[mat];
                        q.clear();
                        q.extend_from_slice(&self.mean[pos..pos + qlen]);
                        // approx = P^ Q^T into gtilde
                        let p = &self.phat[mat];
                        for i in 0..rows {
                            for k in 0..cols {
                                let mut acc = 0.0f32;
                                for c in 0..r {
                                    acc += p[i * r + c] * q[k * r + c];
                                }
                                self.gtilde[offset + i * cols + k] = acc;
                            }
                        }
                        pos += qlen;
                        mat += 1;
                    }
                    offset += numel;
                }
                PassOutcome::Next(PassPlan::PowerEf {
                    ps: Arc::clone(&self.phat),
                    qs: Arc::clone(&self.qs),
                })
            }
            PassPlan::PowerEf { .. } => PassOutcome::Done,
            _ => unreachable!("PowerSgd planned no such pass"),
        })
    }

    fn decode(&mut self, _ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        std::mem::swap(&mut gtilde, &mut self.gtilde);
        let mut comm = arena.take_comm();
        // two all-reduce rounds (P then Q) + uncompressed vectors
        comm.push(CommOp { primitive: Primitive::AllReduce, bytes_per_worker: self.bytes });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::DistributedCompressor;
    use crate::coordinator::RoundCtx;
    use crate::util::stats::l2_norm_sq;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    fn layout_2d(rows: usize, cols: usize) -> Vec<BlockShape> {
        vec![BlockShape { dims: vec![rows, cols] }]
    }

    #[test]
    fn exactly_recovers_rank1_matrix() {
        // A rank-1 gradient is reproduced (numerically) by rank-1 PowerSGD
        // after the warm-up round.
        let rows = 10;
        let cols = 7;
        let mut rng = Rng::new(1);
        let u = rng.normal_vec(rows, 1.0);
        let v = rng.normal_vec(cols, 1.0);
        let mut m = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for k in 0..cols {
                m[i * cols + k] = u[i] * v[k];
            }
        }
        let grads = vec![m.clone(); 2];
        let mut c = PowerSgd::new(1, layout_2d(rows, cols), 2, 9);
        let mut last = Vec::new();
        for _ in 0..3 {
            last = c.round(&grads, &ctx(rows * cols, 2)).gtilde;
        }
        let err: f64 = m
            .iter()
            .zip(&last)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(err < 1e-6 * l2_norm_sq(&m).max(1.0), "err {err}");
    }

    #[test]
    fn vector_blocks_uncompressed() {
        let layout = vec![BlockShape { dims: vec![5] }];
        let grads = vec![vec![1.0f32, 2.0, 3.0, 4.0, 5.0]; 3];
        let mut c = PowerSgd::new(2, layout, 3, 0);
        let r = c.round(&grads, &ctx(5, 3));
        assert_eq!(r.gtilde, grads[0]);
        assert_eq!(r.wire_bytes_per_worker(), 20);
    }

    #[test]
    fn error_feedback_recovers_dropped_mass() {
        // With a fixed gradient, the EF sum gtilde_1 + ... + gtilde_k
        // converges to k * g (residuals don't accumulate unboundedly).
        let rows = 6;
        let cols = 6;
        let mut rng = Rng::new(2);
        let g: Vec<f32> = rng.normal_vec(rows * cols, 1.0);
        let grads = vec![g.clone(); 2];
        let mut c = PowerSgd::new(1, layout_2d(rows, cols), 2, 3);
        let mut acc = vec![0.0f64; g.len()];
        let k = 200;
        for _ in 0..k {
            let r = c.round(&grads, &ctx(rows * cols, 2));
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        // mean transmitted ~= true gradient
        for (a, &x) in acc.iter().zip(&g) {
            assert!(
                (a / k as f64 - x as f64).abs() < 0.05 * (1.0 + x.abs() as f64),
                "{} vs {x}",
                a / k as f64
            );
        }
    }

    #[test]
    fn wire_bytes_much_smaller_than_dense() {
        let rows = 256;
        let cols = 256;
        let grads = vec![vec![0.1f32; rows * cols]; 2];
        let mut c = PowerSgd::new(2, layout_2d(rows, cols), 2, 4);
        let r = c.round(&grads, &ctx(rows * cols, 2));
        assert_eq!(r.wire_bytes_per_worker(), (rows + cols) * 2 * 4);
        assert!(r.wire_bytes_per_worker() < rows * cols * 4 / 10);
    }

    #[test]
    fn orthonormalize_produces_orthonormal_columns() {
        let mut rng = Rng::new(5);
        let rows = 20;
        let r = 4;
        let mut p = rng.normal_vec(rows * r, 1.0);
        PowerSgd::orthonormalize(&mut p, rows, r);
        for a in 0..r {
            for b in a..r {
                let dot: f64 = (0..rows)
                    .map(|i| p[i * r + a] as f64 * p[i * r + b] as f64)
                    .sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "col {a}.{b}: {dot}");
            }
        }
    }
}
