//! PowerSGD (Vogels et al., 2019): rank-r low-rank gradient compression
//! with error feedback and warm-started power iteration.
//!
//! Per round, for each >=2-D parameter block reshaped to an (rows x cols)
//! matrix M_i (gradient + EF memory):
//!
//!   P_i = M_i Q          -> all-reduce mean P       (rows x r)
//!   P^  = orthonormalize(P)
//!   Q_i = M_i^T P^       -> all-reduce mean Q       (cols x r)
//!   approx = P^ Q^T;  e_i <- M_i - approx
//!
//! 1-D blocks (biases, norms) travel uncompressed, as in the reference
//! implementation. Both reductions are plain sums, so PowerSGD keeps
//! all-reduce compatibility — the property Table 1 credits it with — at
//! the cost of EF state and a rank hyperparameter (its footnote (2)).

use std::time::Instant;

use crate::coordinator::RoundCtx;
use crate::util::Rng;

use super::{average, CommOp, DistributedCompressor, Primitive, RoundResult};

/// Shape of one parameter block in the flattened gradient.
#[derive(Clone, Debug)]
pub struct BlockShape {
    pub dims: Vec<usize>,
}

impl BlockShape {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// Matrix view: first dim x rest (None for 1-D blocks).
    pub fn matrix(&self) -> Option<(usize, usize)> {
        if self.dims.len() >= 2 {
            let rows = self.dims[0];
            let cols = self.numel() / rows;
            Some((rows, cols))
        } else {
            None
        }
    }
}

pub struct PowerSgd {
    pub rank: usize,
    layout: Vec<BlockShape>,
    /// Warm-started Q per matrix block (shared across workers: it is the
    /// output of the previous round's all-reduce).
    qs: Vec<Vec<f32>>, // cols x r, row-major
    /// EF memory per worker over the full flattened gradient.
    errors: Vec<Vec<f32>>,
}

impl PowerSgd {
    pub fn new(rank: usize, layout: Vec<BlockShape>, n: usize, seed: u64) -> Self {
        assert!(rank >= 1);
        let mut rng = Rng::new(seed);
        let qs = layout
            .iter()
            .filter_map(|b| b.matrix())
            .map(|(_, cols)| rng.normal_vec(cols * rank, 1.0))
            .collect();
        PowerSgd { rank, layout, qs, errors: vec![Vec::new(); n] }
    }

    /// Gram-Schmidt orthonormalization of the r columns of a (rows x r)
    /// row-major matrix (same as the reference implementation).
    fn orthonormalize(p: &mut [f32], rows: usize, r: usize) {
        for c in 0..r {
            // subtract projections on previous columns
            for prev in 0..c {
                let mut dot = 0.0f64;
                for i in 0..rows {
                    dot += p[i * r + c] as f64 * p[i * r + prev] as f64;
                }
                for i in 0..rows {
                    p[i * r + c] -= dot as f32 * p[i * r + prev];
                }
            }
            let mut norm = 0.0f64;
            for i in 0..rows {
                norm += (p[i * r + c] as f64).powi(2);
            }
            let norm = norm.sqrt().max(1e-12) as f32;
            for i in 0..rows {
                p[i * r + c] /= norm;
            }
        }
    }

    /// C = A(rows x cols) * B(cols x r), all row-major.
    fn matmul(a: &[f32], b: &[f32], rows: usize, cols: usize, r: usize, out: &mut [f32]) {
        out.fill(0.0);
        // branch-free dense inner loops (dense gradients: a zero-skip
        // branch costs more than it saves — §Perf)
        for i in 0..rows {
            let arow = &a[i * cols..(i + 1) * cols];
            let orow = &mut out[i * r..(i + 1) * r];
            for (k, &aik) in arow.iter().enumerate() {
                let brow = &b[k * r..(k + 1) * r];
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += aik * bb;
                }
            }
        }
    }

    /// C = A^T(cols x rows) * B(rows x r): out is cols x r.
    fn matmul_t(a: &[f32], b: &[f32], rows: usize, cols: usize, r: usize, out: &mut [f32]) {
        out.fill(0.0);
        for i in 0..rows {
            let arow = &a[i * cols..(i + 1) * cols];
            let brow = &b[i * r..(i + 1) * r];
            for (k, &aik) in arow.iter().enumerate() {
                let orow = &mut out[k * r..(k + 1) * r];
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += aik * bb;
                }
            }
        }
    }
}

impl DistributedCompressor for PowerSgd {
    fn name(&self) -> String {
        format!("powersgd_rank{}", self.rank)
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn round(&mut self, grads: &[Vec<f32>], _ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();
        let r = self.rank;
        let t0 = Instant::now();

        // EF-corrected inputs
        for e in &mut self.errors {
            if e.len() != d {
                e.clear();
                e.resize(d, 0.0);
            }
        }
        let corrected: Vec<Vec<f32>> = grads
            .iter()
            .zip(&self.errors)
            .map(|(g, e)| g.iter().zip(e).map(|(&a, &b)| a + b).collect())
            .collect();

        let mut gtilde = vec![0.0f32; d];
        let mut bytes = 0usize;
        let mut offset = 0usize;
        let mut mat_idx = 0usize;
        // rank-1 (vector) blocks: uncompressed all-reduce of the raw grads
        for block in &self.layout.clone() {
            let numel = block.numel();
            let range = offset..offset + numel;
            match block.matrix() {
                None => {
                    let slices: Vec<Vec<f32>> =
                        grads.iter().map(|g| g[range.clone()].to_vec()).collect();
                    let avg = average(&slices);
                    gtilde[range.clone()].copy_from_slice(&avg);
                    bytes += numel * 4;
                    // vector blocks bypass EF (they are exact)
                    for e in &mut self.errors {
                        e[range.clone()].fill(0.0);
                    }
                }
                Some((rows, cols)) => {
                    let q = &mut self.qs[mat_idx];
                    // P = mean_i M_i Q
                    let mut p = vec![0.0f32; rows * r];
                    let mut tmp = vec![0.0f32; rows * r];
                    for c in &corrected {
                        Self::matmul(&c[range.clone()], q, rows, cols, r, &mut tmp);
                        for (pp, &t) in p.iter_mut().zip(&tmp) {
                            *pp += t;
                        }
                    }
                    let inv = 1.0 / n as f32;
                    for pp in &mut p {
                        *pp *= inv;
                    }
                    Self::orthonormalize(&mut p, rows, r);
                    // Q = mean_i M_i^T P^
                    let mut qnew = vec![0.0f32; cols * r];
                    let mut tmpq = vec![0.0f32; cols * r];
                    for c in &corrected {
                        Self::matmul_t(&c[range.clone()], &p, rows, cols, r, &mut tmpq);
                        for (qq, &t) in qnew.iter_mut().zip(&tmpq) {
                            *qq += t;
                        }
                    }
                    for qq in &mut qnew {
                        *qq *= inv;
                    }
                    // approx = P^ Q^T, write into gtilde; EF residuals
                    for i in 0..rows {
                        for k in 0..cols {
                            let mut acc = 0.0f32;
                            for c in 0..r {
                                acc += p[i * r + c] * qnew[k * r + c];
                            }
                            gtilde[offset + i * cols + k] = acc;
                        }
                    }
                    for (ei, ci) in self.errors.iter_mut().zip(&corrected) {
                        for j in range.clone() {
                            ei[j] = ci[j] - gtilde[j];
                        }
                    }
                    *q = qnew;
                    bytes += (rows + cols) * r * 4;
                    mat_idx += 1;
                }
            }
            offset += numel;
        }
        assert_eq!(offset, d, "layout must tile the gradient");
        // dominant cost (the per-worker M_i Q / M_i^T P matmuls) runs in
        // parallel across real workers: report per-worker time.
        let encode_seconds = t0.elapsed().as_secs_f64() / n as f64;

        RoundResult {
            gtilde,
            comm: vec![
                // two all-reduce rounds (P then Q) + uncompressed vectors
                CommOp { primitive: Primitive::AllReduce, bytes_per_worker: bytes },
            ],
            encode_seconds,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundCtx;
    use crate::util::stats::l2_norm_sq;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    fn layout_2d(rows: usize, cols: usize) -> Vec<BlockShape> {
        vec![BlockShape { dims: vec![rows, cols] }]
    }

    #[test]
    fn exactly_recovers_rank1_matrix() {
        // A rank-1 gradient is reproduced (numerically) by rank-1 PowerSGD
        // after the warm-up round.
        let rows = 10;
        let cols = 7;
        let mut rng = Rng::new(1);
        let u = rng.normal_vec(rows, 1.0);
        let v = rng.normal_vec(cols, 1.0);
        let mut m = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for k in 0..cols {
                m[i * cols + k] = u[i] * v[k];
            }
        }
        let grads = vec![m.clone(); 2];
        let mut c = PowerSgd::new(1, layout_2d(rows, cols), 2, 9);
        let mut last = Vec::new();
        for _ in 0..3 {
            last = c.round(&grads, &ctx(rows * cols, 2)).gtilde;
        }
        let err: f64 = m
            .iter()
            .zip(&last)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(err < 1e-6 * l2_norm_sq(&m).max(1.0), "err {err}");
    }

    #[test]
    fn vector_blocks_uncompressed() {
        let layout = vec![BlockShape { dims: vec![5] }];
        let grads = vec![vec![1.0f32, 2.0, 3.0, 4.0, 5.0]; 3];
        let mut c = PowerSgd::new(2, layout, 3, 0);
        let r = c.round(&grads, &ctx(5, 3));
        assert_eq!(r.gtilde, grads[0]);
        assert_eq!(r.wire_bytes_per_worker(), 20);
    }

    #[test]
    fn error_feedback_recovers_dropped_mass() {
        // With a fixed gradient, the EF sum gtilde_1 + ... + gtilde_k
        // converges to k * g (residuals don't accumulate unboundedly).
        let rows = 6;
        let cols = 6;
        let mut rng = Rng::new(2);
        let g: Vec<f32> = rng.normal_vec(rows * cols, 1.0);
        let grads = vec![g.clone(); 2];
        let mut c = PowerSgd::new(1, layout_2d(rows, cols), 2, 3);
        let mut acc = vec![0.0f64; g.len()];
        let k = 200;
        for _ in 0..k {
            let r = c.round(&grads, &ctx(rows * cols, 2));
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        // mean transmitted ~= true gradient
        for (a, &x) in acc.iter().zip(&g) {
            assert!(
                (a / k as f64 - x as f64).abs() < 0.05 * (1.0 + x.abs() as f64),
                "{} vs {x}",
                a / k as f64
            );
        }
    }

    #[test]
    fn wire_bytes_much_smaller_than_dense() {
        let rows = 256;
        let cols = 256;
        let grads = vec![vec![0.1f32; rows * cols]; 2];
        let mut c = PowerSgd::new(2, layout_2d(rows, cols), 2, 4);
        let r = c.round(&grads, &ctx(rows * cols, 2));
        assert_eq!(r.wire_bytes_per_worker(), (rows + cols) * 2 * 4);
        assert!(r.wire_bytes_per_worker() < rows * cols * 4 / 10);
    }

    #[test]
    fn orthonormalize_produces_orthonormal_columns() {
        let mut rng = Rng::new(5);
        let rows = 20;
        let r = 4;
        let mut p = rng.normal_vec(rows * r, 1.0);
        PowerSgd::orthonormalize(&mut p, rows, r);
        for a in 0..r {
            for b in a..r {
                let dot: f64 = (0..rows)
                    .map(|i| p[i * r + a] as f64 * p[i * r + b] as f64)
                    .sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "col {a}.{b}: {dot}");
            }
        }
    }
}
