//! Typed integer wire buffers: the payload of every integer-compression
//! message, stored at its *wire width* instead of widened to `i64`.
//!
//! IntSGD's systems pitch is that integer codecs are computationally
//! cheaper than float schemes; storing an int8 wire message in a
//! `Vec<i64>` threw that advantage away — 8x the write traffic on encode,
//! 8x the read traffic on reduce, and a `try_from` per element at the wire
//! codec. [`IntVec`] keeps the lanes native (`i8` / `i32`, with an `i64`
//! escape hatch for the SwitchML rule's widest setting), so:
//!
//! - the fused encoder writes one wire-width lane per coordinate,
//! - the reduce fold reads wire-width lanes and widens once into the
//!   `i64` accumulator (`IntVec::add_range_to` — the kernel both the
//!   serial fold and the worker-pool chunked fold call), and
//! - `compress::wire::encode_int8` is a memcpy.
//!
//! The lane width of a round is chosen by the leader from the proved
//! per-worker bound (IntSGD's clip, SwitchML's profiled budget), so lane
//! stores never saturate: every value fits by construction.

use super::intsgd::WireInt;
use crate::simd;

/// Native storage width of one integer message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lanes {
    I8,
    I32,
    I64,
}

impl Lanes {
    /// Bytes per coordinate at this width.
    pub fn bytes(self) -> usize {
        match self {
            Lanes::I8 => 1,
            Lanes::I32 => 4,
            Lanes::I64 => 8,
        }
    }

    /// Narrowest lane that can hold any value with |v| <= bound.
    pub fn for_bound(bound: i64) -> Lanes {
        if bound <= i8::MAX as i64 {
            Lanes::I8
        } else if bound <= i32::MAX as i64 {
            Lanes::I32
        } else {
            Lanes::I64
        }
    }

    /// The lane matching a wire integer type.
    pub fn of_wire(wire: WireInt) -> Lanes {
        match wire {
            WireInt::Int8 => Lanes::I8,
            WireInt::Int32 => Lanes::I32,
        }
    }
}

/// A vector of integers stored at wire width. All mutation paths reuse the
/// underlying buffer when the lane width is unchanged, so steady-state
/// rounds never reallocate.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IntVec {
    I8(Vec<i8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Default for IntVec {
    fn default() -> Self {
        IntVec::I8(Vec::new())
    }
}

impl IntVec {
    pub fn new(lanes: Lanes) -> IntVec {
        match lanes {
            Lanes::I8 => IntVec::I8(Vec::new()),
            Lanes::I32 => IntVec::I32(Vec::new()),
            Lanes::I64 => IntVec::I64(Vec::new()),
        }
    }

    pub fn lanes(&self) -> Lanes {
        match self {
            IntVec::I8(_) => Lanes::I8,
            IntVec::I32(_) => Lanes::I32,
            IntVec::I64(_) => Lanes::I64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            IntVec::I8(v) => v.len(),
            IntVec::I32(v) => v.len(),
            IntVec::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Empty the buffer, switching lane width only when it changed (the
    /// allocation survives otherwise).
    pub fn reset(&mut self, lanes: Lanes) {
        if self.lanes() != lanes {
            *self = IntVec::new(lanes);
            return;
        }
        match self {
            IntVec::I8(v) => v.clear(),
            IntVec::I32(v) => v.clear(),
            IntVec::I64(v) => v.clear(),
        }
    }

    /// Widened read of one coordinate (tests, the saturating switch
    /// simulator; hot loops use [`IntVec::add_range_to`] instead).
    #[inline]
    pub fn get(&self, j: usize) -> i64 {
        match self {
            IntVec::I8(v) => v[j] as i64,
            IntVec::I32(v) => v[j] as i64,
            IntVec::I64(v) => v[j],
        }
    }

    /// Largest |value| (paper Fig. 6 diagnostics), through the dispatched
    /// max-abs fold.
    pub fn max_abs(&self) -> i64 {
        match self {
            IntVec::I8(v) => simd::max_abs_i8(v),
            IntVec::I32(v) => simd::max_abs_i32(v),
            IntVec::I64(v) => simd::max_abs_i64(v),
        }
    }

    /// out[k] += self[lo + k]: the widening accumulate at the heart of the
    /// integer reduce. One dispatched kernel per lane width — no
    /// per-element `try_from`, no dispatch inside the loop — widening once
    /// into the `i64` accumulator (exact integer arithmetic, so every
    /// backend is bit-identical).
    #[inline]
    pub fn add_range_to(&self, lo: usize, out: &mut [i64]) {
        assert!(
            lo + out.len() <= self.len(),
            "reduce range {}..{} exceeds message length {}",
            lo,
            lo + out.len(),
            self.len()
        );
        let hi = lo + out.len();
        match self {
            IntVec::I8(v) => simd::add_widen_i8(&v[lo..hi], out),
            IntVec::I32(v) => simd::add_widen_i32(&v[lo..hi], out),
            IntVec::I64(v) => simd::add_i64(&v[lo..hi], out),
        }
    }

    /// Widened copy (tests and diagnostics).
    pub fn to_i64_vec(&self) -> Vec<i64> {
        match self {
            IntVec::I8(v) => v.iter().map(|&x| x as i64).collect(),
            IntVec::I32(v) => v.iter().map(|&x| x as i64).collect(),
            IntVec::I64(v) => v.clone(),
        }
    }

    /// Build from widened values, panicking if one does not fit the lane
    /// (tests; production paths write lanes directly via the fused
    /// encoders, whose clip guarantees the fit).
    pub fn from_i64(vals: &[i64], lanes: Lanes) -> IntVec {
        match lanes {
            Lanes::I8 => IntVec::I8(
                vals.iter().map(|&x| i8::try_from(x).expect("fits i8")).collect(),
            ),
            Lanes::I32 => IntVec::I32(
                vals.iter().map(|&x| i32::try_from(x).expect("fits i32")).collect(),
            ),
            Lanes::I64 => IntVec::I64(vals.to_vec()),
        }
    }
}

/// Double-buffered per-rank block slots for the streamed round driver:
/// slot (k mod 2, rank) holds rank's encoded block k. Two parities are
/// exactly enough readiness state for the pipeline — block k is being
/// reduced and drained while block k+1 is being filled, and slot reuse is
/// sound precisely because block k-1 has fully left the wire before
/// block k+1 (same parity) starts encoding: the leader collects every
/// k+1 encode ack only after block k's collective returned.
///
/// The two parities live in two separate `Vec`s, so the worker threads'
/// writes into one parity's slots never alias the leader's concurrent
/// reads of the other (the `WorkerPool` borrowed-views argument applies
/// per `Vec`). The inner `IntVec`s are reused via [`IntVec::reset`], so
/// streamed steady state allocates nothing (`tests/zero_alloc.rs`).
#[derive(Default)]
pub struct BlockSlots {
    bufs: [Vec<IntVec>; 2],
    ranks: usize,
}

impl BlockSlots {
    /// Size both parities for an `n`-rank world. Existing slot buffers
    /// survive (growing only appends empty slots; a failover shrink keeps
    /// the spares — they are skipped by the `..ranks` views).
    pub fn ensure(&mut self, ranks: usize) {
        self.ranks = ranks;
        for bufs in &mut self.bufs {
            if bufs.len() < ranks {
                bufs.resize_with(ranks, IntVec::default);
            }
        }
    }

    /// Block `block`'s per-rank slots, mutable (the encode fill).
    pub fn block_mut(&mut self, block: usize) -> &mut [IntVec] {
        &mut self.bufs[block % 2][..self.ranks]
    }

    /// Block `block`'s per-rank slots, read-only (the collective's view).
    pub fn block(&self, block: usize) -> &[IntVec] {
        &self.bufs[block % 2][..self.ranks]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_slots_alternate_parities_and_survive_shrink() {
        let mut s = BlockSlots::default();
        s.ensure(3);
        assert_eq!(s.block(0).len(), 3);
        s.block_mut(0)[1] = IntVec::from_i64(&[7], Lanes::I8);
        s.block_mut(1)[1] = IntVec::from_i64(&[9], Lanes::I8);
        // parity 2 aliases parity 0, parity 3 aliases parity 1
        assert_eq!(s.block(2)[1].get(0), 7);
        assert_eq!(s.block(3)[1].get(0), 9);
        // failover shrink: views narrow, spare slots stay allocated
        s.ensure(2);
        assert_eq!(s.block(0).len(), 2);
        s.ensure(3);
        assert_eq!(s.block(0)[1].get(0), 7);
    }

    #[test]
    fn lane_selection_matches_bounds() {
        assert_eq!(Lanes::for_bound(0), Lanes::I8);
        assert_eq!(Lanes::for_bound(127), Lanes::I8);
        assert_eq!(Lanes::for_bound(128), Lanes::I32);
        assert_eq!(Lanes::for_bound(i32::MAX as i64), Lanes::I32);
        assert_eq!(Lanes::for_bound(i32::MAX as i64 + 1), Lanes::I64);
        assert_eq!(Lanes::of_wire(WireInt::Int8), Lanes::I8);
        assert_eq!(Lanes::of_wire(WireInt::Int32), Lanes::I32);
    }

    #[test]
    fn reset_keeps_capacity_on_same_lanes() {
        let mut v = IntVec::from_i64(&[1, 2, 3], Lanes::I8);
        let cap_before = match &v {
            IntVec::I8(b) => b.capacity(),
            _ => unreachable!(),
        };
        v.reset(Lanes::I8);
        assert_eq!(v.len(), 0);
        let cap_after = match &v {
            IntVec::I8(b) => b.capacity(),
            _ => unreachable!(),
        };
        assert_eq!(cap_before, cap_after);
        // switching lanes swaps the representation
        v.reset(Lanes::I32);
        assert_eq!(v.lanes(), Lanes::I32);
    }

    #[test]
    fn add_range_widens_each_lane() {
        for lanes in [Lanes::I8, Lanes::I32, Lanes::I64] {
            let v = IntVec::from_i64(&[1, -2, 3, -4], lanes);
            let mut out = vec![10i64; 2];
            v.add_range_to(1, &mut out);
            assert_eq!(out, vec![8, 13], "{lanes:?}");
        }
    }

    #[test]
    fn max_abs_and_roundtrip() {
        let vals = vec![-128i64, 5, 127];
        let v = IntVec::from_i64(&vals, Lanes::I8);
        assert_eq!(v.max_abs(), 128);
        assert_eq!(v.to_i64_vec(), vals);
        assert_eq!(v.get(0), -128);
    }

    #[test]
    #[should_panic(expected = "fits i8")]
    fn from_i64_rejects_lane_overflow() {
        IntVec::from_i64(&[200], Lanes::I8);
    }

    #[test]
    #[should_panic(expected = "exceeds message length")]
    fn add_range_rejects_overrun() {
        let v = IntVec::from_i64(&[1, 2], Lanes::I32);
        let mut out = vec![0i64; 2];
        v.add_range_to(1, &mut out);
    }
}
