//! Top-k sparsification with error feedback (Stich et al., 2018).
//!
//! Each worker ships its k largest-magnitude coordinates as (index, value)
//! pairs. Sparse supports differ across workers, so aggregation needs
//! all-gather; convergence needs EF (paper Table 1). The EF memory and the
//! O(d) selection scratch live in the rank's encoder and run on the rank's
//! worker thread.

use crate::coordinator::RoundCtx;

use super::engine::{
    Message, PassOutcome, PassPlan, PhasedCompressor, RankEncoder, RankMessages,
    Reducer, RoundArena,
};
use super::{CommOp, ErrorFeedback, Primitive, RoundResult};

pub struct TopK {
    /// Fraction of coordinates kept (k = max(1, ratio * d)).
    pub ratio: f64,
    encoders: Vec<Box<dyn RankEncoder>>,
    acc: Vec<f32>,
    d: usize,
}

impl TopK {
    pub fn new(ratio: f64, _n: usize) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        TopK { ratio, encoders: Vec::new(), acc: Vec::new(), d: 0 }
    }

    pub fn k_of(&self, d: usize) -> usize {
        k_for(self.ratio, d)
    }

    /// Select top-k |a| into (idx, val) pairs, O(d) selection via
    /// `select_nth_unstable`, reusing both buffers.
    pub fn select_into(
        a: &[f32],
        k: usize,
        idx: &mut Vec<u32>,
        out: &mut Vec<(u32, f32)>,
    ) {
        idx.clear();
        idx.extend(0..a.len() as u32);
        if k < a.len() {
            idx.select_nth_unstable_by(k, |&i, &j| {
                a[j as usize]
                    .abs()
                    .partial_cmp(&a[i as usize].abs())
                    .unwrap()
            });
            idx.truncate(k);
        }
        out.clear();
        out.extend(idx.iter().map(|&i| (i, a[i as usize])));
    }

    /// Convenience wrapper allocating fresh buffers.
    pub fn select(a: &[f32], k: usize) -> Vec<(u32, f32)> {
        let mut idx = Vec::new();
        let mut out = Vec::new();
        Self::select_into(a, k, &mut idx, &mut out);
        out
    }
}

/// k = max(1, round(ratio * d)) — one definition shared by the encoder's
/// selection and the leader's wire accounting, so they cannot drift.
fn k_for(ratio: f64, d: usize) -> usize {
    ((ratio * d as f64).round() as usize).clamp(1, d)
}

/// One rank's state: EF memory, corrected-gradient scratch, the dense
/// image of the selection (for the residual), and the index scratch.
struct TopKEncoder {
    ratio: f64,
    ef: ErrorFeedback,
    a: Vec<f32>,
    dense: Vec<f32>,
    idx: Vec<u32>,
    msg: Message,
}

impl RankEncoder for TopKEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Plain => {
                let d = grad.len();
                let k = k_for(self.ratio, d);
                self.ef.corrected_into(grad, &mut self.a);
                let sel = self.msg.sparse_mut();
                TopK::select_into(&self.a, k, &mut self.idx, sel);
                // dense image of the compressed message for the EF update
                self.dense.clear();
                self.dense.resize(d, 0.0);
                for &(j, v) in sel.iter() {
                    self.dense[j as usize] = v;
                }
                self.ef.store_residual(&self.a, &self.dense);
            }
            _ => panic!("TopK encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }

    // checkpoint v2: the EF residual is the algorithm's convergence-
    // critical state (module docs of compress::error_feedback)
    fn ef_memory(&self) -> Option<&[f32]> {
        Some(self.ef.memory())
    }

    fn set_ef_memory(&mut self, mem: &[f32]) -> bool {
        self.ef.set_memory(mem);
        true
    }
}

impl PhasedCompressor for TopK {
    fn name(&self) -> String {
        format!("topk_{}", self.ratio)
    }

    fn supports_allreduce(&self) -> bool {
        false
    }

    fn make_encoder(&mut self, _rank: usize) -> Box<dyn RankEncoder> {
        Box::new(TopKEncoder {
            ratio: self.ratio,
            ef: ErrorFeedback::new(),
            a: Vec::new(),
            dense: Vec::new(),
            idx: Vec::new(),
            msg: Message::Empty,
        })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        PassPlan::Plain
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        _plan: &PassPlan,
        ctx: &RoundCtx,
        _red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        self.acc.clear();
        self.acc.resize(ctx.d, 0.0);
        for m in msgs.iter() {
            for &(j, v) in m.as_sparse() {
                self.acc[j as usize] += v;
            }
        }
        let inv = 1.0 / msgs.len() as f32;
        for x in &mut self.acc {
            *x *= inv;
        }
        Ok(PassOutcome::Done)
    }

    fn decode(&mut self, _ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        std::mem::swap(&mut gtilde, &mut self.acc);
        let mut comm = arena.take_comm();
        comm.push(CommOp {
            primitive: Primitive::AllGather,
            bytes_per_worker: self.k_of(self.d) * 8, // u32 index + f32 value
        });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::DistributedCompressor;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn selects_largest_magnitudes() {
        let a = [0.1f32, -5.0, 0.3, 2.0, -0.2];
        let mut sel = TopK::select(&a, 2);
        sel.sort_by_key(|&(i, _)| i);
        assert_eq!(sel, vec![(1, -5.0), (3, 2.0)]);
    }

    #[test]
    fn k_equals_d_is_lossless() {
        let mut rng = Rng::new(0);
        let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(50, 1.0)).collect();
        let mut c = TopK::new(1.0, 3);
        let r = c.round(&grads, &ctx(50, 3));
        let avg = super::super::average(&grads);
        for (a, b) in r.gtilde.iter().zip(&avg) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn ef_preserves_total_mass_over_time() {
        let mut rng = Rng::new(1);
        let g = rng.normal_vec(100, 1.0);
        let grads = vec![g.clone(); 2];
        let mut c = TopK::new(0.1, 2);
        let mut acc = vec![0.0f64; 100];
        let rounds = 300;
        for _ in 0..rounds {
            let r = c.round(&grads, &ctx(100, 2));
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            assert!(
                (a / rounds as f64 - x as f64).abs() < 0.05,
                "{} vs {x}",
                a / rounds as f64
            );
        }
    }

    #[test]
    fn wire_bytes_scale_with_k() {
        let grads = vec![vec![1.0f32; 1000]; 2];
        let mut c = TopK::new(0.01, 2);
        let r = c.round(&grads, &ctx(1000, 2));
        assert_eq!(r.wire_bytes_per_worker(), 10 * 8);
    }
}
