//! Top-k sparsification with error feedback (Stich et al., 2018).
//!
//! Each worker ships its k largest-magnitude coordinates as (index, value)
//! pairs. Sparse supports differ across workers, so aggregation needs
//! all-gather; convergence needs EF (paper Table 1).

use std::time::Instant;

use crate::coordinator::RoundCtx;

use super::{CommOp, DistributedCompressor, ErrorFeedback, Primitive, RoundResult};

pub struct TopK {
    /// Fraction of coordinates kept (k = max(1, ratio * d)).
    pub ratio: f64,
    ef: ErrorFeedback,
}

impl TopK {
    pub fn new(ratio: f64, n: usize) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        TopK { ratio, ef: ErrorFeedback::new(n) }
    }

    pub fn k_of(&self, d: usize) -> usize {
        ((self.ratio * d as f64).round() as usize).clamp(1, d)
    }

    /// Select top-k |a| as (idx, val) pairs, O(d) selection via
    /// `select_nth_unstable`.
    pub fn select(a: &[f32], k: usize) -> Vec<(u32, f32)> {
        let mut idx: Vec<u32> = (0..a.len() as u32).collect();
        if k < a.len() {
            idx.select_nth_unstable_by(k, |&i, &j| {
                a[j as usize]
                    .abs()
                    .partial_cmp(&a[i as usize].abs())
                    .unwrap()
            });
            idx.truncate(k);
        }
        idx.into_iter().map(|i| (i, a[i as usize])).collect()
    }
}

impl DistributedCompressor for TopK {
    fn name(&self) -> String {
        format!("topk_{}", self.ratio)
    }

    fn supports_allreduce(&self) -> bool {
        false
    }

    fn round(&mut self, grads: &[Vec<f32>], _ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();
        let k = self.k_of(d);

        let t0 = Instant::now();
        let mut msgs = Vec::with_capacity(n);
        for (i, g) in grads.iter().enumerate() {
            let a = self.ef.corrected(i, g);
            let sel = Self::select(&a, k);
            // dense image of the compressed message for the EF update
            let mut dense = vec![0.0f32; d];
            for &(j, v) in &sel {
                dense[j as usize] = v;
            }
            self.ef.store_residual(i, &a, &dense);
            msgs.push(sel);
        }
        // per-worker encode cost (parallel in reality)
        let encode_seconds = t0.elapsed().as_secs_f64() / n as f64;

        let t1 = Instant::now();
        let mut gtilde = vec![0.0f32; d];
        for sel in &msgs {
            for &(j, v) in sel {
                gtilde[j as usize] += v;
            }
        }
        let inv = 1.0 / n as f32;
        for x in &mut gtilde {
            *x *= inv;
        }
        let decode_seconds = t1.elapsed().as_secs_f64();

        RoundResult {
            gtilde,
            comm: vec![CommOp {
                primitive: Primitive::AllGather,
                bytes_per_worker: k * 8, // u32 index + f32 value
            }],
            encode_seconds,
            decode_seconds,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn selects_largest_magnitudes() {
        let a = [0.1f32, -5.0, 0.3, 2.0, -0.2];
        let mut sel = TopK::select(&a, 2);
        sel.sort_by_key(|&(i, _)| i);
        assert_eq!(sel, vec![(1, -5.0), (3, 2.0)]);
    }

    #[test]
    fn k_equals_d_is_lossless() {
        let mut rng = Rng::new(0);
        let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(50, 1.0)).collect();
        let mut c = TopK::new(1.0, 3);
        let r = c.round(&grads, &ctx(50, 3));
        let avg = super::super::average(&grads);
        for (a, b) in r.gtilde.iter().zip(&avg) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn ef_preserves_total_mass_over_time() {
        let mut rng = Rng::new(1);
        let g = rng.normal_vec(100, 1.0);
        let grads = vec![g.clone(); 2];
        let mut c = TopK::new(0.1, 2);
        let mut acc = vec![0.0f64; 100];
        let rounds = 300;
        for _ in 0..rounds {
            let r = c.round(&grads, &ctx(100, 2));
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            assert!(
                (a / rounds as f64 - x as f64).abs() < 0.05,
                "{} vs {x}",
                a / rounds as f64
            );
        }
    }

    #[test]
    fn wire_bytes_scale_with_k() {
        let grads = vec![vec![1.0f32; 1000]; 2];
        let mut c = TopK::new(0.01, 2);
        let r = c.round(&grads, &ctx(1000, 2));
        assert_eq!(r.wire_bytes_per_worker(), 10 * 8);
    }
}
