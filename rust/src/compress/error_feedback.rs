//! Error feedback (EF-SGD) memory, Stich et al. (2018) / Karimireddy et
//! al. (2019): biased compressors (sign, top-k, PowerSGD) only converge
//! when each worker accumulates its compression residual and re-injects it
//! the following round:
//!
//!   a_i^k = g_i^k + e_i^k;   msg = C(a_i^k);   e_i^{k+1} = a_i^k - msg.
//!
//! The paper's Table 1 "Works without error-feedback" column is exactly
//! about avoiding the extra O(d) state this module holds per worker.

/// Per-worker residual memories.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    mem: Vec<Vec<f32>>,
}

impl ErrorFeedback {
    pub fn new(n: usize) -> Self {
        ErrorFeedback { mem: vec![Vec::new(); n] }
    }

    pub fn workers(&self) -> usize {
        self.mem.len()
    }

    /// a_i = g_i + e_i (allocates e_i lazily as zeros).
    pub fn corrected(&mut self, rank: usize, grad: &[f32]) -> Vec<f32> {
        let e = &mut self.mem[rank];
        if e.len() != grad.len() {
            e.clear();
            e.resize(grad.len(), 0.0);
        }
        grad.iter().zip(e.iter()).map(|(&g, &m)| g + m).collect()
    }

    /// e_i <- a_i - compressed(a_i).
    pub fn store_residual(&mut self, rank: usize, a: &[f32], compressed: &[f32]) {
        let e = &mut self.mem[rank];
        e.clear();
        e.extend(a.iter().zip(compressed).map(|(&x, &c)| x - c));
    }

    /// Total residual mass (diagnostic).
    pub fn residual_norm_sq(&self) -> f64 {
        self.mem
            .iter()
            .flat_map(|e| e.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_identity_round_trips() {
        // e + g == a  and  a - c == e'  =>  over two rounds the memory
        // carries exactly what compression dropped.
        let mut ef = ErrorFeedback::new(1);
        let g = vec![1.0f32, -0.5, 0.25];
        let a = ef.corrected(0, &g);
        assert_eq!(a, g); // first round: zero memory
        let c = vec![1.0f32, 0.0, 0.0]; // a crude compressor
        ef.store_residual(0, &a, &c);
        let g2 = vec![0.0f32, 0.0, 0.0];
        let a2 = ef.corrected(0, &g2);
        assert_eq!(a2, vec![0.0, -0.5, 0.25]);
    }

    #[test]
    fn memories_are_per_worker() {
        let mut ef = ErrorFeedback::new(2);
        let g = vec![1.0f32];
        let a0 = ef.corrected(0, &g);
        ef.store_residual(0, &a0, &[0.0]);
        // worker 1 unaffected
        assert_eq!(ef.corrected(1, &g), vec![1.0]);
        assert_eq!(ef.corrected(0, &g), vec![2.0]);
    }

    #[test]
    fn residual_norm_tracks_mass() {
        let mut ef = ErrorFeedback::new(1);
        let a = vec![3.0f32, 4.0];
        ef.store_residual(0, &a, &[0.0, 0.0]);
        assert!((ef.residual_norm_sq() - 25.0).abs() < 1e-9);
    }
}
