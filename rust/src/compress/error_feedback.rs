//! Error feedback (EF-SGD) memory, Stich et al. (2018) / Karimireddy et
//! al. (2019): biased compressors (sign, top-k, PowerSGD) only converge
//! when each worker accumulates its compression residual and re-injects it
//! the following round:
//!
//!   a_i^k = g_i^k + e_i^k;   msg = C(a_i^k);   e_i^{k+1} = a_i^k - msg.
//!
//! The paper's Table 1 "Works without error-feedback" column is exactly
//! about avoiding the extra O(d) state this module holds per worker.
//!
//! One `ErrorFeedback` is ONE rank's memory: it lives inside the rank's
//! `RankEncoder` (`compress::engine`), is `Send`, and travels with the
//! encoder to the rank's worker thread — exactly where a real deployment
//! keeps it (device-local, never communicated).

/// One rank's residual memory.
#[derive(Clone, Debug, Default)]
pub struct ErrorFeedback {
    mem: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new() -> Self {
        ErrorFeedback { mem: Vec::new() }
    }

    /// out = g + e, reusing `out`'s capacity (the memory is lazily sized
    /// to the gradient's dimension on first use).
    pub fn corrected_into(&mut self, grad: &[f32], out: &mut Vec<f32>) {
        if self.mem.len() != grad.len() {
            self.mem.clear();
            self.mem.resize(grad.len(), 0.0);
        }
        out.clear();
        out.extend(grad.iter().zip(&self.mem).map(|(&g, &m)| g + m));
    }

    /// a = g + e as a fresh vector (convenience for tests and callers
    /// without a reusable buffer).
    pub fn corrected(&mut self, grad: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.corrected_into(grad, &mut out);
        out
    }

    /// e <- a - compressed(a).
    pub fn store_residual(&mut self, a: &[f32], compressed: &[f32]) {
        self.mem.clear();
        self.mem.extend(a.iter().zip(compressed).map(|(&x, &c)| x - c));
    }

    /// Residual mass (diagnostic).
    pub fn residual_norm_sq(&self) -> f64 {
        self.mem.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// The raw residual memory (checkpoint v2 persists it — a resume that
    /// zeroes the residual is not the run the EF analysis covers).
    pub fn memory(&self) -> &[f32] {
        &self.mem
    }

    /// Restore residual memory saved by [`ErrorFeedback::memory`].
    pub fn set_memory(&mut self, mem: &[f32]) {
        self.mem.clear();
        self.mem.extend_from_slice(mem);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_identity_round_trips() {
        // e + g == a  and  a - c == e'  =>  over two rounds the memory
        // carries exactly what compression dropped.
        let mut ef = ErrorFeedback::new();
        let g = vec![1.0f32, -0.5, 0.25];
        let a = ef.corrected(&g);
        assert_eq!(a, g); // first round: zero memory
        let c = vec![1.0f32, 0.0, 0.0]; // a crude compressor
        ef.store_residual(&a, &c);
        let g2 = vec![0.0f32, 0.0, 0.0];
        let a2 = ef.corrected(&g2);
        assert_eq!(a2, vec![0.0, -0.5, 0.25]);
    }

    #[test]
    fn memories_are_per_rank() {
        // each rank owns an independent instance — state cannot leak
        let mut ef0 = ErrorFeedback::new();
        let mut ef1 = ErrorFeedback::new();
        let g = vec![1.0f32];
        let a0 = ef0.corrected(&g);
        ef0.store_residual(&a0, &[0.0]);
        // rank 1 unaffected
        assert_eq!(ef1.corrected(&g), vec![1.0]);
        assert_eq!(ef0.corrected(&g), vec![2.0]);
    }

    #[test]
    fn residual_norm_tracks_mass() {
        let mut ef = ErrorFeedback::new();
        let a = vec![3.0f32, 4.0];
        ef.store_residual(&a, &[0.0, 0.0]);
        assert!((ef.residual_norm_sq() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn corrected_into_reuses_buffer_and_resizes_memory() {
        let mut ef = ErrorFeedback::new();
        let mut buf = Vec::new();
        ef.corrected_into(&[1.0, 2.0], &mut buf);
        assert_eq!(buf, vec![1.0, 2.0]);
        // dimension change resets the memory to zeros of the new size
        ef.store_residual(&[1.0, 2.0], &[0.0, 0.0]);
        ef.corrected_into(&[5.0, 5.0, 5.0], &mut buf);
        assert_eq!(buf, vec![5.0, 5.0, 5.0]);
    }
}
