//! IntSGD (paper Alg. 1 / Alg. 2): adaptive integer rounding with a scale
//! shared by every worker, aggregated by summing integers in-flight.
//!
//! This is the rust mirror of the Pallas kernel
//! (`python/compile/kernels/int_round.py`); `rust/tests/pjrt_roundtrip.rs`
//! asserts the two produce identical integers for identical inputs, so the
//! coordinator can run either implementation on the hot path (the rust one
//! avoids a PJRT host round-trip for the small models used in the
//! experiments; the artifact path demonstrates the on-device variant).

use std::time::Instant;

use crate::collective::{allreduce_i64, InaSwitch};
use crate::coordinator::RoundCtx;
use crate::scaling::AlphaRule;
use crate::util::Rng;

use super::{average, CommOp, DistributedCompressor, Primitive, RoundResult};

/// Rounding mode (paper §5.1: IntSGD (Random) vs IntSGD (Determ.)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// floor(t + u), u ~ U[0,1): unbiased, the analyzed variant.
    Stochastic,
    /// round-half-to-even (torch.round): biased but cheaper.
    Deterministic,
}

/// Wire integer width (paper tests int8 and int32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireInt {
    Int8,
    Int32,
}

impl WireInt {
    pub fn bytes(self) -> usize {
        match self {
            WireInt::Int8 => 1,
            WireInt::Int32 => 4,
        }
    }

    /// Largest magnitude the *aggregate* may reach.
    pub fn max_aggregate(self) -> i64 {
        match self {
            WireInt::Int8 => i8::MAX as i64,
            WireInt::Int32 => i32::MAX as i64,
        }
    }
}

pub struct IntSgd {
    pub rounding: Rounding,
    pub wire: WireInt,
    rule: Box<dyn AlphaRule>,
    /// Aggregate through the INA switch simulator instead of ring
    /// all-reduce (same math unless saturation occurs).
    pub use_switch: bool,
    /// Per-worker RNG streams for stochastic rounding.
    rngs: Vec<Rng>,
    /// Reusable per-round buffers (perf: no allocation after warmup).
    ints: Vec<Vec<i64>>,
    sum: Vec<i64>,
}

impl IntSgd {
    pub fn new(
        rounding: Rounding,
        wire: WireInt,
        rule: Box<dyn AlphaRule>,
        n: usize,
        seed: u64,
    ) -> Self {
        let mut root = Rng::new(seed);
        IntSgd {
            rounding,
            wire,
            rule,
            use_switch: false,
            rngs: (0..n).map(|i| root.fork(i as u64)).collect(),
            ints: Vec::new(),
            sum: Vec::new(),
        }
    }

    /// Per-worker clip bound: each local integer is clipped to
    /// floor((2^{b-1}-1)/n) so the aggregate of n workers provably fits the
    /// wire type (paper §5.1 "we clip the local stochastic gradients").
    pub fn local_clip(&self, n: usize) -> i64 {
        (self.wire.max_aggregate() / n as i64).max(1)
    }

    /// Encode one worker's gradient (the Pallas-kernel mirror).
    ///
    /// All arithmetic is f32 to match the kernel exactly (`alpha * g`,
    /// `floor(t + u)` / round-ties-even, clip); the uniform draws come two
    /// per PRNG step (§Perf: this path is the paper's "computation
    /// overhead" column and was the top L3 bottleneck before the f32
    /// rewrite — see EXPERIMENTS.md §Perf).
    pub fn encode(
        rounding: Rounding,
        grad: &[f32],
        alpha: f64,
        clip: i64,
        rng: &mut Rng,
        out: &mut Vec<i64>,
    ) {
        out.clear();
        out.reserve(grad.len());
        let a = alpha as f32;
        let c = clip as f32; // clip <= 2^31: exactly representable ranges we use
        match rounding {
            Rounding::Stochastic => {
                // counter-based randomness: no loop-carried RNG dependency,
                // so the scale+floor+clip chain auto-vectorizes (§Perf).
                // One draw from the worker's stream keys this round.
                let base = rng.next_u64();
                const SCALE: f32 = 1.0 / (1u32 << 24) as f32;
                out.extend(grad.iter().enumerate().map(|(j, &g)| {
                    let u =
                        (crate::util::rng::splitmix64_at(base, j as u64) >> 40) as f32
                            * SCALE;
                    (g * a + u).floor().clamp(-c, c) as i64
                }));
            }
            Rounding::Deterministic => {
                // f32 round-ties-even mirrors jnp.round in the kernel
                out.extend(
                    grad.iter()
                        .map(|&g| (g * a).round_ties_even().clamp(-c, c) as i64),
                );
            }
        }
    }
}

impl DistributedCompressor for IntSgd {
    fn name(&self) -> String {
        let r = match self.rounding {
            Rounding::Stochastic => "random",
            Rounding::Deterministic => "determ",
        };
        let w = match self.wire {
            WireInt::Int8 => 8,
            WireInt::Int32 => 32,
        };
        format!("intsgd_{r}_{w}bit[{}]", self.rule.name())
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn round(&mut self, grads: &[Vec<f32>], ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();
        assert_eq!(n, self.rngs.len(), "worker count changed mid-run");

        // Paper: "we assume that the first communication is exact" — there
        // is no alpha_0 (it needs ||x^1 - x^0||).
        if ctx.round == 0 {
            return RoundResult {
                gtilde: average(grads),
                comm: vec![CommOp {
                    primitive: Primitive::AllReduce,
                    bytes_per_worker: d * 4,
                }],
                encode_seconds: 0.0,
                decode_seconds: 0.0,
                max_abs_int: 0,
                alpha: 0.0,
            };
        }

        let alpha = self.rule.alpha(ctx);
        let clip = self.local_clip(n);

        // encode every worker (timed: this is the paper's "computation
        // overhead" column)
        let t0 = Instant::now();
        if self.ints.len() != n {
            self.ints = vec![Vec::new(); n];
        }
        for (i, g) in grads.iter().enumerate() {
            let mut buf = std::mem::take(&mut self.ints[i]);
            Self::encode(self.rounding, g, alpha, clip, &mut self.rngs[i], &mut buf);
            self.ints[i] = buf;
        }
        // workers encode in parallel in a real deployment; the measured
        // loop runs them sequentially, so per-worker overhead = total / n
        let encode_seconds = t0.elapsed().as_secs_f64() / n as f64;

        // aggregate integers in-flight
        let views: Vec<&[i64]> = self.ints.iter().map(|v| v.as_slice()).collect();
        if self.use_switch {
            let switch = InaSwitch::default();
            switch.aggregate_into(&views, self.wire, &mut self.sum);
        } else {
            allreduce_i64(&views, &mut self.sum);
        }
        let max_abs_int = self.sum.iter().map(|&x| x.abs()).max().unwrap_or(0);

        // decode: g_tilde = sum / (n * alpha)
        let t1 = Instant::now();
        let inv = 1.0 / (n as f64 * alpha);
        let gtilde: Vec<f32> = self.sum.iter().map(|&s| (s as f64 * inv) as f32).collect();
        let decode_seconds = t1.elapsed().as_secs_f64();

        RoundResult {
            gtilde,
            comm: vec![CommOp {
                primitive: if self.use_switch {
                    Primitive::Switch
                } else {
                    Primitive::AllReduce
                },
                bytes_per_worker: d * self.wire.bytes(),
            }],
            encode_seconds,
            decode_seconds,
            max_abs_int,
            alpha,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BlockInfo;
    use crate::prop_assert;
    use crate::scaling::MovingAverageRule;
    use crate::util::prop::prop_check;
    use crate::util::stats::l2_norm_sq;

    fn ctx(round: usize, d: usize, n: usize, step_sq: f64) -> RoundCtx {
        RoundCtx {
            round,
            n,
            d,
            lr: 0.1,
            step_norm_sq: step_sq,
            blocks: vec![BlockInfo { dim: d, step_norm_sq: step_sq }],
        }
    }

    fn make(rounding: Rounding, wire: WireInt, n: usize) -> IntSgd {
        IntSgd::new(
            rounding,
            wire,
            Box::new(MovingAverageRule::default_paper()),
            n,
            7,
        )
    }

    #[test]
    fn first_round_is_exact() {
        let mut c = make(Rounding::Stochastic, WireInt::Int8, 2);
        let grads = vec![vec![0.123f32, -4.5], vec![0.001f32, 2.5]];
        let r = c.round(&grads, &ctx(0, 2, 2, 0.0));
        assert_eq!(r.gtilde, average(&grads));
        assert_eq!(r.wire_bytes_per_worker(), 2 * 4);
    }

    #[test]
    fn int8_wire_bytes() {
        let mut c = make(Rounding::Deterministic, WireInt::Int8, 4);
        let grads = vec![vec![0.5f32; 100]; 4];
        let r = c.round(&grads, &ctx(3, 100, 4, 0.01));
        assert_eq!(r.wire_bytes_per_worker(), 100);
        let mut c32 = make(Rounding::Deterministic, WireInt::Int32, 4);
        let r32 = c32.round(&grads, &ctx(3, 100, 4, 0.01));
        assert_eq!(r32.wire_bytes_per_worker(), 400);
    }

    #[test]
    fn aggregate_fits_wire_type() {
        // Even with huge gradients the clipping guarantees the aggregate
        // fits the wire integer.
        prop_check(0xC11F, 50, |rng| {
            let n = 1 + rng.usize_below(32);
            let d = 1 + rng.usize_below(500);
            let mut c = make(Rounding::Stochastic, WireInt::Int8, n);
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| 1e6 * rng.normal_f32()).collect())
                .collect();
            let r = c.round(&grads, &ctx(1, d, n, 1e-12));
            prop_assert!(
                r.max_abs_int <= i8::MAX as i64,
                "aggregate {} exceeds int8",
                r.max_abs_int
            );
            Ok(())
        });
    }

    #[test]
    fn deterministic_encode_matches_scalar_math() {
        let grad = [0.04f32, -0.26, 0.25, 1.0];
        let mut out = Vec::new();
        let mut rng = Rng::new(0);
        IntSgd::encode(Rounding::Deterministic, &grad, 10.0, 1000, &mut rng, &mut out);
        // 0.4 -> 0, -2.6 -> -3, 2.5 -> 2 (ties-even), 10 -> 10
        assert_eq!(out, vec![0, -3, 2, 10]);
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // E[Int(alpha g)]/alpha == g, estimated over many draws.
        let g = [0.3f32, -0.7, 0.01, 2.4];
        let alpha = 1.0;
        let mut rng = Rng::new(99);
        let mut acc = [0f64; 4];
        let trials = 60_000;
        let mut out = Vec::new();
        for _ in 0..trials {
            IntSgd::encode(Rounding::Stochastic, &g, alpha, 1 << 40, &mut rng, &mut out);
            for (a, &v) in acc.iter_mut().zip(&out) {
                *a += v as f64;
            }
        }
        for (a, &gi) in acc.iter().zip(&g) {
            let mean = *a / trials as f64;
            assert!(
                (mean - gi as f64).abs() < 0.01,
                "mean {mean} vs {gi}"
            );
        }
    }

    #[test]
    fn recovers_average_gradient_at_high_alpha() {
        // With near-zero rounding error (huge alpha via tiny steps),
        // gtilde ~= mean(grads).
        let n = 4;
        let d = 64;
        let mut rng = Rng::new(5);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let mut c = make(Rounding::Stochastic, WireInt::Int32, n);
        let r = c.round(&grads, &ctx(1, d, n, 1e-14));
        let avg = average(&grads);
        let err = l2_norm_sq(
            &r.gtilde
                .iter()
                .zip(&avg)
                .map(|(&a, &b)| a - b)
                .collect::<Vec<_>>(),
        );
        assert!(err < 1e-6, "err {err}, alpha {}", r.alpha);
    }

    #[test]
    fn rounding_error_bounded_by_lemma1() {
        // || gtilde - avg ||^2 <= d / (4 n alpha^2) * (1/n) ... verify the
        // per-worker bound E||Q(g)-g||^2 <= d/(4 alpha^2) empirically for
        // the aggregate: Var <= d/(4 n alpha^2).
        prop_check(0x1EE7, 20, |rng| {
            let n = 2 + rng.usize_below(8);
            let d = 100;
            let grads: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
            let avg = average(&grads);
            let mut c = make(Rounding::Stochastic, WireInt::Int32, n);
            // moderate alpha via a moderate step norm
            let cx = ctx(1, d, n, 1e-4);
            let mut sq = 0.0;
            let reps = 40;
            let mut alpha = 0.0;
            for _ in 0..reps {
                let r = c.round(&grads, &cx);
                alpha = r.alpha;
                sq += r
                    .gtilde
                    .iter()
                    .zip(&avg)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            let mean_sq = sq / reps as f64;
            let bound = d as f64 / (4.0 * n as f64 * alpha * alpha);
            // allow 3x slack for the monte-carlo estimate
            prop_assert!(
                mean_sq <= 3.0 * bound + 1e-12,
                "E err^2 {mean_sq} > bound {bound} (alpha {alpha})"
            );
            Ok(())
        });
    }

    #[test]
    fn switch_and_allreduce_agree_without_saturation() {
        let n = 4;
        let d = 128;
        let mut rng = Rng::new(11);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let mut a = make(Rounding::Deterministic, WireInt::Int32, n);
        let mut b = make(Rounding::Deterministic, WireInt::Int32, n);
        b.use_switch = true;
        let ra = a.round(&grads, &ctx(1, d, n, 1e-3));
        let rb = b.round(&grads, &ctx(1, d, n, 1e-3));
        assert_eq!(ra.gtilde, rb.gtilde);
        assert_eq!(rb.comm[0].primitive, Primitive::Switch);
    }
}
