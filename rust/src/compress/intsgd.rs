//! IntSGD (paper Alg. 1 / Alg. 2): adaptive integer rounding with a scale
//! shared by every worker, aggregated by summing integers in-flight.
//!
//! This is the rust mirror of the Pallas kernel
//! (`python/compile/kernels/int_round.py`); `rust/tests/pjrt_roundtrip.rs`
//! asserts the two produce identical integers for identical inputs, so the
//! coordinator can run either implementation on the hot path (the rust one
//! avoids a PJRT host round-trip for the small models used in the
//! experiments; the artifact path demonstrates the on-device variant).
//!
//! Phase split (`compress::engine`): the leader derives per-block alphas
//! from the `AlphaRule` (Alg. 2 when the ctx carries a block layout), each
//! rank's [`RankEncoder`] rounds its gradient with its own RNG stream, and
//! the reduce phase sums integers through the engine's [`Reducer`] (serial
//! or coordinate-chunked across the worker pool — bit-identical) or the
//! INA switch simulator.
//!
//! §Perf: the encoder is *fused and typed* — one pass over the gradient
//! does scale → stochastic-round → clip → pack, writing the wire lane
//! (`i8` for the int8 wire) directly into the rank's reused [`IntVec`]
//! buffer. Same arithmetic as before (f32, counter-based uniforms), an
//! eighth of the write traffic, zero steady-state allocation.

use crate::collective::InaSwitch;
use crate::coordinator::{BlockInfo, RoundCtx};
use crate::scaling::AlphaRule;
use crate::simd;
use crate::telemetry;
use crate::util::Rng;

use std::sync::Arc;

use super::engine::{
    decode_block_ints, mean_dense_into, spans_from_ctx_into, BlockSpan, Message,
    PassOutcome, PassPlan, PhasedCompressor, RankEncoder, RankMessages, Reducer,
    RoundArena,
};
use super::intvec::{IntVec, Lanes};
use super::{CommOp, Primitive, RoundResult};

/// Rounding mode (paper §5.1: IntSGD (Random) vs IntSGD (Determ.)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// floor(t + u), u ~ U[0,1): unbiased, the analyzed variant.
    Stochastic,
    /// round-half-to-even (torch.round): biased but cheaper.
    Deterministic,
}

/// Wire integer width (paper tests int8 and int32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireInt {
    Int8,
    Int32,
}

impl WireInt {
    pub fn bytes(self) -> usize {
        match self {
            WireInt::Int8 => 1,
            WireInt::Int32 => 4,
        }
    }

    /// Largest magnitude the *aggregate* may reach.
    pub fn max_aggregate(self) -> i64 {
        match self {
            WireInt::Int8 => i8::MAX as i64,
            WireInt::Int32 => i32::MAX as i64,
        }
    }
}

/// A lane type the fused encoders can pack into. The value handed to
/// `of_f32`/`of_f64` is already rounded and bounded to the lane's range
/// by the caller's clip/budget proof, so the `as` casts are
/// value-preserving (NaN maps to 0, same as the old `as i64` path).
///
/// `of_rounded` is the clip+pack step of the fused encode: it takes the
/// *rounded but unclipped* f32 from the rounding kernel and clamps it to
/// `±clip`. For the narrow i8 lane the clamp runs in f32 (`clip <= 127`
/// is always exactly representable); for the wide i32/i64 lanes it runs
/// in the *integer* domain, because a clip bound above 2^24 need not be
/// f32-representable — `clip as f32` can round up and admit an
/// aggregate one past the proved wire bound (see the large-clip
/// property test in `tests/fused_encode.rs`). Either way a NaN packs to
/// 0 (f32 clamp propagates NaN and `as` maps it to 0; `as i64` maps it
/// to 0 directly).
pub trait WireLane: Copy + Send {
    fn of_f32(x: f32) -> Self;
    fn of_f64(x: f64) -> Self;
    /// Clamp a rounded value to `±clip` and pack it into the lane.
    fn of_rounded(x: f32, clip: i64) -> Self;
}

impl WireLane for i8 {
    #[inline]
    fn of_f32(x: f32) -> i8 {
        x as i8
    }
    #[inline]
    fn of_f64(x: f64) -> i8 {
        x as i8
    }
    #[inline]
    fn of_rounded(x: f32, clip: i64) -> i8 {
        debug_assert!(clip <= i8::MAX as i64);
        let c = clip as f32; // <= 127: exact
        x.clamp(-c, c) as i8
    }
}

impl WireLane for i32 {
    #[inline]
    fn of_f32(x: f32) -> i32 {
        x as i32
    }
    #[inline]
    fn of_f64(x: f64) -> i32 {
        x as i32
    }
    #[inline]
    fn of_rounded(x: f32, clip: i64) -> i32 {
        // integer-domain clamp: clip may not be f32-representable
        (x as i64).clamp(-clip, clip) as i32
    }
}

impl WireLane for i64 {
    #[inline]
    fn of_f32(x: f32) -> i64 {
        x as i64
    }
    #[inline]
    fn of_f64(x: f64) -> i64 {
        x as i64
    }
    #[inline]
    fn of_rounded(x: f32, clip: i64) -> i64 {
        (x as i64).clamp(-clip, clip)
    }
}

/// Coordinates per fused-encode chunk: enough to amortize the kernel
/// dispatch, small enough that the 4 KiB rounded-value scratch and the
/// chunk's lanes stay in L1.
const ENCODE_CHUNK: usize = 1024;

/// Round one block of coordinates into a typed lane buffer — the fused
/// scale → round → clip → pack pass. `base` keys the counter-based uniform
/// stream and `offset` is the block's absolute coordinate offset, so a
/// multi-block encode with equal alphas is bit-identical to a single-block
/// encode of the whole gradient (and independent of the block layout).
///
/// All arithmetic is f32 to match the Pallas kernel exactly (`alpha * g`,
/// `floor(t + u)` / round-ties-even, clip); the uniform draws are
/// counter-based off one generator step per round, so there is no
/// loop-carried RNG dependency (§Perf: this path is the paper's
/// "computation overhead" column). The scale→round fill runs through the
/// dispatched kernel layer (`crate::simd`) into a fixed stack scratch;
/// the clip+pack step is the *same* scalar `WireLane::of_rounded` loop on
/// every backend, so encode bit-identity reduces to the rounding kernels'
/// contract (DESIGN.md §10).
fn encode_span<T: WireLane>(
    rounding: Rounding,
    grad: &[f32],
    alpha: f64,
    clip: i64,
    base: u64,
    offset: usize,
    out: &mut Vec<T>,
) {
    let a = alpha as f32;
    let mut rounded = [0.0f32; ENCODE_CHUNK];
    let mut j = offset as u64;
    for chunk in grad.chunks(ENCODE_CHUNK) {
        let r = &mut rounded[..chunk.len()];
        match rounding {
            Rounding::Stochastic => simd::round_stoch(chunk, a, base, j, r),
            Rounding::Deterministic => simd::round_determ(chunk, a, r),
        }
        out.extend(r.iter().map(|&x| T::of_rounded(x, clip)));
        j += chunk.len() as u64;
    }
}

/// [`encode_span`] over every block of one gradient, into one lane type.
fn encode_blocks_typed<T: WireLane>(
    rounding: Rounding,
    blocks: &[BlockSpan],
    alphas: &[f64],
    clip: i64,
    grad: &[f32],
    base: u64,
    out: &mut Vec<T>,
) {
    out.reserve(grad.len());
    for (span, &alpha) in blocks.iter().zip(alphas) {
        encode_span(rounding, &grad[span.range()], alpha, clip, base, span.offset, out);
    }
}

/// Encode a full gradient (per-block alphas) into the typed wire buffer.
/// Public so the fused-vs-reference property tests can drive it with a
/// fixed counter base.
pub fn encode_blocks(
    rounding: Rounding,
    blocks: &[BlockSpan],
    alphas: &[f64],
    clip: i64,
    grad: &[f32],
    base: u64,
    out: &mut IntVec,
) {
    match out {
        IntVec::I8(v) => encode_blocks_typed(rounding, blocks, alphas, clip, grad, base, v),
        IntVec::I32(v) => encode_blocks_typed(rounding, blocks, alphas, clip, grad, base, v),
        IntVec::I64(v) => encode_blocks_typed(rounding, blocks, alphas, clip, grad, base, v),
    }
}

/// [`encode_span`] into a typed wire buffer — one block of the streamed
/// driver's per-block fill. `offset` is the block's absolute coordinate
/// offset, which keys the uniforms, so this is bit-identical to the same
/// block's slice of a whole-gradient [`encode_blocks`].
fn encode_span_into(
    rounding: Rounding,
    grad: &[f32],
    alpha: f64,
    clip: i64,
    base: u64,
    offset: usize,
    out: &mut IntVec,
) {
    match out {
        IntVec::I8(v) => encode_span(rounding, grad, alpha, clip, base, offset, v),
        IntVec::I32(v) => encode_span(rounding, grad, alpha, clip, base, offset, v),
        IntVec::I64(v) => encode_span(rounding, grad, alpha, clip, base, offset, v),
    }
}

pub struct IntSgd {
    pub rounding: Rounding,
    pub wire: WireInt,
    rule: Box<dyn AlphaRule>,
    /// Aggregate through the INA switch simulator instead of ring
    /// all-reduce (same math unless saturation occurs).
    pub use_switch: bool,
    /// Configured worker count (the wire-fit proof depends on it).
    n: usize,
    /// Pre-forked per-worker RNG streams, handed to encoders on creation.
    streams: Vec<Option<Rng>>,
    encoders: Vec<Box<dyn RankEncoder>>,
    // -- leader round state ------------------------------------------------
    /// Reusable integer-aggregate buffer (perf: no allocation after warmup).
    sum: Vec<i64>,
    /// Exact-round (round 0) average.
    exact: Vec<f32>,
    /// Plan geometry, `Arc`-shared with the in-flight plan and rebuilt in
    /// place each round once the previous plan is gone (`Arc::make_mut`).
    blocks: Arc<Vec<BlockSpan>>,
    alphas: Arc<Vec<f64>>,
    /// Reused normalized ctx for block-less callers (one whole-gradient
    /// block), so that path is as allocation-free as the blocked one.
    norm_ctx: RoundCtx,
    max_abs_int: i64,
    exact_round: bool,
    d: usize,
}

impl IntSgd {
    // intlint: allow(R2, reason="constructor: state is built once, before the round loop")
    pub fn new(
        rounding: Rounding,
        wire: WireInt,
        rule: Box<dyn AlphaRule>,
        n: usize,
        seed: u64,
    ) -> Self {
        assert!(n >= 1, "at least one worker");
        assert!(
            (n as i64) <= wire.max_aggregate(),
            "{n} workers exceed the {wire:?} wire budget: even clip 1 lets the \
             aggregate reach {n} > {}",
            wire.max_aggregate()
        );
        let mut root = Rng::new(seed);
        IntSgd {
            rounding,
            wire,
            rule,
            use_switch: false,
            n,
            streams: (0..n).map(|i| Some(root.fork(i as u64))).collect(),
            encoders: Vec::new(),
            sum: Vec::new(),
            exact: Vec::new(),
            blocks: Arc::new(Vec::new()),
            alphas: Arc::new(Vec::new()),
            norm_ctx: RoundCtx {
                round: 0,
                n,
                d: 0,
                lr: 0.0,
                step_norm_sq: 0.0,
                blocks: Vec::new(),
            },
            max_abs_int: 0,
            exact_round: false,
            d: 0,
        }
    }

    /// Per-worker clip bound: floor((2^{b-1}-1)/n), so the aggregate of n
    /// workers provably fits the wire type (paper §5.1 "we clip the local
    /// stochastic gradients"). The constructor rejects configurations
    /// where even clip 1 would overflow (n workers > wire budget), so the
    /// bound here is always >= 1 without a silent floor.
    pub fn local_clip(&self, n: usize) -> i64 {
        let clip = self.wire.max_aggregate() / n as i64;
        assert!(
            clip >= 1,
            "{n} workers exceed the {:?} wire budget",
            self.wire
        );
        clip
    }

    /// Encode one worker's gradient into widened integers (the Pallas
    /// kernel mirror and the reference shape for tests; the engine's hot
    /// path packs wire lanes via [`encode_blocks`] instead — same
    /// arithmetic, `tests/fused_encode.rs` pins the bit-identity).
    pub fn encode(
        rounding: Rounding,
        grad: &[f32],
        alpha: f64,
        clip: i64,
        rng: &mut Rng,
        out: &mut Vec<i64>,
    ) {
        out.clear();
        out.reserve(grad.len());
        let base = match rounding {
            // counter-based randomness: one draw from the worker's stream
            // keys this round, `splitmix64_at` indexes the coordinates.
            Rounding::Stochastic => rng.next_u64(),
            Rounding::Deterministic => 0,
        };
        encode_span(rounding, grad, alpha, clip, base, 0, out);
    }

    /// Close an integer round around an already-decoded `gtilde`: both the
    /// barrier decode and the streamed drain end here, so the comm ledger
    /// and diagnostics cannot drift between the two drivers.
    fn int_round_result(&self, gtilde: Vec<f32>, arena: &mut RoundArena) -> RoundResult {
        let mut comm = arena.take_comm();
        comm.push(CommOp {
            primitive: if self.use_switch {
                Primitive::Switch
            } else {
                Primitive::AllReduce
            },
            bytes_per_worker: self.d * self.wire.bytes(),
        });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: self.max_abs_int,
            alpha: self.alphas.iter().copied().fold(f64::INFINITY, f64::min),
        }
    }
}

/// One rank's IntSGD state: its RNG stream and reusable typed message
/// buffer.
struct IntEncoder {
    rng: Rng,
    msg: Message,
    /// Counter base of the last stochastic encode, keyed by its round: a
    /// failover re-encode of the SAME round reuses the base instead of
    /// drawing again, so the rank's stream position after the round is
    /// identical to a run that encoded it once (DESIGN.md §7).
    base: Option<(usize, u64)>,
}

impl IntEncoder {
    /// The round-keyed counter base: drawn once per round from the rank's
    /// stream, reused by every same-round encode (the streamed driver's
    /// per-block fills, a failover re-encode) — the stream position after
    /// the round is identical however the round was scheduled.
    fn round_base(&mut self, rounding: Rounding, round: usize) -> u64 {
        match rounding {
            Rounding::Stochastic => match self.base {
                Some((at, base)) if at == round => base,
                _ => {
                    let base = self.rng.next_u64();
                    self.base = Some((round, base));
                    base
                }
            },
            Rounding::Deterministic => 0,
        }
    }
}

impl RankEncoder for IntEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Dense => {
                // exact first communication: ship the raw fp32 gradient
                let out = self.msg.dense_mut();
                out.clear();
                out.extend_from_slice(grad);
            }
            PassPlan::IntBlocks { rounding, blocks, alphas, clip, lanes, round } => {
                let base = self.round_base(*rounding, *round);
                let out = self.msg.ints_mut(*lanes);
                encode_blocks(*rounding, blocks, alphas, *clip, grad, base, out);
            }
            _ => panic!("IntSgd encoder: unexpected plan"),
        }
    }

    fn encode_block(
        &mut self,
        grad: &[f32],
        plan: &PassPlan,
        block: usize,
        out: &mut IntVec,
    ) -> bool {
        match plan {
            PassPlan::IntBlocks { rounding, blocks, alphas, clip, lanes, round } => {
                let base = self.round_base(*rounding, *round);
                let span = blocks[block];
                out.reset(*lanes);
                encode_span_into(
                    *rounding,
                    &grad[span.range()],
                    alphas[block],
                    *clip,
                    base,
                    span.offset,
                    out,
                );
                true
            }
            _ => false,
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }

    // checkpoint v2: the stochastic-rounding stream travels with the
    // checkpoint so a resumed run draws the identical uniforms
    fn rng_state(&self) -> Option<[u64; 6]> {
        Some(self.rng.export_state())
    }

    fn set_rng_state(&mut self, state: [u64; 6]) -> bool {
        self.rng = Rng::from_state(state);
        true
    }
}

impl PhasedCompressor for IntSgd {
    fn name(&self) -> String {
        let r = match self.rounding {
            Rounding::Stochastic => "random",
            Rounding::Deterministic => "determ",
        };
        let w = match self.wire {
            WireInt::Int8 => 8,
            WireInt::Int32 => 32,
        };
        format!("intsgd_{r}_{w}bit[{}]", self.rule.name()) // intlint: allow(R2, reason="display name, called for reports, not per round")
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn make_encoder(&mut self, rank: usize) -> Box<dyn RankEncoder> {
        let rng = self
            .streams
            .get_mut(rank)
            .and_then(|s| s.take())
            .unwrap_or_else(|| {
                panic!("rank {rank} exceeds the configured worker count {}", self.n)
            });
        Box::new(IntEncoder { rng, msg: Message::Empty, base: None }) // intlint: allow(R2, reason="encoder factory runs once at setup")
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        // Paper: "we assume that the first communication is exact" — there
        // is no alpha_0 (it needs ||x^1 - x^0||).
        if ctx.round == 0 {
            self.exact_round = true;
            return PassPlan::Dense;
        }
        self.exact_round = false;
        // steady state: the previous round's plan is gone, so make_mut
        // rebuilds both geometry buffers in place (no allocation)
        let blocks = Arc::make_mut(&mut self.blocks);
        spans_from_ctx_into(ctx, blocks);
        let alphas = Arc::make_mut(&mut self.alphas);
        // Alpha rules consume ctx.blocks; normalize block-less contexts to
        // one block covering the whole gradient so BlockRule stays valid
        // (into the reused scratch ctx — this path allocates nothing).
        if ctx.blocks.is_empty() {
            let norm = &mut self.norm_ctx;
            norm.round = ctx.round;
            norm.n = ctx.n;
            norm.d = ctx.d;
            norm.lr = ctx.lr;
            norm.step_norm_sq = ctx.step_norm_sq;
            norm.blocks.clear();
            norm.blocks.push(BlockInfo { dim: ctx.d, step_norm_sq: ctx.step_norm_sq });
            self.rule.block_alphas_into(&self.norm_ctx, alphas);
        } else {
            self.rule.block_alphas_into(ctx, alphas);
        }
        assert_eq!(self.alphas.len(), self.blocks.len(), "one alpha per block");
        telemetry::m::ALPHA_BLOCK.set_all(&self.alphas);
        let clip = self.local_clip(ctx.n);
        PassPlan::IntBlocks {
            rounding: self.rounding,
            blocks: Arc::clone(&self.blocks),
            alphas: Arc::clone(&self.alphas),
            clip,
            // every clipped value fits the clip-implied lane, which never
            // exceeds the wire width (clip <= max_aggregate)
            lanes: Lanes::for_bound(clip),
            round: ctx.round,
        }
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        plan: &PassPlan,
        ctx: &RoundCtx,
        red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        match plan {
            PassPlan::Dense => {
                mean_dense_into(msgs, &mut self.exact);
                self.max_abs_int = 0;
            }
            PassPlan::IntBlocks { .. } => {
                if self.use_switch {
                    // saturating accumulation is order-sensitive; the
                    // switch data plane stays a leader-side simulation
                    let switch = InaSwitch::default();
                    switch.aggregate_messages(msgs, self.wire, &mut self.sum);
                } else {
                    red.sum_ints(msgs, &mut self.sum)?;
                }
                self.max_abs_int = simd::max_abs_i64(&self.sum);
                // clip headroom: |aggregate| against the proved wire bound
                // n * clip (Lemma 5 — the reason the sum cannot overflow).
                // A utilization of 1.0 means the clip actually bit.
                let bound = self.local_clip(ctx.n) * ctx.n as i64;
                if bound > 0 {
                    let util = self.max_abs_int as f64 / bound as f64;
                    telemetry::m::CLIP_UTILIZATION.set(util);
                    if self.max_abs_int >= bound {
                        telemetry::m::CLIP_SATURATED_ROUNDS.inc();
                    }
                }
            }
            _ => unreachable!("IntSgd planned no such pass"),
        }
        Ok(PassOutcome::Done)
    }

    fn decode(&mut self, ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        if self.exact_round {
            let mut comm = arena.take_comm();
            let mut gtilde = arena.take_f32();
            std::mem::swap(&mut gtilde, &mut self.exact);
            comm.push(CommOp {
                primitive: Primitive::AllReduce,
                bytes_per_worker: self.d * 4,
            });
            return RoundResult {
                gtilde,
                comm,
                encode_seconds: 0.0,
                reduce_seconds: 0.0,
                decode_seconds: 0.0,
                max_abs_int: 0,
                alpha: 0.0,
            };
        }
        let mut gtilde = arena.take_f32();
        decode_block_ints(&self.sum, &self.blocks, &self.alphas, ctx.n, &mut gtilde);
        self.int_round_result(gtilde, arena)
    }

    /// Streamable exactly when the round is a plain integer sum: one
    /// encode pass, `reduce` == `sum_ints` over the full range, per-block
    /// decode. Round 0 is dense, and the switch data plane is a
    /// saturating (order-sensitive) leader-side simulation — both stay on
    /// the barrier path.
    fn streams(&self, plan: &PassPlan) -> bool {
        matches!(plan, PassPlan::IntBlocks { .. }) && !self.use_switch
    }

    fn finish_streamed(
        &mut self,
        _ctx: &RoundCtx,
        arena: &mut RoundArena,
        gtilde: Vec<f32>,
    ) -> RoundResult {
        debug_assert!(!self.exact_round, "round 0 never streams");
        debug_assert_eq!(gtilde.len(), self.d, "drained decode must cover the gradient");
        self.int_round_result(gtilde, arena)
    }

    // checkpoint v2: the scaling rule's moving-average state is part of
    // the algorithm the proof analyzes — a resume that drops it is a
    // different run
    fn export_rule_state(&self) -> Option<Vec<f64>> {
        self.rule.export_state()
    }

    fn import_rule_state(&mut self, state: &[f64]) -> anyhow::Result<()> {
        self.rule.import_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{average, DistributedCompressor};
    use crate::coordinator::BlockInfo;
    use crate::prop_assert;
    use crate::scaling::{BlockRule, MovingAverageRule};
    use crate::util::prop::prop_check;
    use crate::util::stats::l2_norm_sq;

    fn ctx(round: usize, d: usize, n: usize, step_sq: f64) -> RoundCtx {
        RoundCtx {
            round,
            n,
            d,
            lr: 0.1,
            step_norm_sq: step_sq,
            blocks: vec![BlockInfo { dim: d, step_norm_sq: step_sq }],
        }
    }

    fn make(rounding: Rounding, wire: WireInt, n: usize) -> IntSgd {
        IntSgd::new(
            rounding,
            wire,
            Box::new(MovingAverageRule::default_paper()),
            n,
            7,
        )
    }

    #[test]
    fn first_round_is_exact() {
        let mut c = make(Rounding::Stochastic, WireInt::Int8, 2);
        let grads = vec![vec![0.123f32, -4.5], vec![0.001f32, 2.5]];
        let r = c.round(&grads, &ctx(0, 2, 2, 0.0));
        assert_eq!(r.gtilde, average(&grads));
        assert_eq!(r.wire_bytes_per_worker(), 2 * 4);
    }

    #[test]
    fn int8_wire_bytes() {
        let mut c = make(Rounding::Deterministic, WireInt::Int8, 4);
        let grads = vec![vec![0.5f32; 100]; 4];
        let r = c.round(&grads, &ctx(3, 100, 4, 0.01));
        assert_eq!(r.wire_bytes_per_worker(), 100);
        let mut c32 = make(Rounding::Deterministic, WireInt::Int32, 4);
        let r32 = c32.round(&grads, &ctx(3, 100, 4, 0.01));
        assert_eq!(r32.wire_bytes_per_worker(), 400);
    }

    #[test]
    fn aggregate_fits_wire_type() {
        // Even with huge gradients the clipping guarantees the aggregate
        // fits the wire integer — including large fleets (n up to 512,
        // which forces the int32 wire: int8 tops out at 127 workers).
        prop_check(0xC11F, 50, |rng| {
            let n = 1 + rng.usize_below(512);
            let d = 1 + rng.usize_below(500);
            let wire = if n <= i8::MAX as usize { WireInt::Int8 } else { WireInt::Int32 };
            let mut c = make(Rounding::Stochastic, wire, n);
            let grads: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| 1e6 * rng.normal_f32()).collect())
                .collect();
            let r = c.round(&grads, &ctx(1, d, n, 1e-12));
            prop_assert!(
                r.max_abs_int <= wire.max_aggregate(),
                "aggregate {} exceeds {:?} (n={n})",
                r.max_abs_int,
                wire
            );
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "exceed")]
    fn int8_wire_rejects_too_many_workers() {
        // 128 workers cannot fit the int8 aggregate even at clip 1; the
        // old `.max(1)` floor silently violated the wire-fit guarantee.
        let _ = make(Rounding::Stochastic, WireInt::Int8, 128);
    }

    #[test]
    fn int8_wire_accepts_exactly_127_workers() {
        let c = make(Rounding::Stochastic, WireInt::Int8, 127);
        assert_eq!(c.local_clip(127), 1);
    }

    #[test]
    fn int8_clip_implies_i8_lanes() {
        let c = make(Rounding::Stochastic, WireInt::Int8, 4);
        assert_eq!(Lanes::for_bound(c.local_clip(4)), Lanes::I8);
        let c32 = make(Rounding::Stochastic, WireInt::Int32, 4);
        assert_eq!(Lanes::for_bound(c32.local_clip(4)), Lanes::I32);
    }

    #[test]
    fn deterministic_encode_matches_scalar_math() {
        let grad = [0.04f32, -0.26, 0.25, 1.0];
        let mut out = Vec::new();
        let mut rng = Rng::new(0);
        IntSgd::encode(Rounding::Deterministic, &grad, 10.0, 1000, &mut rng, &mut out);
        // 0.4 -> 0, -2.6 -> -3, 2.5 -> 2 (ties-even), 10 -> 10
        assert_eq!(out, vec![0, -3, 2, 10]);
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // E[Int(alpha g)]/alpha == g, estimated over many draws.
        let g = [0.3f32, -0.7, 0.01, 2.4];
        let alpha = 1.0;
        let mut rng = Rng::new(99);
        let mut acc = [0f64; 4];
        let trials = 60_000;
        let mut out = Vec::new();
        for _ in 0..trials {
            IntSgd::encode(Rounding::Stochastic, &g, alpha, 1 << 40, &mut rng, &mut out);
            for (a, &v) in acc.iter_mut().zip(&out) {
                *a += v as f64;
            }
        }
        for (a, &gi) in acc.iter().zip(&g) {
            let mean = *a / trials as f64;
            assert!(
                (mean - gi as f64).abs() < 0.01,
                "mean {mean} vs {gi}"
            );
        }
    }

    #[test]
    fn recovers_average_gradient_at_high_alpha() {
        // With near-zero rounding error (huge alpha via tiny steps),
        // gtilde ~= mean(grads).
        let n = 4;
        let d = 64;
        let mut rng = Rng::new(5);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let mut c = make(Rounding::Stochastic, WireInt::Int32, n);
        let r = c.round(&grads, &ctx(1, d, n, 1e-14));
        let avg = average(&grads);
        let err = l2_norm_sq(
            &r.gtilde
                .iter()
                .zip(&avg)
                .map(|(&a, &b)| a - b)
                .collect::<Vec<_>>(),
        );
        assert!(err < 1e-6, "err {err}, alpha {}", r.alpha);
    }

    #[test]
    fn rounding_error_bounded_by_lemma1() {
        // || gtilde - avg ||^2 <= d / (4 n alpha^2) * (1/n) ... verify the
        // per-worker bound E||Q(g)-g||^2 <= d/(4 alpha^2) empirically for
        // the aggregate: Var <= d/(4 n alpha^2).
        prop_check(0x1EE7, 20, |rng| {
            let n = 2 + rng.usize_below(8);
            let d = 100;
            let grads: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
            let avg = average(&grads);
            let mut c = make(Rounding::Stochastic, WireInt::Int32, n);
            let mut sq = 0.0;
            let reps = 40;
            let mut alpha = 0.0;
            for rep in 0..reps {
                // fresh round per rep: the stochastic base is round-keyed
                // (same-round re-encodes are deliberately bit-identical),
                // and the constant step norm keeps alpha fixed across reps
                let cx = ctx(1 + rep, d, n, 1e-4);
                let r = c.round(&grads, &cx);
                alpha = r.alpha;
                sq += r
                    .gtilde
                    .iter()
                    .zip(&avg)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            let mean_sq = sq / reps as f64;
            let bound = d as f64 / (4.0 * n as f64 * alpha * alpha);
            // allow 3x slack for the monte-carlo estimate
            prop_assert!(
                mean_sq <= 3.0 * bound + 1e-12,
                "E err^2 {mean_sq} > bound {bound} (alpha {alpha})"
            );
            Ok(())
        });
    }

    #[test]
    fn switch_and_allreduce_agree_without_saturation() {
        let n = 4;
        let d = 128;
        let mut rng = Rng::new(11);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
        let mut a = make(Rounding::Deterministic, WireInt::Int32, n);
        let mut b = make(Rounding::Deterministic, WireInt::Int32, n);
        b.use_switch = true;
        let ra = a.round(&grads, &ctx(1, d, n, 1e-3));
        let rb = b.round(&grads, &ctx(1, d, n, 1e-3));
        assert_eq!(ra.gtilde, rb.gtilde);
        assert_eq!(rb.comm[0].primitive, Primitive::Switch);
    }

    #[test]
    fn per_block_alphas_decode_blockwise() {
        // Two blocks with very different step norms get different alphas
        // under BlockRule (Alg. 2), and the decode divides block-wise: a
        // gradient that is identical in both blocks decodes to (nearly)
        // the same values in both, because each block's alpha cancels.
        let n = 2;
        let d = 8;
        let blocks = vec![
            BlockInfo { dim: 4, step_norm_sq: 1e-2 },
            BlockInfo { dim: 4, step_norm_sq: 1e-8 },
        ];
        let cx = RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 1e-2 + 1e-8, blocks };
        let mut c = IntSgd::new(
            Rounding::Deterministic,
            WireInt::Int32,
            Box::new(BlockRule::new(0.9, 1e-8)),
            n,
            3,
        );
        let g = vec![vec![0.5f32, -0.25, 0.125, 1.0, 0.5, -0.25, 0.125, 1.0]; n];
        let r = c.round(&g, &cx);
        // the second block's tiny step norm means a much larger alpha
        // there, i.e. far finer resolution: its decode error is smaller
        for j in 0..4 {
            let coarse = (r.gtilde[j] - g[0][j]).abs();
            let fine = (r.gtilde[j + 4] - g[0][j + 4]).abs();
            assert!(fine <= coarse + 1e-6, "coord {j}: fine {fine} coarse {coarse}");
        }
        assert!(r.alpha.is_finite());
    }
}
