//! EF-SignSGD (Karimireddy et al., 2019): scaled sign compression with
//! error feedback — `C(a) = (||a||_1 / d) sign(a)` — 1 bit/coordinate plus
//! one fp32 scale. Sign messages carry per-worker scales, so aggregation is
//! all-gather (majority-vote variants change the estimator, not the
//! transport).

use std::time::Instant;

use crate::coordinator::RoundCtx;

use super::{CommOp, DistributedCompressor, ErrorFeedback, Primitive, RoundResult};

pub struct SignSgd {
    ef: ErrorFeedback,
}

/// Encoded message: packed sign bits + the l1/d scale.
#[derive(Clone, Debug)]
pub struct SignMsg {
    pub bits: Vec<u64>,
    pub scale: f32,
}

impl SignSgd {
    pub fn new(n: usize) -> Self {
        SignSgd { ef: ErrorFeedback::new(n) }
    }

    pub fn encode(a: &[f32]) -> SignMsg {
        let d = a.len();
        let mut bits = vec![0u64; d.div_ceil(64)];
        let mut l1 = 0.0f64;
        // branch-free: sign bit straight from the f32 representation,
        // 64 coordinates per word (§Perf)
        for (w, chunk) in a.chunks(64).enumerate() {
            let mut word = 0u64;
            let mut acc = 0.0f32;
            for (j, &x) in chunk.iter().enumerate() {
                word |= ((x.to_bits() >> 31) as u64) << j;
                acc += x.abs();
            }
            bits[w] = word;
            l1 += acc as f64;
        }
        SignMsg { bits, scale: (l1 / d as f64) as f32 }
    }

    pub fn decode(msg: &SignMsg, d: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(d);
        for j in 0..d {
            let neg = msg.bits[j / 64] >> (j % 64) & 1 == 1;
            out.push(if neg { -msg.scale } else { msg.scale });
        }
    }

    pub fn wire_bytes(d: usize) -> usize {
        d.div_ceil(8) + 4
    }
}

impl DistributedCompressor for SignSgd {
    fn name(&self) -> String {
        "ef_signsgd".into()
    }

    fn supports_allreduce(&self) -> bool {
        false
    }

    fn round(&mut self, grads: &[Vec<f32>], _ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();

        let t0 = Instant::now();
        let mut msgs = Vec::with_capacity(n);
        let mut dense = Vec::with_capacity(d);
        for (i, g) in grads.iter().enumerate() {
            let a = self.ef.corrected(i, g);
            let msg = Self::encode(&a);
            Self::decode(&msg, d, &mut dense);
            self.ef.store_residual(i, &a, &dense);
            msgs.push(msg);
        }
        // per-worker encode cost (parallel in reality)
        let encode_seconds = t0.elapsed().as_secs_f64() / n as f64;

        let t1 = Instant::now();
        let mut gtilde = vec![0.0f32; d];
        for msg in &msgs {
            Self::decode(msg, d, &mut dense);
            for (o, &x) in gtilde.iter_mut().zip(&dense) {
                *o += x;
            }
        }
        let inv = 1.0 / n as f32;
        for x in &mut gtilde {
            *x *= inv;
        }
        let decode_seconds = t1.elapsed().as_secs_f64();

        RoundResult {
            gtilde,
            comm: vec![CommOp {
                primitive: Primitive::AllGather,
                bytes_per_worker: Self::wire_bytes(d),
            }],
            encode_seconds,
            decode_seconds,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let a = vec![1.0f32, -2.0, 3.0, -4.0];
        let msg = SignSgd::encode(&a);
        assert!((msg.scale - 2.5).abs() < 1e-6);
        let mut out = Vec::new();
        SignSgd::decode(&msg, 4, &mut out);
        assert_eq!(out, vec![2.5, -2.5, 2.5, -2.5]);
    }

    #[test]
    fn compression_is_contraction() {
        // ||a - C(a)||^2 <= (1 - 1/d')||a||^2 for the l1-scaled sign
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let d = 1 + rng.usize_below(200);
            let a = rng.normal_vec(d, 1.0);
            let msg = SignSgd::encode(&a);
            let mut out = Vec::new();
            SignSgd::decode(&msg, d, &mut out);
            let err: f64 = a
                .iter()
                .zip(&out)
                .map(|(&x, &c)| ((x - c) as f64).powi(2))
                .sum();
            let norm: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum();
            assert!(err <= norm + 1e-9, "err {err} > ||a||^2 {norm}");
        }
    }

    #[test]
    fn ef_mean_converges_to_gradient() {
        let mut rng = Rng::new(1);
        let g = rng.normal_vec(64, 1.0);
        let grads = vec![g.clone(); 2];
        let mut c = SignSgd::new(2);
        let mut acc = vec![0.0f64; 64];
        let rounds = 500;
        for _ in 0..rounds {
            let r = c.round(&grads, &ctx(64, 2));
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            assert!(
                (a / rounds as f64 - x as f64).abs() < 0.1,
                "{} vs {x}",
                a / rounds as f64
            );
        }
    }

    #[test]
    fn one_bit_per_coordinate() {
        assert_eq!(SignSgd::wire_bytes(64), 12);
        assert_eq!(SignSgd::wire_bytes(1000), 129);
    }
}
