//! EF-SignSGD (Karimireddy et al., 2019): scaled sign compression with
//! error feedback — `C(a) = (||a||_1 / d) sign(a)` — 1 bit/coordinate plus
//! one fp32 scale. Sign messages carry per-worker scales, so aggregation is
//! all-gather (majority-vote variants change the estimator, not the
//! transport).
//!
//! Phase split: each rank's encoder owns its EF memory and scratch
//! buffers; the whole EF update (correct, compress, self-decode, store
//! residual) is rank-local and runs on the rank's worker thread.

use crate::coordinator::RoundCtx;

use super::engine::{
    Message, PassOutcome, PassPlan, PhasedCompressor, RankEncoder, RankMessages,
    Reducer, RoundArena,
};
use super::{CommOp, ErrorFeedback, Primitive, RoundResult};

pub struct SignSgd {
    encoders: Vec<Box<dyn RankEncoder>>,
    acc: Vec<f32>,
    scratch: Vec<f32>,
    d: usize,
}

/// Encoded message: packed sign bits + the l1/d scale.
#[derive(Clone, Debug, Default)]
pub struct SignMsg {
    pub bits: Vec<u64>,
    pub scale: f32,
}

impl SignSgd {
    pub fn new(_n: usize) -> Self {
        SignSgd { encoders: Vec::new(), acc: Vec::new(), scratch: Vec::new(), d: 0 }
    }

    /// C(a) into a reusable message slot.
    pub fn encode_into(a: &[f32], msg: &mut SignMsg) {
        let d = a.len();
        msg.bits.clear();
        msg.bits.resize(d.div_ceil(64), 0);
        let mut l1 = 0.0f64;
        // branch-free: sign bit straight from the f32 representation,
        // 64 coordinates per word (§Perf)
        for (w, chunk) in a.chunks(64).enumerate() {
            let mut word = 0u64;
            let mut acc = 0.0f32;
            for (j, &x) in chunk.iter().enumerate() {
                word |= ((x.to_bits() >> 31) as u64) << j;
                acc += x.abs();
            }
            msg.bits[w] = word;
            l1 += acc as f64;
        }
        msg.scale = (l1 / d as f64) as f32;
    }

    pub fn encode(a: &[f32]) -> SignMsg {
        let mut msg = SignMsg::default();
        Self::encode_into(a, &mut msg);
        msg
    }

    pub fn decode(msg: &SignMsg, d: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(d);
        for j in 0..d {
            let neg = msg.bits[j / 64] >> (j % 64) & 1 == 1;
            out.push(if neg { -msg.scale } else { msg.scale });
        }
    }

    pub fn wire_bytes(d: usize) -> usize {
        d.div_ceil(8) + 4
    }
}

/// One rank's state: EF memory + scratch for the corrected gradient and
/// the self-decoded message (both needed for the residual update).
struct SignEncoder {
    ef: ErrorFeedback,
    a: Vec<f32>,
    dense: Vec<f32>,
    msg: Message,
}

impl RankEncoder for SignEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Plain => {
                self.ef.corrected_into(grad, &mut self.a);
                if !matches!(self.msg, Message::Sign(_)) {
                    self.msg = Message::Sign(SignMsg::default());
                }
                let Message::Sign(msg) = &mut self.msg else { unreachable!() };
                SignSgd::encode_into(&self.a, msg);
                SignSgd::decode(msg, grad.len(), &mut self.dense);
                self.ef.store_residual(&self.a, &self.dense);
            }
            _ => panic!("SignSgd encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }

    // checkpoint v2: the EF residual is the algorithm's convergence-
    // critical state (module docs of compress::error_feedback)
    fn ef_memory(&self) -> Option<&[f32]> {
        Some(self.ef.memory())
    }

    fn set_ef_memory(&mut self, mem: &[f32]) -> bool {
        self.ef.set_memory(mem);
        true
    }
}

impl PhasedCompressor for SignSgd {
    fn name(&self) -> String {
        "ef_signsgd".into()
    }

    fn supports_allreduce(&self) -> bool {
        false
    }

    fn make_encoder(&mut self, _rank: usize) -> Box<dyn RankEncoder> {
        Box::new(SignEncoder {
            ef: ErrorFeedback::new(),
            a: Vec::new(),
            dense: Vec::new(),
            msg: Message::Empty,
        })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        PassPlan::Plain
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        _plan: &PassPlan,
        ctx: &RoundCtx,
        _red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        // all-gather: every worker decodes all n messages and averages
        let d = ctx.d;
        self.acc.clear();
        self.acc.resize(d, 0.0);
        for m in msgs.iter() {
            SignSgd::decode(m.as_sign(), d, &mut self.scratch);
            for (o, &x) in self.acc.iter_mut().zip(&self.scratch) {
                *o += x;
            }
        }
        let inv = 1.0 / msgs.len() as f32;
        for x in &mut self.acc {
            *x *= inv;
        }
        Ok(PassOutcome::Done)
    }

    fn decode(&mut self, _ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        std::mem::swap(&mut gtilde, &mut self.acc);
        let mut comm = arena.take_comm();
        comm.push(CommOp {
            primitive: Primitive::AllGather,
            bytes_per_worker: Self::wire_bytes(self.d),
        });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::DistributedCompressor;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let a = vec![1.0f32, -2.0, 3.0, -4.0];
        let msg = SignSgd::encode(&a);
        assert!((msg.scale - 2.5).abs() < 1e-6);
        let mut out = Vec::new();
        SignSgd::decode(&msg, 4, &mut out);
        assert_eq!(out, vec![2.5, -2.5, 2.5, -2.5]);
    }

    #[test]
    fn compression_is_contraction() {
        // ||a - C(a)||^2 <= (1 - 1/d')||a||^2 for the l1-scaled sign
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let d = 1 + rng.usize_below(200);
            let a = rng.normal_vec(d, 1.0);
            let msg = SignSgd::encode(&a);
            let mut out = Vec::new();
            SignSgd::decode(&msg, d, &mut out);
            let err: f64 = a
                .iter()
                .zip(&out)
                .map(|(&x, &c)| ((x - c) as f64).powi(2))
                .sum();
            let norm: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum();
            assert!(err <= norm + 1e-9, "err {err} > ||a||^2 {norm}");
        }
    }

    #[test]
    fn ef_mean_converges_to_gradient() {
        let mut rng = Rng::new(1);
        let g = rng.normal_vec(64, 1.0);
        let grads = vec![g.clone(); 2];
        let mut c = SignSgd::new(2);
        let mut acc = vec![0.0f64; 64];
        let rounds = 500;
        for _ in 0..rounds {
            let r = c.round(&grads, &ctx(64, 2));
            for (a, &x) in acc.iter_mut().zip(&r.gtilde) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            assert!(
                (a / rounds as f64 - x as f64).abs() < 0.1,
                "{} vs {x}",
                a / rounds as f64
            );
        }
    }

    #[test]
    fn one_bit_per_coordinate() {
        assert_eq!(SignSgd::wire_bytes(64), 12);
        assert_eq!(SignSgd::wire_bytes(1000), 129);
    }
}
