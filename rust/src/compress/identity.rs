//! Full-precision SGD baselines: the same fp32 gradients shipped over
//! either ring all-reduce (paper "SGD (All-reduce)") or all-gather (paper
//! "SGD (All-gather)") — the two reference rows of Tables 2-3.

use crate::collective::ring_allreduce_f32;
use crate::coordinator::RoundCtx;

use super::{average, CommOp, DistributedCompressor, Primitive, RoundResult};

pub struct IdentitySgd {
    pub primitive: Primitive,
}

impl IdentitySgd {
    pub fn allreduce() -> Self {
        IdentitySgd { primitive: Primitive::AllReduce }
    }

    pub fn allgather() -> Self {
        IdentitySgd { primitive: Primitive::AllGather }
    }
}

impl DistributedCompressor for IdentitySgd {
    fn name(&self) -> String {
        match self.primitive {
            Primitive::AllGather => "sgd_allgather".into(),
            _ => "sgd_allreduce".into(),
        }
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn round(&mut self, grads: &[Vec<f32>], _ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();
        let gtilde = match self.primitive {
            Primitive::AllReduce | Primitive::Switch => {
                let mut sum = ring_allreduce_f32(grads);
                let inv = 1.0 / n as f32;
                for x in &mut sum {
                    *x *= inv;
                }
                sum
            }
            Primitive::AllGather => average(grads),
        };
        // full-precision SGD has no compression stage: the in-process ring
        // reduction stands in for the network data plane, whose time is
        // modeled by netsim — so overhead is genuinely zero here.
        RoundResult {
            gtilde,
            comm: vec![CommOp { primitive: self.primitive, bytes_per_worker: d * 4 }],
            encode_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn allreduce_and_allgather_agree() {
        let mut rng = Rng::new(0);
        let grads: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(100, 1.0)).collect();
        let mut ar = IdentitySgd::allreduce();
        let mut ag = IdentitySgd::allgather();
        let a = ar.round(&grads, &ctx(100, 5)).gtilde;
        let b = ag.round(&grads, &ctx(100, 5)).gtilde;
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn wire_bytes_are_full_precision() {
        let grads = vec![vec![0.0f32; 64]; 2];
        let mut c = IdentitySgd::allreduce();
        let r = c.round(&grads, &ctx(64, 2));
        assert_eq!(r.wire_bytes_per_worker(), 256);
    }
}
