//! Full-precision SGD baselines: the same fp32 gradients shipped over
//! either ring all-reduce (paper "SGD (All-reduce)") or all-gather (paper
//! "SGD (All-gather)") — the two reference rows of Tables 2-3.

use crate::collective::ring_allreduce_f32;
use crate::coordinator::RoundCtx;

use super::engine::{
    mean_dense_into, Message, PassOutcome, PassPlan, PhasedCompressor, RankEncoder,
    RankMessages, Reducer, RoundArena,
};
use super::{CommOp, Primitive, RoundResult};

pub struct IdentitySgd {
    pub primitive: Primitive,
    encoders: Vec<Box<dyn RankEncoder>>,
    gtilde: Vec<f32>,
    d: usize,
}

impl IdentitySgd {
    pub fn allreduce() -> Self {
        IdentitySgd {
            primitive: Primitive::AllReduce,
            encoders: Vec::new(),
            gtilde: Vec::new(),
            d: 0,
        }
    }

    pub fn allgather() -> Self {
        IdentitySgd {
            primitive: Primitive::AllGather,
            encoders: Vec::new(),
            gtilde: Vec::new(),
            d: 0,
        }
    }
}

/// Identity "encoding": the rank ships its raw fp32 gradient.
struct DenseEncoder {
    msg: Message,
}

impl RankEncoder for DenseEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Dense => {
                let out = self.msg.dense_mut();
                out.clear();
                out.extend_from_slice(grad);
            }
            _ => panic!("IdentitySgd encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }
}

impl PhasedCompressor for IdentitySgd {
    fn name(&self) -> String {
        match self.primitive {
            Primitive::AllGather => "sgd_allgather".into(),
            _ => "sgd_allreduce".into(),
        }
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn make_encoder(&mut self, _rank: usize) -> Box<dyn RankEncoder> {
        Box::new(DenseEncoder { msg: Message::Empty })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        PassPlan::Dense
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        _plan: &PassPlan,
        _ctx: &RoundCtx,
        _red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        let n = msgs.len();
        let inv = 1.0 / n as f32;
        match self.primitive {
            Primitive::AllReduce | Primitive::Switch => {
                // the in-process ring reduction stands in for the network
                // data plane, whose time is modeled by netsim; its fixed
                // pairwise order is part of the parity guarantee, so fp32
                // never goes through the parallel reducer
                let views: Vec<&[f32]> = msgs.iter().map(|m| m.as_dense()).collect();
                self.gtilde = ring_allreduce_f32(&views);
                for x in &mut self.gtilde {
                    *x *= inv;
                }
            }
            Primitive::AllGather => {
                mean_dense_into(msgs, &mut self.gtilde);
            }
        }
        Ok(PassOutcome::Done)
    }

    fn decode(&mut self, _ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        std::mem::swap(&mut gtilde, &mut self.gtilde);
        let mut comm = arena.take_comm();
        comm.push(CommOp { primitive: self.primitive, bytes_per_worker: self.d * 4 });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::DistributedCompressor;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn allreduce_and_allgather_agree() {
        let mut rng = Rng::new(0);
        let grads: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(100, 1.0)).collect();
        let mut ar = IdentitySgd::allreduce();
        let mut ag = IdentitySgd::allgather();
        let a = ar.round(&grads, &ctx(100, 5)).gtilde;
        let b = ag.round(&grads, &ctx(100, 5)).gtilde;
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn wire_bytes_are_full_precision() {
        let grads = vec![vec![0.0f32; 64]; 2];
        let mut c = IdentitySgd::allreduce();
        let r = c.round(&grads, &ctx(64, 2));
        assert_eq!(r.wire_bytes_per_worker(), 256);
    }
}
