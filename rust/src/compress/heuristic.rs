//! Heuristic IntSGD: the SwitchML scaling rule of Sapio et al. (2021),
//! the paper's primary point of comparison (§5.2 / Fig. 1).
//!
//! The scale is set by a profiling pass over the outgoing package:
//!
//!   alpha = (2^nb - 1) / (n * 2^max_exp)
//!
//! where `nb` is the wire bit width and `max_exp` the rounded-up exponent
//! of the largest |value| observed. This provably avoids overflow but has
//! no convergence guarantee: when a few large coordinates dominate, the
//! effective resolution (2^nb-1)/2^max_exp crushes small gradients to
//! zero — which is exactly the failure Fig. 1 shows for the 8-bit wire.
//!
//! Phase split: pass 1 is the profiling collective (each rank reports its
//! per-block max |g|, reduced by max — a handful of floats on the wire),
//! pass 2 rounds at the profiled per-block alphas into typed wire buffers
//! sized by the rule's own bound: |alpha * g| <= (2^nb - 1)/n, so the
//! leader picks the narrowest lane that holds that budget (plus rounding
//! slack). Profiling per block follows the same Alg. 2 geometry the
//! adaptive rule uses, so a single outlier layer no longer crushes every
//! other layer's resolution.

use std::sync::Arc;

use crate::coordinator::RoundCtx;
use crate::util::stats::linf_norm;

use super::engine::{
    decode_block_ints, spans_from_ctx_into, BlockSpan, Message, PassOutcome, PassPlan,
    PhasedCompressor, RankEncoder, RankMessages, Reducer, RoundArena,
};
use super::intsgd::WireLane;
use super::intvec::{IntVec, Lanes};
use super::{CommOp, Primitive, RoundResult};

pub struct HeuristicIntSgd {
    /// Wire bits per coordinate (8 or 32 in the paper).
    pub nb: u32,
    encoders: Vec<Box<dyn RankEncoder>>,
    // -- leader round state ------------------------------------------------
    sum: Vec<i64>,
    /// Plan geometry, `Arc`-shared with the in-flight plan (see IntSgd).
    blocks: Arc<Vec<BlockSpan>>,
    alphas: Arc<Vec<f64>>,
    max_abs_int: i64,
    d: usize,
}

impl HeuristicIntSgd {
    pub fn new(nb: u32) -> Self {
        assert!((2..=32).contains(&nb));
        HeuristicIntSgd {
            nb,
            encoders: Vec::new(),
            sum: Vec::new(),
            blocks: Arc::new(Vec::new()),
            alphas: Arc::new(Vec::new()),
            max_abs_int: 0,
            d: 0,
        }
    }

    /// The SwitchML profiling rule: alpha from the global max exponent.
    pub fn alpha_for_max(nb: u32, n: usize, max_abs: f64) -> f64 {
        if max_abs == 0.0 {
            return 1.0;
        }
        let max_exp = max_abs.log2().ceil();
        ((1u64 << nb) - 1) as f64 / (n as f64 * max_exp.exp2())
    }

    /// Per-worker value budget the profiled alpha guarantees:
    /// |alpha * g| <= (2^nb - 1)/n, plus 1 for round-to-nearest slack —
    /// the bound that sizes the wire lane.
    pub fn lane_bound(nb: u32, n: usize) -> i64 {
        (((1u64 << nb) - 1) / n as u64 + 1) as i64
    }
}

/// The SwitchML round: deterministic round-to-nearest in f64, per block.
/// The profiled alpha bounds every value by the lane budget
/// ([`HeuristicIntSgd::lane_bound`]), so the lane cast is
/// value-preserving — one generic body instead of a copy per lane width.
fn scaled_round_blocks<T: WireLane>(
    blocks: &[BlockSpan],
    alphas: &[f64],
    grad: &[f32],
    out: &mut Vec<T>,
) {
    out.reserve(grad.len());
    for (span, &alpha) in blocks.iter().zip(alphas) {
        out.extend(
            grad[span.range()]
                .iter()
                .map(|&x| T::of_f64((x as f64 * alpha).round())),
        );
    }
}

/// SwitchML ranks are stateless: profile, then round deterministically.
struct HeuristicEncoder {
    msg: Message,
}

impl RankEncoder for HeuristicEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Profile { blocks } => {
                let out = self.msg.scalars_mut();
                out.clear();
                out.extend(blocks.iter().map(|span| linf_norm(&grad[span.range()])));
            }
            PassPlan::ScaledRound { blocks, alphas, lanes } => {
                let out = self.msg.ints_mut(*lanes);
                match out {
                    IntVec::I8(v) => scaled_round_blocks(blocks, alphas, grad, v),
                    IntVec::I32(v) => scaled_round_blocks(blocks, alphas, grad, v),
                    IntVec::I64(v) => scaled_round_blocks(blocks, alphas, grad, v),
                }
            }
            _ => panic!("HeuristicIntSgd encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }
}

impl PhasedCompressor for HeuristicIntSgd {
    fn name(&self) -> String {
        format!("heuristic_intsgd_{}bit", self.nb)
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn make_encoder(&mut self, _rank: usize) -> Box<dyn RankEncoder> {
        Box::new(HeuristicEncoder { msg: Message::Empty })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        let blocks = Arc::make_mut(&mut self.blocks);
        spans_from_ctx_into(ctx, blocks);
        PassPlan::Profile { blocks: Arc::clone(&self.blocks) }
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        plan: &PassPlan,
        _ctx: &RoundCtx,
        red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        Ok(match plan {
            PassPlan::Profile { .. } => {
                let n = msgs.len();
                let alphas = Arc::make_mut(&mut self.alphas);
                alphas.clear();
                for b in 0..self.blocks.len() {
                    let max_abs = msgs
                        .iter()
                        .map(|m| m.as_scalars()[b])
                        .fold(0.0f32, f32::max) as f64;
                    alphas.push(Self::alpha_for_max(self.nb, n, max_abs));
                }
                PassOutcome::Next(PassPlan::ScaledRound {
                    blocks: Arc::clone(&self.blocks),
                    alphas: Arc::clone(&self.alphas),
                    lanes: Lanes::for_bound(Self::lane_bound(self.nb, n)),
                })
            }
            PassPlan::ScaledRound { .. } => {
                red.sum_ints(msgs, &mut self.sum)?;
                self.max_abs_int = crate::simd::max_abs_i64(&self.sum);
                PassOutcome::Done
            }
            _ => unreachable!("HeuristicIntSgd planned no such pass"),
        })
    }

    fn decode(&mut self, ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        decode_block_ints(&self.sum, &self.blocks, &self.alphas, ctx.n, &mut gtilde);
        let mut comm = arena.take_comm();
        comm.push(CommOp {
            primitive: Primitive::Switch,
            bytes_per_worker: self.d * (self.nb as usize).div_ceil(8),
        });
        // the profiling collective: one fp32 max per block
        comm.push(CommOp {
            primitive: Primitive::AllReduce,
            bytes_per_worker: 4 * self.blocks.len(),
        });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: self.max_abs_int,
            alpha: self.alphas.iter().copied().fold(f64::INFINITY, f64::min),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::DistributedCompressor;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn aggregate_never_overflows_wire() {
        // by construction |alpha * g| <= (2^nb - 1)/n, so |sum| <= 2^nb - 1
        let mut rng = Rng::new(0);
        let n = 16;
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(1000, 3.0)).collect();
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &ctx(1000, n));
        assert!(r.max_abs_int <= 255 + n as i64); // rounding slack of <= 1/worker
    }

    #[test]
    fn lane_bound_covers_rule_budget() {
        // nb=8, n=1: values reach 255 -> needs i32 lanes; n=4 -> 64 fits i8
        assert_eq!(Lanes::for_bound(HeuristicIntSgd::lane_bound(8, 1)), Lanes::I32);
        assert_eq!(Lanes::for_bound(HeuristicIntSgd::lane_bound(8, 4)), Lanes::I8);
        // nb=32, n=1: budget 2^32 - 1 -> i64 escape hatch
        assert_eq!(Lanes::for_bound(HeuristicIntSgd::lane_bound(32, 1)), Lanes::I64);
        assert_eq!(Lanes::for_bound(HeuristicIntSgd::lane_bound(32, 4)), Lanes::I32);
    }

    #[test]
    fn low_bits_crush_small_gradients() {
        // One huge coordinate forces a tiny alpha; small coords round to 0.
        let mut g = vec![1e-3f32; 100];
        g[0] = 1000.0;
        let grads = vec![g; 4];
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &ctx(100, 4));
        // everything but coordinate 0 got zeroed — the Fig. 1 failure mode
        assert!(r.gtilde[1..].iter().all(|&x| x == 0.0));
        assert!(r.gtilde[0] > 0.0);
    }

    #[test]
    fn high_bits_preserve_small_gradients() {
        let mut g = vec![1e-3f32; 100];
        g[0] = 1000.0;
        let grads = vec![g; 4];
        let mut c = HeuristicIntSgd::new(32);
        let r = c.round(&grads, &ctx(100, 4));
        for &x in &r.gtilde[1..] {
            assert!((x - 1e-3).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn zero_gradient_safe() {
        let grads = vec![vec![0.0f32; 10]; 3];
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &ctx(10, 3));
        assert!(r.gtilde.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn per_block_profiling_isolates_outlier_layers() {
        // With the outlier in its own block, the other block keeps full
        // resolution — the improvement over whole-tensor SwitchML.
        use crate::coordinator::BlockInfo;
        let mut g = vec![1e-3f32; 100];
        g[0] = 1000.0;
        let grads = vec![g; 4];
        let cx = RoundCtx {
            round: 1,
            n: 4,
            d: 100,
            lr: 0.1,
            step_norm_sq: 0.0,
            blocks: vec![
                BlockInfo { dim: 10, step_norm_sq: 0.0 },
                BlockInfo { dim: 90, step_norm_sq: 0.0 },
            ],
        };
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &cx);
        // coords 10.. live in the outlier-free block and survive
        for &x in &r.gtilde[10..] {
            assert!(x > 0.0, "small gradient crushed despite block profiling");
        }
        // coords 1..10 share the outlier's block and are crushed
        assert!(r.gtilde[1..10].iter().all(|&x| x == 0.0));
    }
}
