//! Heuristic IntSGD: the SwitchML scaling rule of Sapio et al. (2021),
//! the paper's primary point of comparison (§5.2 / Fig. 1).
//!
//! The scale is set by a profiling pass over the outgoing package:
//!
//!   alpha = (2^nb - 1) / (n * 2^max_exp)
//!
//! where `nb` is the wire bit width and `max_exp` the rounded-up exponent
//! of the largest |value| observed. This provably avoids overflow but has
//! no convergence guarantee: when a few large coordinates dominate, the
//! effective resolution (2^nb-1)/2^max_exp crushes small gradients to
//! zero — which is exactly the failure Fig. 1 shows for the 8-bit wire.

use std::time::Instant;

use crate::collective::allreduce_i64;
use crate::coordinator::RoundCtx;
use crate::util::stats::linf_norm;

use super::{CommOp, DistributedCompressor, Primitive, RoundResult};

pub struct HeuristicIntSgd {
    /// Wire bits per coordinate (8 or 32 in the paper).
    pub nb: u32,
    ints: Vec<Vec<i64>>,
    sum: Vec<i64>,
}

impl HeuristicIntSgd {
    pub fn new(nb: u32) -> Self {
        assert!((2..=32).contains(&nb));
        HeuristicIntSgd { nb, ints: Vec::new(), sum: Vec::new() }
    }

    /// The SwitchML profiling step: alpha from the global max exponent.
    pub fn profile_alpha(&self, grads: &[Vec<f32>]) -> f64 {
        let n = grads.len() as f64;
        let max_abs = grads
            .iter()
            .map(|g| linf_norm(g))
            .fold(0.0f32, f32::max) as f64;
        if max_abs == 0.0 {
            return 1.0;
        }
        let max_exp = max_abs.log2().ceil();
        ((1u64 << self.nb) - 1) as f64 / (n * max_exp.exp2())
    }
}

impl DistributedCompressor for HeuristicIntSgd {
    fn name(&self) -> String {
        format!("heuristic_intsgd_{}bit", self.nb)
    }

    fn supports_allreduce(&self) -> bool {
        true
    }

    fn round(&mut self, grads: &[Vec<f32>], _ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();
        let t0 = Instant::now();
        let alpha = self.profile_alpha(grads);
        if self.ints.len() != n {
            self.ints = vec![Vec::new(); n];
        }
        for (buf, g) in self.ints.iter_mut().zip(grads) {
            buf.clear();
            // SwitchML rounds deterministically (round-to-nearest).
            buf.extend(g.iter().map(|&x| (x as f64 * alpha).round() as i64));
        }
        // per-worker overhead: the n encodes run in parallel in reality
        let encode_seconds = t0.elapsed().as_secs_f64() / n as f64;

        let views: Vec<&[i64]> = self.ints.iter().map(|v| v.as_slice()).collect();
        allreduce_i64(&views, &mut self.sum);
        let max_abs_int = self.sum.iter().map(|&x| x.abs()).max().unwrap_or(0);

        let t1 = Instant::now();
        let inv = 1.0 / (n as f64 * alpha);
        let gtilde = self.sum.iter().map(|&s| (s as f64 * inv) as f32).collect();
        let decode_seconds = t1.elapsed().as_secs_f64();

        RoundResult {
            gtilde,
            comm: vec![CommOp {
                primitive: Primitive::Switch,
                bytes_per_worker: d * (self.nb as usize).div_ceil(8),
            }],
            encode_seconds,
            decode_seconds,
            max_abs_int,
            alpha,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundCtx;
    use crate::util::Rng;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn aggregate_never_overflows_wire() {
        // by construction |alpha * g| <= (2^nb - 1)/n, so |sum| <= 2^nb - 1
        let mut rng = Rng::new(0);
        let n = 16;
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(1000, 3.0)).collect();
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &ctx(1000, n));
        assert!(r.max_abs_int <= 255 + n as i64); // rounding slack of <= 1/worker
    }

    #[test]
    fn low_bits_crush_small_gradients() {
        // One huge coordinate forces a tiny alpha; small coords round to 0.
        let mut g = vec![1e-3f32; 100];
        g[0] = 1000.0;
        let grads = vec![g; 4];
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &ctx(100, 4));
        // everything but coordinate 0 got zeroed — the Fig. 1 failure mode
        assert!(r.gtilde[1..].iter().all(|&x| x == 0.0));
        assert!(r.gtilde[0] > 0.0);
    }

    #[test]
    fn high_bits_preserve_small_gradients() {
        let mut g = vec![1e-3f32; 100];
        g[0] = 1000.0;
        let grads = vec![g; 4];
        let mut c = HeuristicIntSgd::new(32);
        let r = c.round(&grads, &ctx(100, 4));
        for &x in &r.gtilde[1..] {
            assert!((x - 1e-3).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn zero_gradient_safe() {
        let grads = vec![vec![0.0f32; 10]; 3];
        let mut c = HeuristicIntSgd::new(8);
        let r = c.round(&grads, &ctx(10, 3));
        assert!(r.gtilde.iter().all(|&x| x == 0.0));
    }
}
