//! Phase-based compression engine: per-rank **encode**, leader-side
//! **reduce**, leader-side **decode**.
//!
//! The monolithic `round(&[Vec<f32>])` entry point hid a real systems
//! property: every rank's encode is independent and runs concurrently on a
//! real cluster, while the reduction is the collective's job and the decode
//! is cheap leader/edge work. This module makes that split explicit:
//!
//! - [`RankEncoder`] — one rank's encode state (its RNG stream,
//!   error-feedback memory, PowerSGD scratch). `encode` is pure with
//!   respect to the other ranks, so encoders can hop to worker threads
//!   (`Send`), and their finished messages can be read by several reduce
//!   workers at once (`Sync`).
//! - [`PhasedCompressor`] — the leader half: it plans each pass
//!   ([`PassPlan`], shared read-only with all ranks), folds the rank
//!   messages ([`PhasedCompressor::reduce`], which may request further
//!   passes — PowerSGD needs three), and decodes the final estimate.
//! - [`RoundEngine`] — the driver. [`RoundEngine::round_parallel`] runs
//!   each rank's encode inside its `WorkerPool` thread and hands integer
//!   reductions to the pool's coordinate-chunked fold;
//!   [`RoundEngine::round_sequential`] runs the same phases inline on the
//!   caller thread (the parity reference, also what the old
//!   `DistributedCompressor::round` shape adapts to).
//!
//! **Zero-allocation hot path.** Three pieces keep steady-state rounds off
//! the allocator (pinned by `tests/zero_alloc.rs`):
//!
//! - integer payloads live in typed, reused [`IntVec`] buffers
//!   (`compress::intvec`) instead of fresh `Vec<i64>`s;
//! - pass plans share their geometry (`Arc<Vec<BlockSpan>>`,
//!   `Arc<Vec<f64>>`) with the leader state, rebuilt in place via
//!   `Arc::make_mut` once the previous round's plan is gone;
//! - [`RoundArena`] recycles the round outputs (`gtilde`, the comm
//!   schedule) that `RoundResult` moves out to the caller — callers hand
//!   them back via [`RoundEngine::reclaim`].
//!
//! Reduction order: [`Reducer::sum_ints`] folds every coordinate over the
//! ranks in rank order, whether it runs serially ([`SerialReducer`]) or
//! chunked across the worker pool ([`PoolReducer`]) — integer addition is
//! exactly associative, so the two are bit-identical
//! (`tests/engine_parity.rs` pins this for the whole zoo; fp32 folds keep
//! their fixed pairwise order and never go through a parallel reducer).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::worker::WorkerPool;
use crate::coordinator::RoundCtx;
use crate::net::NetError;
use crate::telemetry::journal::{self, Phase, ALL};

use super::intsgd::Rounding;
use super::intvec::{BlockSlots, IntVec, Lanes};
use super::natsgd::NatMsg;
use super::qsgd::QsgdBucket;
use super::signsgd::SignMsg;
use super::{CommOp, DistributedCompressor, RoundResult};

/// One contiguous parameter block of the flattened gradient.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpan {
    pub offset: usize,
    pub dim: usize,
}

impl BlockSpan {
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.dim
    }
}

/// Block geometry for a round, written into a reused buffer: the ctx
/// blocks when given, otherwise one span covering the whole gradient.
pub fn spans_from_ctx_into(ctx: &RoundCtx, out: &mut Vec<BlockSpan>) {
    out.clear();
    if ctx.blocks.is_empty() {
        out.push(BlockSpan { offset: 0, dim: ctx.d });
        return;
    }
    let mut offset = 0;
    for b in &ctx.blocks {
        out.push(BlockSpan { offset, dim: b.dim });
        offset += b.dim;
    }
    assert_eq!(offset, ctx.d, "blocks must tile the gradient");
}

/// Allocating convenience wrapper around [`spans_from_ctx_into`].
pub fn spans_from_ctx(ctx: &RoundCtx) -> Vec<BlockSpan> {
    let mut out = Vec::with_capacity(ctx.blocks.len().max(1));
    spans_from_ctx_into(ctx, &mut out);
    out
}

/// The immutable instruction the leader broadcasts for one encode pass.
/// Shared read-only with every rank's encoder; block geometry and alphas
/// are `Arc`-shared with the leader state, so a plan costs pointer copies,
/// not per-round buffer clones.
#[derive(Clone, Debug)]
pub enum PassPlan {
    /// Ship the raw fp32 gradient (identity SGD; IntSGD's exact round 0).
    Dense,
    /// Nothing shared is needed (EF-sign, top-k, natural compression).
    Plain,
    /// IntSGD: per-block integer rounding at the given alphas, clipped so
    /// the aggregate provably fits the wire type. `lanes` is the storage
    /// width implied by the clip — every clipped value fits it. `round`
    /// keys the stochastic-rounding draw: a failover re-encode of the
    /// same round reuses the rank's counter base, so the re-run is
    /// bit-identical to a fresh run that encoded the round once.
    IntBlocks {
        rounding: Rounding,
        blocks: Arc<Vec<BlockSpan>>,
        alphas: Arc<Vec<f64>>,
        clip: i64,
        lanes: Lanes,
        round: usize,
    },
    /// Heuristic IntSGD pass 1: report per-block max |g| for profiling.
    Profile { blocks: Arc<Vec<BlockSpan>> },
    /// Heuristic IntSGD pass 2: per-block f64 scale-and-round (the
    /// SwitchML rule has no clipping; the profiled alpha bounds every
    /// value by construction, which is what sizes `lanes`).
    ScaledRound {
        blocks: Arc<Vec<BlockSpan>>,
        alphas: Arc<Vec<f64>>,
        lanes: Lanes,
    },
    /// QSGD: stochastic level quantization per bucket.
    Buckets { spans: Vec<BlockSpan>, levels: u16 },
    /// PowerSGD pass 1: P_i = M_i Q per matrix block (+ raw vector
    /// blocks). Factor sets are `Arc`-shared with the leader state — a
    /// plan costs a pointer copy, not a per-round deep clone.
    PowerP { qs: Arc<Vec<Vec<f32>>> },
    /// PowerSGD pass 2: Q_i = M_i^T P_hat per matrix block.
    PowerQ { ps: Arc<Vec<Vec<f32>>> },
    /// PowerSGD pass 3: update EF memory from the decoded factors (every
    /// rank holds P_hat and Q_hat after the all-reduces and reconstructs
    /// the approximation locally).
    PowerEf { ps: Arc<Vec<Vec<f32>>>, qs: Arc<Vec<Vec<f32>>> },
}

/// A rank's encoded payload for one pass.
#[derive(Clone, Debug)]
pub enum Message {
    Empty,
    Dense(Vec<f32>),
    Ints(IntVec),
    Scalars(Vec<f32>),
    Buckets(Vec<QsgdBucket>),
    Sign(SignMsg),
    Nat(NatMsg),
    Sparse(Vec<(u32, f32)>),
}

impl Message {
    /// Reusable dense slot (keeps capacity across rounds).
    pub fn dense_mut(&mut self) -> &mut Vec<f32> {
        if !matches!(self, Message::Dense(_)) {
            *self = Message::Dense(Vec::new()); // intlint: allow(R2, reason="slot (re)shape on variant switch; steady state reuses the buffer")
        }
        match self {
            Message::Dense(v) => v,
            _ => unreachable!(),
        }
    }

    /// Reusable integer slot at the given lane width, emptied and ready
    /// to fill (the buffer survives across rounds at a fixed width).
    pub fn ints_mut(&mut self, lanes: Lanes) -> &mut IntVec {
        if !matches!(self, Message::Ints(_)) {
            *self = Message::Ints(IntVec::new(lanes));
        }
        match self {
            Message::Ints(v) => {
                v.reset(lanes);
                v
            }
            _ => unreachable!(),
        }
    }

    pub fn scalars_mut(&mut self) -> &mut Vec<f32> {
        if !matches!(self, Message::Scalars(_)) {
            *self = Message::Scalars(Vec::new()); // intlint: allow(R2, reason="slot (re)shape on variant switch; steady state reuses the buffer")
        }
        match self {
            Message::Scalars(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn buckets_mut(&mut self) -> &mut Vec<QsgdBucket> {
        if !matches!(self, Message::Buckets(_)) {
            *self = Message::Buckets(Vec::new()); // intlint: allow(R2, reason="slot (re)shape on variant switch; steady state reuses the buffer")
        }
        match self {
            Message::Buckets(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn sparse_mut(&mut self) -> &mut Vec<(u32, f32)> {
        if !matches!(self, Message::Sparse(_)) {
            *self = Message::Sparse(Vec::new()); // intlint: allow(R2, reason="slot (re)shape on variant switch; steady state reuses the buffer")
        }
        match self {
            Message::Sparse(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn as_dense(&self) -> &[f32] {
        match self {
            Message::Dense(v) => v,
            _ => panic!("expected dense message"),
        }
    }

    pub fn as_ints(&self) -> &IntVec {
        match self {
            Message::Ints(v) => v,
            _ => panic!("expected integer message"),
        }
    }

    pub fn as_scalars(&self) -> &[f32] {
        match self {
            Message::Scalars(v) => v,
            _ => panic!("expected scalar message"),
        }
    }

    pub fn as_buckets(&self) -> &[QsgdBucket] {
        match self {
            Message::Buckets(v) => v,
            _ => panic!("expected bucket message"),
        }
    }

    pub fn as_sign(&self) -> &SignMsg {
        match self {
            Message::Sign(m) => m,
            _ => panic!("expected sign message"),
        }
    }

    pub fn as_nat(&self) -> &NatMsg {
        match self {
            Message::Nat(m) => m,
            _ => panic!("expected natural-compression message"),
        }
    }

    pub fn as_sparse(&self) -> &[(u32, f32)] {
        match self {
            Message::Sparse(v) => v,
            _ => panic!("expected sparse message"),
        }
    }
}

/// One rank's encode state. `Send` so the engine can run it on the rank's
/// worker thread, `Sync` so several reduce workers can read its finished
/// message concurrently; all buffers are owned and reused across rounds.
pub trait RankEncoder: Send + Sync {
    /// Run one encode pass over this rank's gradient. The result stays
    /// readable via [`RankEncoder::message`] until the next call.
    fn encode(&mut self, grad: &[f32], plan: &PassPlan);

    /// The payload produced by the last `encode` call.
    fn message(&self) -> &Message;

    /// Error-feedback residual memory, if this encoder carries one
    /// (checkpoint v2 persists it — dropping the residual silently breaks
    /// the EF convergence argument on resume). EF encoders return
    /// `Some(&[])` before their first round.
    fn ef_memory(&self) -> Option<&[f32]> {
        None
    }

    /// Restore the error-feedback residual (checkpoint resume). Returns
    /// whether this encoder accepted it.
    fn set_ef_memory(&mut self, _mem: &[f32]) -> bool {
        false
    }

    /// This rank's RNG stream state (stochastic encoders), for bit-exact
    /// resume.
    fn rng_state(&self) -> Option<[u64; 6]> {
        None
    }

    /// Restore this rank's RNG stream. Returns whether accepted.
    fn set_rng_state(&mut self, _state: [u64; 6]) -> bool {
        false
    }

    /// Encode only block `block` of the plan into `out` — the streamed
    /// driver's per-block fill. Must write exactly the lanes a whole-plan
    /// [`RankEncoder::encode`] writes for that block's span (IntSGD keys
    /// its stochastic draws by absolute coordinate, so this holds by
    /// construction), and must consume the SAME per-round RNG amount as
    /// one whole-plan encode in total. Returns `false` when unsupported
    /// (the default) — the engine then keeps the round on the barrier
    /// path.
    fn encode_block(
        &mut self,
        _grad: &[f32],
        _plan: &PassPlan,
        _block: usize,
        _out: &mut IntVec,
    ) -> bool {
        false
    }
}

/// What a [`RankMessages`] view reads through: the parked encoders (every
/// barrier pass), or a bare per-rank `IntVec` slice (the streamed driver's
/// per-block collectives, where the payloads live in block slots instead
/// of encoder messages).
#[derive(Clone, Copy)]
enum MsgBacking<'a> {
    Encoders(&'a [Box<dyn RankEncoder>]),
    Ints(&'a [IntVec]),
}

/// The n rank messages of one pass, viewed straight through the parked
/// encoders — no per-pass `Vec<&Message>` (or `Vec<&[i64]>`) is ever
/// materialized.
#[derive(Clone, Copy)]
pub struct RankMessages<'a> {
    back: MsgBacking<'a>,
}

impl<'a> RankMessages<'a> {
    pub fn new(encs: &'a [Box<dyn RankEncoder>]) -> Self {
        RankMessages { back: MsgBacking::Encoders(encs) }
    }

    /// A view over bare per-rank integer buffers — one pipelined block of
    /// the streamed driver. Only the integer accessors ([`Self::ints`],
    /// [`Self::iter_ints`]) are valid on this backing, which is exactly
    /// what every [`Reducer`] reads.
    pub fn from_ints(ints: &'a [IntVec]) -> Self {
        RankMessages { back: MsgBacking::Ints(ints) }
    }

    pub fn len(&self) -> usize {
        match self.back {
            MsgBacking::Encoders(e) => e.len(),
            MsgBacking::Ints(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, rank: usize) -> &'a Message {
        match self.back {
            MsgBacking::Encoders(e) => e[rank].message(),
            MsgBacking::Ints(_) => {
                panic!("per-block integer views carry no full rank messages")
            }
        }
    }

    /// Rank `rank`'s integer payload — valid on both backings, and the
    /// accessor every integer reducer goes through.
    pub fn ints(&self, rank: usize) -> &'a IntVec {
        match self.back {
            MsgBacking::Encoders(e) => e[rank].message().as_ints(),
            MsgBacking::Ints(v) => &v[rank],
        }
    }

    /// Messages in rank order (Clone so multi-sweep folds can re-iterate).
    pub fn iter(&self) -> impl Iterator<Item = &'a Message> + Clone {
        let this = *self;
        (0..this.len()).map(move |rank| this.get(rank))
    }

    /// Integer payloads in rank order (both backings).
    pub fn iter_ints(&self) -> impl Iterator<Item = &'a IntVec> + Clone {
        let this = *self;
        (0..this.len()).map(move |rank| this.ints(rank))
    }

    /// The raw encoder slice (the pool's chunked reduce reads messages on
    /// its worker threads through this).
    pub fn encoders(&self) -> &'a [Box<dyn RankEncoder>] {
        match self.back {
            MsgBacking::Encoders(e) => e,
            MsgBacking::Ints(_) => {
                panic!("per-block integer views carry no encoders")
            }
        }
    }
}

/// Strategy for the integer-sum reduction. Every implementation produces
/// the rank-order fold bit for bit: per coordinate the ranks are always
/// added in order, and integer addition is exactly associative, so
/// coordinate-chunking across threads cannot change a single bit.
///
/// In-process reducers are infallible (they fold leader-owned slices); a
/// transport-backed reducer (`net::TransportReducer`) retries recoverable
/// faults internally and surfaces only what retry cannot fix — above all
/// [`NetError::PeerDead`], which the `Coordinator` answers by shrinking
/// the world ([`Reducer::remove_rank`]) and re-running the round.
pub trait Reducer {
    /// out[j] = sum over ranks of msgs[rank].ints[j], out resized to the
    /// message length.
    fn sum_ints(&mut self, msgs: &RankMessages, out: &mut Vec<i64>) -> Result<(), NetError>;

    /// Drop a permanently failed rank from the reduction world (failover).
    /// In-process reducers fold whatever messages they are handed, so the
    /// default is a no-op; transport reducers re-key their endpoints.
    fn remove_rank(&mut self, _rank: usize) {}

    /// Announce the pipeline block index of the next [`Reducer::sum_ints`]
    /// call (the streamed driver stamps each per-block collective so the
    /// frame guard can reject cross-block frames). In-process reducers
    /// fold leader-owned slices and need no stamp — the default is a
    /// no-op; transport reducers thread it into their frame headers.
    fn begin_block(&mut self, _block: usize) {}

    /// Read-and-reset the (measured wire seconds, retried attempts) spent
    /// since the last call, for reducers that move real bytes. `None` for
    /// in-process folds — the caller then reports the modeled comm cost
    /// instead (`Coordinator::run_round`'s observer breakdown).
    fn take_wire_measure(&mut self) -> Option<(f64, u64)> {
        None
    }
}

/// Rank-order fold on the calling thread (the parity reference). The fold
/// body lives in `collective::allreduce_intvec_iter`, shared with the
/// collective benchmarks so they measure the production kernel.
pub struct SerialReducer;

impl Reducer for SerialReducer {
    fn sum_ints(&mut self, msgs: &RankMessages, out: &mut Vec<i64>) -> Result<(), NetError> {
        assert!(!msgs.is_empty(), "at least one rank message");
        crate::collective::allreduce_intvec_iter(msgs.iter_ints(), out);
        Ok(())
    }
}

/// A [`Reducer`] whose "sum" was already computed — the streamed driver
/// assembles the round aggregate block by block over the wire, then runs
/// the compressor's normal `reduce` bookkeeping (max-int tracking, comm
/// accounting) against this, so the leader-side state ends bit-identical
/// to a barrier round without folding anything twice.
struct PrecomputedReducer<'a> {
    sum: &'a [i64],
}

impl Reducer for PrecomputedReducer<'_> {
    fn sum_ints(&mut self, _msgs: &RankMessages, out: &mut Vec<i64>) -> Result<(), NetError> {
        out.clear();
        out.extend_from_slice(self.sum);
        Ok(())
    }
}

/// Coordinate-chunked fold across the worker pool's threads: worker w
/// sums all ranks (in rank order) over its contiguous coordinate chunk.
pub struct PoolReducer<'a> {
    pool: &'a mut WorkerPool,
}

impl<'a> PoolReducer<'a> {
    pub fn new(pool: &'a mut WorkerPool) -> Self {
        PoolReducer { pool }
    }
}

impl Reducer for PoolReducer<'_> {
    fn sum_ints(&mut self, msgs: &RankMessages, out: &mut Vec<i64>) -> Result<(), NetError> {
        let d = prepare_sum(msgs, out);
        self.pool.sum_ints_round(msgs.encoders(), &mut out[..d]);
        Ok(())
    }
}

/// Precondition of the chunked reducer: consistent message lengths, `out`
/// zeroed to the message length (capacity reused across rounds) before
/// the disjoint chunks fan out.
fn prepare_sum(msgs: &RankMessages, out: &mut Vec<i64>) -> usize {
    assert!(!msgs.is_empty(), "at least one rank message");
    let d = msgs.ints(0).len();
    for m in msgs.iter_ints() {
        assert_eq!(m.len(), d, "mismatched message lengths");
    }
    out.clear();
    out.resize(d, 0);
    d
}

/// Recycled round outputs. `RoundResult` moves `gtilde` and the comm
/// schedule out to the caller each round; the arena takes them back
/// ([`RoundArena::reclaim`]) so steady-state rounds never touch the
/// allocator. Compressors draw their output buffers from here in `decode`.
#[derive(Default)]
pub struct RoundArena {
    f32_bufs: Vec<Vec<f32>>,
    comm_bufs: Vec<Vec<CommOp>>,
}

/// Cap on pooled buffers per kind — one round produces one of each, so
/// anything beyond a small margin is a caller that never reclaims.
const ARENA_POOL_CAP: usize = 8;

impl RoundArena {
    /// An empty (cleared) f32 buffer, with capacity when one was
    /// reclaimed.
    pub fn take_f32(&mut self) -> Vec<f32> {
        let mut v = self.f32_bufs.pop().unwrap_or_default();
        v.clear();
        v
    }

    pub fn put_f32(&mut self, v: Vec<f32>) {
        if self.f32_bufs.len() < ARENA_POOL_CAP {
            self.f32_bufs.push(v);
        }
    }

    pub fn take_comm(&mut self) -> Vec<CommOp> {
        let mut v = self.comm_bufs.pop().unwrap_or_default();
        v.clear();
        v
    }

    pub fn put_comm(&mut self, v: Vec<CommOp>) {
        if self.comm_bufs.len() < ARENA_POOL_CAP {
            self.comm_bufs.push(v);
        }
    }

    /// Take a finished round's buffers back for reuse.
    pub fn reclaim(&mut self, result: RoundResult) {
        self.put_f32(result.gtilde);
        self.put_comm(result.comm);
    }
}

/// What the leader does with a pass's messages.
pub enum PassOutcome {
    /// The round's aggregate is complete; `decode` may run.
    Done,
    /// Another encode pass is required (e.g. PowerSGD's Q and EF passes).
    Next(PassPlan),
}

/// The leader half of a compression algorithm, split into phases so the
/// per-rank encode can execute on worker threads.
pub trait PhasedCompressor: Send {
    fn name(&self) -> String;

    /// Whether the messages can be reduced in-flight (paper Table 1).
    fn supports_allreduce(&self) -> bool;

    /// Build rank `rank`'s encoder (called lazily, once per rank).
    fn make_encoder(&mut self, rank: usize) -> Box<dyn RankEncoder>;

    /// Parked per-rank encoders; the engine checks them out per pass.
    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>>;

    /// Plan the round's first encode pass. Must be **idempotent per
    /// `ctx.round`**: a failover re-runs the round at a smaller world, so
    /// `begin` may be called twice for the same round and any per-round
    /// state update (e.g. the alpha rule's moving average) must apply
    /// exactly once (`scaling::AlphaRule` implements this).
    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan;

    /// Fold the n rank messages of one pass — integer sums through the
    /// provided [`Reducer`], everything else in rank order on the caller
    /// thread — either finishing the round or requesting another pass.
    /// Fallible only through the reducer (a transport collective that
    /// could not be retried into success).
    fn reduce(
        &mut self,
        msgs: &RankMessages,
        plan: &PassPlan,
        ctx: &RoundCtx,
        red: &mut dyn Reducer,
    ) -> Result<PassOutcome, NetError>;

    /// Produce the round result from the reduced state, drawing output
    /// buffers from the arena. Timing fields are filled by the driver.
    fn decode(&mut self, ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult;

    /// Whether `reduce` for this plan is expressible as ONE integer sum
    /// over the full coordinate range — the contract the streamed driver
    /// needs to run the collective block by block: a single encode pass,
    /// `reduce` reading the rank messages only through
    /// [`Reducer::sum_ints`] (exactly once, whole range), and a decode
    /// whose per-block body is [`decode_span_ints`]. Default `false`
    /// keeps a compressor on the barrier path.
    fn streams(&self, _plan: &PassPlan) -> bool {
        false
    }

    /// Close a streamed round: build the [`RoundResult`] around `gtilde`,
    /// which the driver already decoded block by block as the aggregates
    /// landed ([`decode_span_ints`] per block — bit-identical to this
    /// compressor's `decode` by the [`PhasedCompressor::streams`]
    /// contract). Only called after `streams` returned `true` for the
    /// round's plan and `reduce` ran over the assembled aggregate.
    fn finish_streamed(
        &mut self,
        _ctx: &RoundCtx,
        _arena: &mut RoundArena,
        _gtilde: Vec<f32>,
    ) -> RoundResult {
        unreachable!("compressor declared streams() but did not implement finish_streamed")
    }

    /// Opaque scaling-rule state for checkpoint v2 (None = no such
    /// state). IntSGD's moving average lives here — dropping it on resume
    /// silently changes the alpha sequence the proof is about.
    fn export_rule_state(&self) -> Option<Vec<f64>> {
        None
    }

    /// Restore scaling-rule state saved by [`Self::export_rule_state`].
    fn import_rule_state(&mut self, _state: &[f64]) -> Result<()> {
        Err(anyhow!("this compressor carries no scaling-rule state"))
    }

    /// Per-rank error-feedback residuals (rank order, EF encoders only).
    fn export_ef(&mut self) -> Vec<Vec<f32>> {
        self.encoders()
            .iter()
            .filter_map(|e| e.ef_memory().map(<[f32]>::to_vec))
            .collect() // intlint: allow(R2, reason="checkpoint export, off the round loop")
    }

    /// Restore per-rank EF residuals (encoders must already be built).
    fn import_ef(&mut self, mems: &[Vec<f32>]) -> Result<()> {
        let mut used = 0usize;
        for enc in self.encoders().iter_mut() {
            if enc.ef_memory().is_some() {
                let mem = mems.get(used).ok_or_else(|| {
                    anyhow!("checkpoint carries {} EF residuals, model wants more", used)
                })?;
                if !enc.set_ef_memory(mem) {
                    return Err(anyhow!("encoder refused its EF residual"));
                }
                used += 1;
            }
        }
        if used != mems.len() {
            return Err(anyhow!(
                "checkpoint carries {} EF residuals, model holds {used}",
                mems.len()
            ));
        }
        Ok(())
    }

    /// Per-rank encoder RNG stream states (rank order, stochastic
    /// encoders only) — what makes a resumed stochastic run bit-exact.
    fn export_rng_streams(&mut self) -> Vec<[u64; 6]> {
        self.encoders().iter().filter_map(|e| e.rng_state()).collect() // intlint: allow(R2, reason="checkpoint export, off the round loop")
    }

    /// Restore per-rank RNG streams (encoders must already be built).
    fn import_rng_streams(&mut self, states: &[[u64; 6]]) -> Result<()> {
        let mut used = 0usize;
        for enc in self.encoders().iter_mut() {
            if enc.rng_state().is_some() {
                let st = states.get(used).ok_or_else(|| {
                    anyhow!("checkpoint carries {} RNG streams, model wants more", used)
                })?;
                if !enc.set_rng_state(*st) {
                    return Err(anyhow!("encoder refused its RNG stream"));
                }
                used += 1;
            }
        }
        if used != states.len() {
            return Err(anyhow!(
                "checkpoint carries {} RNG streams, model holds {used}",
                states.len()
            ));
        }
        Ok(())
    }
}

fn ensure_encoders(comp: &mut dyn PhasedCompressor, n: usize) {
    let have = comp.encoders().len();
    if have == n {
        return;
    }
    assert!(
        have == 0,
        "worker count changed mid-run: {have} encoders, {n} ranks"
    );
    for rank in 0..n {
        let enc = comp.make_encoder(rank);
        comp.encoders().push(enc);
    }
}

/// Sum dense rank messages elementwise into `out` and divide by n — the
/// shared fold for every "average the fp32 payloads" reduction (identity
/// all-gather, IntSGD's exact round 0, PowerSGD's factor means). Folds in
/// rank order, which the parity guarantee depends on.
pub(crate) fn mean_dense_into(msgs: &RankMessages, out: &mut Vec<f32>) {
    let n = msgs.len();
    assert!(n > 0);
    let len = msgs.get(0).as_dense().len();
    out.clear();
    out.resize(len, 0.0);
    for m in msgs.iter() {
        let v = m.as_dense();
        assert_eq!(v.len(), len, "rank messages disagree on length");
        for (o, &x) in out.iter_mut().zip(v) {
            *o += x;
        }
    }
    let inv = 1.0 / n as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// g_tilde = sum / (n * alpha_l), block by block, into a reused buffer —
/// the Alg. 2 decode, shared by IntSGD and Heuristic IntSGD so the two
/// cannot drift.
pub(crate) fn decode_block_ints(
    sum: &[i64],
    blocks: &[BlockSpan],
    alphas: &[f64],
    n: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(sum.len());
    for (span, &alpha) in blocks.iter().zip(alphas) {
        decode_span_ints(&sum[span.range()], alpha, n, out);
    }
}

/// One block of the Alg. 2 decode: append `sum / (n * alpha)` to `out`.
/// Shared between the whole-round decode above and the streamed driver's
/// per-block drain, so the two cannot drift (bit-parity by construction).
/// The int→f32 scale runs through the dispatched decode kernel.
pub(crate) fn decode_span_ints(sum: &[i64], alpha: f64, n: usize, out: &mut Vec<f32>) {
    let inv = 1.0 / (n as f64 * alpha);
    let start = out.len();
    out.resize(start + sum.len(), 0.0);
    crate::simd::decode_scale_i64(sum, inv, &mut out[start..]);
}

/// Drive one round with every phase on the caller thread — the sequential
/// reference path. Encode cost is reported as the per-worker share
/// (total / n), mirroring what the old monolithic `round` estimated.
///
/// Timing policy (both drivers): the reduce fold is charged as decode
/// time only for all-gather algorithms, where it IS the per-worker edge
/// decode; for all-reduce/INA algorithms the in-process fold stands in
/// for the network data plane, whose cost is modeled by `netsim` —
/// timing it there would double-count against the comm model. The raw
/// fold wallclock is always reported separately as
/// `RoundResult::reduce_seconds` for the per-phase benchmarks.
pub fn sequential_round(
    comp: &mut dyn PhasedCompressor,
    grads: &[Vec<f32>],
    ctx: &RoundCtx,
    arena: &mut RoundArena,
) -> RoundResult {
    let n = grads.len();
    assert!(n > 0, "at least one rank");
    assert_eq!(n, ctx.n, "ctx.n must match the gradient count (decode scales by it)");
    ensure_encoders(comp, n);
    let edge_decode = !comp.supports_allreduce();
    let mut plan = comp.begin(ctx);
    let mut encode_total = 0.0f64;
    let mut reduce_total = 0.0f64;
    let mut leader_seconds = 0.0f64;
    let round = ctx.round as u32;
    loop {
        let mut encs = std::mem::take(comp.encoders());
        let span_t = journal::start();
        // Telemetry timing: phase-seconds probe (clippy.toml).
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        for (enc, grad) in encs.iter_mut().zip(grads) {
            enc.encode(grad, &plan);
        }
        // Dense passes stage the raw fp32 buffer for the data plane — a
        // real deployment hands the gradient pointer straight to the
        // collective, so the staging copy is not compression overhead.
        if !matches!(plan, PassPlan::Dense) {
            encode_total += t0.elapsed().as_secs_f64();
            journal::record(Phase::Encode, round, ALL, ALL, span_t);
        }
        let outcome = {
            let msgs = RankMessages::new(&encs);
            let span_t = journal::start();
            // Telemetry timing: phase-seconds probe (clippy.toml).
            #[allow(clippy::disallowed_methods)]
            let t1 = Instant::now();
            let outcome = comp.reduce(&msgs, &plan, ctx, &mut SerialReducer);
            let dt = t1.elapsed().as_secs_f64();
            journal::record(Phase::Reduce, round, ALL, ALL, span_t);
            reduce_total += dt;
            if edge_decode {
                leader_seconds += dt;
            }
            outcome
        };
        *comp.encoders() = encs;
        match outcome.expect("the serial in-process reduce cannot fail") {
            PassOutcome::Done => break,
            PassOutcome::Next(next) => plan = next,
        }
    }
    let span_t = journal::start();
    // Telemetry timing: phase-seconds probe (clippy.toml).
    #[allow(clippy::disallowed_methods)]
    let t2 = Instant::now();
    let mut result = comp.decode(ctx, arena);
    leader_seconds += t2.elapsed().as_secs_f64();
    journal::record(Phase::Decode, round, ALL, ALL, span_t);
    result.encode_seconds = encode_total / n as f64;
    result.reduce_seconds = reduce_total;
    result.decode_seconds = leader_seconds;
    result
}

/// Every phased compressor is also usable through the old call shape; the
/// adapter runs the sequential driver with a throwaway arena, so existing
/// call sites and the parity tests keep working unchanged.
impl<T: PhasedCompressor> DistributedCompressor for T {
    fn name(&self) -> String {
        PhasedCompressor::name(self)
    }

    fn supports_allreduce(&self) -> bool {
        PhasedCompressor::supports_allreduce(self)
    }

    fn round(&mut self, grads: &[Vec<f32>], ctx: &RoundCtx) -> RoundResult {
        let mut arena = RoundArena::default();
        sequential_round(self, grads, ctx, &mut arena)
    }
}

/// Where the parallel driver sends integer reductions: the pool's
/// coordinate-chunked fold (the in-process default) or an external
/// reducer (a transport running staged collectives). Either way the
/// result is the rank-order fold bit for bit — the `Reducer` contract.
enum ReduceVia<'a> {
    Pool,
    External(&'a mut dyn Reducer),
}

/// Which round driver a session runs: the classic three-barrier path, or
/// the double-buffered block pipeline ([`RoundEngine::round_streamed_over`]
/// — bit-identical output, overlapped encode/wire/decode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pipeline {
    Barrier,
    Streamed,
}

impl Default for Pipeline {
    fn default() -> Self {
        Pipeline::Barrier
    }
}

/// The streamed driver's reused leader-side buffers: the double-buffered
/// per-rank block slots and the (block, whole-round) aggregate scratch.
/// All of it survives across rounds, so streamed steady state allocates
/// nothing (`tests/zero_alloc.rs`).
#[derive(Default)]
struct StreamScratch {
    slots: BlockSlots,
    block_sum: Vec<i64>,
    sum: Vec<i64>,
}

/// The round driver owning a phased compressor and the round arena.
pub struct RoundEngine {
    comp: Box<dyn PhasedCompressor>,
    arena: RoundArena,
    stream: StreamScratch,
}

impl RoundEngine {
    pub fn new(comp: Box<dyn PhasedCompressor>) -> Self {
        RoundEngine { comp, arena: RoundArena::default(), stream: StreamScratch::default() }
    }

    pub fn name(&self) -> String {
        self.comp.name()
    }

    pub fn supports_allreduce(&self) -> bool {
        self.comp.supports_allreduce()
    }

    pub fn compressor_mut(&mut self) -> &mut dyn PhasedCompressor {
        self.comp.as_mut()
    }

    /// Hand a finished round's buffers back for reuse. Optional — skipping
    /// it only costs fresh allocations next round.
    pub fn reclaim(&mut self, result: RoundResult) {
        self.arena.reclaim(result);
    }

    /// Drop a permanently failed rank's encoder (failover: the world
    /// shrank to the survivors, and the dead rank's encode state — EF
    /// memory, RNG stream — dies with it, exactly as on a real cluster).
    pub fn remove_rank(&mut self, rank: usize) {
        let encs = self.comp.encoders();
        if rank < encs.len() {
            encs.remove(rank);
        }
    }

    /// Build the per-rank encoders for an n-rank world without running a
    /// round — required before importing per-rank checkpoint state
    /// (EF residuals, RNG streams) into a fresh engine.
    pub fn ensure_world(&mut self, n: usize) {
        ensure_encoders(self.comp.as_mut(), n);
    }

    /// Checkpoint v2 plumbing (see `runtime::checkpoint`): the
    /// compression state a bit-exact resume needs.
    pub fn export_rule_state(&self) -> Option<Vec<f64>> {
        self.comp.export_rule_state()
    }

    pub fn import_rule_state(&mut self, state: &[f64]) -> anyhow::Result<()> {
        self.comp.import_rule_state(state)
    }

    pub fn export_ef(&mut self) -> Vec<Vec<f32>> {
        self.comp.export_ef()
    }

    pub fn import_ef(&mut self, mems: &[Vec<f32>]) -> anyhow::Result<()> {
        self.comp.import_ef(mems)
    }

    pub fn export_rng_streams(&mut self) -> Vec<[u64; 6]> {
        self.comp.export_rng_streams()
    }

    pub fn import_rng_streams(&mut self, states: &[[u64; 6]]) -> anyhow::Result<()> {
        self.comp.import_rng_streams(states)
    }

    /// One round with every phase inline on this thread.
    pub fn round_sequential(&mut self, grads: &[Vec<f32>], ctx: &RoundCtx) -> RoundResult {
        let RoundEngine { comp, arena, .. } = self;
        sequential_round(comp.as_mut(), grads, ctx, arena)
    }

    /// One round with the encode phase executed inside the worker pool's
    /// threads — rank i's encoder works on worker thread i directly over
    /// the leader's gradient slice — and integer reductions chunked across
    /// the same threads. `encode_seconds` is the straggler max over ranks,
    /// summed over passes — the quantity a synchronous data-parallel round
    /// actually pays.
    pub fn round_parallel(
        &mut self,
        pool: &mut WorkerPool,
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
    ) -> RoundResult {
        self.round_parallel_via(pool, ReduceVia::Pool, grads, ctx)
            .expect("the in-process pool reduce cannot fail")
    }

    /// [`RoundEngine::round_parallel`] with the integer reduce phase
    /// handed to an external [`Reducer`] — the hook a
    /// `net::TransportReducer` plugs into so the aggregation runs as a
    /// staged collective over real sockets (encode still executes on the
    /// pool's threads; fp32 folds stay on the leader as ever).
    ///
    /// Fallible: a transport collective that retry could not fix surfaces
    /// here as a typed [`NetError`] (above all `PeerDead`, which the
    /// `Coordinator` answers with a world shrink + round re-run). On
    /// `Err` the engine is left consistent — encoders parked, arena
    /// untouched — so the very next round call is valid.
    pub fn round_parallel_over(
        &mut self,
        pool: &mut WorkerPool,
        red: &mut dyn Reducer,
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
    ) -> Result<RoundResult, NetError> {
        self.round_parallel_via(pool, ReduceVia::External(red), grads, ctx)
    }

    /// [`RoundEngine::round_parallel_over`] rebuilt as a double-buffered
    /// block pipeline: the pool's encoders fill block k+1's `IntVec`
    /// slots while the reducer's collective moves block k, and the decode
    /// drains each landed block immediately — no global barrier until the
    /// last block. Output is bit-identical to the barrier path (integer
    /// sums are exactly associative, the stochastic draws are keyed by
    /// absolute coordinate, and the per-block decode shares
    /// [`decode_span_ints`] with the whole-round decode), pinned by
    /// `tests/net_parity.rs`.
    ///
    /// Rounds whose plan cannot stream — dense round 0, multi-pass
    /// schemes, all-gather codecs, the switch simulation
    /// ([`PhasedCompressor::streams`]) — fall back to the barrier driver,
    /// so this is safe to call for the whole compressor zoo.
    ///
    /// Failure discipline matches the barrier path: a mid-pipeline error
    /// (above all `PeerDead`) first drains the in-flight encode (every
    /// worker ack collected), then parks the encoders and returns the
    /// typed error — the coordinator's retry/failover re-runs the round
    /// and the round-keyed stochastic bases make the re-encode
    /// bit-identical.
    pub fn round_streamed_over(
        &mut self,
        pool: &mut WorkerPool,
        red: &mut dyn Reducer,
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
    ) -> Result<RoundResult, NetError> {
        let n = grads.len();
        assert!(n > 0, "at least one rank");
        assert_eq!(pool.workers(), n, "one worker thread per rank");
        assert_eq!(n, ctx.n, "ctx.n must match the gradient count (decode scales by it)");
        // probe the plan; `begin` is idempotent per round, so re-planning
        // on the barrier fallback (or below) repeats no state update
        let eligible = {
            let comp = self.comp.as_mut();
            ensure_encoders(comp, n);
            let plan = comp.begin(ctx);
            comp.streams(&plan)
        };
        if !eligible {
            return self.round_parallel_via(pool, ReduceVia::External(red), grads, ctx);
        }
        let RoundEngine { comp, arena, stream } = self;
        let comp = comp.as_mut();
        let plan = comp.begin(ctx);
        let (blocks, alphas) = match &plan {
            PassPlan::IntBlocks { blocks, alphas, .. } => {
                (Arc::clone(blocks), Arc::clone(alphas))
            }
            _ => unreachable!("streams() promised a single-pass integer-block plan"),
        };
        let nblocks = blocks.len();
        stream.slots.ensure(n);
        stream.sum.clear();
        stream.sum.resize(ctx.d, 0);
        let mut gtilde = arena.take_f32();
        let mut encs = std::mem::take(comp.encoders());
        let mut encode_seconds = 0.0f64;
        let mut reduce_total = 0.0f64;
        let mut leader_seconds = 0.0f64;

        let round = ctx.round as u32;

        // prologue: block 0 must exist before the wire can start
        let mut enc_span_t = journal::start();
        pool.post_encode_block(&plan, 0, &mut encs, grads, stream.slots.block_mut(0));
        encode_seconds += pool.collect_encode_block();
        journal::record(Phase::Encode, round, 0, ALL, enc_span_t);

        let mut failure: Option<NetError> = None;
        for k in 0..nblocks {
            // double buffer: the pool fills block k+1's slots (opposite
            // parity — disjoint from everything read below) while the
            // collective moves block k and the leader drains its decode
            if k + 1 < nblocks {
                enc_span_t = journal::start();
                pool.post_encode_block(
                    &plan,
                    k + 1,
                    &mut encs,
                    grads,
                    stream.slots.block_mut(k + 1),
                );
            }
            red.begin_block(k);
            let bmsgs = RankMessages::from_ints(stream.slots.block(k));
            let red_span_t = journal::start();
            // Telemetry timing: phase-seconds probe (clippy.toml).
            #[allow(clippy::disallowed_methods)]
            let t0 = Instant::now();
            let folded = red.sum_ints(&bmsgs, &mut stream.block_sum);
            reduce_total += t0.elapsed().as_secs_f64();
            journal::record(Phase::Reduce, round, k as u16, ALL, red_span_t);
            match folded {
                Ok(()) => {
                    // drain the landed block: assemble the aggregate and
                    // decode it while block k+1 is still encoding
                    let drain_span_t = journal::start();
                    // Telemetry timing: phase-seconds probe (clippy.toml).
                    #[allow(clippy::disallowed_methods)]
                    let t1 = Instant::now();
                    stream.sum[blocks[k].range()].copy_from_slice(&stream.block_sum);
                    decode_span_ints(&stream.block_sum, alphas[k], ctx.n, &mut gtilde);
                    leader_seconds += t1.elapsed().as_secs_f64();
                    journal::record(Phase::Drain, round, k as u16, ALL, drain_span_t);
                }
                Err(e) => failure = Some(e),
            }
            if k + 1 < nblocks {
                encode_seconds += pool.collect_encode_block();
                // the encode span for block k+1 covers post -> collect:
                // in the trace it sits on the encode lane directly above
                // the reduce span for block k — the overlap, visible
                journal::record(Phase::Encode, round, (k + 1) as u16, ALL, enc_span_t);
            }
            if let Some(e) = failure {
                // the in-flight encode was drained above (every ack
                // collected), so the borrowed views are dead: park the
                // encoders, hand the decode buffer back, reset the block
                // stamp — the next round over this engine starts clean
                red.begin_block(0);
                *comp.encoders() = encs;
                arena.put_f32(gtilde);
                return Err(e);
            }
        }
        red.begin_block(0);

        // the aggregate is assembled: run the compressor's normal reduce
        // bookkeeping (max-int tracking, comm accounting) against it,
        // then close the round around the drained decode
        let outcome = {
            let msgs = RankMessages::new(&encs);
            let mut pre = PrecomputedReducer { sum: &stream.sum };
            comp.reduce(&msgs, &plan, ctx, &mut pre)
        };
        *comp.encoders() = encs;
        match outcome.expect("a precomputed reduce cannot fail") {
            PassOutcome::Done => {}
            PassOutcome::Next(_) => {
                unreachable!("streams() promised a single-pass plan")
            }
        }
        let span_t = journal::start();
        // Telemetry timing: phase-seconds probe (clippy.toml).
        #[allow(clippy::disallowed_methods)]
        let t2 = Instant::now();
        let mut result = comp.finish_streamed(ctx, arena, gtilde);
        leader_seconds += t2.elapsed().as_secs_f64();
        journal::record(Phase::Decode, round, ALL, ALL, span_t);
        result.encode_seconds = encode_seconds;
        result.reduce_seconds = reduce_total;
        result.decode_seconds = leader_seconds;
        Ok(result)
    }

    fn round_parallel_via(
        &mut self,
        pool: &mut WorkerPool,
        mut via: ReduceVia<'_>,
        grads: &[Vec<f32>],
        ctx: &RoundCtx,
    ) -> Result<RoundResult, NetError> {
        let n = grads.len();
        assert!(n > 0, "at least one rank");
        assert_eq!(pool.workers(), n, "one worker thread per rank");
        assert_eq!(n, ctx.n, "ctx.n must match the gradient count (decode scales by it)");
        let RoundEngine { comp, arena, .. } = self;
        let comp = comp.as_mut();
        ensure_encoders(comp, n);
        let edge_decode = !comp.supports_allreduce();
        let mut plan = comp.begin(ctx);
        let mut encode_seconds = 0.0f64;
        let mut reduce_total = 0.0f64;
        let mut leader_seconds = 0.0f64;
        let round = ctx.round as u32;
        loop {
            let mut encs = std::mem::take(comp.encoders());
            let span_t = journal::start();
            let straggler = pool.encode_round(&plan, &mut encs, grads);
            // Dense staging is data-plane work, not compression overhead
            // (see sequential_round) — keep the drivers' accounting equal.
            if !matches!(plan, PassPlan::Dense) {
                encode_seconds += straggler;
                journal::record(Phase::Encode, round, ALL, ALL, span_t);
            }
            let outcome = {
                let msgs = RankMessages::new(&encs);
                let span_t = journal::start();
                // Telemetry timing: phase-seconds probe (clippy.toml).
                #[allow(clippy::disallowed_methods)]
                let t0 = Instant::now();
                let outcome = match &mut via {
                    ReduceVia::Pool => {
                        let mut red = PoolReducer::new(pool);
                        comp.reduce(&msgs, &plan, ctx, &mut red)
                    }
                    ReduceVia::External(red) => comp.reduce(&msgs, &plan, ctx, &mut **red),
                };
                let dt = t0.elapsed().as_secs_f64();
                journal::record(Phase::Reduce, round, ALL, ALL, span_t);
                reduce_total += dt;
                if edge_decode {
                    leader_seconds += dt;
                }
                outcome
            };
            // park the encoders BEFORE propagating a failure: an erroring
            // round must not strand the per-rank state (streams, EF
            // memory) or the reused message buffers — the retry/failover
            // path runs the next round over the same engine
            *comp.encoders() = encs;
            match outcome? {
                PassOutcome::Done => break,
                PassOutcome::Next(next) => plan = next,
            }
        }
        let span_t = journal::start();
        // Telemetry timing: phase-seconds probe (clippy.toml).
        #[allow(clippy::disallowed_methods)]
        let t1 = Instant::now();
        let mut result = comp.decode(ctx, arena);
        leader_seconds += t1.elapsed().as_secs_f64();
        journal::record(Phase::Decode, round, ALL, ALL, span_t);
        result.encode_seconds = encode_seconds;
        result.reduce_seconds = reduce_total;
        result.decode_seconds = leader_seconds;
        Ok(result)
    }
}
