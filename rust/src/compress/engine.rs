//! Phase-based compression engine: per-rank **encode**, leader-side
//! **reduce**, leader-side **decode**.
//!
//! The monolithic `round(&[Vec<f32>])` entry point hid a real systems
//! property: every rank's encode is independent and runs concurrently on a
//! real cluster, while the reduction is the collective's job and the decode
//! is cheap leader/edge work. This module makes that split explicit:
//!
//! - [`RankEncoder`] — one rank's `Send` encode state (its RNG stream,
//!   error-feedback memory, PowerSGD scratch). `encode` is pure with
//!   respect to the other ranks, so encoders can hop to worker threads.
//! - [`PhasedCompressor`] — the leader half: it plans each pass
//!   ([`PassPlan`], shared read-only with all ranks), folds the rank
//!   messages ([`PhasedCompressor::reduce`], which may request further
//!   passes — PowerSGD needs three), and decodes the final estimate.
//! - [`RoundEngine`] — the driver. [`RoundEngine::round_parallel`] ships
//!   each rank's encoder to its `WorkerPool` thread, so the measured
//!   encode cost is the true straggler max and scales with cores;
//!   [`RoundEngine::round_sequential`] runs the same phases inline on the
//!   caller thread (the parity reference, also what the old
//!   `DistributedCompressor::round` shape adapts to).
//!
//! Per-block scales (paper Alg. 2) thread through the plan: `RoundCtx.
//! blocks` becomes [`BlockSpan`]s + per-block alphas inside
//! `PassPlan::IntBlocks`, and the decode divides block-wise.
//!
//! Both drivers produce bit-identical results: encoders consume only their
//! own state and the shared plan, and reduction folds messages in rank
//! order (`tests/engine_parity.rs` pins this for the whole zoo).

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::worker::{EncodeTask, WorkerPool};
use crate::coordinator::RoundCtx;

use super::intsgd::Rounding;
use super::natsgd::NatMsg;
use super::qsgd::QsgdBucket;
use super::signsgd::SignMsg;
use super::{DistributedCompressor, RoundResult};

/// One contiguous parameter block of the flattened gradient.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSpan {
    pub offset: usize,
    pub dim: usize,
}

impl BlockSpan {
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.dim
    }
}

/// Block geometry for a round: the ctx blocks when given, otherwise one
/// span covering the whole gradient.
pub fn spans_from_ctx(ctx: &RoundCtx) -> Vec<BlockSpan> {
    if ctx.blocks.is_empty() {
        return vec![BlockSpan { offset: 0, dim: ctx.d }];
    }
    let mut out = Vec::with_capacity(ctx.blocks.len());
    let mut offset = 0;
    for b in &ctx.blocks {
        out.push(BlockSpan { offset, dim: b.dim });
        offset += b.dim;
    }
    assert_eq!(offset, ctx.d, "blocks must tile the gradient");
    out
}

/// The immutable instruction the leader broadcasts for one encode pass.
/// Shared read-only (`Arc`) with every rank's encoder.
#[derive(Clone, Debug)]
pub enum PassPlan {
    /// Ship the raw fp32 gradient (identity SGD; IntSGD's exact round 0).
    Dense,
    /// Nothing shared is needed (EF-sign, top-k, natural compression).
    Plain,
    /// IntSGD: per-block integer rounding at the given alphas, clipped so
    /// the aggregate provably fits the wire type.
    IntBlocks {
        rounding: Rounding,
        blocks: Vec<BlockSpan>,
        alphas: Vec<f64>,
        clip: i64,
    },
    /// Heuristic IntSGD pass 1: report per-block max |g| for profiling.
    Profile { blocks: Vec<BlockSpan> },
    /// Heuristic IntSGD pass 2: per-block f64 scale-and-round (the
    /// SwitchML rule has no clipping; the profiled alpha prevents
    /// overflow by construction).
    ScaledRound { blocks: Vec<BlockSpan>, alphas: Vec<f64> },
    /// QSGD: stochastic level quantization per bucket.
    Buckets { spans: Vec<BlockSpan>, levels: u16 },
    /// PowerSGD pass 1: P_i = M_i Q per matrix block (+ raw vector
    /// blocks). Factor sets are `Arc`-shared with the leader state — a
    /// plan costs a pointer copy, not a per-round deep clone.
    PowerP { qs: Arc<Vec<Vec<f32>>> },
    /// PowerSGD pass 2: Q_i = M_i^T P_hat per matrix block.
    PowerQ { ps: Arc<Vec<Vec<f32>>> },
    /// PowerSGD pass 3: update EF memory from the decoded factors (every
    /// rank holds P_hat and Q_hat after the all-reduces and reconstructs
    /// the approximation locally).
    PowerEf { ps: Arc<Vec<Vec<f32>>>, qs: Arc<Vec<Vec<f32>>> },
}

/// A rank's encoded payload for one pass.
#[derive(Clone, Debug)]
pub enum Message {
    Empty,
    Dense(Vec<f32>),
    Ints(Vec<i64>),
    Scalars(Vec<f32>),
    Buckets(Vec<QsgdBucket>),
    Sign(SignMsg),
    Nat(NatMsg),
    Sparse(Vec<(u32, f32)>),
}

impl Message {
    /// Reusable dense slot (keeps capacity across rounds).
    pub fn dense_mut(&mut self) -> &mut Vec<f32> {
        if !matches!(self, Message::Dense(_)) {
            *self = Message::Dense(Vec::new());
        }
        match self {
            Message::Dense(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn ints_mut(&mut self) -> &mut Vec<i64> {
        if !matches!(self, Message::Ints(_)) {
            *self = Message::Ints(Vec::new());
        }
        match self {
            Message::Ints(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn scalars_mut(&mut self) -> &mut Vec<f32> {
        if !matches!(self, Message::Scalars(_)) {
            *self = Message::Scalars(Vec::new());
        }
        match self {
            Message::Scalars(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn buckets_mut(&mut self) -> &mut Vec<QsgdBucket> {
        if !matches!(self, Message::Buckets(_)) {
            *self = Message::Buckets(Vec::new());
        }
        match self {
            Message::Buckets(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn sparse_mut(&mut self) -> &mut Vec<(u32, f32)> {
        if !matches!(self, Message::Sparse(_)) {
            *self = Message::Sparse(Vec::new());
        }
        match self {
            Message::Sparse(v) => v,
            _ => unreachable!(),
        }
    }

    pub fn as_dense(&self) -> &[f32] {
        match self {
            Message::Dense(v) => v,
            _ => panic!("expected dense message"),
        }
    }

    pub fn as_ints(&self) -> &[i64] {
        match self {
            Message::Ints(v) => v,
            _ => panic!("expected integer message"),
        }
    }

    pub fn as_scalars(&self) -> &[f32] {
        match self {
            Message::Scalars(v) => v,
            _ => panic!("expected scalar message"),
        }
    }

    pub fn as_buckets(&self) -> &[QsgdBucket] {
        match self {
            Message::Buckets(v) => v,
            _ => panic!("expected bucket message"),
        }
    }

    pub fn as_sign(&self) -> &SignMsg {
        match self {
            Message::Sign(m) => m,
            _ => panic!("expected sign message"),
        }
    }

    pub fn as_nat(&self) -> &NatMsg {
        match self {
            Message::Nat(m) => m,
            _ => panic!("expected natural-compression message"),
        }
    }

    pub fn as_sparse(&self) -> &[(u32, f32)] {
        match self {
            Message::Sparse(v) => v,
            _ => panic!("expected sparse message"),
        }
    }
}

/// One rank's encode state. `Send` so the engine can ship it to the rank's
/// worker thread and back; all buffers are owned and reused across rounds.
pub trait RankEncoder: Send {
    /// Run one encode pass over this rank's gradient. The result stays
    /// readable via [`RankEncoder::message`] until the next call.
    fn encode(&mut self, grad: &[f32], plan: &PassPlan);

    /// The payload produced by the last `encode` call.
    fn message(&self) -> &Message;
}

/// What the leader does with a pass's messages.
pub enum PassOutcome {
    /// The round's aggregate is complete; `decode` may run.
    Done,
    /// Another encode pass is required (e.g. PowerSGD's Q and EF passes).
    Next(PassPlan),
}

/// The leader half of a compression algorithm, split into phases so the
/// per-rank encode can execute on worker threads.
pub trait PhasedCompressor: Send {
    fn name(&self) -> String;

    /// Whether the messages can be reduced in-flight (paper Table 1).
    fn supports_allreduce(&self) -> bool;

    /// Build rank `rank`'s encoder (called lazily, once per rank).
    fn make_encoder(&mut self, rank: usize) -> Box<dyn RankEncoder>;

    /// Parked per-rank encoders; the engine checks them out per pass.
    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>>;

    /// Plan the round's first encode pass.
    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan;

    /// Fold the n rank messages of one pass (in rank order — this is what
    /// makes the parallel and sequential drivers bit-identical), either
    /// finishing the round or requesting another pass.
    fn reduce(&mut self, msgs: &[&Message], plan: &PassPlan, ctx: &RoundCtx) -> PassOutcome;

    /// Produce the round result from the reduced state. Timing fields are
    /// filled in by the driver.
    fn decode(&mut self, ctx: &RoundCtx) -> RoundResult;
}

fn ensure_encoders(comp: &mut dyn PhasedCompressor, n: usize) {
    let have = comp.encoders().len();
    if have == n {
        return;
    }
    assert!(
        have == 0,
        "worker count changed mid-run: {have} encoders, {n} ranks"
    );
    for rank in 0..n {
        let enc = comp.make_encoder(rank);
        comp.encoders().push(enc);
    }
}

/// Sum dense rank messages elementwise into `out` and divide by n — the
/// shared fold for every "average the fp32 payloads" reduction (identity
/// all-gather, IntSGD's exact round 0, PowerSGD's factor means). Folds in
/// rank order, which the parity guarantee depends on.
pub(crate) fn mean_dense_into(msgs: &[&Message], out: &mut Vec<f32>) {
    let len = msgs[0].as_dense().len();
    out.clear();
    out.resize(len, 0.0);
    for m in msgs {
        let v = m.as_dense();
        assert_eq!(v.len(), len, "rank messages disagree on length");
        for (o, &x) in out.iter_mut().zip(v) {
            *o += x;
        }
    }
    let inv = 1.0 / msgs.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// g_tilde = sum / (n * alpha_l), block by block — the Alg. 2 decode,
/// shared by IntSGD and Heuristic IntSGD so the two cannot drift.
pub(crate) fn decode_block_ints(
    sum: &[i64],
    blocks: &[BlockSpan],
    alphas: &[f64],
    n: usize,
) -> Vec<f32> {
    let mut gtilde = Vec::with_capacity(sum.len());
    for (span, &alpha) in blocks.iter().zip(alphas) {
        let inv = 1.0 / (n as f64 * alpha);
        gtilde.extend(sum[span.range()].iter().map(|&s| (s as f64 * inv) as f32));
    }
    gtilde
}

/// Drive one round with every phase on the caller thread — the sequential
/// reference path. Encode cost is reported as the per-worker share
/// (total / n), mirroring what the old monolithic `round` estimated.
///
/// Timing policy (both drivers): the reduce fold is charged as decode
/// time only for all-gather algorithms, where it IS the per-worker edge
/// decode; for all-reduce/INA algorithms the in-process fold stands in
/// for the network data plane, whose cost is modeled by `netsim` —
/// timing it here would double-count against the comm model.
pub fn sequential_round(
    comp: &mut dyn PhasedCompressor,
    grads: &[Vec<f32>],
    ctx: &RoundCtx,
) -> RoundResult {
    let n = grads.len();
    assert!(n > 0, "at least one rank");
    assert_eq!(n, ctx.n, "ctx.n must match the gradient count (decode scales by it)");
    ensure_encoders(comp, n);
    let edge_decode = !comp.supports_allreduce();
    let mut plan = comp.begin(ctx);
    let mut encode_total = 0.0f64;
    let mut leader_seconds = 0.0f64;
    loop {
        let mut encs = std::mem::take(comp.encoders());
        let t0 = Instant::now();
        for (enc, grad) in encs.iter_mut().zip(grads) {
            enc.encode(grad, &plan);
        }
        // Dense passes stage the raw fp32 buffer for the data plane — a
        // real deployment hands the gradient pointer straight to the
        // collective, so the staging copy is not compression overhead.
        if !matches!(plan, PassPlan::Dense) {
            encode_total += t0.elapsed().as_secs_f64();
        }
        let msgs: Vec<&Message> = encs.iter().map(|e| e.message()).collect();
        let t1 = Instant::now();
        let outcome = comp.reduce(&msgs, &plan, ctx);
        if edge_decode {
            leader_seconds += t1.elapsed().as_secs_f64();
        }
        drop(msgs);
        *comp.encoders() = encs;
        match outcome {
            PassOutcome::Done => break,
            PassOutcome::Next(next) => plan = next,
        }
    }
    let t2 = Instant::now();
    let mut result = comp.decode(ctx);
    leader_seconds += t2.elapsed().as_secs_f64();
    result.encode_seconds = encode_total / n as f64;
    result.decode_seconds = leader_seconds;
    result
}

/// Every phased compressor is also usable through the old call shape; the
/// adapter runs the sequential driver, so existing call sites and the
/// parity tests keep working unchanged.
impl<T: PhasedCompressor> DistributedCompressor for T {
    fn name(&self) -> String {
        PhasedCompressor::name(self)
    }

    fn supports_allreduce(&self) -> bool {
        PhasedCompressor::supports_allreduce(self)
    }

    fn round(&mut self, grads: &[Vec<f32>], ctx: &RoundCtx) -> RoundResult {
        sequential_round(self, grads, ctx)
    }
}

/// The round driver owning a phased compressor.
pub struct RoundEngine {
    comp: Box<dyn PhasedCompressor>,
}

impl RoundEngine {
    pub fn new(comp: Box<dyn PhasedCompressor>) -> Self {
        RoundEngine { comp }
    }

    pub fn name(&self) -> String {
        self.comp.name()
    }

    pub fn supports_allreduce(&self) -> bool {
        self.comp.supports_allreduce()
    }

    pub fn compressor_mut(&mut self) -> &mut dyn PhasedCompressor {
        self.comp.as_mut()
    }

    /// One round with every phase inline on this thread.
    pub fn round_sequential(&mut self, grads: &[Vec<f32>], ctx: &RoundCtx) -> RoundResult {
        sequential_round(self.comp.as_mut(), grads, ctx)
    }

    /// One round with the encode phase executed inside the worker pool's
    /// threads: rank i's encoder and gradient travel to worker i, encode
    /// there, and come back with the pass's message. `encode_seconds` is
    /// the straggler max over ranks, summed over passes — the quantity a
    /// synchronous data-parallel round actually pays.
    pub fn round_parallel(
        &mut self,
        pool: &mut WorkerPool,
        grads: &mut [Vec<f32>],
        ctx: &RoundCtx,
    ) -> RoundResult {
        let n = grads.len();
        assert!(n > 0, "at least one rank");
        assert_eq!(pool.workers(), n, "one worker thread per rank");
        assert_eq!(n, ctx.n, "ctx.n must match the gradient count (decode scales by it)");
        let comp = self.comp.as_mut();
        ensure_encoders(comp, n);
        let edge_decode = !comp.supports_allreduce();
        let mut plan = comp.begin(ctx);
        let mut encode_seconds = 0.0f64;
        let mut leader_seconds = 0.0f64;
        loop {
            let shared = Arc::new(plan);
            let mut encs = std::mem::take(comp.encoders());
            let tasks: Vec<EncodeTask> = encs
                .drain(..)
                .zip(grads.iter_mut())
                .enumerate()
                .map(|(rank, (encoder, grad))| EncodeTask {
                    rank,
                    encoder,
                    grad: std::mem::take(grad),
                    plan: Arc::clone(&shared),
                })
                .collect();
            let (done, straggler) = pool.encode_round(tasks);
            // Dense staging is data-plane work, not compression overhead
            // (see sequential_round) — keep the drivers' accounting equal.
            if !matches!(&*shared, PassPlan::Dense) {
                encode_seconds += straggler;
            }
            for (item, grad) in done.into_iter().zip(grads.iter_mut()) {
                *grad = item.grad;
                encs.push(item.encoder);
            }
            let msgs: Vec<&Message> = encs.iter().map(|e| e.message()).collect();
            let t0 = Instant::now();
            let outcome = comp.reduce(&msgs, &shared, ctx);
            if edge_decode {
                leader_seconds += t0.elapsed().as_secs_f64();
            }
            drop(msgs);
            *comp.encoders() = encs;
            match outcome {
                PassOutcome::Done => break,
                PassOutcome::Next(next) => plan = next,
            }
        }
        let t1 = Instant::now();
        let mut result = comp.decode(ctx);
        leader_seconds += t1.elapsed().as_secs_f64();
        result.encode_seconds = encode_seconds;
        result.decode_seconds = leader_seconds;
        result
    }
}
