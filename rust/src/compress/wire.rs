//! Wire codecs: bit-exact byte serialization of every message type.
//!
//! The netsim cost model charges each algorithm its true wire bytes; this
//! module makes those numbers *honest* by actually producing the byte
//! streams a deployment would ship: packed int8 payloads, 9-bit NatSGD
//! (sign bitset + exponent bytes), QSGD (sign+level bytes + bucket norms),
//! sparse (varint-delta indices + f32 values), and sign bitsets. The
//! collective simulators operate on decoded vectors; these codecs close
//! the loop for tests and for anyone wiring a real transport underneath.

use anyhow::{anyhow, Result};

use crate::util::cast;

use super::intvec::{IntVec, Lanes};
use super::natsgd::{NatMsg, EXP_ZERO};
use super::qsgd::QsgdBucket;
use super::signsgd::SignMsg;

/// Both bitstream halves move at most 57 bits per call: the 64-bit
/// staging word holds up to 7 residual bits, so a 58-bit-plus operand
/// would shift data off its top end (and `(1u64 << 64)` is not even a
/// defined mask). The writer asserts, the reader reports a decode error.
pub const MAX_BITS_PER_OP: u32 = 57;

/// Little-endian bit writer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u64,
    bits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, value: u64, nbits: u32) {
        assert!(
            nbits <= MAX_BITS_PER_OP,
            "push up to {MAX_BITS_PER_OP} bits at a time (got {nbits})"
        );
        self.cur |= value << self.bits;
        self.bits += nbits;
        while self.bits >= 8 {
            self.buf.push(cast::low_u8(self.cur));
            self.cur >>= 8;
            self.bits -= 8;
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.bits > 0 {
            self.buf.push(cast::low_u8(self.cur));
        }
        self.buf
    }
}

/// Little-endian bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    cur: u64,
    bits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, cur: 0, bits: 0 }
    }

    pub fn pull(&mut self, nbits: u32) -> Result<u64> {
        // A 64-bit pull used to slip past this point and silently return
        // a zero mask in release builds ((1u64 << 64) - 1 wraps to 0);
        // reject anything beyond the staging word's guaranteed headroom.
        if nbits > MAX_BITS_PER_OP {
            return Err(anyhow!(
                "pull up to {MAX_BITS_PER_OP} bits at a time (got {nbits})"
            ));
        }
        while self.bits < nbits {
            let byte = *self
                .buf
                .get(self.pos)
                .ok_or_else(|| anyhow!("bitstream underrun"))?;
            self.cur |= (byte as u64) << self.bits;
            self.bits += 8;
            self.pos += 1;
        }
        let v = self.cur & ((1u64 << nbits) - 1);
        self.cur >>= nbits;
        self.bits -= nbits;
        Ok(v)
    }
}

/// Unsigned LEB128 varint.
pub fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = cast::low_u8(v & 0x7F);
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

pub fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0;
    loop {
        let byte = *buf.get(*pos).ok_or_else(|| anyhow!("varint underrun"))?;
        *pos += 1;
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(anyhow!("varint overflow"));
        }
    }
}

/// Zigzag i64 <-> u64.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

// ---------------------------------------------------------------------------
// IntSGD payloads
// ---------------------------------------------------------------------------

/// Pack an integer message as int8. When the payload already lives in i8
/// lanes — the IntSGD int8 hot path — this is a memcpy-shaped pass (cast
/// each lane to its byte, no range check: the lane *is* the proof); wider
/// lanes are range-checked per element.
pub fn encode_int8(ints: &IntVec) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(ints.len());
    match ints {
        IntVec::I8(v) => out.extend(v.iter().map(|&x| cast::byte_of_i8(x))),
        _ => {
            for j in 0..ints.len() {
                let v = ints.get(j);
                let x =
                    i8::try_from(v).map_err(|_| anyhow!("{v} out of int8 range"))?;
                out.push(cast::byte_of_i8(x));
            }
        }
    }
    Ok(out)
}

pub fn decode_int8(bytes: &[u8]) -> IntVec {
    IntVec::I8(bytes.iter().map(|&b| cast::i8_of_byte(b)).collect())
}

/// Pack an integer message as int32 LE; i8/i32 lanes need no range check.
pub fn encode_int32(ints: &IntVec) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(ints.len() * 4);
    match ints {
        IntVec::I8(v) => {
            for &x in v {
                out.extend_from_slice(&i32::from(x).to_le_bytes());
            }
        }
        IntVec::I32(v) => {
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        IntVec::I64(v) => {
            for &x in v {
                let y =
                    i32::try_from(x).map_err(|_| anyhow!("{x} out of int32 range"))?;
                out.extend_from_slice(&y.to_le_bytes());
            }
        }
    }
    Ok(out)
}

pub fn decode_int32(bytes: &[u8]) -> Result<IntVec> {
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("int32 payload not 4-aligned"));
    }
    Ok(IntVec::I32(
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    ))
}

/// Round-trip helper: encode at the message's own lane width.
pub fn encode_ints(ints: &IntVec) -> Result<Vec<u8>> {
    match ints.lanes() {
        Lanes::I8 => encode_int8(ints),
        _ => encode_int32(ints),
    }
}

// ---------------------------------------------------------------------------
// NatSGD: 9 bits/coordinate = 1 sign + 8-bit biased exponent (0 = zero)
// ---------------------------------------------------------------------------

pub fn encode_nat(msg: &NatMsg) -> Vec<u8> {
    let mut w = BitWriter::new();
    for (j, &e) in msg.exps.iter().enumerate() {
        let sign = (msg.signs[j / 64] >> (j % 64)) & 1;
        let biased: u64 = if e == EXP_ZERO { 0 } else { (e + 127) as u64 + 1 };
        w.push(sign | (biased << 1), 9);
    }
    w.finish()
}

pub fn decode_nat(bytes: &[u8], d: usize) -> Result<NatMsg> {
    let mut r = BitReader::new(bytes);
    let mut signs = vec![0u64; d.div_ceil(64)];
    let mut exps = Vec::with_capacity(d);
    for j in 0..d {
        let v = r.pull(9)?;
        signs[j / 64] |= (v & 1) << (j % 64);
        let biased = v >> 1;
        exps.push(if biased == 0 {
            EXP_ZERO
        } else {
            // 9-bit field: biased <= 511, so the checked cast never fires
            cast::to_i16(biased)? - 1 - 127
        });
    }
    Ok(NatMsg { signs, exps })
}

// ---------------------------------------------------------------------------
// QSGD: per bucket f32 norm + one byte (sign + 7-bit level) per coordinate
// ---------------------------------------------------------------------------

pub fn encode_qsgd(msg: &[QsgdBucket]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    write_varint(&mut out, msg.len() as u64);
    for b in msg {
        write_varint(&mut out, b.levels.len() as u64);
        out.extend_from_slice(&b.norm.to_le_bytes());
        for &l in &b.levels {
            let sign = u8::from(l < 0);
            let mag = l.unsigned_abs();
            if mag > 127 {
                return Err(anyhow!("level {l} exceeds 7 bits"));
            }
            out.push((sign << 7) | cast::to_u8(mag)?);
        }
    }
    Ok(out)
}

pub fn decode_qsgd(bytes: &[u8]) -> Result<Vec<QsgdBucket>> {
    let mut pos = 0usize;
    // Counts arrive from the wire and size allocations: bound them by
    // what the remaining bytes could possibly hold (a bucket is at least
    // a length byte + 4 norm bytes) so a hostile count is a decode
    // error, not a multi-gigabyte `with_capacity`.
    let nbuckets = read_varint(bytes, &mut pos)?;
    if nbuckets > ((bytes.len() - pos) / 5) as u64 {
        return Err(anyhow!("qsgd bucket count {nbuckets} exceeds the buffer"));
    }
    let nbuckets = cast::to_usize(nbuckets)?;
    let mut out = Vec::with_capacity(nbuckets);
    for _ in 0..nbuckets {
        let len = read_varint(bytes, &mut pos)?;
        if len > (bytes.len() - pos) as u64 {
            return Err(anyhow!("qsgd bucket length {len} exceeds the buffer"));
        }
        let len = cast::to_usize(len)?;
        let norm_bytes = bytes
            .get(pos..pos + 4)
            .ok_or_else(|| anyhow!("qsgd underrun"))?;
        let norm =
            f32::from_le_bytes([norm_bytes[0], norm_bytes[1], norm_bytes[2], norm_bytes[3]]);
        pos += 4;
        let mut levels = Vec::with_capacity(len);
        for _ in 0..len {
            let b = *bytes.get(pos).ok_or_else(|| anyhow!("qsgd underrun"))?;
            pos += 1;
            let mag = i16::from(b & 0x7F);
            levels.push(if b & 0x80 != 0 { -mag } else { mag });
        }
        out.push(QsgdBucket { norm, levels });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Sparse (top-k): varint-delta indices + f32 values
// ---------------------------------------------------------------------------

/// Delta-varint encode into reused buffers: `order` holds the index
/// permutation (sorted by coordinate — entries are never copied, only the
/// u32 permutation is sorted) and `out` receives the byte stream. Indices
/// are unique per message (a top-k support), so the unstable sort is
/// deterministic.
pub fn encode_sparse_with(
    entries: &[(u32, f32)],
    order: &mut Vec<u32>,
    out: &mut Vec<u8>,
) {
    order.clear();
    order.extend(0..entries.len() as u32); // intlint: allow(R3, reason="top-k support is u32-indexed by type; len() <= u32::MAX by construction")
    order.sort_unstable_by_key(|&k| entries[cast::usize_from(k)].0);
    out.clear();
    write_varint(out, entries.len() as u64);
    let mut prev = 0u32;
    for &k in order.iter() {
        let i = entries[cast::usize_from(k)].0;
        write_varint(out, (i - prev) as u64);
        prev = i;
    }
    for &k in order.iter() {
        out.extend_from_slice(&entries[cast::usize_from(k)].1.to_le_bytes());
    }
}

/// Allocating convenience wrapper around [`encode_sparse_with`].
pub fn encode_sparse(entries: &[(u32, f32)]) -> Vec<u8> {
    let mut order = Vec::new();
    let mut out = Vec::new();
    encode_sparse_with(entries, &mut order, &mut out);
    out
}

pub fn decode_sparse(bytes: &[u8]) -> Result<Vec<(u32, f32)>> {
    let mut pos = 0usize;
    // every entry costs at least one delta byte + 4 value bytes; a count
    // beyond that is hostile (see decode_qsgd)
    let k = read_varint(bytes, &mut pos)?;
    if k > ((bytes.len() - pos) / 5) as u64 {
        return Err(anyhow!("sparse entry count {k} exceeds the buffer"));
    }
    let k = cast::to_usize(k)?;
    let mut idx = Vec::with_capacity(k);
    let mut prev = 0u64;
    for i in 0..k {
        let delta = read_varint(bytes, &mut pos)?;
        // first index is absolute (delta from 0); the accumulation must
        // be checked — a hostile delta would wrap u64 in release builds
        // and fabricate a small-but-bogus index instead of erroring
        prev = if i == 0 {
            delta
        } else {
            prev.checked_add(delta)
                .ok_or_else(|| anyhow!("sparse index overflow"))?
        };
        idx.push(u32::try_from(prev).map_err(|_| anyhow!("index overflow"))?);
    }
    let mut out = Vec::with_capacity(k);
    for &i in &idx {
        let b = bytes
            .get(pos..pos + 4)
            .ok_or_else(|| anyhow!("sparse underrun"))?;
        out.push((i, f32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        pos += 4;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Sign: 1 bit per coordinate + f32 scale
// ---------------------------------------------------------------------------

pub fn encode_sign(msg: &SignMsg, d: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + d.div_ceil(8));
    out.extend_from_slice(&msg.scale.to_le_bytes());
    let mut w = BitWriter::new();
    for j in 0..d {
        w.push((msg.bits[j / 64] >> (j % 64)) & 1, 1);
    }
    out.extend(w.finish());
    out
}

pub fn decode_sign(bytes: &[u8], d: usize) -> Result<SignMsg> {
    let scale_b = bytes.get(..4).ok_or_else(|| anyhow!("sign underrun"))?;
    let scale = f32::from_le_bytes([scale_b[0], scale_b[1], scale_b[2], scale_b[3]]);
    let mut r = BitReader::new(&bytes[4..]);
    let mut bits = vec![0u64; d.div_ceil(64)];
    for j in 0..d {
        bits[j / 64] |= r.pull(1)? << (j % 64);
    }
    Ok(SignMsg { bits, scale })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::natsgd::NatSgd;
    use crate::compress::qsgd::Qsgd;
    use crate::compress::signsgd::SignSgd;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn bitstream_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [
            (5u64, 3u32),
            (1, 1),
            (511, 9),
            (0, 9),
            (123456, 17),
            // the widest legal operand, with its top bit set
            ((1u64 << 56) | 12345, MAX_BITS_PER_OP),
        ];
        for &(v, n) in &vals {
            w.push(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.pull(n).unwrap(), v);
        }
    }

    #[test]
    fn bitstream_rejects_oversized_pulls() {
        // 64-bit pulls used to wrap the mask to zero in release builds;
        // now every oversized width is an explicit decode error, even when
        // the stream holds plenty of data.
        let mut w = BitWriter::new();
        for _ in 0..4 {
            w.push(u32::MAX as u64, 32);
        }
        let bytes = w.finish();
        for nbits in [MAX_BITS_PER_OP + 1, 63, 64] {
            let mut r = BitReader::new(&bytes);
            let err = r.pull(nbits).expect_err("oversized pull must fail");
            assert!(err.to_string().contains("57"), "{err}");
        }
        // and the cap itself still works
        let mut r = BitReader::new(&bytes);
        assert!(r.pull(MAX_BITS_PER_OP).is_ok());
    }

    #[test]
    #[should_panic(expected = "57 bits")]
    fn bitstream_writer_rejects_oversized_pushes() {
        BitWriter::new().push(1, 64);
    }

    #[test]
    fn varint_zigzag_roundtrip() {
        prop_check(0x7A91, 200, |rng| {
            let v = rng.next_u64() >> rng.below(64) as u32;
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut pos = 0;
            let back = read_varint(&buf, &mut pos).map_err(|e| e.to_string())?;
            prop_assert!(back == v, "varint {v}");
            prop_assert!(pos == buf.len(), "trailing bytes");
            let s = v as i64;
            prop_assert!(unzigzag(zigzag(s)) == s, "zigzag {s}");
            Ok(())
        });
    }

    #[test]
    fn int8_int32_roundtrip_and_range_checks() {
        use crate::compress::intvec::Lanes;
        let ints = vec![-128i64, -1, 0, 1, 127];
        // native i8 lanes: the memcpy path
        let typed = IntVec::from_i64(&ints, Lanes::I8);
        assert_eq!(decode_int8(&encode_int8(&typed).unwrap()).to_i64_vec(), ints);
        // widened lanes carrying int8-range values: the checked path
        let widened = IntVec::from_i64(&ints, Lanes::I64);
        assert_eq!(decode_int8(&encode_int8(&widened).unwrap()).to_i64_vec(), ints);
        assert!(encode_int8(&IntVec::from_i64(&[200], Lanes::I64)).is_err());
        let big = vec![i32::MIN as i64, -7, 0, i32::MAX as i64];
        let typed32 = IntVec::from_i64(&big, Lanes::I32);
        assert_eq!(
            decode_int32(&encode_int32(&typed32).unwrap()).unwrap().to_i64_vec(),
            big
        );
        assert!(encode_int32(&IntVec::from_i64(&[i64::MAX], Lanes::I64)).is_err());
        // lane-dispatching helper agrees with the direct codecs
        assert_eq!(encode_ints(&typed).unwrap(), encode_int8(&typed).unwrap());
        assert_eq!(encode_ints(&typed32).unwrap(), encode_int32(&typed32).unwrap());
    }

    #[test]
    fn nat_wire_roundtrip_and_size() {
        let mut rng = Rng::new(0);
        let d = 1000;
        let g = rng.normal_vec(d, 2.0);
        let mut stream = Rng::new(1);
        let mut msg = NatMsg::default();
        NatSgd::encode_into(&mut stream, &g, &mut msg);
        let bytes = encode_nat(&msg);
        assert_eq!(bytes.len(), (d * 9).div_ceil(8));
        let back = decode_nat(&bytes, d).unwrap();
        assert_eq!(back.exps, msg.exps);
        assert_eq!(back.signs, msg.signs);
    }

    #[test]
    fn qsgd_wire_roundtrip() {
        let mut rng = Rng::new(1);
        let g = rng.normal_vec(500, 1.0);
        let mut stream = Rng::new(2);
        let mut msg = Vec::new();
        Qsgd::encode_buckets(64, &Qsgd::spans_of(&[100, 400], 500), &g, &mut stream, &mut msg);
        let bytes = encode_qsgd(&msg).unwrap();
        let back = decode_qsgd(&bytes).unwrap();
        assert_eq!(back.len(), msg.len());
        for (a, b) in back.iter().zip(&msg) {
            assert_eq!(a.norm, b.norm);
            assert_eq!(a.levels, b.levels);
        }
    }

    #[test]
    fn sparse_wire_roundtrip_sorted() {
        let entries = vec![(900u32, 1.5f32), (3, -2.0), (77, 0.25)];
        let bytes = encode_sparse(&entries);
        let back = decode_sparse(&bytes).unwrap();
        assert_eq!(back, vec![(3, -2.0), (77, 0.25), (900, 1.5)]);
    }

    #[test]
    fn sparse_wire_beats_dense_pairs() {
        // delta-varint indices: nearby indices cost 1 byte, not 4
        let entries: Vec<(u32, f32)> = (0..100).map(|i| (i * 3, 1.0f32)).collect();
        let bytes = encode_sparse(&entries);
        assert!(bytes.len() < 100 * 8, "{} bytes", bytes.len());
    }

    #[test]
    fn sign_wire_roundtrip() {
        let mut rng = Rng::new(2);
        let d = 300;
        let a = rng.normal_vec(d, 1.0);
        let msg = SignSgd::encode(&a);
        let bytes = encode_sign(&msg, d);
        assert_eq!(bytes.len(), 4 + d.div_ceil(8));
        let back = decode_sign(&bytes, d).unwrap();
        assert_eq!(back.scale, msg.scale);
        let mut va = Vec::new();
        let mut vb = Vec::new();
        SignSgd::decode(&msg, d, &mut va);
        SignSgd::decode(&back, d, &mut vb);
        assert_eq!(va, vb);
    }

    #[test]
    fn corrupt_streams_are_rejected() {
        assert!(decode_int32(&[1, 2, 3]).is_err());
        assert!(decode_nat(&[0xFF], 100).is_err());
        assert!(decode_qsgd(&[5]).is_err());
        assert!(decode_sparse(&[10, 1]).is_err());
        assert!(decode_sign(&[1, 2], 8).is_err());
    }
}
