//! QSGD (Alistarh et al., 2017): per-bucket normalized stochastic
//! quantization to s levels.
//!
//! Each bucket (one parameter block / layer, matching the paper's setup
//! "we use the gradient matrix of each layer as a bucket" with 64 levels)
//! ships its l2 norm plus one (sign, level) pair per coordinate. Because
//! the norms differ per worker, the messages are NOT summable in-flight:
//! QSGD requires all-gather + per-worker decompression, which is the
//! systems cost Tables 2-3 demonstrate.

use std::time::Instant;

use crate::coordinator::RoundCtx;
use crate::util::stats::l2_norm;
use crate::util::Rng;

use super::{CommOp, DistributedCompressor, Primitive, RoundResult};

/// One encoded bucket.
#[derive(Clone, Debug)]
pub struct QsgdBucket {
    pub norm: f32,
    /// signed level per coordinate, |level| <= s
    pub levels: Vec<i16>,
}

pub struct Qsgd {
    /// Quantization levels (paper: 64, i.e. ~6 bits + sign).
    pub levels: u16,
    /// Bucket boundaries = parameter-block dims; a single bucket when empty.
    pub bucket_dims: Vec<usize>,
    rngs: Vec<Rng>,
}

impl Qsgd {
    pub fn new(levels: u16, bucket_dims: Vec<usize>, n: usize, seed: u64) -> Self {
        assert!(levels >= 1);
        let mut root = Rng::new(seed);
        Qsgd {
            levels,
            bucket_dims,
            rngs: (0..n).map(|i| root.fork(i as u64)).collect(),
        }
    }

    fn buckets_of(&self, d: usize) -> Vec<(usize, usize)> {
        if self.bucket_dims.is_empty() {
            return vec![(0, d)];
        }
        let mut out = Vec::with_capacity(self.bucket_dims.len());
        let mut lo = 0;
        for &bd in &self.bucket_dims {
            out.push((lo, lo + bd));
            lo += bd;
        }
        assert_eq!(lo, d, "bucket dims must tile the gradient");
        out
    }

    /// Encode one worker's gradient.
    pub fn encode(&mut self, rank: usize, grad: &[f32]) -> Vec<QsgdBucket> {
        let s = self.levels as f64;
        let buckets = self.buckets_of(grad.len());
        let rng = &mut self.rngs[rank];
        buckets
            .iter()
            .map(|&(lo, hi)| {
                let v = &grad[lo..hi];
                let norm = l2_norm(v) as f32;
                let levels = if norm == 0.0 {
                    vec![0i16; v.len()]
                } else {
                    v.iter()
                        .map(|&x| {
                            let r = (x.abs() as f64 / norm as f64) * s;
                            let base = r.floor();
                            let l = base as i16
                                + (rng.uniform() < r - base) as i16;
                            if x < 0.0 {
                                -l
                            } else {
                                l
                            }
                        })
                        .collect()
                };
                QsgdBucket { norm, levels }
            })
            .collect()
    }

    /// Decode one worker's message.
    pub fn decode(&self, msg: &[QsgdBucket], out: &mut Vec<f32>) {
        out.clear();
        let s = self.levels as f32;
        for b in msg {
            out.extend(b.levels.iter().map(|&l| b.norm * l as f32 / s));
        }
    }

    /// Wire bytes: one byte per coordinate (sign + 6-bit level packs into
    /// 7 bits; we charge 1 byte as the GRACE implementation does) + the
    /// fp32 norm per bucket.
    pub fn wire_bytes(&self, d: usize) -> usize {
        let nbuckets = if self.bucket_dims.is_empty() { 1 } else { self.bucket_dims.len() };
        d + 4 * nbuckets
    }
}

impl DistributedCompressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd_{}levels", self.levels)
    }

    fn supports_allreduce(&self) -> bool {
        false // per-worker norms: not summable in flight
    }

    fn round(&mut self, grads: &[Vec<f32>], _ctx: &RoundCtx) -> RoundResult {
        let n = grads.len();
        let d = grads[0].len();

        let t0 = Instant::now();
        let msgs: Vec<Vec<QsgdBucket>> = (0..n)
            .map(|i| self.encode(i, &grads[i]))
            .collect();
        // per-worker encode cost: the n encodes run in parallel in reality
        let encode_seconds = t0.elapsed().as_secs_f64() / n as f64;

        // all-gather + decode + average at every worker (this n-message
        // decode loop IS the per-worker cost: every worker decodes all n)
        let t1 = Instant::now();
        let mut gtilde = vec![0.0f32; d];
        let mut buf = Vec::with_capacity(d);
        for msg in &msgs {
            self.decode(msg, &mut buf);
            for (o, &x) in gtilde.iter_mut().zip(&buf) {
                *o += x;
            }
        }
        let inv = 1.0 / n as f32;
        for o in &mut gtilde {
            *o *= inv;
        }
        let decode_seconds = t1.elapsed().as_secs_f64();

        RoundResult {
            gtilde,
            comm: vec![CommOp {
                primitive: Primitive::AllGather,
                bytes_per_worker: self.wire_bytes(d),
            }],
            encode_seconds,
            decode_seconds,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundCtx;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn ctx(d: usize, n: usize) -> RoundCtx {
        RoundCtx { round: 1, n, d, lr: 0.1, step_norm_sq: 0.0, blocks: vec![] }
    }

    #[test]
    fn roundtrip_preserves_signs_and_bounds() {
        let mut q = Qsgd::new(64, vec![], 1, 3);
        let g = vec![0.5f32, -0.3, 0.0, 1.0, -1.0];
        let msg = q.encode(0, &g);
        let mut out = Vec::new();
        q.decode(&msg, &mut out);
        assert_eq!(out.len(), g.len());
        for (&o, &x) in out.iter().zip(&g) {
            assert!(o.signum() * x.signum() >= 0.0, "sign flip {o} vs {x}");
            assert!(o.abs() <= msg[0].norm * 1.001);
        }
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn unbiased_estimator() {
        let g = vec![0.37f32, -0.81, 0.12, 0.55];
        let mut q = Qsgd::new(4, vec![], 1, 44);
        let mut acc = vec![0f64; g.len()];
        let trials = 40_000;
        let mut buf = Vec::new();
        for _ in 0..trials {
            let msg = q.encode(0, &g);
            q.decode(&msg, &mut buf);
            for (a, &x) in acc.iter_mut().zip(&buf) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            let mean = a / trials as f64;
            assert!((mean - x as f64).abs() < 0.01, "mean {mean} vs {x}");
        }
    }

    #[test]
    fn buckets_tile_gradient() {
        let mut q = Qsgd::new(64, vec![3, 5, 2], 1, 0);
        let g: Vec<f32> = (0..10).map(|i| i as f32 / 10.0).collect();
        let msg = q.encode(0, &g);
        assert_eq!(msg.len(), 3);
        assert_eq!(msg[0].levels.len(), 3);
        assert_eq!(msg[1].levels.len(), 5);
        assert_eq!(msg[2].levels.len(), 2);
    }

    #[test]
    #[should_panic(expected = "tile")]
    fn mismatched_buckets_rejected() {
        let mut q = Qsgd::new(64, vec![3, 3], 1, 0);
        q.encode(0, &[0.0; 10]);
    }

    #[test]
    fn wire_smaller_than_fp32() {
        let q = Qsgd::new(64, vec![100, 200], 1, 0);
        assert!(q.wire_bytes(300) < 300 * 4);
    }

    #[test]
    fn quantization_error_vanishes_with_levels() {
        prop_check(0x05D, 30, |rng| {
            let d = 1 + rng.usize_below(200);
            let g = rng.normal_vec(d, 1.0);
            let mut coarse = Qsgd::new(4, vec![], 1, 1);
            let mut fine = Qsgd::new(1024, vec![], 1, 1);
            let mut bc = Vec::new();
            let mut bf = Vec::new();
            let mc = coarse.encode(0, &g);
            coarse.decode(&mc, &mut bc);
            let mf = fine.encode(0, &g);
            fine.decode(&mf, &mut bf);
            let ec: f64 = g.iter().zip(&bc).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let ef: f64 = g.iter().zip(&bf).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            prop_assert!(ef <= ec + 1e-9, "fine {ef} vs coarse {ec}");
            Ok(())
        });
    }
}
