//! QSGD (Alistarh et al., 2017): per-bucket normalized stochastic
//! quantization to s levels.
//!
//! Each bucket (one parameter block / layer, matching the paper's setup
//! "we use the gradient matrix of each layer as a bucket" with 64 levels)
//! ships its l2 norm plus one (sign, level) pair per coordinate. Because
//! the norms differ per worker, the messages are NOT summable in-flight:
//! QSGD requires all-gather + per-worker decompression, which is the
//! systems cost Tables 2-3 demonstrate.
//!
//! Bucket geometry: the configured `bucket_dims` when non-empty, otherwise
//! the round's parameter-block layout from `RoundCtx.blocks` (one bucket
//! per layer, the paper's setting).

use crate::coordinator::RoundCtx;
use crate::util::stats::l2_norm;
use crate::util::Rng;

use super::engine::{
    spans_from_ctx, BlockSpan, Message, PassOutcome, PassPlan, PhasedCompressor,
    RankEncoder, RankMessages, Reducer, RoundArena,
};
use super::{CommOp, Primitive, RoundResult};

/// One encoded bucket.
#[derive(Clone, Debug)]
pub struct QsgdBucket {
    pub norm: f32,
    /// signed level per coordinate, |level| <= s
    pub levels: Vec<i16>,
}

pub struct Qsgd {
    /// Quantization levels (paper: 64, i.e. ~6 bits + sign).
    pub levels: u16,
    /// Bucket boundaries = parameter-block dims; the ctx layout (or a
    /// single bucket) when empty.
    pub bucket_dims: Vec<usize>,
    n: usize,
    streams: Vec<Option<Rng>>,
    encoders: Vec<Box<dyn RankEncoder>>,
    acc: Vec<f32>,
    nbuckets: usize,
    d: usize,
}

impl Qsgd {
    pub fn new(levels: u16, bucket_dims: Vec<usize>, n: usize, seed: u64) -> Self {
        assert!(levels >= 1);
        let mut root = Rng::new(seed);
        Qsgd {
            levels,
            bucket_dims,
            n,
            streams: (0..n).map(|i| Some(root.fork(i as u64))).collect(),
            encoders: Vec::new(),
            acc: Vec::new(),
            nbuckets: 1,
            d: 0,
        }
    }

    /// Bucket spans for dims tiling a d-dimensional gradient.
    pub fn spans_of(dims: &[usize], d: usize) -> Vec<BlockSpan> {
        if dims.is_empty() {
            return vec![BlockSpan { offset: 0, dim: d }];
        }
        let mut out = Vec::with_capacity(dims.len());
        let mut offset = 0;
        for &bd in dims {
            out.push(BlockSpan { offset, dim: bd });
            offset += bd;
        }
        assert_eq!(offset, d, "bucket dims must tile the gradient");
        out
    }

    /// Quantize one gradient into per-bucket messages, reusing `out`.
    pub fn encode_buckets(
        levels: u16,
        spans: &[BlockSpan],
        grad: &[f32],
        rng: &mut Rng,
        out: &mut Vec<QsgdBucket>,
    ) {
        let s = levels as f64;
        while out.len() < spans.len() {
            out.push(QsgdBucket { norm: 0.0, levels: Vec::new() });
        }
        out.truncate(spans.len());
        for (bucket, span) in out.iter_mut().zip(spans) {
            let v = &grad[span.range()];
            let norm = l2_norm(v) as f32;
            bucket.norm = norm;
            bucket.levels.clear();
            if norm == 0.0 {
                bucket.levels.resize(v.len(), 0);
            } else {
                bucket.levels.extend(v.iter().map(|&x| {
                    let r = (x.abs() as f64 / norm as f64) * s;
                    let base = r.floor();
                    let l = base as i16 + (rng.uniform() < r - base) as i16;
                    if x < 0.0 {
                        -l
                    } else {
                        l
                    }
                }));
            }
        }
    }

    /// Decode one worker's message.
    pub fn decode_buckets(levels: u16, msg: &[QsgdBucket], out: &mut Vec<f32>) {
        out.clear();
        let s = levels as f32;
        for b in msg {
            out.extend(b.levels.iter().map(|&l| b.norm * l as f32 / s));
        }
    }

    /// Wire bytes for a given bucket count: one byte per coordinate (sign
    /// + 6-bit level packs into 7 bits; we charge 1 byte as the GRACE
    /// implementation does) + the fp32 norm per bucket. `RoundResult`
    /// charges the round's actual layout through this.
    pub fn wire_bytes_for(d: usize, nbuckets: usize) -> usize {
        d + 4 * nbuckets
    }

    /// Wire bytes for the *configured* layout (a single bucket when
    /// `bucket_dims` is empty; ctx-derived layouts are charged per round
    /// via [`Qsgd::wire_bytes_for`]).
    pub fn wire_bytes(&self, d: usize) -> usize {
        let nbuckets =
            if self.bucket_dims.is_empty() { 1 } else { self.bucket_dims.len() };
        Self::wire_bytes_for(d, nbuckets)
    }
}

/// One rank's state: its RNG stream and reusable bucket buffers.
struct QsgdEncoder {
    rng: Rng,
    msg: Message,
}

impl RankEncoder for QsgdEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Buckets { spans, levels } => {
                let out = self.msg.buckets_mut();
                Qsgd::encode_buckets(*levels, spans, grad, &mut self.rng, out);
            }
            _ => panic!("Qsgd encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }
}

impl PhasedCompressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd_{}levels", self.levels)
    }

    fn supports_allreduce(&self) -> bool {
        false // per-worker norms: not summable in flight
    }

    fn make_encoder(&mut self, rank: usize) -> Box<dyn RankEncoder> {
        let rng = self
            .streams
            .get_mut(rank)
            .and_then(|s| s.take())
            .unwrap_or_else(|| {
                panic!("rank {rank} exceeds the configured worker count {}", self.n)
            });
        Box::new(QsgdEncoder { rng, msg: Message::Empty })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        let spans = if self.bucket_dims.is_empty() {
            spans_from_ctx(ctx)
        } else {
            Self::spans_of(&self.bucket_dims, ctx.d)
        };
        self.nbuckets = spans.len();
        PassPlan::Buckets { spans, levels: self.levels }
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        _plan: &PassPlan,
        ctx: &RoundCtx,
        _red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        // all-gather + decode + average at every worker (this n-message
        // decode loop IS the per-worker cost: every worker decodes all n)
        let d = ctx.d;
        let s = self.levels as f32;
        self.acc.clear();
        self.acc.resize(d, 0.0);
        for m in msgs.iter() {
            let mut j = 0;
            for b in m.as_buckets() {
                for &l in &b.levels {
                    self.acc[j] += b.norm * l as f32 / s;
                    j += 1;
                }
            }
            debug_assert_eq!(j, d);
        }
        let inv = 1.0 / msgs.len() as f32;
        for o in &mut self.acc {
            *o *= inv;
        }
        Ok(PassOutcome::Done)
    }

    fn decode(&mut self, _ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        std::mem::swap(&mut gtilde, &mut self.acc);
        let mut comm = arena.take_comm();
        comm.push(CommOp {
            primitive: Primitive::AllGather,
            bytes_per_worker: Self::wire_bytes_for(self.d, self.nbuckets),
        });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single_span(d: usize) -> Vec<BlockSpan> {
        vec![BlockSpan { offset: 0, dim: d }]
    }

    #[test]
    fn roundtrip_preserves_signs_and_bounds() {
        let mut rng = Rng::new(3);
        let g = vec![0.5f32, -0.3, 0.0, 1.0, -1.0];
        let mut msg = Vec::new();
        Qsgd::encode_buckets(64, &single_span(5), &g, &mut rng, &mut msg);
        let mut out = Vec::new();
        Qsgd::decode_buckets(64, &msg, &mut out);
        assert_eq!(out.len(), g.len());
        for (&o, &x) in out.iter().zip(&g) {
            assert!(o.signum() * x.signum() >= 0.0, "sign flip {o} vs {x}");
            assert!(o.abs() <= msg[0].norm * 1.001);
        }
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn unbiased_estimator() {
        let g = vec![0.37f32, -0.81, 0.12, 0.55];
        let mut rng = Rng::new(44);
        let mut acc = vec![0f64; g.len()];
        let trials = 40_000;
        let mut msg = Vec::new();
        let mut buf = Vec::new();
        for _ in 0..trials {
            Qsgd::encode_buckets(4, &single_span(4), &g, &mut rng, &mut msg);
            Qsgd::decode_buckets(4, &msg, &mut buf);
            for (a, &x) in acc.iter_mut().zip(&buf) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            let mean = a / trials as f64;
            assert!((mean - x as f64).abs() < 0.01, "mean {mean} vs {x}");
        }
    }

    #[test]
    fn buckets_tile_gradient() {
        let mut rng = Rng::new(0);
        let g: Vec<f32> = (0..10).map(|i| i as f32 / 10.0).collect();
        let spans = Qsgd::spans_of(&[3, 5, 2], 10);
        let mut msg = Vec::new();
        Qsgd::encode_buckets(64, &spans, &g, &mut rng, &mut msg);
        assert_eq!(msg.len(), 3);
        assert_eq!(msg[0].levels.len(), 3);
        assert_eq!(msg[1].levels.len(), 5);
        assert_eq!(msg[2].levels.len(), 2);
    }

    #[test]
    #[should_panic(expected = "tile")]
    fn mismatched_buckets_rejected() {
        Qsgd::spans_of(&[3, 3], 10);
    }

    #[test]
    fn wire_smaller_than_fp32() {
        let q = Qsgd::new(64, vec![100, 200], 1, 0);
        assert!(q.wire_bytes(300) < 300 * 4);
    }

    #[test]
    fn quantization_error_vanishes_with_levels() {
        use crate::prop_assert;
        use crate::util::prop::prop_check;
        prop_check(0x05D, 30, |rng| {
            let d = 1 + rng.usize_below(200);
            let g = rng.normal_vec(d, 1.0);
            // identical uniform draws for both level counts: the finer
            // grid can then never do worse coordinate-wise
            let mut coarse_rng = Rng::new(1);
            let mut fine_rng = Rng::new(1);
            let mut mc = Vec::new();
            let mut mf = Vec::new();
            let mut bc = Vec::new();
            let mut bf = Vec::new();
            Qsgd::encode_buckets(4, &single_span(d), &g, &mut coarse_rng, &mut mc);
            Qsgd::decode_buckets(4, &mc, &mut bc);
            Qsgd::encode_buckets(1024, &single_span(d), &g, &mut fine_rng, &mut mf);
            Qsgd::decode_buckets(1024, &mf, &mut bf);
            let ec: f64 =
                g.iter().zip(&bc).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let ef: f64 =
                g.iter().zip(&bf).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            prop_assert!(ef <= ec + 1e-9, "fine {ef} vs coarse {ec}");
            Ok(())
        });
    }
}
