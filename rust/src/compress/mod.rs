//! Gradient compression engine: IntSGD and every baseline the paper
//! evaluates against (Table 1 / §5).
//!
//! Since the parallel-round refactor the zoo is organized around the
//! **phase API** in [`engine`]: every algorithm is a [`PhasedCompressor`]
//! whose per-rank **encode** state ([`engine::RankEncoder`] — RNG stream,
//! error-feedback memory, PowerSGD scratch) is `Send` and executes inside
//! the coordinator's worker threads, while **reduce** (the collective:
//! integer all-reduce, ring all-reduce, all-gather folds) and **decode**
//! run on the leader. `RoundCtx.blocks` threads per-parameter-block
//! geometry through the whole pipeline, so IntSGD and Heuristic IntSGD
//! scale each block with its own alpha (paper Alg. 2).
//!
//! Integer payloads live in typed wire buffers ([`intvec::IntVec`]: `i8` /
//! `i32` lanes instead of widened `i64`), the encode is a fused
//! scale→round→clip→pack pass, and the round outputs recycle through the
//! engine's [`engine::RoundArena`] — steady-state rounds perform zero heap
//! allocation (`tests/zero_alloc.rs`; the INA switch *simulator* is the
//! one exempt reduce path — it hoists per-rank slice views each round).
//!
//! The original monolithic entry point survives as a thin adapter: every
//! `PhasedCompressor` automatically implements [`DistributedCompressor`],
//! whose `round(&[Vec<f32>], &RoundCtx)` drives the same phases
//! sequentially on the caller thread. `tests/engine_parity.rs` pins that
//! the two drivers are bit-identical for the whole zoo.
//!
//! A round produces the shared gradient estimate `g_tilde` plus an exact
//! account of what went on the wire (which collective primitive, how many
//! bytes per worker) and how long encode/decode took on this machine. The
//! wire account feeds the network cost model (`netsim`) that regenerates
//! the paper's Tables 2-3 and Fig. 2; the estimate feeds the optimizer.

pub mod engine;
pub mod error_feedback;
pub mod heuristic;
pub mod identity;
pub mod intsgd;
pub mod intvec;
pub mod natsgd;
pub mod powersgd;
pub mod qsgd;
pub mod signsgd;
pub mod topk;
pub mod wire;

pub use engine::{
    sequential_round, BlockSpan, Message, PassOutcome, PassPlan, PhasedCompressor,
    Pipeline, PoolReducer, RankEncoder, RankMessages, Reducer, RoundArena,
    RoundEngine, SerialReducer,
};
pub use intvec::{IntVec, Lanes};
pub use error_feedback::ErrorFeedback;
pub use heuristic::HeuristicIntSgd;
pub use identity::IdentitySgd;
pub use intsgd::IntSgd;
pub use natsgd::NatSgd;
pub use powersgd::PowerSgd;
pub use qsgd::Qsgd;
pub use signsgd::SignSgd;
pub use topk::TopK;

use crate::coordinator::RoundCtx;

/// The collective primitive a message travels over. Which primitives a
/// compressor supports is the paper's central systems argument (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Primitive {
    /// Ring all-reduce: messages must be summable in-flight.
    AllReduce,
    /// All-gather: every worker receives every message, then decodes.
    AllGather,
    /// SwitchML-style in-network aggregation with integer adders.
    Switch,
}

/// One wire transfer within a round.
#[derive(Clone, Debug)]
pub struct CommOp {
    pub primitive: Primitive,
    /// Payload bytes contributed by each worker.
    pub bytes_per_worker: usize,
}

/// Outcome of one compression round.
#[derive(Clone, Debug)]
pub struct RoundResult {
    /// The decoded average-gradient estimate shared by all workers.
    pub gtilde: Vec<f32>,
    /// Wire schedule for the network cost model.
    pub comm: Vec<CommOp>,
    /// Measured encode wallclock, seconds: the straggler max across ranks
    /// on the parallel path, the per-worker share (total / n) on the
    /// sequential reference.
    pub encode_seconds: f64,
    /// Measured wallclock of the in-process reduce folds, seconds, summed
    /// over passes. Reported for the per-phase benchmarks regardless of
    /// how the fold is *charged* (see `decode_seconds`).
    pub reduce_seconds: f64,
    /// Measured decode wallclock, seconds: the final decode plus — for
    /// all-gather algorithms only — the per-worker fold over the n
    /// messages. In-flight reductions (all-reduce / INA) are not charged
    /// here: their cost belongs to the `netsim` comm model.
    pub decode_seconds: f64,
    /// Largest |integer| in the aggregated message (paper Fig. 6); 0 when
    /// the algorithm does not produce integers.
    pub max_abs_int: i64,
    /// Scale used this round (min over blocks under Alg. 2; 0 when n/a).
    pub alpha: f64,
}

impl RoundResult {
    pub fn wire_bytes_per_worker(&self) -> usize {
        self.comm.iter().map(|c| c.bytes_per_worker).sum()
    }
}

/// The classic single-call shape: one round over the per-worker flattened
/// gradients, every phase on the caller thread. Automatically implemented
/// for every [`PhasedCompressor`]; kept as the parity reference and for
/// call sites that have no worker pool at hand.
pub trait DistributedCompressor: Send {
    fn name(&self) -> String;

    /// Whether the algorithm's messages can be reduced in-flight
    /// (all-reduce / INA) or require all-gather (paper Table 1).
    fn supports_allreduce(&self) -> bool;

    /// Run one round over the per-worker flattened gradients.
    fn round(&mut self, grads: &[Vec<f32>], ctx: &RoundCtx) -> RoundResult;
}

/// Average of per-worker gradients (the uncompressed reference reduction).
pub fn average(grads: &[Vec<f32>]) -> Vec<f32> {
    let n = grads.len();
    assert!(n > 0);
    let d = grads[0].len();
    let mut out = vec![0.0f32; d];
    for g in grads {
        assert_eq!(g.len(), d);
        for (o, &x) in out.iter_mut().zip(g) {
            *o += x;
        }
    }
    let inv = 1.0 / n as f32;
    for o in &mut out {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_of_identical_is_identity() {
        let g = vec![vec![1.0f32, -2.0, 3.5]; 4];
        assert_eq!(average(&g), vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn average_basic() {
        let g = vec![vec![1.0f32, 0.0], vec![3.0f32, 2.0]];
        assert_eq!(average(&g), vec![2.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn average_rejects_mismatched_dims() {
        average(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
