//! NatSGD — natural compression (Horváth et al., 2019): every coordinate
//! is rounded to a signed power of two, stochastically so the operator is
//! unbiased. The wire format is sign + 8-bit exponent (9 bits/coord; the
//! authors' implementation ships exponent bytes + a packed sign bitset),
//! which is NOT summable in flight: like QSGD it needs all-gather — the
//! very bit-level-manipulation overhead the paper's Tables 2-3 measure.

use crate::coordinator::RoundCtx;
use crate::util::Rng;

use super::engine::{
    Message, PassOutcome, PassPlan, PhasedCompressor, RankEncoder, RankMessages,
    Reducer, RoundArena,
};
use super::{CommOp, Primitive, RoundResult};

/// Encoded message: packed sign bits + per-coordinate exponents.
/// exp == EXP_ZERO encodes exact zero.
#[derive(Clone, Debug, Default)]
pub struct NatMsg {
    pub signs: Vec<u64>,
    pub exps: Vec<i16>,
}

pub const EXP_ZERO: i16 = i16::MIN;

pub struct NatSgd {
    n: usize,
    streams: Vec<Option<Rng>>,
    encoders: Vec<Box<dyn RankEncoder>>,
    acc: Vec<f32>,
    scratch: Vec<f32>,
    d: usize,
}

impl NatSgd {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut root = Rng::new(seed);
        NatSgd {
            n,
            streams: (0..n).map(|i| Some(root.fork(i as u64))).collect(),
            encoders: Vec::new(),
            acc: Vec::new(),
            scratch: Vec::new(),
            d: 0,
        }
    }

    /// Natural compression by direct f32 bit manipulation (this is the
    /// point of the scheme: exponent extraction is free). For normal
    /// x = (-1)^s 2^e (1+m), round up to 2^{e+1} with probability m —
    /// exactly the unbiased rule, with m read straight from the mantissa
    /// bits. Subnormals are tiny enough to flush to zero.
    pub fn encode_into(rng: &mut Rng, grad: &[f32], out: &mut NatMsg) {
        out.signs.clear();
        out.signs.resize(grad.len().div_ceil(64), 0);
        out.exps.clear();
        out.exps.reserve(grad.len());
        const MANT_SCALE: f32 = 1.0 / (1u32 << 23) as f32;
        for (j, &x) in grad.iter().enumerate() {
            let bits = x.to_bits();
            let biased = (bits >> 23) & 0xFF;
            if biased == 0 || biased == 0xFF {
                // zero / subnormal / inf / nan -> 0 on the wire
                out.exps.push(EXP_ZERO);
                continue;
            }
            out.signs[j / 64] |= (((bits >> 31) as u64) & 1) << (j % 64);
            // P(round up) = mantissa fraction m in [0, 1)
            let m = (bits & 0x7F_FFFF) as f32 * MANT_SCALE;
            let e = biased as i16 - 127;
            let exp = e + (rng.uniform_f32() < m) as i16;
            out.exps.push(exp.clamp(-126, 127));
        }
    }

    pub fn decode(msg: &NatMsg, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(msg.exps.len());
        for (j, &e) in msg.exps.iter().enumerate() {
            if e == EXP_ZERO {
                out.push(0.0);
                continue;
            }
            // construct +-2^e directly from bits
            let sign = (msg.signs[j / 64] >> (j % 64) & 1) as u32;
            let bits = (sign << 31) | (((e + 127) as u32) << 23);
            out.push(f32::from_bits(bits));
        }
    }

    /// 9 bits per coordinate: 1 sign + 8 exponent.
    pub fn wire_bytes(d: usize) -> usize {
        (d * 9).div_ceil(8)
    }
}

/// One rank's state: its RNG stream and reusable message.
struct NatEncoder {
    rng: Rng,
    msg: Message,
}

impl RankEncoder for NatEncoder {
    fn encode(&mut self, grad: &[f32], plan: &PassPlan) {
        match plan {
            PassPlan::Plain => {
                if !matches!(self.msg, Message::Nat(_)) {
                    self.msg = Message::Nat(NatMsg::default());
                }
                let Message::Nat(msg) = &mut self.msg else { unreachable!() };
                NatSgd::encode_into(&mut self.rng, grad, msg);
            }
            _ => panic!("NatSgd encoder: unexpected plan"),
        }
    }

    fn message(&self) -> &Message {
        &self.msg
    }
}

impl PhasedCompressor for NatSgd {
    fn name(&self) -> String {
        "natsgd".into()
    }

    fn supports_allreduce(&self) -> bool {
        false
    }

    fn make_encoder(&mut self, rank: usize) -> Box<dyn RankEncoder> {
        let rng = self
            .streams
            .get_mut(rank)
            .and_then(|s| s.take())
            .unwrap_or_else(|| {
                panic!("rank {rank} exceeds the configured worker count {}", self.n)
            });
        Box::new(NatEncoder { rng, msg: Message::Empty })
    }

    fn encoders(&mut self) -> &mut Vec<Box<dyn RankEncoder>> {
        &mut self.encoders
    }

    fn begin(&mut self, ctx: &RoundCtx) -> PassPlan {
        self.d = ctx.d;
        PassPlan::Plain
    }

    fn reduce(
        &mut self,
        msgs: &RankMessages,
        _plan: &PassPlan,
        ctx: &RoundCtx,
        _red: &mut dyn Reducer,
    ) -> Result<PassOutcome, crate::net::NetError> {
        let d = ctx.d;
        self.acc.clear();
        self.acc.resize(d, 0.0);
        for m in msgs.iter() {
            NatSgd::decode(m.as_nat(), &mut self.scratch);
            for (o, &x) in self.acc.iter_mut().zip(&self.scratch) {
                *o += x;
            }
        }
        let inv = 1.0 / msgs.len() as f32;
        for o in &mut self.acc {
            *o *= inv;
        }
        Ok(PassOutcome::Done)
    }

    fn decode(&mut self, _ctx: &RoundCtx, arena: &mut RoundArena) -> RoundResult {
        let mut gtilde = arena.take_f32();
        std::mem::swap(&mut gtilde, &mut self.acc);
        let mut comm = arena.take_comm();
        comm.push(CommOp {
            primitive: Primitive::AllGather,
            bytes_per_worker: Self::wire_bytes(self.d),
        });
        RoundResult {
            gtilde,
            comm,
            encode_seconds: 0.0,
            reduce_seconds: 0.0,
            decode_seconds: 0.0,
            max_abs_int: 0,
            alpha: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn decodes_to_powers_of_two() {
        let mut rng = Rng::new(5);
        let g = vec![0.3f32, -1.7, 0.0, 5.0, -0.001];
        let mut msg = NatMsg::default();
        NatSgd::encode_into(&mut rng, &g, &mut msg);
        let mut out = Vec::new();
        NatSgd::decode(&msg, &mut out);
        for (&o, &x) in out.iter().zip(&g) {
            if x == 0.0 {
                assert_eq!(o, 0.0);
            } else {
                assert!(o.abs().log2().fract() == 0.0, "{o} not a power of two");
                assert_eq!(o.signum(), x.signum());
                // within factor 2
                assert!(o.abs() >= x.abs() / 2.0 && o.abs() <= x.abs() * 2.0);
            }
        }
    }

    #[test]
    fn unbiased() {
        let g = vec![0.3f32, -1.7, 5.1, 0.077];
        let mut rng = Rng::new(6);
        let mut acc = vec![0f64; g.len()];
        let trials = 60_000;
        let mut msg = NatMsg::default();
        let mut buf = Vec::new();
        for _ in 0..trials {
            NatSgd::encode_into(&mut rng, &g, &mut msg);
            NatSgd::decode(&msg, &mut buf);
            for (a, &x) in acc.iter_mut().zip(&buf) {
                *a += x as f64;
            }
        }
        for (a, &x) in acc.iter().zip(&g) {
            let mean = a / trials as f64;
            assert!(
                (mean - x as f64).abs() < 0.02 * x.abs().max(0.1) as f64,
                "mean {mean} vs {x}"
            );
        }
    }

    #[test]
    fn wire_is_9_bits_per_coord() {
        assert_eq!(NatSgd::wire_bytes(8), 9);
        assert_eq!(NatSgd::wire_bytes(1000), 1125);
    }

    #[test]
    fn variance_bounded_relative() {
        // natural compression has relative variance <= 1/8 ||x||^2
        prop_check(0xA7, 20, |rng| {
            let d = 1 + rng.usize_below(100);
            let g = rng.normal_vec(d, 1.0);
            let norm_sq: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
            let mut stream = Rng::new(rng.next_u64());
            let mut msg = NatMsg::default();
            let mut buf = Vec::new();
            let mut err = 0.0;
            let reps = 200;
            for _ in 0..reps {
                NatSgd::encode_into(&mut stream, &g, &mut msg);
                NatSgd::decode(&msg, &mut buf);
                err += g
                    .iter()
                    .zip(&buf)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            let mean_err = err / reps as f64;
            prop_assert!(
                mean_err <= 0.25 * norm_sq + 1e-9,
                "err {mean_err} vs bound {}",
                0.125 * norm_sq
            );
            Ok(())
        });
    }
}
