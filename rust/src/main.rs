//! `repro` — the IntSGD reproduction launcher.

use anyhow::Result;

use intsgd::config::Config;

const USAGE: &str = "\
intsgd repro — IntSGD (ICLR 2022) full-system reproduction

USAGE:
  repro exp <id> [key=value ...] [--config file]   run an experiment
  repro train [key=value ...] [--config file]      generic launcher
        (model=classifier|lm|transformer algo=... rounds=... workers=...
         lr=... save=path.ckpt)
  repro net-bench [key=value ...] [--config file]  IntSGD rounds over a
        real transport (transport=tcp|channel algo=ring|halving
        workers=... d=... rounds=...), measured-vs-modeled wire time
  repro list                                       list experiments
  repro artifacts                                  show artifact manifest

Experiments write results/<id>*.csv; see DESIGN.md §4 for the index.
";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("exp") => {
            let id = args.get(1).map(|s| s.as_str()).unwrap_or("");
            let mut cfg = Config::new();
            let mut i = 2;
            while i < args.len() {
                if args[i] == "--config" {
                    i += 1;
                    cfg.merge(Config::load(&args[i])?);
                } else {
                    cfg.set_kv(&args[i])?;
                }
                i += 1;
            }
            intsgd::experiments::run(id, &cfg)
        }
        Some("train") => {
            let mut cfg = Config::new();
            let mut i = 1;
            while i < args.len() {
                if args[i] == "--config" {
                    i += 1;
                    cfg.merge(Config::load(&args[i])?);
                } else {
                    cfg.set_kv(&args[i])?;
                }
                i += 1;
            }
            intsgd::experiments::train_cmd::run(&cfg)
        }
        Some("net-bench") => {
            let mut cfg = Config::new();
            let mut i = 1;
            while i < args.len() {
                if args[i] == "--config" {
                    i += 1;
                    cfg.merge(Config::load(&args[i])?);
                } else {
                    cfg.set_kv(&args[i])?;
                }
                i += 1;
            }
            intsgd::coordinator::net_driver::run(&cfg)
        }
        Some("list") => {
            for (id, desc) in intsgd::experiments::list() {
                println!("{id:12} {desc}");
            }
            Ok(())
        }
        Some("artifacts") => {
            let rt = intsgd::runtime::Runtime::open_default()?;
            for (name, meta) in &rt.manifest.artifacts {
                println!(
                    "{name}: kind={} inputs={} outputs={} grad_dim={}",
                    meta.kind,
                    meta.inputs.len(),
                    meta.outputs,
                    meta.grad_dim
                );
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
