//! `repro` — the IntSGD reproduction launcher.
//!
//! Every subcommand is a thin layer over the typed `api::Session` front
//! door: the CLI's only jobs are to assemble a `Config` (one shared
//! `--config`/`key=value` parser for all subcommands) and to validate it
//! against the subcommand's known-key schema (`api::keys`) so a typo'd
//! knob fails loudly — with a suggestion — instead of silently running a
//! different experiment.

use anyhow::{anyhow, Result};

use intsgd::api;
use intsgd::config::Config;

const USAGE: &str = "\
intsgd repro — IntSGD (ICLR 2022) full-system reproduction

USAGE:
  repro exp <id> [key=value ...] [--config file]   run an experiment
  repro train [key=value ...] [--config file]      generic launcher
        (model=classifier|lm|transformer algo=... rounds=... workers=...
         lr=... save=path.ckpt)
  repro net-bench [key=value ...] [--config file]  IntSGD rounds over a
        real transport (transport=tcp|channel algo=ring|halving
        workers=... d=... rounds=...), measured-vs-modeled wire time
  repro trace [key=value ...] [--config file]      traced run: phase spans
        (encode/reduce/drain/decode per block) -> Chrome trace
        (out=trace.json pipeline=streamed telemetry.listen=127.0.0.1:0
         serve_ms=...); net-bench also takes telemetry.trace_path/.listen
  repro serve [key=value ...] [--config file]      N concurrent jobs over
        ONE shared socket mesh, multiplexed by logical channel
        (jobs=... workers=... d=... rounds=... algo=ring|halving|two-level
         server.schedule=rr|jitter server.jitter_seed=...
         net.mux.queue_frames=... net.timeout_ms=... net.retries=...
         telemetry.listen=... serve_ms=...); each job's result is
        bit-identical to a solo run
  repro list                                       list experiments
  repro artifacts                                  show artifact manifest

ENV:
  INTSGD_NET_TIMEOUT_MS   default blocking-IO deadline for transport
                          backends (the net.timeout_ms knob overrides)
  INTSGD_FORCE_SCALAR     set to 1 to pin the scalar encode/reduce kernels
                          (bit-parity debugging for the simd feature)

TOOLING:
  cargo run -p intlint      repo-invariant static analysis (SAFETY
                            comments, hot-path allocation, checked casts,
                            socket-reachable panics, intrinsic gating,
                            telemetry registration); --json for the
                            machine report, greppable `INTLINT status=`
                            line, waivers via `// intlint: allow(Rn,
                            reason=\"...\")` — see DESIGN.md §12

Experiments write results/<id>*.csv; see DESIGN.md §4 for the index,
§8 for the Session API the subcommands drive, §11 for telemetry, and
§12 for static analysis & soundness (intlint, clippy.toml, Miri/ASan).
";

/// The one `--config file` / `key=value` parser every subcommand shares.
fn cli_config(args: &[String]) -> Result<Config> {
    let mut cfg = Config::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            i += 1;
            let path = args
                .get(i)
                .ok_or_else(|| anyhow!("--config expects a file path"))?;
            cfg.merge(Config::load(path)?);
        } else {
            cfg.set_kv(&args[i])?;
        }
        i += 1;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("exp") => {
            let id = args.get(1).map(|s| s.as_str()).unwrap_or("");
            let cfg = cli_config(&args[2.min(args.len())..])?;
            cfg.validate_keys(api::keys::EXP)?;
            intsgd::experiments::run(id, &cfg)
        }
        Some("train") => {
            let cfg = cli_config(&args[1..])?;
            cfg.validate_keys(api::keys::TRAIN)?;
            intsgd::experiments::train_cmd::run(&cfg)
        }
        Some("net-bench") => {
            let cfg = cli_config(&args[1..])?;
            cfg.validate_keys(api::keys::NET)?;
            intsgd::coordinator::net_driver::run(&cfg)
        }
        Some("trace") => {
            let cfg = cli_config(&args[1..])?;
            cfg.validate_keys(api::keys::TRACE)?;
            intsgd::coordinator::trace_cmd::run(&cfg)
        }
        Some("serve") => {
            let cfg = cli_config(&args[1..])?;
            cfg.validate_keys(api::keys::SERVE)?;
            intsgd::coordinator::serve_cmd::run(&cfg)
        }
        Some("list") => {
            for (id, desc) in intsgd::experiments::list() {
                println!("{id:12} {desc}");
            }
            Ok(())
        }
        Some("artifacts") => {
            let rt = intsgd::runtime::Runtime::open_default()?;
            for (name, meta) in &rt.manifest.artifacts {
                println!(
                    "{name}: kind={} inputs={} outputs={} grad_dim={}",
                    meta.kind,
                    meta.inputs.len(),
                    meta.outputs,
                    meta.grad_dim
                );
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
