//! # intsgd — IntSGD: Adaptive Floatless Compression of Stochastic Gradients
//!
//! Full-system reproduction of Mishchenko, Wang, Kovalev & Richtárik (ICLR
//! 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1** (`python/compile/kernels/`): Pallas kernels for the
//!   compression hot-spot, lowered AOT.
//! - **Layer 2** (`python/compile/model.py`): JAX train/eval graphs,
//!   exported once as HLO text + manifest.
//! - **Layer 3** (this crate): the distributed-training coordinator —
//!   leader/worker runtime, the compressor zoo, collectives, the network
//!   cost model, optimizers, data substrates, and the experiment drivers
//!   that regenerate every table and figure of the paper.
//!
//! The public entry point is [`api::Session`]: a typed, validated builder
//! over train / net / fault / checkpoint paths (DESIGN.md §8). The CLI
//! subcommands, experiment drivers, and examples are all thin layers over
//! it.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod api;
pub mod collective;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod net;
pub mod netsim;
pub mod optim;
pub mod runtime;
pub mod scaling;
pub mod simd;
pub mod telemetry;
pub mod util;
