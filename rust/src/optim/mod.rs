//! Optimizers: SGD with momentum/weight-decay (the paper's deep-learning
//! recipe, §C.1) and IntDIANA (Alg. 3) with GD and L-SVRG estimators for
//! the heterogeneous-data experiments (Fig. 6).

pub mod intdiana;

pub use intdiana::{Estimator, IntDiana};

/// SGD with heavy-ball momentum and decoupled-into-gradient weight decay:
///   v <- m v + (g + wd * x);   x <- x - lr * v
/// (PyTorch SGD semantics, which the paper's experiments use.)
pub struct Sgd {
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(d: usize, momentum: f32, weight_decay: f32) -> Self {
        Sgd { momentum, weight_decay, velocity: vec![0.0; d] }
    }

    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(params.len(), self.velocity.len());
        if self.momentum == 0.0 && self.weight_decay == 0.0 {
            for (p, &g) in params.iter_mut().zip(grad) {
                *p -= lr * g;
            }
            return;
        }
        for ((p, v), &g) in params.iter_mut().zip(&mut self.velocity).zip(grad) {
            let eff = g + self.weight_decay * *p;
            *v = self.momentum * *v + eff;
            *p -= lr * *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut opt = Sgd::new(2, 0.0, 0.0);
        let mut p = vec![1.0f32, -1.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, -0.95]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        opt.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = Sgd::new(1, 0.0, 0.1);
        let mut p = vec![10.0f32];
        opt.step(&mut p, &[0.0], 1.0);
        assert!((p[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic_with_momentum() {
        // f(x) = 0.5 x^2
        let mut opt = Sgd::new(1, 0.9, 0.0);
        let mut p = vec![10.0f32];
        for _ in 0..300 {
            let g = p[0];
            opt.step(&mut p, &[g], 0.05);
        }
        assert!(p[0].abs() < 1e-3, "{}", p[0]);
    }
}
