//! IntDIANA (paper Alg. 3 + Appendix A.2): integer compression of gradient
//! *differences* g_i - h_i against learned per-worker shifts, which fixes
//! IntSGD's max-integer blowup under heterogeneous data (Fig. 6).
//!
//! Per round k (every worker i):
//!   alpha_k = eta sqrt(d) / (sqrt(n) ||x^k - x^{k-1}||)          (Thm. 4)
//!   Q_i     = Int(alpha_k (g_i^k - h_i^k))                       (integers)
//!   h_i    <- h_i + Q_i / alpha_k
//!   gtilde  = h + (1/(n alpha_k)) sum_i Q_i;   h <- same update
//!   x      <- x - eta gtilde
//!
//! Estimators: GD (g_i = full local gradient) or L-SVRG (Kovalev et al.,
//! 2020) with reference-point resampling probability p.

use crate::models::LogReg;
use crate::util::stats::l2_norm_sq;
use crate::util::Rng;

/// Gradient estimator run on each worker (paper §C.5: IntDIANA vs
/// VR-IntDIANA).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Estimator {
    /// Full local gradient.
    Gd,
    /// L-SVRG with reference resample probability p.
    LSvrg { p: f64 },
}

/// Per-round telemetry (drives Fig. 6's two panels).
#[derive(Clone, Debug)]
pub struct DianaRecord {
    pub round: usize,
    /// f(x^k) - f(x^*) surrogate: current global objective.
    pub objective: f64,
    /// max |integer| in the aggregated message sum_i Q_i.
    pub max_abs_int: i64,
    /// gradient oracle calls this round (for the oracle-complexity axis).
    pub oracle_calls: usize,
    /// bits per coordinate actually needed for the aggregate.
    pub agg_bits_per_coord: f64,
}

/// IntDIANA driver over per-worker LogReg shards.
pub struct IntDiana {
    pub estimator: Estimator,
    pub eta: f64,
    /// `None` runs *uncompressed* DIANA-free IntSGD-style full vectors
    /// (the paper's IntGD baseline compresses g_i directly instead of
    /// g_i - h_i); `true` = compress differences (IntDIANA).
    pub use_shifts: bool,
    /// Local shifts h_i and the global shift h.
    h: Vec<Vec<f64>>,
    h_global: Vec<f64>,
    /// L-SVRG reference points w_i and their full gradients.
    w: Vec<Vec<f32>>,
    w_grad: Vec<Vec<f64>>,
    rng: Rng,
}

impl IntDiana {
    pub fn new(n: usize, d: usize, eta: f64, estimator: Estimator, use_shifts: bool, seed: u64) -> Self {
        IntDiana {
            estimator,
            eta,
            use_shifts,
            h: vec![vec![0.0; d]; n],
            h_global: vec![0.0; d],
            w: vec![Vec::new(); n],
            w_grad: vec![Vec::new(); n],
            rng: Rng::new(seed),
        }
    }

    /// Stochastic integer rounding of v (f64 domain), returning ints.
    fn int_round(&mut self, v: &[f64], out: &mut Vec<i64>) {
        out.clear();
        out.extend(v.iter().map(|&t| (t + self.rng.uniform()).floor() as i64));
    }

    /// Worker i's estimator g_i^k (returns (grad, oracle_calls)).
    fn estimate(
        &mut self,
        i: usize,
        shard: &LogReg,
        x: &[f32],
        minibatch: usize,
    ) -> (Vec<f64>, usize) {
        match self.estimator {
            Estimator::Gd => {
                let g = shard.grad(x);
                (g.iter().map(|&v| v as f64).collect(), shard.examples())
            }
            Estimator::LSvrg { p } => {
                let m = shard.examples();
                let d = shard.dim();
                // initialize reference at first use
                if self.w[i].is_empty() {
                    self.w[i] = x.to_vec();
                    self.w_grad[i] =
                        shard.grad(x).iter().map(|&v| v as f64).collect();
                }
                let mut calls = 0usize;
                let mut g = vec![0.0f64; d];
                let mut gx = vec![0.0f64; d];
                let mut gw = vec![0.0f64; d];
                let w_snapshot = self.w[i].clone();
                for _ in 0..minibatch {
                    let l = self.rng.usize_below(m);
                    shard.grad_one(x, l, &mut gx);
                    shard.grad_one(&w_snapshot, l, &mut gw);
                    calls += 2;
                    for j in 0..d {
                        g[j] += gx[j] - gw[j];
                    }
                }
                let inv = 1.0 / minibatch as f64;
                for j in 0..d {
                    g[j] = g[j] * inv + self.w_grad[i][j];
                }
                // resample reference with probability p
                if self.rng.bernoulli(p) {
                    self.w[i] = x.to_vec();
                    self.w_grad[i] =
                        shard.grad(x).iter().map(|&v| v as f64).collect();
                    calls += m;
                }
                (g, calls)
            }
        }
    }

    /// One synchronous round; mutates `x` in place.
    pub fn round(
        &mut self,
        shards: &[LogReg],
        x: &mut Vec<f32>,
        x_prev: &mut Vec<f32>,
        round: usize,
        minibatch: usize,
    ) -> (i64, usize) {
        let n = shards.len();
        let d = x.len();

        // adaptive alpha (Thm. 4): eta sqrt(d) / (sqrt(n) ||x - x_prev||)
        let step_sq = l2_norm_sq(
            &x.iter().zip(x_prev.iter()).map(|(&a, &b)| a - b).collect::<Vec<_>>(),
        );
        let alpha = if round == 0 || step_sq == 0.0 {
            f64::INFINITY // first round exact (paper: first comm uncompressed)
        } else {
            self.eta * (d as f64).sqrt() / ((n as f64).sqrt() * step_sq.sqrt())
        };

        let mut agg = vec![0.0f64; d];
        let mut max_int: i64 = 0;
        let mut oracle = 0usize;
        let mut ints = Vec::with_capacity(d);
        for i in 0..n {
            let (g, calls) = self.estimate(i, &shards[i], x, minibatch);
            oracle += calls;
            if alpha.is_infinite() {
                // exact first communication; also used by pure IntGD when
                // the iterates have stalled exactly.
                for j in 0..d {
                    let delta = if self.use_shifts { g[j] - self.h[i][j] } else { g[j] };
                    agg[j] += delta;
                    if self.use_shifts {
                        self.h[i][j] += delta;
                    }
                }
                continue;
            }
            let diff: Vec<f64> = if self.use_shifts {
                (0..d).map(|j| alpha * (g[j] - self.h[i][j])).collect()
            } else {
                (0..d).map(|j| alpha * g[j]).collect()
            };
            self.int_round(&diff, &mut ints);
            for &v in &ints {
                max_int = max_int.max(v.abs());
            }
            for j in 0..d {
                let dq = ints[j] as f64 / alpha;
                agg[j] += dq;
                if self.use_shifts {
                    self.h[i][j] += dq;
                }
            }
        }
        // NOTE: max_int above is per-worker; the aggregated max is what the
        // paper plots. Recompute by summing per-coordinate — we already
        // summed dq, so derive the aggregate integer domain:
        // sum_i Q_i = alpha * (agg - n*h_old_contrib); simpler: track below.

        let inv_n = 1.0 / n as f64;
        let gtilde: Vec<f64> = if self.use_shifts {
            (0..d).map(|j| self.h_global[j] + agg[j] * inv_n).collect()
        } else {
            (0..d).map(|j| agg[j] * inv_n).collect()
        };
        if self.use_shifts {
            for j in 0..d {
                self.h_global[j] += agg[j] * inv_n;
            }
        }

        x_prev.copy_from_slice(x);
        for j in 0..d {
            x[j] = (x[j] as f64 - self.eta * gtilde[j]) as f32;
        }
        (max_int, oracle)
    }

    /// Full optimization loop with telemetry.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        shards: &[LogReg],
        x0: Vec<f32>,
        rounds: usize,
        minibatch: usize,
        global: &LogReg,
        f_star: f64,
        log_every: usize,
    ) -> (Vec<f32>, Vec<DianaRecord>) {
        let mut x = x0.clone();
        let mut x_prev = x0;
        let mut records = Vec::new();
        for k in 0..rounds {
            let (max_int, oracle) = self.round(shards, &mut x, &mut x_prev, k, minibatch);
            if log_every > 0 && k % log_every == 0 {
                let bits = if max_int > 0 {
                    // signed integers: 1 + ceil(log2(n * max_int + 1))
                    1.0 + (((shards.len() as i64 * max_int) as f64) + 1.0).log2().max(0.0)
                } else {
                    1.0
                };
                records.push(DianaRecord {
                    round: k,
                    objective: global.loss(&x) - f_star,
                    max_abs_int: max_int * shards.len() as i64,
                    oracle_calls: oracle,
                    agg_bits_per_coord: bits,
                });
            }
        }
        (x, records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SparseMatrix;

    /// Heterogeneous shards: each worker's data drawn around a different
    /// direction so grad f_i(x*) != 0.
    fn hetero_shards(n: usize, m: usize, d: usize, seed: u64) -> (Vec<LogReg>, LogReg) {
        let mut rng = Rng::new(seed);
        let mut all_rows = Vec::new();
        let mut all_b = Vec::new();
        let mut shards = Vec::new();
        for i in 0..n {
            let shift: Vec<f32> = (0..d)
                .map(|j| if j == i % d { 2.0 } else { 0.0 })
                .collect();
            let rows: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    (0..d)
                        .map(|j| rng.normal_f32() + shift[j])
                        .collect()
                })
                .collect();
            let b: Vec<f32> = rows
                .iter()
                .map(|r| if r[0] - r[d - 1] > 0.0 { 1.0 } else { -1.0 })
                .collect();
            all_rows.extend(rows.clone());
            all_b.extend(b.clone());
            shards.push(LogReg {
                a: SparseMatrix::from_dense(&rows, d),
                b,
                lambda: 1e-2,
            });
        }
        let global = LogReg {
            a: SparseMatrix::from_dense(&all_rows, d),
            b: all_b,
            lambda: 1e-2,
        };
        (shards, global)
    }

    fn f_star(global: &LogReg) -> (Vec<f32>, f64) {
        let mut x = vec![0.0f32; global.dim()];
        for _ in 0..3000 {
            let g = global.grad(&x);
            for (xi, &gi) in x.iter_mut().zip(&g) {
                *xi -= 1.0 * gi;
            }
        }
        let f = global.loss(&x);
        (x, f)
    }

    #[test]
    fn intdiana_gd_converges_linearly() {
        let (shards, global) = hetero_shards(4, 30, 6, 0);
        let (_, fs) = f_star(&global);
        let mut opt = IntDiana::new(4, 6, 0.5, Estimator::Gd, true, 1);
        let (x, recs) =
            opt.run(&shards, vec![0.0; 6], 400, 0, &global, fs, 50);
        let gap = global.loss(&x) - fs;
        assert!(gap < 1e-6, "gap {gap}");
        // objective decreases over records
        assert!(recs.last().unwrap().objective < recs[0].objective);
    }

    #[test]
    fn intdiana_bounded_integers_vs_intgd_blowup() {
        // The Fig. 6 claim: with heterogeneous data, IntGD's transmitted
        // integers blow up as x -> x*, while IntDIANA's stay bounded.
        let (shards, global) = hetero_shards(4, 30, 6, 3);
        let (_, fs) = f_star(&global);

        let mut diana = IntDiana::new(4, 6, 0.5, Estimator::Gd, true, 4);
        let (_, drecs) =
            diana.run(&shards, vec![0.0; 6], 600, 0, &global, fs, 10);
        let mut intgd = IntDiana::new(4, 6, 0.5, Estimator::Gd, false, 4);
        let (_, grecs) =
            intgd.run(&shards, vec![0.0; 6], 600, 0, &global, fs, 10);

        let d_late: i64 = drecs.iter().rev().take(10).map(|r| r.max_abs_int).max().unwrap();
        let g_late: i64 = grecs.iter().rev().take(10).map(|r| r.max_abs_int).max().unwrap();
        assert!(
            g_late > 10 * d_late.max(1),
            "IntGD late max int {g_late} should dwarf IntDIANA's {d_late}"
        );
    }

    #[test]
    fn lsvrg_converges() {
        let (shards, global) = hetero_shards(3, 40, 5, 7);
        let (_, fs) = f_star(&global);
        let mb = 4;
        let mut opt = IntDiana::new(
            3,
            5,
            0.25,
            Estimator::LSvrg { p: mb as f64 / 40.0 },
            true,
            8,
        );
        let (x, _) = opt.run(&shards, vec![0.0; 5], 1500, mb, &global, fs, 100);
        let gap = global.loss(&x) - fs;
        assert!(gap < 1e-4, "gap {gap}");
    }

    #[test]
    fn oracle_accounting() {
        let (shards, global) = hetero_shards(2, 10, 4, 9);
        let mut opt = IntDiana::new(2, 4, 0.1, Estimator::Gd, true, 10);
        let (_, recs) = opt.run(&shards, vec![0.0; 4], 3, 0, &global, 0.0, 1);
        // GD estimator: every worker touches all m examples per round
        for r in &recs {
            assert_eq!(r.oracle_calls, 2 * 10);
        }
    }
}
