//! Minimal JSON parser for artifacts/manifest.json.
//!
//! The vendored crate set has no serde, so the manifest (written by
//! python/compile/aot.py with the standard library `json` module) is parsed
//! by this hand-rolled recursive-descent parser. It supports the full JSON
//! grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected byte")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed for the manifest;
                            // map lone surrogates to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a Json value (used by metrics writers).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(e, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":{"x":{"shape":[1,2,3],"ok":true}},"n":1.5}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format": 1, "artifacts": {"m": {"file": "m.hlo.txt",
            "inputs": [{"shape": [32, 3072], "dtype": "f32"}],
            "outputs": 7, "grad_dim": 820874}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize(), Some(1));
        let m = v.get("artifacts").unwrap().get("m").unwrap();
        assert_eq!(m.get("grad_dim").unwrap().as_usize(), Some(820874));
    }
}
