//! Shared infrastructure: PRNG, JSON, numerics, property-test harness.
//!
//! These modules exist because the offline vendor set carries no `rand`,
//! `serde`/`serde_json`, or `proptest`; the repository is self-contained.

pub mod cast;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
