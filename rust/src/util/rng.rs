//! Deterministic PRNG (SplitMix64 core + xoshiro256** stream).
//!
//! The offline vendor set has no `rand` crate, so the repository carries its
//! own generator. Everything that needs randomness (data synthesis, worker
//! shards, stochastic rounding, property tests) takes an explicit seed so
//! every experiment is replayable bit-for-bit.

/// xoshiro256** seeded through SplitMix64 (the reference seeding procedure).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the last Box-Muller draw
    spare_normal: Option<f64>,
}

/// Stateless SplitMix64 finalizer: a counter-based random stream. Unlike
/// the sequential generator below, `splitmix64_at(base, j)` has no loop-
/// carried dependency, so hot loops over j auto-vectorize (§Perf: used by
/// the stochastic-rounding encoder).
#[inline]
pub fn splitmix64_at(base: u64, j: u64) -> u64 {
    let mut z = base.wrapping_add(j.wrapping_mul(0x9E3779B97F4A7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per worker) from this one.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Serialize the full generator state — the four xoshiro words plus
    /// the cached Box-Muller spare — so a checkpointed stream resumes at
    /// the exact draw it would have made (bit-exact resume).
    pub fn export_state(&self) -> [u64; 6] {
        [
            self.s[0],
            self.s[1],
            self.s[2],
            self.s[3],
            self.spare_normal.is_some() as u64,
            self.spare_normal.map(f64::to_bits).unwrap_or(0),
        ]
    }

    /// Rebuild a generator from [`Rng::export_state`].
    pub fn from_state(w: [u64; 6]) -> Rng {
        Rng {
            s: [w[0], w[1], w[2], w[3]],
            spare_normal: (w[4] != 0).then(|| f64::from_bits(w[5])),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Two independent uniforms in [0,1) from one generator step (24-bit
    /// precision each) — the hot-path variant used by stochastic rounding.
    #[inline]
    pub fn uniform_f32x2(&mut self) -> (f32, f32) {
        let x = self.next_u64();
        const SCALE: f32 = 1.0 / (1u32 << 24) as f32;
        (
            ((x >> 40) as u32) as f32 * SCALE,
            (((x >> 8) & 0xFF_FFFF) as u32) as f32 * SCALE,
        )
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Vector of iid N(0, sigma^2) f32.
    pub fn normal_vec(&mut self, d: usize, sigma: f32) -> Vec<f32> {
        (0..d).map(|_| sigma * self.normal_f32()).collect()
    }

    /// Vector of iid U[0,1) f32.
    pub fn uniform_vec(&mut self, d: usize) -> Vec<f32> {
        (0..d).map(|_| self.uniform_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_resumes_the_exact_stream() {
        let mut a = Rng::new(99);
        let _ = a.normal(); // leave a cached spare in the state
        let snap = Rng::from_state(a.export_state());
        let mut b = snap;
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_half() {
        let mut r = Rng::new(8);
        let m: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 1e5;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(10);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let idx = r.sample_indices(100, 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(12);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
