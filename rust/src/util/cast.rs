//! Checked narrowing casts — the one audited home for integer narrowing.
//!
//! intlint rule R3 bans raw `as` casts to narrower integer types inside
//! the hostile-input decode paths (`net/`, `compress/wire.rs`,
//! `compress/intvec.rs`): a silent wrap on an attacker-chosen element
//! count or lane tag is how "provably exact" becomes "quietly wrong".
//! Every narrowing in those files goes through this module instead —
//! either a checked `to_*` helper that errors on overflow (surfaced as
//! `NetError::Corrupt` via [`crate::net::NetError::from_cast`], or
//! through `anyhow` in the wire codecs), or one of the named infallible
//! reinterpretations below whose correctness is proved here once.
//!
//! This module itself is *outside* R3's scope by design: raw `as` is
//! reviewed in one place rather than at a hundred call sites.

use std::fmt;

/// A narrowing conversion failed: `value` does not fit in the target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CastError {
    /// The offending value, widened for display. Saturates at
    /// `i128::MAX` for `u128` sources beyond the signed range.
    pub value: i128,
    /// Name of the target type that could not hold it.
    pub target: &'static str,
}

impl fmt::Display for CastError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "value {} does not fit in {}", self.value, self.target)
    }
}

impl std::error::Error for CastError {}

macro_rules! checked_cast {
    ($(#[$doc:meta])* $name:ident => $target:ty) => {
        $(#[$doc])*
        pub fn $name<T>(v: T) -> Result<$target, CastError>
        where
            T: Copy + TryInto<$target> + TryInto<i128>,
        {
            TryInto::<$target>::try_into(v).map_err(|_| CastError {
                value: TryInto::<i128>::try_into(v).unwrap_or(i128::MAX),
                target: stringify!($target),
            })
        }
    };
}

checked_cast!(
    /// Checked conversion to `i8`; `Err` if the value is out of range.
    to_i8 => i8
);
checked_cast!(
    /// Checked conversion to `u8`; `Err` if the value is out of range.
    to_u8 => u8
);
checked_cast!(
    /// Checked conversion to `i16`; `Err` if the value is out of range.
    to_i16 => i16
);
checked_cast!(
    /// Checked conversion to `u16`; `Err` if the value is out of range.
    to_u16 => u16
);
checked_cast!(
    /// Checked conversion to `i32`; `Err` if the value is out of range.
    to_i32 => i32
);
checked_cast!(
    /// Checked conversion to `u32`; `Err` if the value is out of range.
    to_u32 => u32
);
checked_cast!(
    /// Checked conversion to `usize`; `Err` if the value is out of range.
    to_usize => usize
);

// Supported targets are at least 32-bit; `usize_from` relies on it.
const _: () = assert!(usize::BITS >= 32, "intsgd requires a 32-bit-or-wider usize");

/// Infallible `u32 -> usize` widening (the build asserts
/// `usize::BITS >= 32` above, so this can never truncate).
#[inline]
pub fn usize_from(v: u32) -> usize {
    v as usize
}

/// Intentional truncation to the low byte — the wire writers emit
/// little-endian bytes by shifting, and the `& 0xFF` mask makes the
/// truncation explicit rather than incidental.
#[inline]
pub fn low_u8(v: u64) -> u8 {
    (v & 0xFF) as u8
}

/// Bit-reinterpret an `i8` lane as its wire byte (two's complement,
/// value-preserving mod 256; the inverse of [`i8_of_byte`]).
#[inline]
pub fn byte_of_i8(v: i8) -> u8 {
    u8::from_ne_bytes(v.to_ne_bytes())
}

/// Bit-reinterpret a wire byte as an `i8` lane (two's complement; the
/// inverse of [`byte_of_i8`]).
#[inline]
pub fn i8_of_byte(b: u8) -> i8 {
    i8::from_ne_bytes(b.to_ne_bytes())
}

/// Saturating conversion to `u16` for telemetry labels (a journal block
/// id beyond 65534 clamps rather than wraps; `u16::MAX` is the journal's
/// `ALL` sentinel, so saturate one below it).
#[inline]
pub fn sat_u16(v: usize) -> u16 {
    v.try_into().unwrap_or(u16::MAX - 1)
}

/// Saturating conversion to `u32` for telemetry labels and round
/// counters that only feed displays, never the wire.
#[inline]
pub fn sat_u32(v: usize) -> u32 {
    v.try_into().unwrap_or(u32::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checked_casts_accept_in_range_values() {
        assert_eq!(to_i8(-128i32), Ok(-128i8));
        assert_eq!(to_u8(255u16), Ok(255u8));
        assert_eq!(to_i16(255u8), Ok(255i16));
        assert_eq!(to_u16(65_535u32), Ok(65_535u16));
        assert_eq!(to_i32(i64::from(i32::MAX)), Ok(i32::MAX));
        assert_eq!(to_u32(4_294_967_295u64), Ok(u32::MAX));
        assert_eq!(to_usize(7u64), Ok(7usize));
    }

    #[test]
    fn checked_casts_error_with_value_and_target() {
        let e = to_i8(200i32).unwrap_err();
        assert_eq!(e, CastError { value: 200, target: "i8" });
        assert_eq!(e.to_string(), "value 200 does not fit in i8");
        assert!(to_u8(-1i32).is_err());
        assert!(to_i16(40_000u32).is_err());
        assert!(to_u32(u64::MAX).is_err());
        assert!(to_usize(-1i64).is_err());
    }

    #[test]
    fn reinterpretations_round_trip() {
        for b in 0..=u8::MAX {
            assert_eq!(byte_of_i8(i8_of_byte(b)), b);
        }
        assert_eq!(byte_of_i8(-1), 0xFF);
        assert_eq!(i8_of_byte(0x80), i8::MIN);
        assert_eq!(low_u8(0x1234_5678_9ABC_DEF0), 0xF0);
        assert_eq!(usize_from(u32::MAX), u32::MAX as usize);
    }

    #[test]
    fn saturating_casts_clamp() {
        assert_eq!(sat_u16(3), 3);
        assert_eq!(sat_u16(usize::MAX), u16::MAX - 1);
        assert_eq!(sat_u32(9), 9);
        assert_eq!(sat_u32(usize::MAX), u32::MAX);
    }
}
