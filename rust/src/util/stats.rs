//! Small numerical helpers shared across the crate.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for < 2 elements).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Euclidean norm of an f32 slice, accumulated in f64.
pub fn l2_norm(v: &[f32]) -> f64 {
    l2_norm_sq(v).sqrt()
}

/// Squared euclidean norm, accumulated in f64 through the dispatched
/// striped fold (`crate::simd::sq_norm`): element `i` lands in stripe
/// accumulator `i mod 8`, stripes folded in order. The striping *is* the
/// definition — scalar and SIMD backends evaluate the same expression,
/// so the alpha rules fed by this norm see identical bits either way.
pub fn l2_norm_sq(v: &[f32]) -> f64 {
    crate::simd::sq_norm(v)
}

/// Squared euclidean distance ||a - b||^2, accumulated in f64 with the
/// difference fused into the pass — no temporary diff vector (this runs
/// on the coordinator hot path every round). Same striping as
/// [`l2_norm_sq`], with the difference taken in f32 first, so the fused
/// form equals the two-pass subtract-then-norm form bit-for-bit.
pub fn l2_diff_norm_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    crate::simd::sq_diff_norm(a, b)
}

/// Max |x|.
pub fn linf_norm(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// Dot product accumulated in f64.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// a += s * b
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += s * y;
    }
}

/// Elementwise a - b into a fresh vec.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile (0..=100), linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((l2_norm_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-9);
        assert_eq!(linf_norm(&[-7.0, 2.0]), 7.0);
    }

    #[test]
    fn diff_norm_matches_explicit_subtraction() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 1.0, 0.5];
        assert!((l2_diff_norm_sq(&a, &b) - (1.0 + 9.0)).abs() < 1e-12);
        // fused form must equal the two-pass form bit-for-bit (f32
        // subtraction first, f64 accumulation second)
        let diff: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
        assert_eq!(l2_diff_norm_sq(&a, &b), l2_norm_sq(&diff));
    }

    #[test]
    fn dot_axpy_sub() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, -1.0];
        assert!((dot(&a, &b) - 1.0).abs() < 1e-9);
        let mut c = a;
        axpy(&mut c, 2.0, &b);
        assert_eq!(c, [7.0, 0.0]);
        assert_eq!(sub(&a, &b), vec![-2.0, 3.0]);
    }

    #[test]
    fn median_percentile() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 0.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 100.0), 4.0);
        assert!((percentile(&[1.0, 2.0, 3.0, 4.0], 50.0) - 2.5).abs() < 1e-12);
    }
}
