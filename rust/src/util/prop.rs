//! Mini property-testing harness (the vendored crate set has no proptest).
//!
//! Usage:
//! ```ignore
//! prop_check(123, 200, |rng| {
//!     let d = 1 + rng.usize_below(5000);
//!     let v = rng.normal_vec(d, 1.0);
//!     // ... assert the invariant, returning Err(msg) on violation
//!     Ok(())
//! });
//! ```
//! On failure it reports the case index and the derived seed so the exact
//! case can be replayed with `prop_replay`.

use super::rng::Rng;

/// Run `cases` random test cases; panic with a replayable seed on failure.
///
/// Under Miri the case count is capped at 16: the interpreter is ~100x
/// slower than native, and the UB the Miri CI job hunts lives in the
/// decode paths themselves, not in the breadth of the random sweep (the
/// full sweep still runs natively in every other job).
pub fn prop_check<F>(seed: u64, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = if cfg!(miri) { cases.min(16) } else { cases };
    for i in 0..cases {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed on case {i}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn prop_replay<F>(case_seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed property failure (seed {case_seed:#x}): {msg}");
    }
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(1, 50, |rng| {
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        prop_check(2, 50, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.5, "x={x}");
            Ok(())
        });
    }
}
