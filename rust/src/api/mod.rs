//! The public front door: a typed, validated [`Session`] over the whole
//! stack (DESIGN.md §8).
//!
//! Before this module the repository had four ways to wire a run — the
//! CLI `train` path, `net-bench`, `experiments::common`, and each example
//! by hand — all funneling through a stringly-typed `Config` and a
//! string-match compressor factory, each re-implementing the same
//! validation (or skipping it). The paper's pitch is that IntSGD is a
//! drop-in operator; the API should be too:
//!
//! ```text
//! Session::builder()                         SessionBuilder (plain data)
//!     .world(4)                                 │
//!     .model(ModelSpec::flat(1 << 16))          │ build(): every invariant
//!     .sources(quad_factories(...))             │ checked here — wire
//!     .compressor(CompressorSpec::parse(        │ budget (int8 ⇒ n ≤ 127),
//!         "intsgd_random8")?)                   │ pow2 world for halving,
//!     .backend(Backend::Tcp { algo })           │ fault-knob ranges,
//!     .faults(FaultSpec { .. })                 │ checkpoint plumbing —
//!     .checkpoint_every(50)                     │ BEFORE any thread or
//!     .build()?                                 ▼ socket exists
//! Session ── run(k) / step() ──▶ Coordinator::run_round (the one loop)
//!     │            │
//!     │            └─▶ RoundObserver::on_round(RoundRecord, RoundBreakdown)
//!     ├── snapshot() / resume_from(path)   (checkpoint v2, bit-exact)
//!     └── finish() ──▶ TrainResult
//! ```
//!
//! The `Session` drives the same internal layers as ever —
//! `Coordinator`, `RoundEngine`, `WorkerPool`, and the `Reducer` family —
//! so `Session::run` is **bitwise identical** to the legacy
//! `Coordinator::train` path (pinned by `tests/session_api.rs`).

pub mod keys;
pub mod serve;
pub mod spec;

pub use serve::{JobHandle, JobSchedule, SessionServer};
pub use spec::{CompressorSpec, RuleSpec, ZOO};

pub use crate::coordinator::{
    GradientSource, LrSchedule, RoundObserver, RoundRecord, TrainResult,
};
pub use crate::compress::Pipeline;
pub use crate::net::StagedAlgo;
pub use crate::netsim::{Network, RoundBreakdown};

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::compress::engine::{Reducer, SerialReducer};
use crate::compress::{Lanes, RoundEngine};
use crate::coordinator::{Coordinator, TrainConfig, TrainState, WorkerPool};
use crate::net::{
    default_io_timeout, ChannelTransport, FaultPlan, FaultTransport, KillAt,
    MuxTransport, TcpTransport, Transport, TransportReducer,
};
use crate::runtime::Checkpoint;

/// A worker-rank gradient-source factory: runs once, inside the rank's
/// thread (so non-`Send` resources like PJRT clients can live there).
pub type SourceFactory = Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>;

/// Per-round eval hook: `params -> (loss, accuracy)`.
pub type EvalHook = Box<dyn FnMut(&[f32]) -> (f64, f64)>;

/// Where a round's integer reduction executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Leader-side rank-order fold (the parity reference).
    Serial,
    /// Coordinate-chunked fold across the worker pool's threads (the
    /// in-process default — bit-identical to `Serial`).
    Pool,
    /// Staged collective over in-process channel mailboxes: the real
    /// collective schedules without syscalls (tier-1 testable).
    Channel { algo: StagedAlgo },
    /// Staged collective over loopback TCP sockets: framed bytes between
    /// ranks, the measured-wire reference.
    Tcp { algo: StagedAlgo },
    /// Staged collective over one channel of the multiplexed nonblocking
    /// runtime (`net::poll`): by default a private single-channel
    /// loopback mesh, or — via [`SessionBuilder::mux_endpoints`] — one
    /// channel of a mesh shared with other concurrent jobs
    /// ([`SessionServer`]).
    Mux { algo: StagedAlgo },
}

impl Backend {
    fn is_transport(self) -> bool {
        matches!(
            self,
            Backend::Channel { .. } | Backend::Tcp { .. } | Backend::Mux { .. }
        )
    }

    fn staged_algo(self) -> Option<StagedAlgo> {
        match self {
            Backend::Channel { algo } | Backend::Tcp { algo } | Backend::Mux { algo } => Some(algo),
            _ => None,
        }
    }
}

/// Deterministic seeded fault injection over a transport backend
/// (`net::FaultTransport`). All knobs validated at [`SessionBuilder::build`].
#[derive(Clone, Debug, Default)]
pub struct FaultSpec {
    /// Fault-stream seed (defaults to the session seed).
    pub seed: Option<u64>,
    /// Per-frame fault probabilities; each in [0, 1], summing to at most 1.
    pub drop: f64,
    pub dup: f64,
    pub corrupt: f64,
    pub truncate: f64,
    pub delay: f64,
    /// Kill `(rank, collective_round)`: that rank's transport dies for
    /// good at that collective, and the run fails over to the survivors.
    pub kill: Option<(usize, u32)>,
}

impl FaultSpec {
    /// Whether this spec requests anything at all. Any nonzero knob —
    /// including an *invalid* negative one — counts, so a malformed spec
    /// reaches `validate()` instead of silently reading as "no chaos".
    pub fn is_chaotic(&self) -> bool {
        [self.drop, self.dup, self.corrupt, self.truncate, self.delay]
            .iter()
            .any(|&p| p != 0.0)
            || self.kill.is_some()
    }

    fn probability_sum(&self) -> f64 {
        self.drop + self.dup + self.corrupt + self.truncate + self.delay
    }

    fn validate(&self, world: usize) -> Result<()> {
        let ps = [self.drop, self.dup, self.corrupt, self.truncate, self.delay];
        if ps.iter().any(|p| !(0.0..=1.0).contains(p)) || self.probability_sum() > 1.0 {
            return Err(anyhow!(
                "fault probabilities must each lie in [0, 1] and sum to at most 1 \
                 (got drop={} dup={} corrupt={} truncate={} delay={})",
                ps[0], ps[1], ps[2], ps[3], ps[4]
            ));
        }
        if let Some((rank, _)) = self.kill {
            if rank >= world {
                return Err(anyhow!(
                    "fault kill rank {rank} outside the world of {world} workers"
                ));
            }
        }
        Ok(())
    }

    fn plan(&self, default_seed: u64) -> FaultPlan {
        FaultPlan {
            seed: self.seed.unwrap_or(default_seed),
            drop_p: self.drop,
            dup_p: self.dup,
            corrupt_p: self.corrupt,
            truncate_p: self.truncate,
            delay_p: self.delay,
        }
    }

    fn kill_at(&self) -> Option<(usize, KillAt)> {
        self.kill.map(|(rank, round)| (rank, KillAt::Round(round)))
    }
}

/// What the leader optimizes: initial parameters plus the layout (shapes
/// in flattening order) that drives per-block scaling (Alg. 2), PowerSGD
/// matrix factorization, and checkpoint layouts.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    init: Option<Vec<f32>>,
    layout: Vec<Vec<usize>>,
}

impl ModelSpec {
    /// A zero-initialised flat vector of `d` coordinates (one block).
    pub fn flat(d: usize) -> Self {
        ModelSpec { init: None, layout: vec![vec![d]] }
    }

    /// Zero-initialised with an explicit 1-D block layout.
    pub fn blocks(dims: Vec<usize>) -> Self {
        ModelSpec { init: None, layout: dims.into_iter().map(|d| vec![d]).collect() }
    }

    /// Explicit initial parameters over a full shaped layout (what the
    /// PJRT-manifest path provides).
    pub fn with_params(init: Vec<f32>, layout: Vec<Vec<usize>>) -> Self {
        ModelSpec { init: Some(init), layout }
    }

    /// Flattened per-block dims, in order.
    pub fn block_dims(&self) -> Vec<usize> {
        self.layout
            .iter()
            .map(|s| s.iter().product::<usize>().max(1))
            .collect()
    }

    /// Total parameter count.
    pub fn dim(&self) -> usize {
        self.block_dims().iter().sum()
    }
}

/// Wire-side counters of a transport-backed session (None on in-process
/// backends).
#[derive(Clone, Copy, Debug)]
pub struct WireStats {
    /// Staged collectives executed (logical, not attempts).
    pub collectives: u64,
    /// Stale frames the round/seq guard discarded (retry litter).
    pub stale_skipped: u64,
    /// Lane width the last collective shipped its partial sums at.
    pub last_wire: Option<Lanes>,
}

/// The typed builder — plain data until [`SessionBuilder::build`], which
/// validates everything and only then spawns threads/sockets.
pub struct SessionBuilder {
    world: Option<usize>,
    model: Option<ModelSpec>,
    compressor: CompressorSpec,
    backend: Backend,
    network: Option<Network>,
    faults: Option<FaultSpec>,
    sources: Vec<SourceFactory>,
    eval_hook: Option<EvalHook>,
    schedule: Option<LrSchedule>,
    momentum: f32,
    weight_decay: f32,
    eval_every: usize,
    beta: f64,
    eps: f64,
    seed: u64,
    checkpoint_every: usize,
    checkpoint_path: Option<String>,
    net_timeout: Duration,
    net_retries: usize,
    pipeline: Pipeline,
    trace_path: Option<String>,
    metrics_listen: Option<String>,
    mux: Option<Vec<MuxTransport>>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            world: None,
            model: None,
            compressor: CompressorSpec::IntSgd {
                rounding: crate::compress::intsgd::Rounding::Stochastic,
                wire: crate::compress::intsgd::WireInt::Int8,
                rule: RuleSpec::MovingAverage,
            },
            backend: Backend::Pool,
            network: None,
            faults: None,
            sources: Vec::new(),
            eval_hook: None,
            schedule: None,
            momentum: 0.0,
            weight_decay: 0.0,
            eval_every: 0,
            beta: 0.9,
            eps: 1e-8,
            seed: 0,
            checkpoint_every: 0,
            checkpoint_path: None,
            net_timeout: default_io_timeout(),
            net_retries: 8,
            pipeline: Pipeline::Barrier,
            trace_path: None,
            metrics_listen: None,
            mux: None,
        }
    }
}

impl SessionBuilder {
    /// Number of worker ranks. Optional when [`SessionBuilder::sources`]
    /// is given (the source count is the world size); if both are set
    /// they must agree.
    pub fn world(mut self, n: usize) -> Self {
        self.world = Some(n);
        self
    }

    pub fn model(mut self, model: ModelSpec) -> Self {
        self.model = Some(model);
        self
    }

    /// Shorthand for `.model(ModelSpec::blocks(dims))`.
    pub fn blocks(self, dims: Vec<usize>) -> Self {
        self.model(ModelSpec::blocks(dims))
    }

    pub fn compressor(mut self, spec: CompressorSpec) -> Self {
        self.compressor = spec;
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Network cost model for the `comm_seconds` account (default: the
    /// paper cluster for in-process backends, loopback for transports).
    pub fn network(mut self, network: Network) -> Self {
        self.network = Some(network);
        self
    }

    /// Inject deterministic seeded faults (transport backends only).
    pub fn faults(mut self, faults: FaultSpec) -> Self {
        self.faults = Some(faults);
        self
    }

    /// One gradient-source factory per rank, run inside that rank's
    /// worker thread.
    pub fn sources(mut self, sources: Vec<SourceFactory>) -> Self {
        self.sources = sources;
        self
    }

    /// Eval hook, invoked every [`SessionBuilder::eval_every`] rounds.
    pub fn eval_hook(mut self, hook: EvalHook) -> Self {
        self.eval_hook = Some(hook);
        self
    }

    /// Full learning-rate schedule (overrides [`SessionBuilder::lr`]).
    pub fn schedule(mut self, schedule: LrSchedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Constant learning rate (default 0.1).
    pub fn lr(self, lr: f32) -> Self {
        self.schedule(LrSchedule::constant(lr))
    }

    pub fn momentum(mut self, momentum: f32) -> Self {
        self.momentum = momentum;
        self
    }

    pub fn weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    pub fn eval_every(mut self, every: usize) -> Self {
        self.eval_every = every;
        self
    }

    /// Moving-average decay for the IntSGD scaling rules (default 0.9).
    pub fn beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }

    /// Safeguard epsilon for the IntSGD scaling rules (default 1e-8).
    pub fn eps(mut self, eps: f64) -> Self {
        self.eps = eps;
        self
    }

    /// Root seed for the compressor's per-rank RNG streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Snapshot the run to [`SessionBuilder::checkpoint_path`] every `k`
    /// rounds (0 = never).
    pub fn checkpoint_every(mut self, k: usize) -> Self {
        self.checkpoint_every = k;
        self
    }

    pub fn checkpoint_path(mut self, path: impl Into<String>) -> Self {
        self.checkpoint_path = Some(path.into());
        self
    }

    /// Blocking-IO deadline for transport backends (default
    /// `INTSGD_NET_TIMEOUT_MS` or 30 s).
    pub fn net_timeout(mut self, timeout: Duration) -> Self {
        self.net_timeout = timeout;
        self
    }

    /// Retried attempts per collective before giving up (default 8).
    pub fn net_retries(mut self, retries: usize) -> Self {
        self.net_retries = retries;
        self
    }

    /// Record phase spans (encode / reduce / drain / decode, per block)
    /// into the telemetry journal and write them to `path` as a Chrome
    /// `chrome://tracing` trace when the session finishes
    /// ([`Session::finish`], or earlier via [`Session::write_trace`]).
    pub fn trace_path(mut self, path: impl Into<String>) -> Self {
        self.trace_path = Some(path.into());
        self
    }

    /// Serve the Prometheus text endpoint on `addr` (e.g. `127.0.0.1:0`
    /// for an OS-assigned port) for the life of the session.
    pub fn metrics_listen(mut self, addr: impl Into<String>) -> Self {
        self.metrics_listen = Some(addr.into());
        self
    }

    /// Run this session over pre-built mux endpoints — one
    /// [`MuxTransport`] per rank, all on the same channel of a shared
    /// [`MuxTransport::loopback_mesh`]. This is how a [`SessionServer`]
    /// gives each job its own logical channel of one physical socket
    /// mesh; requires [`Backend::Mux`]. Without this, `Backend::Mux`
    /// builds a private single-channel mesh.
    pub fn mux_endpoints(mut self, endpoints: Vec<MuxTransport>) -> Self {
        self.mux = Some(endpoints);
        self
    }

    /// Round driver: [`Pipeline::Barrier`] (default) or
    /// [`Pipeline::Streamed`], the double-buffered block pipeline that
    /// overlaps encode, the collective, and decode (bit-identical output;
    /// rounds the compressor cannot stream fall back to barrier).
    pub fn pipeline(mut self, pipeline: Pipeline) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Validate the whole configuration, then — and only then — spawn the
    /// worker pool and (for transport backends) the socket mesh. Every
    /// invariant that used to assert deep inside a constructor or hang a
    /// socket fails here as a typed error instead.
    pub fn build(self) -> Result<Session> {
        // -- world geometry ---------------------------------------------
        if self.sources.is_empty() {
            return Err(anyhow!(
                "a Session needs gradient sources (SessionBuilder::sources): one \
                 factory per rank"
            ));
        }
        let n = match self.world {
            Some(n) if n != self.sources.len() => {
                return Err(anyhow!(
                    "world({n}) disagrees with the {} gradient sources",
                    self.sources.len()
                ))
            }
            Some(n) => n,
            None => self.sources.len(),
        };
        if n == 0 {
            return Err(anyhow!("the world needs at least one rank"));
        }

        // -- model ------------------------------------------------------
        let model = self
            .model
            .ok_or_else(|| anyhow!("a Session needs a model (SessionBuilder::model)"))?;
        let block_dims = model.block_dims();
        let d: usize = block_dims.iter().sum();
        if d == 0 {
            return Err(anyhow!("the model layout is empty"));
        }
        let init = match model.init {
            Some(init) => {
                if init.len() != d {
                    return Err(anyhow!(
                        "initial parameters ({}) do not tile the layout ({d})",
                        init.len()
                    ));
                }
                init
            }
            None => vec![0.0; d],
        };

        // -- compressor (wire budget etc.) ------------------------------
        self.compressor.validate(n)?;
        if matches!(
            &self.compressor,
            CompressorSpec::IntSgd { rule: RuleSpec::Switch, .. }
        ) && self.backend.is_transport()
        {
            return Err(anyhow!(
                "{}: in-network switch aggregation is a leader-side simulation \
                 and would silently bypass the {:?} transport; use the Serial or \
                 Pool backend",
                self.compressor,
                self.backend
            ));
        }

        // -- backend ----------------------------------------------------
        if self.backend.staged_algo() == Some(StagedAlgo::Halving)
            && !n.is_power_of_two()
        {
            return Err(anyhow!(
                "halving-doubling all-reduce needs a power-of-two world, got {n} \
                 ranks; use StagedAlgo::Ring"
            ));
        }
        if let Some(StagedAlgo::TwoLevel { group }) = self.backend.staged_algo() {
            if group == 0 || group > n || n % group != 0 {
                return Err(anyhow!(
                    "two-level all-reduce needs a group size in 1..={n} that \
                     divides the world evenly, got group {group} over {n} ranks"
                ));
            }
        }
        if self.pipeline == Pipeline::Streamed && self.backend == Backend::Pool {
            return Err(anyhow!(
                "the streamed pipeline reduces each block through an explicit \
                 reducer; the Pool backend folds inside the worker pool and has \
                 none (use Backend::Serial, Channel, Tcp, or Mux)"
            ));
        }
        if let Some(f) = &self.faults {
            if !self.backend.is_transport() {
                return Err(anyhow!(
                    "fault injection wraps a transport; the {:?} backend has none \
                     (use Backend::Channel, Backend::Tcp, or Backend::Mux)",
                    self.backend
                ));
            }
            f.validate(n)?;
        }
        if self.net_timeout.is_zero() {
            return Err(anyhow!("the net timeout must be positive"));
        }
        if let Some(eps) = &self.mux {
            if !matches!(self.backend, Backend::Mux { .. }) {
                return Err(anyhow!(
                    "mux_endpoints were provided but the backend is {:?}; shared \
                     mux channels need Backend::Mux",
                    self.backend
                ));
            }
            if eps.len() != n {
                return Err(anyhow!(
                    "mux_endpoints holds {} transports for a world of {n} ranks",
                    eps.len()
                ));
            }
            for (r, ep) in eps.iter().enumerate() {
                if ep.world() != n {
                    return Err(anyhow!(
                        "mux endpoint {r} belongs to a {}-rank mesh, not {n}",
                        ep.world()
                    ));
                }
                if ep.rank() != r {
                    return Err(anyhow!(
                        "mux endpoint at position {r} reports rank {}; pass the \
                         channel's endpoints in rank order",
                        ep.rank()
                    ));
                }
            }
        }

        // -- checkpointing ----------------------------------------------
        if self.checkpoint_every > 0 && self.checkpoint_path.is_none() {
            return Err(anyhow!(
                "checkpoint_every({}) needs a checkpoint_path",
                self.checkpoint_every
            ));
        }

        // -- telemetry ---------------------------------------------------
        // Bind first: a bad listen address is a configuration error and
        // should fail before the journal flips on or any thread spawns.
        let metrics = match &self.metrics_listen {
            Some(addr) => Some(
                crate::telemetry::MetricsServer::bind(addr)
                    .map_err(|e| anyhow!("telemetry.listen {addr}: {e}"))?,
            ),
            None => None,
        };
        if self.trace_path.is_some() {
            crate::telemetry::journal::enable(crate::telemetry::journal::DEFAULT_CAPACITY);
        }

        // -- construction: nothing below can fail on configuration ------
        let comp = self.compressor.build(n, &model.layout, self.beta, self.eps, self.seed)?;
        let engine = RoundEngine::new(comp);
        let network = self.network.unwrap_or_else(|| {
            if self.backend.is_transport() {
                Network::tcp_loopback()
            } else {
                Network::paper_cluster()
            }
        });
        let faults = self.faults.unwrap_or_default();
        let mut red = match self.backend {
            Backend::Pool => SessionReducer::Pool,
            Backend::Serial => SessionReducer::Serial(SerialReducer),
            Backend::Channel { algo } => {
                let mesh = ChannelTransport::mesh(n);
                if faults.is_chaotic() {
                    let wrapped = FaultTransport::wrap_mesh(
                        mesh,
                        &faults.plan(self.seed),
                        faults.kill_at(),
                    );
                    SessionReducer::ChannelFaulty(TransportReducer::new(wrapped, algo))
                } else {
                    SessionReducer::Channel(TransportReducer::new(mesh, algo))
                }
            }
            Backend::Tcp { algo } => {
                let mesh = TcpTransport::loopback_mesh(n)?;
                if faults.is_chaotic() {
                    let wrapped = FaultTransport::wrap_mesh(
                        mesh,
                        &faults.plan(self.seed),
                        faults.kill_at(),
                    );
                    SessionReducer::TcpFaulty(TransportReducer::new(wrapped, algo))
                } else {
                    SessionReducer::Tcp(TransportReducer::new(mesh, algo))
                }
            }
            Backend::Mux { algo } => {
                // either one channel of a shared mesh (SessionServer) or a
                // private single-channel mesh of our own
                let mesh = match self.mux {
                    Some(endpoints) => endpoints,
                    None => {
                        let mut channels = MuxTransport::loopback_mesh(n, 1)?;
                        channels.remove(0)
                    }
                };
                if faults.is_chaotic() {
                    let wrapped = FaultTransport::wrap_mesh(
                        mesh,
                        &faults.plan(self.seed),
                        faults.kill_at(),
                    );
                    SessionReducer::MuxFaulty(TransportReducer::new(wrapped, algo))
                } else {
                    SessionReducer::Mux(TransportReducer::new(mesh, algo))
                }
            }
        };
        red.configure(self.net_timeout, self.net_retries);

        let cfg = TrainConfig {
            rounds: 0,
            start_round: 0,
            schedule: self.schedule.unwrap_or_else(|| LrSchedule::constant(0.1)),
            momentum: self.momentum,
            weight_decay: self.weight_decay,
            eval_every: self.eval_every,
            pipeline: self.pipeline,
        };
        let coord = Coordinator::new(init, block_dims, network);
        let state = coord.begin(&cfg);
        Ok(Session {
            coord,
            engine,
            pool: WorkerPool::spawn(self.sources),
            red,
            cfg,
            state,
            eval: self.eval_hook,
            checkpoint_every: self.checkpoint_every,
            checkpoint_path: self.checkpoint_path,
            trace_path: self.trace_path,
            metrics,
        })
    }
}

/// The reducer a session was built with. The pool reducer borrows the
/// worker pool per round, so it has no standalone value here.
enum SessionReducer {
    Pool,
    Serial(SerialReducer),
    Channel(TransportReducer<ChannelTransport>),
    ChannelFaulty(TransportReducer<FaultTransport<ChannelTransport>>),
    Tcp(TransportReducer<TcpTransport>),
    TcpFaulty(TransportReducer<FaultTransport<TcpTransport>>),
    Mux(TransportReducer<MuxTransport>),
    MuxFaulty(TransportReducer<FaultTransport<MuxTransport>>),
}

impl SessionReducer {
    fn as_dyn(&mut self) -> Option<&mut dyn Reducer> {
        match self {
            SessionReducer::Pool => None,
            SessionReducer::Serial(r) => Some(r),
            SessionReducer::Channel(r) => Some(r),
            SessionReducer::ChannelFaulty(r) => Some(r),
            SessionReducer::Tcp(r) => Some(r),
            SessionReducer::TcpFaulty(r) => Some(r),
            SessionReducer::Mux(r) => Some(r),
            SessionReducer::MuxFaulty(r) => Some(r),
        }
    }

    fn configure(&mut self, timeout: Duration, retries: usize) {
        fn cfg<T: Transport>(r: &mut TransportReducer<T>, t: Duration, k: usize) {
            r.set_timeout(t);
            r.set_max_retries(k);
        }
        match self {
            SessionReducer::Pool | SessionReducer::Serial(_) => {}
            SessionReducer::Channel(r) => cfg(r, timeout, retries),
            SessionReducer::ChannelFaulty(r) => cfg(r, timeout, retries),
            SessionReducer::Tcp(r) => cfg(r, timeout, retries),
            SessionReducer::TcpFaulty(r) => cfg(r, timeout, retries),
            SessionReducer::Mux(r) => cfg(r, timeout, retries),
            SessionReducer::MuxFaulty(r) => cfg(r, timeout, retries),
        }
    }

    fn wire_stats(&self) -> Option<WireStats> {
        fn stats<T: Transport>(r: &TransportReducer<T>) -> WireStats {
            WireStats {
                collectives: r.calls(),
                stale_skipped: r.stale_skipped(),
                last_wire: r.last_wire(),
            }
        }
        match self {
            SessionReducer::Pool | SessionReducer::Serial(_) => None,
            SessionReducer::Channel(r) => Some(stats(r)),
            SessionReducer::ChannelFaulty(r) => Some(stats(r)),
            SessionReducer::Tcp(r) => Some(stats(r)),
            SessionReducer::TcpFaulty(r) => Some(stats(r)),
            SessionReducer::Mux(r) => Some(stats(r)),
            SessionReducer::MuxFaulty(r) => Some(stats(r)),
        }
    }
}

/// A live run: worker threads up, transport (if any) connected, optimizer
/// and compression state owned. Drive it with [`Session::run`] /
/// [`Session::step`]; close it with [`Session::finish`].
pub struct Session {
    coord: Coordinator,
    engine: RoundEngine,
    pool: WorkerPool,
    red: SessionReducer,
    cfg: TrainConfig,
    state: TrainState,
    eval: Option<EvalHook>,
    checkpoint_every: usize,
    checkpoint_path: Option<String>,
    trace_path: Option<String>,
    metrics: Option<crate::telemetry::MetricsServer>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The next round this session will execute.
    pub fn round(&self) -> usize {
        self.state.round()
    }

    /// Current surviving world size (shrinks on failover).
    pub fn world(&self) -> usize {
        self.pool.workers()
    }

    pub fn params(&self) -> &[f32] {
        &self.coord.params
    }

    pub fn records(&self) -> &[RoundRecord] {
        self.state.records()
    }

    pub fn evals(&self) -> &[(usize, f64, f64)] {
        self.state.evals()
    }

    pub fn failovers(&self) -> &[(usize, usize)] {
        self.state.failovers()
    }

    /// The compressor's display name.
    pub fn algorithm(&self) -> String {
        self.engine.name()
    }

    /// Wire counters of the transport backend (None for in-process
    /// backends).
    pub fn wire_stats(&self) -> Option<WireStats> {
        self.red.wire_stats()
    }

    /// Address the Prometheus endpoint is listening on (None unless
    /// [`SessionBuilder::metrics_listen`] was set).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.metrics.as_ref().map(|m| m.addr())
    }

    /// Flush the phase-span journal to [`SessionBuilder::trace_path`] as a
    /// Chrome trace now, mid-run. [`Session::finish`] does this
    /// automatically.
    pub fn write_trace(&self) -> std::io::Result<()> {
        match &self.trace_path {
            Some(path) => crate::telemetry::write_trace(path),
            None => Ok(()),
        }
    }

    /// Run one synchronous round.
    pub fn step(&mut self) -> Result<RoundRecord> {
        self.step_with(None)
    }

    /// [`Session::step`] with a per-round observer.
    pub fn step_observed(&mut self, obs: &mut dyn RoundObserver) -> Result<RoundRecord> {
        self.step_with(Some(obs))
    }

    fn step_with(&mut self, obs: Option<&mut dyn RoundObserver>) -> Result<RoundRecord> {
        let rec = self
            .coord
            .run_round(
                &mut self.state,
                &mut self.pool,
                &mut self.engine,
                self.red.as_dyn(),
                &self.cfg,
                self.eval.as_deref_mut(),
                obs,
            )
            // keep the typed NetError downcastable: callers distinguish a
            // retryable Timeout from a PeerDead that exhausted failover
            .map_err(|e| {
                anyhow::Error::new(e).context("unrecoverable collective failure")
            })?;
        if self.checkpoint_every > 0 && (rec.round + 1) % self.checkpoint_every == 0 {
            let path = self
                .checkpoint_path
                .clone()
                .expect("checkpoint_path validated at build()");
            self.save_checkpoint(&path)?;
        }
        Ok(rec)
    }

    /// Run `rounds` more rounds.
    pub fn run(&mut self, rounds: usize) -> Result<()> {
        for _ in 0..rounds {
            self.step()?;
        }
        Ok(())
    }

    /// [`Session::run`] with a per-round observer.
    pub fn run_observed(
        &mut self,
        rounds: usize,
        obs: &mut dyn RoundObserver,
    ) -> Result<()> {
        for _ in 0..rounds {
            self.step_observed(obs)?;
        }
        Ok(())
    }

    /// Snapshot the full training state (checkpoint v2: params, prev
    /// params, scaling-rule state, EF residuals, encoder RNG streams).
    pub fn snapshot(&mut self) -> Result<Checkpoint> {
        let round = self.state.round() as u64;
        self.coord.snapshot(&mut self.engine, round)
    }

    pub fn save_checkpoint(&mut self, path: &str) -> Result<()> {
        self.snapshot()?.save(path)
    }

    /// Restore a checkpoint into this session and position the run at its
    /// round — together with deterministic sources this makes the resumed
    /// run bit-exact (`tests/chaos.rs` semantics). Momentum restarts from
    /// zero, exactly as on the legacy resume path.
    pub fn resume_from(&mut self, path: &str) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        let n = self.pool.workers();
        self.coord.restore(&mut self.engine, n, &ck)?;
        self.cfg.start_round = ck.round as usize;
        self.state = self.coord.begin(&self.cfg);
        Ok(())
    }

    /// Shut the worker pool down and return the run's full log. Writes the
    /// Chrome trace (best effort) when a trace path was configured.
    pub fn finish(self) -> TrainResult {
        let Session { coord, mut pool, state, trace_path, .. } = self;
        if let Some(path) = &trace_path {
            if let Err(e) = crate::telemetry::write_trace(path) {
                eprintln!("warning: could not write trace {path}: {e}");
            }
        }
        pool.shutdown();
        coord.finish_run(state)
    }
}
