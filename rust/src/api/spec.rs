//! [`CompressorSpec`]: the typed compressor registry — every algorithm of
//! the paper's zoo as a value, not a string.
//!
//! This replaces the ~100-line string-match factory that used to live in
//! `experiments::common`: the experiment ids (`"intsgd_random8"`,
//! `"powersgd_rank4"`, …) stay the user-facing vocabulary, but they now
//! parse into a typed spec whose `Display` round-trips the id, whose
//! invariants are checked *before* construction ([`CompressorSpec::validate`]
//! — above all the IntSGD wire budget: n clipped int8 messages only
//! provably sum within i8 for n ≤ 127), and whose [`CompressorSpec::build`]
//! is the one place the zoo is instantiated.
//!
//! Legacy ids are canonical: parsing any id in [`ZOO`] and
//! `Display`ing the spec reproduces the id byte for byte, so every config
//! file and results CSV written before this module keeps meaning the same
//! run. Combinations without a legacy name (e.g. the block rule with
//! deterministic rounding) use a systematic grammar,
//! `intsgd_<rule>_<rounding><bits>`, that round-trips the same way.

use std::fmt;

use anyhow::{anyhow, Result};

use crate::compress::intsgd::{Rounding, WireInt};
use crate::compress::powersgd::BlockShape;
use crate::compress::{
    HeuristicIntSgd, IdentitySgd, IntSgd, NatSgd, PhasedCompressor, PowerSgd, Qsgd,
    SignSgd, TopK,
};
use crate::scaling::{AlphaRule, BlockRule, MovingAverageRule, Prop3Rule};

/// Which scaling rule (paper §4 / Appendix A.1) an IntSGD spec uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleSpec {
    /// Alg. 1 / Prop. 2 moving average with safeguard (the paper default).
    MovingAverage,
    /// Prop. 3: the moving-average rule at beta = 0, eps = 0 (ablations).
    Prop3,
    /// Alg. 2 / Prop. 4: one moving average per parameter block.
    Block,
    /// Moving average + aggregation through the INA switch simulator.
    Switch,
}

/// A typed compressor configuration: what `experiments::common` used to
/// express as a bare string. Parse with [`CompressorSpec::parse`]; the
/// `Display` impl round-trips every spec back to its canonical id.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorSpec {
    /// Full-precision SGD over ring all-reduce (`"sgd_ar"`).
    SgdAllReduce,
    /// Full-precision SGD over all-gather (`"sgd_ag"`).
    SgdAllGather,
    /// IntSGD (paper Alg. 1/2): adaptive integer rounding.
    IntSgd { rounding: Rounding, wire: WireInt, rule: RuleSpec },
    /// SwitchML-style heuristic integer quantization at `bits` bits.
    Heuristic { bits: u32 },
    /// QSGD stochastic level quantization (`levels` per bucket).
    Qsgd { levels: u16 },
    /// Natural compression (power-of-two stochastic rounding).
    NatSgd,
    /// PowerSGD rank-`rank` low-rank approximation with error feedback.
    PowerSgd { rank: usize },
    /// Top-k sparsification with error feedback (`ratio` of coordinates).
    TopK { ratio: f64 },
    /// EF-SignSGD (sign + norm, error feedback).
    SignSgd,
}

/// The canonical experiment ids — the exact strings the experiment
/// drivers, config files, and result CSVs have always used. Every entry
/// parses, and `Display` of the parse reproduces the entry.
pub const ZOO: &[&str] = &[
    "sgd_ar",
    "sgd_ag",
    "intsgd_random8",
    "intsgd_random32",
    "intsgd_determ8",
    "intsgd_determ32",
    "intsgd_prop3_32",
    "intsgd_block8",
    "intsgd_switch8",
    "heuristic8",
    "heuristic32",
    "qsgd",
    "natsgd",
    "powersgd",
    "powersgd_rank4",
    "topk",
    "signsgd",
];

fn rounding_token(r: Rounding) -> &'static str {
    match r {
        Rounding::Stochastic => "random",
        Rounding::Deterministic => "determ",
    }
}

fn wire_token(w: WireInt) -> &'static str {
    match w {
        WireInt::Int8 => "8",
        WireInt::Int32 => "32",
    }
}

/// Parse `<rounding><bits>`, e.g. `random8`, `determ32`.
fn parse_rounding_bits(s: &str) -> Option<(Rounding, WireInt)> {
    let (rounding, rest) = if let Some(rest) = s.strip_prefix("random") {
        (Rounding::Stochastic, rest)
    } else if let Some(rest) = s.strip_prefix("determ") {
        (Rounding::Deterministic, rest)
    } else {
        return None;
    };
    let wire = match rest {
        "8" => WireInt::Int8,
        "32" => WireInt::Int32,
        _ => return None,
    };
    Some((rounding, wire))
}

impl CompressorSpec {
    /// Parse a compressor id — every legacy experiment id plus the
    /// systematic extensions. Unknown ids get a "did you mean" suggestion
    /// from the zoo.
    pub fn parse(name: &str) -> Result<Self> {
        if let Some(spec) = Self::parse_opt(name) {
            return Ok(spec);
        }
        Err(match crate::config::closest(name, ZOO) {
            Some(s) => anyhow!("unknown algorithm {name:?}; did you mean {s:?}?"),
            None => anyhow!(
                "unknown algorithm {name:?}; known ids: {}",
                ZOO.join(", ")
            ),
        })
    }

    fn parse_opt(name: &str) -> Option<Self> {
        Some(match name {
            "sgd_ar" => CompressorSpec::SgdAllReduce,
            "sgd_ag" => CompressorSpec::SgdAllGather,
            "qsgd" => CompressorSpec::Qsgd { levels: 64 },
            "natsgd" => CompressorSpec::NatSgd,
            "powersgd" => CompressorSpec::PowerSgd { rank: 2 },
            "topk" => CompressorSpec::TopK { ratio: 0.01 },
            "signsgd" => CompressorSpec::SignSgd,
            _ => {
                if let Some(rest) = name.strip_prefix("intsgd_") {
                    Self::parse_intsgd(rest)?
                } else if let Some(rest) = name.strip_prefix("powersgd_rank") {
                    CompressorSpec::PowerSgd { rank: rest.parse().ok()? }
                } else if let Some(rest) = name.strip_prefix("heuristic") {
                    CompressorSpec::Heuristic { bits: rest.parse().ok()? }
                } else if let Some(rest) = name.strip_prefix("qsgd") {
                    CompressorSpec::Qsgd { levels: rest.parse().ok()? }
                } else if let Some(rest) = name.strip_prefix("topk_") {
                    CompressorSpec::TopK { ratio: rest.parse().ok()? }
                } else {
                    return None;
                }
            }
        })
    }

    fn parse_intsgd(rest: &str) -> Option<Self> {
        // legacy special cases first: they have no rule/rounding separator
        let (rule, tail) = match rest {
            "prop3_32" => {
                return Some(CompressorSpec::IntSgd {
                    rounding: Rounding::Stochastic,
                    wire: WireInt::Int32,
                    rule: RuleSpec::Prop3,
                })
            }
            "block8" => {
                return Some(CompressorSpec::IntSgd {
                    rounding: Rounding::Stochastic,
                    wire: WireInt::Int8,
                    rule: RuleSpec::Block,
                })
            }
            "switch8" => {
                return Some(CompressorSpec::IntSgd {
                    rounding: Rounding::Stochastic,
                    wire: WireInt::Int8,
                    rule: RuleSpec::Switch,
                })
            }
            _ => {
                if let Some(tail) = rest.strip_prefix("prop3_") {
                    (RuleSpec::Prop3, tail)
                } else if let Some(tail) = rest.strip_prefix("block_") {
                    (RuleSpec::Block, tail)
                } else if let Some(tail) = rest.strip_prefix("switch_") {
                    (RuleSpec::Switch, tail)
                } else {
                    (RuleSpec::MovingAverage, rest)
                }
            }
        };
        let (rounding, wire) = parse_rounding_bits(tail)?;
        Some(CompressorSpec::IntSgd { rounding, wire, rule })
    }

    /// Check the invariants construction would otherwise assert on, so a
    /// misconfiguration is a typed error *before* any state exists.
    pub fn validate(&self, n: usize) -> Result<()> {
        if n == 0 {
            return Err(anyhow!("{self}: the world needs at least one rank"));
        }
        match self {
            CompressorSpec::IntSgd { wire, .. } => {
                let budget = wire.max_aggregate();
                if n as i64 > budget {
                    return Err(anyhow!(
                        "{self}: {n} workers overflow the {wire:?} wire — the \
                         aggregate of n clipped integer messages only provably \
                         fits for n <= {budget}"
                    ));
                }
            }
            CompressorSpec::Heuristic { bits } => {
                if !(2..=32).contains(bits) {
                    return Err(anyhow!(
                        "{self}: heuristic bit width must lie in 2..=32"
                    ));
                }
            }
            CompressorSpec::Qsgd { levels } => {
                if *levels == 0 {
                    return Err(anyhow!("{self}: QSGD needs at least one level"));
                }
            }
            CompressorSpec::PowerSgd { rank } => {
                if *rank == 0 {
                    return Err(anyhow!("{self}: PowerSGD rank must be positive"));
                }
            }
            CompressorSpec::TopK { ratio } => {
                if !(*ratio > 0.0 && *ratio <= 1.0) {
                    return Err(anyhow!(
                        "{self}: top-k ratio must lie in (0, 1], got {ratio}"
                    ));
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Instantiate the compressor for an `n`-rank world over the given
    /// parameter layout (shapes in flattening order). `beta`/`eps` feed
    /// the moving-average rules, `seed` forks the per-rank RNG streams —
    /// the exact constructions the legacy string factory performed.
    pub fn build(
        &self,
        n: usize,
        layout: &[Vec<usize>],
        beta: f64,
        eps: f64,
        seed: u64,
    ) -> Result<Box<dyn PhasedCompressor>> {
        self.validate(n)?;
        let numels: Vec<usize> = layout
            .iter()
            .map(|s| s.iter().product::<usize>().max(1))
            .collect();
        Ok(match self {
            CompressorSpec::SgdAllReduce => Box::new(IdentitySgd::allreduce()),
            CompressorSpec::SgdAllGather => Box::new(IdentitySgd::allgather()),
            CompressorSpec::IntSgd { rounding, wire, rule } => {
                let alpha: Box<dyn AlphaRule> = match rule {
                    RuleSpec::MovingAverage | RuleSpec::Switch => {
                        Box::new(MovingAverageRule::new(beta, eps))
                    }
                    RuleSpec::Prop3 => Box::new(Prop3Rule),
                    RuleSpec::Block => Box::new(BlockRule::new(beta, eps)),
                };
                let mut c = IntSgd::new(*rounding, *wire, alpha, n, seed);
                c.use_switch = matches!(rule, RuleSpec::Switch);
                Box::new(c)
            }
            CompressorSpec::Heuristic { bits } => Box::new(HeuristicIntSgd::new(*bits)),
            CompressorSpec::Qsgd { levels } => {
                Box::new(Qsgd::new(*levels, numels, n, seed))
            }
            CompressorSpec::NatSgd => Box::new(NatSgd::new(n, seed)),
            CompressorSpec::PowerSgd { rank } => Box::new(PowerSgd::new(
                *rank,
                layout.iter().map(|s| BlockShape { dims: s.clone() }).collect(),
                n,
                seed,
            )),
            CompressorSpec::TopK { ratio } => Box::new(TopK::new(*ratio, n)),
            CompressorSpec::SignSgd => Box::new(SignSgd::new(n)),
        })
    }

    /// The display name used in the paper's tables (`"?"` where the paper
    /// has no name for the variant — same contract as the legacy map).
    pub fn paper_name(&self) -> &'static str {
        match self {
            CompressorSpec::SgdAllReduce => "SGD (All-reduce)",
            CompressorSpec::SgdAllGather => "SGD (All-gather)",
            CompressorSpec::IntSgd { rule: RuleSpec::MovingAverage, rounding, .. } => {
                match rounding {
                    Rounding::Stochastic => "IntSGD (Random)",
                    Rounding::Deterministic => "IntSGD (Determ.)",
                }
            }
            CompressorSpec::Heuristic { bits: 8 } => "Heuristic IntSGD (8-bit)",
            CompressorSpec::Heuristic { bits: 32 } => "Heuristic IntSGD (32-bit)",
            CompressorSpec::Qsgd { .. } => "QSGD",
            CompressorSpec::NatSgd => "NatSGD",
            CompressorSpec::PowerSgd { .. } => "PowerSGD (EF)",
            CompressorSpec::TopK { .. } => "Top-k (EF)",
            CompressorSpec::SignSgd => "SignSGD (EF)",
            _ => "?",
        }
    }
}

impl fmt::Display for CompressorSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompressorSpec::SgdAllReduce => write!(f, "sgd_ar"),
            CompressorSpec::SgdAllGather => write!(f, "sgd_ag"),
            CompressorSpec::IntSgd { rounding, wire, rule } => {
                let r = rounding_token(*rounding);
                let b = wire_token(*wire);
                match (rule, rounding, wire) {
                    (RuleSpec::MovingAverage, _, _) => write!(f, "intsgd_{r}{b}"),
                    // legacy ids for the combinations the paper names
                    (RuleSpec::Prop3, Rounding::Stochastic, WireInt::Int32) => {
                        write!(f, "intsgd_prop3_32")
                    }
                    (RuleSpec::Block, Rounding::Stochastic, WireInt::Int8) => {
                        write!(f, "intsgd_block8")
                    }
                    (RuleSpec::Switch, Rounding::Stochastic, WireInt::Int8) => {
                        write!(f, "intsgd_switch8")
                    }
                    (RuleSpec::Prop3, ..) => write!(f, "intsgd_prop3_{r}{b}"),
                    (RuleSpec::Block, ..) => write!(f, "intsgd_block_{r}{b}"),
                    (RuleSpec::Switch, ..) => write!(f, "intsgd_switch_{r}{b}"),
                }
            }
            CompressorSpec::Heuristic { bits } => write!(f, "heuristic{bits}"),
            CompressorSpec::Qsgd { levels: 64 } => write!(f, "qsgd"),
            CompressorSpec::Qsgd { levels } => write!(f, "qsgd{levels}"),
            CompressorSpec::NatSgd => write!(f, "natsgd"),
            CompressorSpec::PowerSgd { rank: 2 } => write!(f, "powersgd"),
            CompressorSpec::PowerSgd { rank } => write!(f, "powersgd_rank{rank}"),
            CompressorSpec::TopK { ratio } => {
                if *ratio == 0.01 {
                    write!(f, "topk")
                } else {
                    write!(f, "topk_{ratio}")
                }
            }
            CompressorSpec::SignSgd => write!(f, "signsgd"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_zoo_id_parses_and_round_trips() {
        for id in ZOO {
            let spec = CompressorSpec::parse(id).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert_eq!(&spec.to_string(), id, "Display must reproduce the legacy id");
            assert_eq!(CompressorSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn systematic_grammar_round_trips() {
        for name in [
            "intsgd_prop3_random8",
            "intsgd_block_determ32",
            "intsgd_switch_random32",
            "qsgd128",
            "powersgd_rank7",
            "heuristic16",
            "topk_0.05",
        ] {
            let spec = CompressorSpec::parse(name).unwrap();
            assert_eq!(
                CompressorSpec::parse(&spec.to_string()).unwrap(),
                spec,
                "{name} -> {spec} must round-trip"
            );
        }
    }

    #[test]
    fn unknown_ids_suggest_the_closest_zoo_entry() {
        let err = CompressorSpec::parse("intsgd_randm8").unwrap_err().to_string();
        assert!(err.contains("intsgd_random8"), "{err}");
        let err = CompressorSpec::parse("entirely-made-up").unwrap_err().to_string();
        assert!(err.contains("known ids"), "{err}");
    }

    #[test]
    fn validate_catches_wire_overflow_before_construction() {
        let spec = CompressorSpec::parse("intsgd_random8").unwrap();
        spec.validate(127).unwrap();
        let err = spec.validate(128).unwrap_err().to_string();
        assert!(err.contains("overflow") && err.contains("127"), "{err}");
        // the 32-bit wire has room for any realistic world
        CompressorSpec::parse("intsgd_random32").unwrap().validate(4096).unwrap();
        // zero-rank worlds are rejected for every spec
        assert!(CompressorSpec::SgdAllReduce.validate(0).is_err());
    }
}
