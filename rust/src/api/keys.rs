//! Known-config-key schemas, one per `repro` subcommand.
//!
//! The launcher validates every parsed config against the schema of the
//! subcommand it is about to run ([`crate::config::Config::validate_keys`]),
//! so a typo'd knob (`workrs=16`) is an error with a "did you mean"
//! suggestion instead of a silently different experiment. The lists live
//! here — next to the `Session` builder that defines what the knobs mean —
//! so adding a builder knob and registering its key happen in one place.

/// `repro train` — the generic launcher (`experiments::train_cmd`).
pub const TRAIN: &[&str] = &[
    "model",
    "algo",
    "workers",
    "rounds",
    "seeds",
    "seed",
    "lr",
    "momentum",
    "weight_decay",
    "eval_every",
    "warmup_rounds",
    "beta",
    "eps",
    "artifacts",
    "out_dir",
    "save",
    "train_examples",
    "test_examples",
    "margin",
    "corpus_len",
];

/// `repro exp <id>` — the experiment drivers (everything in TRAIN plus the
/// driver-specific knobs of fig1/fig5/fig6).
pub const EXP: &[&str] = &[
    "model",
    "algo",
    "workers",
    "rounds",
    "seeds",
    "seed",
    "lr",
    "momentum",
    "weight_decay",
    "eval_every",
    "warmup_rounds",
    "beta",
    "eps",
    "artifacts",
    "out_dir",
    "train_examples",
    "test_examples",
    "margin",
    "corpus_len",
    "task",
    "dataset",
    "fstar_iters",
    "eta",
];

/// `repro net-bench` — training over a real transport
/// (`coordinator::net_driver`).
pub const NET: &[&str] = &[
    "workers",
    "d",
    "rounds",
    "lr",
    "seed",
    "transport",
    "algo",
    "pipeline",
    "hierarchy.group_size",
    "net.timeout_ms",
    "net.retries",
    "fault.seed",
    "fault.drop",
    "fault.dup",
    "fault.corrupt",
    "fault.truncate",
    "fault.delay",
    "fault.kill_rank",
    "fault.kill_round",
    "telemetry.trace_path",
    "telemetry.listen",
];

/// `repro trace` — a traced run (`coordinator::trace_cmd`): everything the
/// net path takes, plus the trace output and the optional serve window.
pub const TRACE: &[&str] = &[
    "workers",
    "d",
    "rounds",
    "lr",
    "seed",
    "transport",
    "algo",
    "pipeline",
    "hierarchy.group_size",
    "net.timeout_ms",
    "net.retries",
    "fault.seed",
    "fault.drop",
    "fault.dup",
    "fault.corrupt",
    "fault.truncate",
    "fault.delay",
    "fault.kill_rank",
    "fault.kill_round",
    "telemetry.trace_path",
    "telemetry.listen",
    "out",
    "serve_ms",
];

/// `repro serve` — N concurrent jobs over one shared mux mesh
/// (`coordinator::serve_cmd`).
pub const SERVE: &[&str] = &[
    "jobs",
    "workers",
    "d",
    "rounds",
    "lr",
    "seed",
    "algo",
    "pipeline",
    "hierarchy.group_size",
    "net.timeout_ms",
    "net.retries",
    "net.mux.queue_frames",
    "server.schedule",
    "server.jitter_seed",
    "telemetry.trace_path",
    "telemetry.listen",
    "serve_ms",
];
