//! Multi-job serving: one [`SessionServer`] schedules many built
//! [`Session`]s over a shared physical mesh (DESIGN.md §13).
//!
//! The mux runtime (`net::poll`) already lets N jobs share one socket
//! mesh — each job drives its own logical channel of a
//! [`crate::net::MuxTransport::loopback_mesh`]. This module adds the
//! serving layer on top:
//!
//! ```text
//! SessionServer::new(JobSchedule::RoundRobin)
//!     ├── add_job("tuning-a", session_a, 200)?   channel 0 of the mesh
//!     ├── add_job("tuning-b", session_b, 200)?   channel 1 of the mesh
//!     ├── run_to_completion()?     interleaved rounds, fair quanta
//!     └── shutdown()               graceful: finish() every session
//! ```
//!
//! The scheduler is cooperative and single-threaded: a quantum is one
//! job running `priority` rounds (weighted round-robin), or one round
//! of a uniformly drawn runnable job ([`JobSchedule::Jitter`], seeded —
//! so any interleaving the scheduler can produce is reproducible, and
//! `tests/serve.rs` pins that *every* interleaving yields bit-identical
//! per-job results). Isolation is the transport's: each job's frames
//! ride a private channel with its own round/seq guard, so a fault —
//! even a killed rank — in one job never perturbs a sibling's bytes.
//!
//! Job lifecycle feeds the registry: `SERVER_JOBS_ACTIVE` (gauge) and
//! `SERVER_JOBS_COMPLETED` (counter), alongside the per-channel
//! `intsgd_mux_queue_depth` gauge the transport maintains.

use anyhow::{anyhow, Result};

use super::Session;
use crate::coordinator::{RoundObserver, TrainResult};
use crate::telemetry::m;
use crate::util::Rng;

/// How the server picks the next job to run a quantum for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobSchedule {
    /// Cycle through runnable jobs in admission order; each visit runs
    /// the job's `priority` rounds (so priority 2 gets twice the rounds
    /// per cycle of priority 1).
    RoundRobin,
    /// Seeded uniform pick among runnable jobs, one round per pick —
    /// deterministic scheduler chaos for interleaving-independence
    /// tests.
    Jitter { seed: u64 },
}

/// An admission ticket for one job, valid only on the server that
/// issued it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobHandle(usize);

struct Job {
    name: String,
    session: Session,
    remaining: usize,
    priority: usize,
    observer: Option<Box<dyn RoundObserver>>,
    error: Option<String>,
}

impl Job {
    fn runnable(&self) -> bool {
        self.remaining > 0 && self.error.is_none()
    }
}

/// A cooperative multi-job scheduler over already-built [`Session`]s.
/// See the module docs for the model; the expected wiring gives every
/// job [`super::Backend::Mux`] endpoints on its own channel of one
/// shared mesh ([`super::SessionBuilder::mux_endpoints`]), though any
/// mix of backends is accepted.
pub struct SessionServer {
    jobs: Vec<Job>,
    schedule: JobSchedule,
    rng: Rng,
    cursor: usize,
    draining: bool,
}

impl SessionServer {
    pub fn new(schedule: JobSchedule) -> SessionServer {
        let seed = match schedule {
            JobSchedule::Jitter { seed } => seed,
            JobSchedule::RoundRobin => 0,
        };
        SessionServer {
            jobs: Vec::new(),
            schedule,
            rng: Rng::new(seed),
            cursor: 0,
            draining: false,
        }
    }

    /// Admit a job at priority 1 with no observer.
    pub fn add_job(
        &mut self,
        name: impl Into<String>,
        session: Session,
        rounds: usize,
    ) -> Result<JobHandle> {
        self.add_job_with(name, session, rounds, 1, None)
    }

    /// Admit a job: run `session` for `rounds` rounds, `priority`
    /// consecutive rounds per round-robin visit, streaming each round
    /// to `observer`. Fails once [`SessionServer::drain`] has begun.
    pub fn add_job_with(
        &mut self,
        name: impl Into<String>,
        session: Session,
        rounds: usize,
        priority: usize,
        observer: Option<Box<dyn RoundObserver>>,
    ) -> Result<JobHandle> {
        let name = name.into();
        if self.draining {
            return Err(anyhow!("server is draining; job {name} refused"));
        }
        if rounds == 0 {
            return Err(anyhow!("job {name} wants zero rounds"));
        }
        if priority == 0 {
            return Err(anyhow!("job {name} wants priority 0; the minimum share is 1"));
        }
        let handle = JobHandle(self.jobs.len());
        self.jobs.push(Job {
            name,
            session,
            remaining: rounds,
            priority,
            observer,
            error: None,
        });
        self.publish_active();
        Ok(handle)
    }

    /// Jobs admitted so far (any state).
    pub fn jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Whether the job ran all its rounds (an errored job is *not*
    /// done — see [`SessionServer::error`]).
    pub fn is_done(&self, handle: JobHandle) -> bool {
        let job = &self.jobs[handle.0];
        job.remaining == 0 && job.error.is_none()
    }

    /// The error that stopped this job, if any. One job's failure never
    /// stops its siblings; it is reported here and summarized by
    /// [`SessionServer::run_to_completion`]'s return value.
    pub fn error(&self, handle: JobHandle) -> Option<&str> {
        self.jobs[handle.0].error.as_deref()
    }

    pub fn name(&self, handle: JobHandle) -> &str {
        &self.jobs[handle.0].name
    }

    /// The job's live session (parameters, records, failovers, wire
    /// stats — everything [`Session`] exposes).
    pub fn session(&self, handle: JobHandle) -> &Session {
        &self.jobs[handle.0].session
    }

    /// Shorthand for `session(handle).params()`.
    pub fn params(&self, handle: JobHandle) -> &[f32] {
        self.jobs[handle.0].session.params()
    }

    fn publish_active(&self) {
        let active = self.jobs.iter().filter(|j| j.runnable()).count();
        m::SERVER_JOBS_ACTIVE.set(crate::util::cast::sat_u32(active).into());
    }

    /// Pick the next job index per the schedule, or None when no job is
    /// runnable.
    fn pick(&mut self) -> Option<usize> {
        let runnable = self.jobs.iter().filter(|j| j.runnable()).count();
        if runnable == 0 {
            return None;
        }
        match self.schedule {
            JobSchedule::RoundRobin => {
                for _ in 0..self.jobs.len() {
                    let idx = self.cursor % self.jobs.len();
                    self.cursor += 1;
                    if self.jobs[idx].runnable() {
                        return Some(idx);
                    }
                }
                None
            }
            JobSchedule::Jitter { .. } => {
                let mut nth = self.rng.below(runnable as u64);
                for (idx, job) in self.jobs.iter().enumerate() {
                    if job.runnable() {
                        if nth == 0 {
                            return Some(idx);
                        }
                        nth -= 1;
                    }
                }
                None
            }
        }
    }

    /// Run one scheduling quantum: the picked job executes up to
    /// `priority` rounds (always exactly one under
    /// [`JobSchedule::Jitter`]). Returns whether any job is still
    /// runnable afterwards. A round error parks the job with its error
    /// recorded; siblings are untouched.
    pub fn step(&mut self) -> bool {
        let Some(idx) = self.pick() else {
            return false;
        };
        let quantum = match self.schedule {
            JobSchedule::RoundRobin => self.jobs[idx].priority,
            JobSchedule::Jitter { .. } => 1,
        };
        let job = &mut self.jobs[idx];
        for _ in 0..quantum.min(job.remaining) {
            let stepped = match job.observer.as_deref_mut() {
                Some(obs) => job.session.step_observed(obs),
                None => job.session.step(),
            };
            match stepped {
                Ok(_) => {
                    job.remaining -= 1;
                    if job.remaining == 0 {
                        m::SERVER_JOBS_COMPLETED.inc();
                    }
                }
                Err(e) => {
                    job.error = Some(format!("{e:#}"));
                    break;
                }
            }
        }
        self.publish_active();
        self.jobs.iter().any(Job::runnable)
    }

    /// Drive every job to completion (or to its first error). Errors
    /// are isolated per job and summarized in the returned `Err` once
    /// everything runnable has finished; `Ok` means every job ran all
    /// its rounds.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.step() {}
        let failed: Vec<String> = self
            .jobs
            .iter()
            .filter_map(|j| j.error.as_ref().map(|e| format!("{}: {e}", j.name)))
            .collect();
        if failed.is_empty() {
            Ok(())
        } else {
            Err(anyhow!(
                "{} of {} jobs failed — {}",
                failed.len(),
                self.jobs.len(),
                failed.join("; ")
            ))
        }
    }

    /// Graceful drain: refuse new admissions, then run what remains to
    /// completion.
    pub fn drain(&mut self) -> Result<()> {
        self.draining = true;
        self.run_to_completion()
    }

    /// Shut down: finish every session (worker pools join, traces
    /// flush) and hand back each job's full result, in admission order.
    pub fn shutdown(self) -> Vec<(String, TrainResult)> {
        self.jobs
            .into_iter()
            .map(|j| (j.name, j.session.finish()))
            .collect()
    }
}
