//! Scalar reference kernels: the canonical definition of every hot-path
//! fold, written as fixed-width chunked loops.
//!
//! These are not "slow paths" — they are the *specification*. Every SIMD
//! implementation in `x86`/`neon` must reproduce these functions
//! bit-for-bit (see the module docs of [`crate::simd`] for the per-kernel
//! argument), and `tests/kernel_parity.rs` sweeps the dispatched kernels
//! against this module directly. The chunked structure (width
//! [`STRIPES`] = 8) serves two purposes: it hands LLVM's auto-vectorizer
//! loops with no cross-iteration dependencies, and — for the f64
//! reduction folds, where float addition is *not* associative — it fixes
//! the accumulation association that the SIMD lanes use, so "scalar" and
//! "vector" are the same mathematical expression, not merely close.
//!
//! Kept `pub` (not `pub(crate)`) so the benches can time the fallback
//! against the dispatched kernel on the same machine.

use crate::util::rng::splitmix64_at;

/// Fixed chunk width shared by the scalar fallbacks and the widest SIMD
/// path (AVX2: 8 f32 lanes / 8 u64 splitmix streams per iteration).
pub const STRIPES: usize = 8;

/// Uniform scale: the top 24 bits of a SplitMix64 draw, mapped to [0, 1).
/// Mirrors the Pallas kernel's `u` input resolution exactly.
pub const UNIFORM_SCALE: f32 = 1.0 / (1u32 << 24) as f32;

/// Fold the 8 stripe accumulators of a striped f64 reduction, in stripe
/// order. Shared by the scalar and SIMD norm kernels so the final
/// combine is one expression: `((((((((0+s0)+s1)+s2)+...)+s7)`.
#[inline]
pub(crate) fn combine_stripes(s: &[f64; STRIPES]) -> f64 {
    s.iter().sum()
}

/// Stochastic-rounding fill: `out[k] = floor(grad[k] * a + u_k)` with
/// `u_k` drawn from the counter-based SplitMix64 stream at `(base,
/// j0 + k)`. Clamping and lane packing happen in the caller (see
/// `WireLane::of_rounded`), so this kernel is lane-agnostic.
pub fn round_stoch(grad: &[f32], a: f32, base: u64, j0: u64, out: &mut [f32]) {
    debug_assert_eq!(grad.len(), out.len());
    let mut j = j0;
    for (g8, o8) in grad.chunks_exact(STRIPES).zip(out.chunks_exact_mut(STRIPES)) {
        for (k, (o, &g)) in o8.iter_mut().zip(g8).enumerate() {
            let u = (splitmix64_at(base, j.wrapping_add(k as u64)) >> 40) as f32 * UNIFORM_SCALE;
            *o = (g * a + u).floor();
        }
        j = j.wrapping_add(STRIPES as u64);
    }
    let done = grad.len() / STRIPES * STRIPES;
    for (k, (o, &g)) in out[done..].iter_mut().zip(&grad[done..]).enumerate() {
        let u = (splitmix64_at(base, j.wrapping_add(k as u64)) >> 40) as f32 * UNIFORM_SCALE;
        *o = (g * a + u).floor();
    }
}

/// Deterministic-rounding fill: `out[k] = round_ties_even(grad[k] * a)`
/// (the f32 mirror of `jnp.round`).
pub fn round_determ(grad: &[f32], a: f32, out: &mut [f32]) {
    debug_assert_eq!(grad.len(), out.len());
    for (g8, o8) in grad.chunks_exact(STRIPES).zip(out.chunks_exact_mut(STRIPES)) {
        for (o, &g) in o8.iter_mut().zip(g8) {
            *o = (g * a).round_ties_even();
        }
    }
    let done = grad.len() / STRIPES * STRIPES;
    for (o, &g) in out[done..].iter_mut().zip(&grad[done..]) {
        *o = (g * a).round_ties_even();
    }
}

/// `acc[k] += src[k]`, widening one i8 message into the i64 aggregate.
pub fn add_widen_i8(src: &[i8], acc: &mut [i64]) {
    debug_assert_eq!(src.len(), acc.len());
    for (o, &x) in acc.iter_mut().zip(src) {
        *o += x as i64;
    }
}

/// `acc[k] += src[k]`, widening one i32 message into the i64 aggregate.
pub fn add_widen_i32(src: &[i32], acc: &mut [i64]) {
    debug_assert_eq!(src.len(), acc.len());
    for (o, &x) in acc.iter_mut().zip(src) {
        *o += x as i64;
    }
}

/// `acc[k] += src[k]` at full width.
pub fn add_i64(src: &[i64], acc: &mut [i64]) {
    debug_assert_eq!(src.len(), acc.len());
    for (o, &x) in acc.iter_mut().zip(src) {
        *o += x;
    }
}

/// `dst[k] = src[k]`, widening (all-gather's distribute step).
pub fn copy_widen_i8(src: &[i8], dst: &mut [i64]) {
    debug_assert_eq!(src.len(), dst.len());
    for (o, &x) in dst.iter_mut().zip(src) {
        *o = x as i64;
    }
}

/// Fused multi-rank i8 fold: `acc[k] += Σ_r msgs[r][k]`, accumulated
/// through an i16 intermediate. The caller proves `msgs.len() <=`
/// [`crate::simd::SUM_RANKS_MAX`] (= 128): each lane is `|v| <= 127`, so
/// the cross-rank partial sum is bounded by `128 * 127 = 16256 <
/// i16::MAX` — the i16 chunk cannot overflow. Exact integer arithmetic,
/// so the result is bit-identical to folding the ranks one
/// `add_widen_i8` at a time (in any order).
pub fn sum_ranks_i8(msgs: &[&[i8]], acc: &mut [i64]) {
    assert!(
        msgs.len() <= crate::simd::SUM_RANKS_MAX,
        "{} ranks exceed the fused i16-intermediate bound",
        msgs.len()
    );
    const CHUNK: usize = 64;
    let d = acc.len();
    for m in msgs {
        debug_assert_eq!(m.len(), d);
    }
    let mut tmp = [0i16; CHUNK];
    let mut lo = 0;
    while lo < d {
        let len = CHUNK.min(d - lo);
        let t = &mut tmp[..len];
        t.fill(0);
        for m in msgs {
            for (a, &x) in t.iter_mut().zip(&m[lo..lo + len]) {
                *a += x as i16;
            }
        }
        for (o, &x) in acc[lo..lo + len].iter_mut().zip(t.iter()) {
            *o += x as i64;
        }
        lo += len;
    }
}

/// Decode fill: `out[k] = (sum[k] as f64 * inv) as f32` — the int→f32
/// scale by `1/(n·α)`. The f64 intermediate is part of the contract (an
/// i64 aggregate is not exactly representable in f32).
pub fn decode_scale_i64(sum: &[i64], inv: f64, out: &mut [f32]) {
    debug_assert_eq!(sum.len(), out.len());
    for (s8, o8) in sum.chunks_exact(STRIPES).zip(out.chunks_exact_mut(STRIPES)) {
        for (o, &s) in o8.iter_mut().zip(s8) {
            *o = (s as f64 * inv) as f32;
        }
    }
    let done = sum.len() / STRIPES * STRIPES;
    for (o, &s) in out[done..].iter_mut().zip(&sum[done..]) {
        *o = (s as f64 * inv) as f32;
    }
}

/// Striped squared euclidean norm, f64 accumulation: element `i` is
/// squared into stripe accumulator `i mod 8`, and the stripes are folded
/// by [`combine_stripes`]. This *is* the definition of `l2_norm_sq` —
/// the SIMD kernels compute the identical expression lane-wise.
pub fn sq_norm(v: &[f32]) -> f64 {
    let mut s = [0.0f64; STRIPES];
    for c in v.chunks_exact(STRIPES) {
        for (sj, &x) in s.iter_mut().zip(c) {
            let x = x as f64;
            *sj += x * x;
        }
    }
    let done = v.len() / STRIPES * STRIPES;
    for (sj, &x) in s.iter_mut().zip(&v[done..]) {
        let x = x as f64;
        *sj += x * x;
    }
    combine_stripes(&s)
}

/// Striped squared distance `||a - b||^2`: the difference is taken in
/// f32 (matching the two-pass subtract-then-norm form bit-for-bit), the
/// square is accumulated in f64 with the same striping as [`sq_norm`].
pub fn sq_diff_norm(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = [0.0f64; STRIPES];
    for (a8, b8) in a.chunks_exact(STRIPES).zip(b.chunks_exact(STRIPES)) {
        for (sj, (&x, &y)) in s.iter_mut().zip(a8.iter().zip(b8)) {
            let d = (x - y) as f64;
            *sj += d * d;
        }
    }
    let done = a.len() / STRIPES * STRIPES;
    for (sj, (&x, &y)) in s.iter_mut().zip(a[done..].iter().zip(&b[done..])) {
        let d = (x - y) as f64;
        *sj += d * d;
    }
    combine_stripes(&s)
}

/// Largest |lane| of an i8 buffer, widened before the abs so
/// `|i8::MIN| = 128` is exact.
pub fn max_abs_i8(v: &[i8]) -> i64 {
    let mut m = 0i32;
    for c in v.chunks_exact(STRIPES) {
        for &x in c {
            m = m.max((x as i32).abs());
        }
    }
    for &x in &v[v.len() / STRIPES * STRIPES..] {
        m = m.max((x as i32).abs());
    }
    m as i64
}

/// Largest |lane| of an i32 buffer, widened before the abs.
pub fn max_abs_i32(v: &[i32]) -> i64 {
    let mut m = 0i64;
    for c in v.chunks_exact(STRIPES) {
        for &x in c {
            m = m.max((x as i64).abs());
        }
    }
    for &x in &v[v.len() / STRIPES * STRIPES..] {
        m = m.max((x as i64).abs());
    }
    m
}

/// Largest |lane| of an i64 buffer. Saturating at `i64::MIN` (whose true
/// magnitude does not fit i64); production aggregates are bounded far
/// below by the wire budget, so the saturation is unobservable.
pub fn max_abs_i64(v: &[i64]) -> i64 {
    let mut m = 0i64;
    for &x in v {
        m = m.max(x.saturating_abs());
    }
    m
}
