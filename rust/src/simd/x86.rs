//! x86_64 kernels: AVX2 implementations of all four hot folds, plus an
//! SSE2 subset (the int8-wire trio) for pre-AVX2 hardware. SSE2 is part
//! of the x86_64 baseline, so those functions need no runtime detection;
//! the AVX2 functions carry `#[target_feature]` and are only reached
//! after `is_x86_feature_detected!("avx2")` (see [`super::backend`]).
//!
//! Bit-identity notes (the contract with [`super::scalar`]):
//! - integer kernels (widening adds, the fused i16-intermediate rank
//!   fold, max-abs) are exact arithmetic — identical by construction;
//! - f32/f64 kernels use only per-lane IEEE ops that match the scalar
//!   operators one-for-one: `vmulps`/`vaddps` = `*`/`+`, `vroundps(0x9)`
//!   = `f32::floor`, `vroundps(0x8)` = `f32::round_ties_even`,
//!   `vcvtps2pd` = `as f64`, `vcvtpd2ps` = `as f32` (both sides round to
//!   nearest-even under the default MXCSR/FPCR, which nothing in this
//!   crate changes). No FMA contraction anywhere: intrinsics are not
//!   re-associated by LLVM;
//! - the f64 reduction folds accumulate into the same 8 stripes as the
//!   scalar kernels and share `combine_stripes`, so the addition order
//!   is the *same expression*, not merely close;
//! - the SplitMix64 stream is mixed with 64-bit lane arithmetic built
//!   from `pmuludq` 32x32 products (`mullo_epu64` below) — exact mod
//!   2^64, so the uniforms equal `splitmix64_at` bit-for-bit.

use core::arch::x86_64::*;

use super::scalar;

// ---------------------------------------------------------------------
// fused encode: vectorized SplitMix64 counter stream + round
// ---------------------------------------------------------------------

const GOLD: u64 = 0x9E3779B97F4A7C15;
const MIX1: u64 = 0xBF58476D1CE4E5B9;
const MIX2: u64 = 0x94D049BB133111EB;

/// 64-bit lane-wise `a * b mod 2^64` on AVX2 (which has no `pmullq`):
/// `lo32(a)*lo32(b) + ((lo32(a)*hi32(b) + hi32(a)*lo32(b)) << 32)`.
/// `b_hi` is `b >> 32`, precomputed once per constant.
///
/// Safety: AVX2 only — reachable solely from the `target_feature`-gated
/// kernels below, whose callers verified support.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mullo_epu64(a: __m256i, b: __m256i, b_hi: __m256i) -> __m256i {
    let lo = _mm256_mul_epu32(a, b);
    let a_hi = _mm256_srli_epi64(a, 32);
    let cross = _mm256_add_epi64(_mm256_mul_epu32(a_hi, b), _mm256_mul_epu32(a, b_hi));
    _mm256_add_epi64(lo, _mm256_slli_epi64(cross, 32))
}

/// The SplitMix64 finalizer on 4 u64 lanes (`util::rng::splitmix64_at`
/// minus the counter add, which the caller folds into `z`).
///
/// Safety: AVX2 only — reachable solely from the `target_feature`-gated
/// kernels below, whose callers verified support.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn splitmix_mix(
    z: __m256i,
    m1: __m256i,
    m1h: __m256i,
    m2: __m256i,
    m2h: __m256i,
) -> __m256i {
    let z = _mm256_xor_si256(z, _mm256_srli_epi64(z, 30));
    let z = mullo_epu64(z, m1, m1h);
    let z = _mm256_xor_si256(z, _mm256_srli_epi64(z, 27));
    let z = mullo_epu64(z, m2, m2h);
    _mm256_xor_si256(z, _mm256_srli_epi64(z, 31))
}

/// Safety: caller must have verified AVX2 support. `grad.len() ==
/// out.len()` (checked by the dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn round_stoch(grad: &[f32], a: f32, base: u64, j0: u64, out: &mut [f32]) {
    let n8 = grad.len() / 8 * 8;
    let m1 = _mm256_set1_epi64x(MIX1 as i64);
    let m1h = _mm256_srli_epi64(m1, 32);
    let m2 = _mm256_set1_epi64x(MIX2 as i64);
    let m2h = _mm256_srli_epi64(m2, 32);
    let basev = _mm256_set1_epi64x(base as i64);
    let av = _mm256_set1_ps(a);
    let scalev = _mm256_set1_ps(scalar::UNIFORM_SCALE);
    // picks the low dword of each u64 lane (the >>40 mix result is 24
    // bits, entirely in the low dword)
    let idx = _mm256_setr_epi32(0, 2, 4, 6, 0, 2, 4, 6);
    // counter lanes pre-multiplied by the golden step: lane k holds
    // (j0 + k) * GOLD mod 2^64, advanced by 8*GOLD per iteration —
    // wrapping adds in the vector domain equal wrapping_mul in the
    // scalar domain, so z = base + j*GOLD is exact per lane.
    let jc = |k: u64| j0.wrapping_add(k).wrapping_mul(GOLD) as i64;
    let mut jc_lo = _mm256_setr_epi64x(jc(0), jc(1), jc(2), jc(3));
    let mut jc_hi = _mm256_setr_epi64x(jc(4), jc(5), jc(6), jc(7));
    let step = _mm256_set1_epi64x(GOLD.wrapping_mul(8) as i64);
    let mut i = 0;
    while i < n8 {
        let z0 = splitmix_mix(_mm256_add_epi64(basev, jc_lo), m1, m1h, m2, m2h);
        let z1 = splitmix_mix(_mm256_add_epi64(basev, jc_hi), m1, m1h, m2, m2h);
        let u0 = _mm256_srli_epi64(z0, 40);
        let u1 = _mm256_srli_epi64(z1, 40);
        let p0 = _mm256_permutevar8x32_epi32(u0, idx);
        let p1 = _mm256_permutevar8x32_epi32(u1, idx);
        // [p0.low128 | p1.low128]: 8 u24 counters in element order
        let u24 = _mm256_permute2x128_si256(p0, p1, 0x20);
        let uf = _mm256_mul_ps(_mm256_cvtepi32_ps(u24), scalev);
        let g = _mm256_loadu_ps(grad.as_ptr().add(i));
        let t = _mm256_add_ps(_mm256_mul_ps(g, av), uf);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_floor_ps(t));
        jc_lo = _mm256_add_epi64(jc_lo, step);
        jc_hi = _mm256_add_epi64(jc_hi, step);
        i += 8;
    }
    scalar::round_stoch(&grad[n8..], a, base, j0.wrapping_add(n8 as u64), &mut out[n8..]);
}

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn round_determ(grad: &[f32], a: f32, out: &mut [f32]) {
    let n8 = grad.len() / 8 * 8;
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i < n8 {
        let g = _mm256_loadu_ps(grad.as_ptr().add(i));
        let t = _mm256_mul_ps(g, av);
        let r = _mm256_round_ps(t, _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
        i += 8;
    }
    scalar::round_determ(&grad[n8..], a, &mut out[n8..]);
}

// ---------------------------------------------------------------------
// widening reduce
// ---------------------------------------------------------------------

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn add_widen_i8(src: &[i8], acc: &mut [i64]) {
    let n16 = src.len() / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let x = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
        let q = [
            _mm256_cvtepi8_epi64(x),
            _mm256_cvtepi8_epi64(_mm_srli_si128(x, 4)),
            _mm256_cvtepi8_epi64(_mm_srli_si128(x, 8)),
            _mm256_cvtepi8_epi64(_mm_srli_si128(x, 12)),
        ];
        for (j, qv) in q.iter().enumerate() {
            let p = acc.as_mut_ptr().add(i + 4 * j) as *mut __m256i;
            _mm256_storeu_si256(p, _mm256_add_epi64(_mm256_loadu_si256(p), *qv));
        }
        i += 16;
    }
    scalar::add_widen_i8(&src[n16..], &mut acc[n16..]);
}

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn add_widen_i32(src: &[i32], acc: &mut [i64]) {
    let n8 = src.len() / 8 * 8;
    let mut i = 0;
    while i < n8 {
        let x0 = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
        let x1 = _mm_loadu_si128(src.as_ptr().add(i + 4) as *const __m128i);
        let p0 = acc.as_mut_ptr().add(i) as *mut __m256i;
        let p1 = acc.as_mut_ptr().add(i + 4) as *mut __m256i;
        _mm256_storeu_si256(
            p0,
            _mm256_add_epi64(_mm256_loadu_si256(p0), _mm256_cvtepi32_epi64(x0)),
        );
        _mm256_storeu_si256(
            p1,
            _mm256_add_epi64(_mm256_loadu_si256(p1), _mm256_cvtepi32_epi64(x1)),
        );
        i += 8;
    }
    scalar::add_widen_i32(&src[n8..], &mut acc[n8..]);
}

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn add_i64(src: &[i64], acc: &mut [i64]) {
    let n4 = src.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        let x = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
        let p = acc.as_mut_ptr().add(i) as *mut __m256i;
        _mm256_storeu_si256(p, _mm256_add_epi64(_mm256_loadu_si256(p), x));
        i += 4;
    }
    scalar::add_i64(&src[n4..], &mut acc[n4..]);
}

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn copy_widen_i8(src: &[i8], dst: &mut [i64]) {
    let n16 = src.len() / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let x = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
        let q = [
            _mm256_cvtepi8_epi64(x),
            _mm256_cvtepi8_epi64(_mm_srli_si128(x, 4)),
            _mm256_cvtepi8_epi64(_mm_srli_si128(x, 8)),
            _mm256_cvtepi8_epi64(_mm_srli_si128(x, 12)),
        ];
        for (j, qv) in q.iter().enumerate() {
            _mm256_storeu_si256(dst.as_mut_ptr().add(i + 4 * j) as *mut __m256i, *qv);
        }
        i += 16;
    }
    scalar::copy_widen_i8(&src[n16..], &mut dst[n16..]);
}

/// Safety: AVX2; the dispatch wrapper checks `msgs.len() <=`
/// [`super::SUM_RANKS_MAX`] (the i16-intermediate bound) and equal
/// lengths.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sum_ranks_i8(msgs: &[&[i8]], acc: &mut [i64]) {
    let d = acc.len();
    let n16 = d / 16 * 16;
    let mut i = 0;
    while i < n16 {
        // cross-rank partial sum in i16 lanes: <= 128 ranks * |127| each
        let mut s16 = _mm256_setzero_si256();
        for m in msgs {
            let x = _mm_loadu_si128(m.as_ptr().add(i) as *const __m128i);
            s16 = _mm256_add_epi16(s16, _mm256_cvtepi8_epi16(x));
        }
        // widen the 16 i16 partial sums once and add into the aggregate
        let lo = _mm256_castsi256_si128(s16);
        let hi = _mm256_extracti128_si256(s16, 1);
        let q = [
            _mm256_cvtepi16_epi64(lo),
            _mm256_cvtepi16_epi64(_mm_srli_si128(lo, 8)),
            _mm256_cvtepi16_epi64(hi),
            _mm256_cvtepi16_epi64(_mm_srli_si128(hi, 8)),
        ];
        for (j, qv) in q.iter().enumerate() {
            let p = acc.as_mut_ptr().add(i + 4 * j) as *mut __m256i;
            _mm256_storeu_si256(p, _mm256_add_epi64(_mm256_loadu_si256(p), *qv));
        }
        i += 16;
    }
    // tail: rank-at-a-time (exact integers — order-independent)
    for m in msgs {
        scalar::add_widen_i8(&m[n16..], &mut acc[n16..]);
    }
}

// ---------------------------------------------------------------------
// decode tail
// ---------------------------------------------------------------------

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn decode_scale_i64(sum: &[i64], inv: f64, out: &mut [f32]) {
    let n4 = sum.len() / 4 * 4;
    // exponent-trick i64 -> f64: valid for |x| <= 2^51 - 1, guarded per
    // group (aggregates are bounded far below by the wire budget; the
    // guard only trips on the i64 escape hatch with extreme sums)
    let magic_i = _mm256_set1_epi64x(0x4338000000000000u64 as i64);
    let magic_d = _mm256_set1_pd(6755399441055744.0); // 2^52 + 2^51
    let invv = _mm256_set1_pd(inv);
    let lim = _mm256_set1_epi64x((1i64 << 51) - 1);
    let zero = _mm256_setzero_si256();
    let mut i = 0;
    while i < n4 {
        let x = _mm256_loadu_si256(sum.as_ptr().add(i) as *const __m256i);
        let negm = _mm256_cmpgt_epi64(zero, x);
        let ax = _mm256_sub_epi64(_mm256_xor_si256(x, negm), negm);
        // ax < 0 catches the i64::MIN wraparound
        let bad = _mm256_or_si256(_mm256_cmpgt_epi64(ax, lim), _mm256_cmpgt_epi64(zero, ax));
        if _mm256_movemask_epi8(bad) != 0 {
            scalar::decode_scale_i64(&sum[i..i + 4], inv, &mut out[i..i + 4]);
        } else {
            let d = _mm256_sub_pd(_mm256_castsi256_pd(_mm256_add_epi64(x, magic_i)), magic_d);
            let f = _mm256_cvtpd_ps(_mm256_mul_pd(d, invv));
            _mm_storeu_ps(out.as_mut_ptr().add(i), f);
        }
        i += 4;
    }
    scalar::decode_scale_i64(&sum[n4..], inv, &mut out[n4..]);
}

// ---------------------------------------------------------------------
// norm and max-abs folds
// ---------------------------------------------------------------------

/// Safety: AVX2.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sq_norm(v: &[f32]) -> f64 {
    let n8 = v.len() / 8 * 8;
    let mut acc0 = _mm256_setzero_pd(); // stripes 0..4
    let mut acc1 = _mm256_setzero_pd(); // stripes 4..8
    let mut i = 0;
    while i < n8 {
        let x = _mm256_loadu_ps(v.as_ptr().add(i));
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(x));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps(x, 1));
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(lo, lo));
        acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(hi, hi));
        i += 8;
    }
    let mut s = [0.0f64; 8];
    _mm256_storeu_pd(s.as_mut_ptr(), acc0);
    _mm256_storeu_pd(s.as_mut_ptr().add(4), acc1);
    for (sj, &x) in s.iter_mut().zip(&v[n8..]) {
        let x = x as f64;
        *sj += x * x;
    }
    scalar::combine_stripes(&s)
}

/// Safety: AVX2; equal slice lengths (dispatch wrapper).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sq_diff_norm(a: &[f32], b: &[f32]) -> f64 {
    let n8 = a.len() / 8 * 8;
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0;
    while i < n8 {
        let xa = _mm256_loadu_ps(a.as_ptr().add(i));
        let xb = _mm256_loadu_ps(b.as_ptr().add(i));
        let d = _mm256_sub_ps(xa, xb); // f32 subtract first, like scalar
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps(d, 1));
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(lo, lo));
        acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(hi, hi));
        i += 8;
    }
    let mut s = [0.0f64; 8];
    _mm256_storeu_pd(s.as_mut_ptr(), acc0);
    _mm256_storeu_pd(s.as_mut_ptr().add(4), acc1);
    for (sj, (&x, &y)) in s.iter_mut().zip(a[n8..].iter().zip(&b[n8..])) {
        let d = (x - y) as f64;
        *sj += d * d;
    }
    scalar::combine_stripes(&s)
}

/// Safety: AVX2.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn max_abs_i8(v: &[i8]) -> i64 {
    let n16 = v.len() / 16 * 16;
    let mut m = _mm256_setzero_si256();
    let mut i = 0;
    while i < n16 {
        let x = _mm_loadu_si128(v.as_ptr().add(i) as *const __m128i);
        // widen before abs so |-128| = 128 is exact in the i16 lanes
        let w = _mm256_cvtepi8_epi16(x);
        m = _mm256_max_epu16(m, _mm256_abs_epi16(w));
        i += 16;
    }
    let mut buf = [0u16; 16];
    _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, m);
    let mut best = buf.iter().copied().max().unwrap_or(0) as i64;
    for &x in &v[n16..] {
        best = best.max((x as i32).abs() as i64);
    }
    best
}

/// Safety: AVX2.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn max_abs_i32(v: &[i32]) -> i64 {
    let n8 = v.len() / 8 * 8;
    let mut m = _mm256_setzero_si256();
    let mut i = 0;
    while i < n8 {
        let x = _mm256_loadu_si256(v.as_ptr().add(i) as *const __m256i);
        // pabsd(i32::MIN) wraps to 0x80000000, which IS |i32::MIN| when
        // the max runs unsigned
        m = _mm256_max_epu32(m, _mm256_abs_epi32(x));
        i += 8;
    }
    let mut buf = [0u32; 8];
    _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, m);
    let mut best = buf.iter().copied().max().unwrap_or(0) as i64;
    for &x in &v[n8..] {
        best = best.max((x as i64).abs());
    }
    best
}

/// Safety: AVX2.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn max_abs_i64(v: &[i64]) -> i64 {
    let n4 = v.len() / 4 * 4;
    let zero = _mm256_setzero_si256();
    let minv = _mm256_set1_epi64x(i64::MIN);
    let mut m = _mm256_setzero_si256();
    let mut saw_min = _mm256_setzero_si256();
    let mut i = 0;
    while i < n4 {
        let x = _mm256_loadu_si256(v.as_ptr().add(i) as *const __m256i);
        saw_min = _mm256_or_si256(saw_min, _mm256_cmpeq_epi64(x, minv));
        let negm = _mm256_cmpgt_epi64(zero, x);
        let ax = _mm256_sub_epi64(_mm256_xor_si256(x, negm), negm);
        let gt = _mm256_cmpgt_epi64(ax, m);
        m = _mm256_blendv_epi8(m, ax, gt);
        i += 4;
    }
    if _mm256_movemask_epi8(saw_min) != 0 {
        // |i64::MIN| saturates, matching scalar `saturating_abs`
        return i64::MAX;
    }
    let mut buf = [0i64; 4];
    _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, m);
    let mut best = buf.iter().copied().max().unwrap_or(0);
    for &x in &v[n4..] {
        best = best.max(x.saturating_abs());
    }
    best
}

// ---------------------------------------------------------------------
// SSE2 subset: the int8-wire trio (x86_64 baseline, no detection needed)
// ---------------------------------------------------------------------

/// Sign-extend the low 8 bytes of `x` to i16 lanes (SSE2 has no
/// `pmovsxbw`): self-interleave then arithmetic-shift the copies out.
///
/// Safety: SSE2 is the x86_64 baseline, unconditionally present.
#[inline]
unsafe fn widen16_lo(x: __m128i) -> __m128i {
    _mm_srai_epi16(_mm_unpacklo_epi8(x, x), 8)
}

/// Sign-extend the high 8 bytes of `x` to i16 lanes.
///
/// Safety: SSE2 is the x86_64 baseline, unconditionally present.
#[inline]
unsafe fn widen16_hi(x: __m128i) -> __m128i {
    _mm_srai_epi16(_mm_unpackhi_epi8(x, x), 8)
}

/// Widen one i16x8 to 4 x i64x2 (sign-interleave twice) and add into
/// `acc[0..8]`. Safety: `acc` must be valid for 8 i64 writes.
#[inline]
unsafe fn add16x8_to_i64(acc: *mut i64, w: __m128i) {
    let s16 = _mm_srai_epi16(w, 15);
    let lo32 = _mm_unpacklo_epi16(w, s16);
    let hi32 = _mm_unpackhi_epi16(w, s16);
    let s_lo = _mm_srai_epi32(lo32, 31);
    let s_hi = _mm_srai_epi32(hi32, 31);
    let q = [
        _mm_unpacklo_epi32(lo32, s_lo),
        _mm_unpackhi_epi32(lo32, s_lo),
        _mm_unpacklo_epi32(hi32, s_hi),
        _mm_unpackhi_epi32(hi32, s_hi),
    ];
    for (j, qv) in q.iter().enumerate() {
        let p = acc.add(2 * j) as *mut __m128i;
        _mm_storeu_si128(p, _mm_add_epi64(_mm_loadu_si128(p), *qv));
    }
}

/// Safety: equal slice lengths (dispatch wrapper).
pub(super) unsafe fn add_widen_i8_sse2(src: &[i8], acc: &mut [i64]) {
    let n16 = src.len() / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let x = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
        add16x8_to_i64(acc.as_mut_ptr().add(i), widen16_lo(x));
        add16x8_to_i64(acc.as_mut_ptr().add(i + 8), widen16_hi(x));
        i += 16;
    }
    scalar::add_widen_i8(&src[n16..], &mut acc[n16..]);
}

/// Safety: the dispatch wrapper checks the rank bound and lengths.
pub(super) unsafe fn sum_ranks_i8_sse2(msgs: &[&[i8]], acc: &mut [i64]) {
    let d = acc.len();
    let n16 = d / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let mut s_lo = _mm_setzero_si128();
        let mut s_hi = _mm_setzero_si128();
        for m in msgs {
            let x = _mm_loadu_si128(m.as_ptr().add(i) as *const __m128i);
            s_lo = _mm_add_epi16(s_lo, widen16_lo(x));
            s_hi = _mm_add_epi16(s_hi, widen16_hi(x));
        }
        add16x8_to_i64(acc.as_mut_ptr().add(i), s_lo);
        add16x8_to_i64(acc.as_mut_ptr().add(i + 8), s_hi);
        i += 16;
    }
    for m in msgs {
        scalar::add_widen_i8(&m[n16..], &mut acc[n16..]);
    }
}

/// Safety: none beyond slice validity (SSE2 is x86_64 baseline).
pub(super) unsafe fn max_abs_i8_sse2(v: &[i8]) -> i64 {
    let n16 = v.len() / 16 * 16;
    let mut m = _mm_setzero_si128();
    let mut i = 0;
    while i < n16 {
        let x = _mm_loadu_si128(v.as_ptr().add(i) as *const __m128i);
        for w in [widen16_lo(x), widen16_hi(x)] {
            let s = _mm_srai_epi16(w, 15);
            let a = _mm_sub_epi16(_mm_xor_si128(w, s), s);
            m = _mm_max_epi16(m, a); // values <= 128: signed max is safe
        }
        i += 16;
    }
    let mut buf = [0i16; 8];
    _mm_storeu_si128(buf.as_mut_ptr() as *mut __m128i, m);
    let mut best = buf.iter().copied().max().unwrap_or(0) as i64;
    for &x in &v[n16..] {
        best = best.max((x as i32).abs() as i64);
    }
    best
}
