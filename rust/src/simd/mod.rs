//! The kernel layer: every hot-path fold in the crate — the fused
//! scale→round encode fill, the widening reduce accumulates, the decode
//! tail, and the squared-norm / max-abs folds the alpha rules run every
//! round — goes through the dispatched functions in this module.
//!
//! Layout (DESIGN.md §10 has the full dispatch diagram):
//!
//! ```text
//!   caller (compress/, net/, util/stats, scaling inputs)
//!        │
//!        ▼
//!   simd::<kernel>()  ── asserts slice-length safety preconditions
//!        │
//!        ├─ feature "simd" off ──────────────► scalar::<kernel>
//!        └─ feature "simd" on: backend() (OnceLock, detected once)
//!             ├─ INTSGD_FORCE_SCALAR set ────► scalar::<kernel>
//!             ├─ x86_64 + avx2 detected ─────► x86::<kernel>      (AVX2)
//!             ├─ x86_64 otherwise ───────────► x86::<kernel>_sse2 (int8
//!             │                                trio; rest scalar)
//!             └─ aarch64 ────────────────────► neon::<kernel>
//! ```
//!
//! **Bit-identity is the contract.** [`scalar`] is the specification —
//! not a fallback to be merely approximated. Integer kernels are exact
//! in every backend (integer add/widen/abs have one right answer in any
//! fold order). Float kernels are pinned by two mechanisms: per-lane
//! IEEE ops that correspond one-to-one to the scalar operators (vector
//! mul/add/floor/round-ties-even/convert, never FMA), and — for the f64
//! norm reductions, where addition is *not* associative — a shared
//! 8-stripe accumulation layout (element `i` → stripe `i mod 8`) folded
//! by one shared `combine_stripes`, so scalar and vector evaluate the
//! same expression rather than a reassociation of it.
//! `tests/kernel_parity.rs` sweeps every dispatched kernel against the
//! scalar spec bitwise; `fused_encode` / `engine_parity` / `net_parity`
//! pin the end-to-end paths.
//!
//! All kernels are allocation-free (fixed-size stack scratch only);
//! `tests/zero_alloc.rs` pins the dispatched steady state at zero
//! allocations. Backend detection reads the environment exactly once
//! (first kernel call) through a `OnceLock`.

pub mod scalar;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86;

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon;

/// Upper bound on the rank count accepted by [`sum_ranks_i8`]: the
/// fused fold accumulates cross-rank partial sums in i16 lanes, and
/// `128 ranks * |v| <= 127` gives `16256 < i16::MAX`, so the
/// intermediate cannot overflow. The wire itself enforces n <= 127 for
/// the i8 lane (`max_aggregate / n >= 1`), so this bound is never the
/// binding constraint in production.
pub const SUM_RANKS_MAX: usize = 128;

/// Environment override: set to any non-empty value other than `"0"` to
/// force the scalar backend even when the `simd` feature is compiled in
/// and the CPU supports a vector backend. Read once, at first dispatch.
pub const FORCE_SCALAR_ENV: &str = "INTSGD_FORCE_SCALAR";

/// The backend the dispatcher selected (or would select) for this
/// process.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    /// The chunked scalar spec in [`scalar`] — feature off, override
    /// set, or no vector unit.
    Scalar,
    /// x86_64 baseline vectors: only the int8-wire trio (widening add,
    /// fused rank fold, max-abs) beats scalar here, the rest dispatches
    /// to [`scalar`].
    Sse2,
    /// Full 256-bit path, selected when `is_x86_feature_detected!`
    /// proves AVX2 at runtime.
    Avx2,
    /// aarch64 baseline (always available on that target).
    Neon,
}

impl Backend {
    /// Stable lowercase name (bench reports, logs).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse2 => "sse2",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

#[cfg(feature = "simd")]
fn force_scalar() -> bool {
    std::env::var(FORCE_SCALAR_ENV)
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn arch_backend() -> Backend {
    if is_x86_feature_detected!("avx2") {
        Backend::Avx2
    } else {
        Backend::Sse2
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
fn arch_backend() -> Backend {
    Backend::Neon
}

#[cfg(all(
    feature = "simd",
    not(any(target_arch = "x86_64", target_arch = "aarch64"))
))]
fn arch_backend() -> Backend {
    Backend::Scalar
}

/// The selected backend, detected once per process (CPUID + env).
#[cfg(feature = "simd")]
pub fn backend() -> Backend {
    static BACKEND: std::sync::OnceLock<Backend> = std::sync::OnceLock::new();
    *BACKEND.get_or_init(|| {
        if force_scalar() {
            Backend::Scalar
        } else {
            arch_backend()
        }
    })
}

/// The selected backend: always [`Backend::Scalar`] without the `simd`
/// feature.
#[cfg(not(feature = "simd"))]
pub fn backend() -> Backend {
    Backend::Scalar
}

/// Stable name of the selected backend (bench reports, logs).
pub fn backend_name() -> &'static str {
    backend().name()
}

// ---------------------------------------------------------------------
// Dispatched kernels. Without a vector backend compiled in, the names
// re-export the scalar spec directly (zero indirection); with one, thin
// wrappers assert the slice-length safety preconditions and branch on
// the detected backend.
// ---------------------------------------------------------------------

#[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub use scalar::{
    add_i64, add_widen_i32, add_widen_i8, copy_widen_i8, decode_scale_i64, max_abs_i32,
    max_abs_i64, max_abs_i8, round_determ, round_stoch, sq_diff_norm, sq_norm, sum_ranks_i8,
};

#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod dispatch {
    use super::*;

    /// Stochastic-rounding fill (spec: [`scalar::round_stoch`]).
    ///
    /// Contract: bit-identical to the scalar spec for every input — each
    /// lane is `floor(grad[k]*a) + (u < frac)` with the splitmix64 draw
    /// for counter `base + j0 + k`, evaluated with per-lane IEEE
    /// mul/floor/convert (no FMA), so lanes never interact.
    /// `kernel_parity` pins the edges: lengths straddling the 4/8/16-lane
    /// chunk boundaries (0..=67), and `j0` within 8 of `u64::MAX` so the
    /// per-lane counter wraps mod 2^64 inside one vector.
    pub fn round_stoch(grad: &[f32], a: f32, base: u64, j0: u64, out: &mut [f32]) {
        assert_eq!(grad.len(), out.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2 is only selected after runtime detection;
            // lengths checked above.
            Backend::Avx2 => unsafe { x86::round_stoch(grad, a, base, j0, out) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64; lengths checked.
            Backend::Neon => unsafe { neon::round_stoch(grad, a, base, j0, out) },
            _ => scalar::round_stoch(grad, a, base, j0, out),
        }
    }

    /// Deterministic-rounding fill (spec: [`scalar::round_determ`]).
    ///
    /// Contract: bit-identical to the scalar spec — each lane is
    /// `round_ties_even(grad[k]*a)` via the hardware round-to-nearest
    /// instruction, which matches `f32::round_ties_even` exactly (never
    /// the away-from-zero `f32::round`). `kernel_parity` pins exact
    /// `.5` ties in both directions and chunk-straddling lengths.
    pub fn round_determ(grad: &[f32], a: f32, out: &mut [f32]) {
        assert_eq!(grad.len(), out.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: as in `round_stoch`.
            Backend::Avx2 => unsafe { x86::round_determ(grad, a, out) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: as in `round_stoch`.
            Backend::Neon => unsafe { neon::round_determ(grad, a, out) },
            _ => scalar::round_determ(grad, a, out),
        }
    }

    /// `acc[k] += src[k]` widening i8→i64 (spec:
    /// [`scalar::add_widen_i8`]).
    ///
    /// Contract: exact in every backend — sign-extension then wrapping
    /// i64 add has one right answer per lane regardless of vector width.
    /// `kernel_parity` pins `i8::MIN`/`i8::MAX` lanes and lengths
    /// straddling the 8/16-lane widen chunks.
    pub fn add_widen_i8(src: &[i8], acc: &mut [i64]) {
        assert_eq!(src.len(), acc.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::add_widen_i8(src, acc) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; SSE2 is x86_64 baseline.
            Backend::Sse2 => unsafe { x86::add_widen_i8_sse2(src, acc) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: lengths checked; NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::add_widen_i8(src, acc) },
            _ => scalar::add_widen_i8(src, acc),
        }
    }

    /// `acc[k] += src[k]` widening i32→i64 (spec:
    /// [`scalar::add_widen_i32`]).
    ///
    /// Contract: exact in every backend (sign-extend + wrapping i64
    /// add, lane-local). `kernel_parity` pins `i32::MIN`/`i32::MAX`
    /// lanes and the 4-lane chunk boundary tails.
    pub fn add_widen_i32(src: &[i32], acc: &mut [i64]) {
        assert_eq!(src.len(), acc.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::add_widen_i32(src, acc) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: lengths checked; NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::add_widen_i32(src, acc) },
            _ => scalar::add_widen_i32(src, acc),
        }
    }

    /// `acc[k] += src[k]` at full width (spec: [`scalar::add_i64`]).
    ///
    /// Contract: exact in every backend — wrapping two's-complement add
    /// per lane, identical to the scalar `wrapping_add`. `kernel_parity`
    /// pins wraparound lanes (`i64::MAX + 1`) and chunk-tail lengths.
    pub fn add_i64(src: &[i64], acc: &mut [i64]) {
        assert_eq!(src.len(), acc.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::add_i64(src, acc) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: lengths checked; NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::add_i64(src, acc) },
            _ => scalar::add_i64(src, acc),
        }
    }

    /// `dst[k] = src[k]` widening i8→i64 (spec:
    /// [`scalar::copy_widen_i8`]).
    ///
    /// Contract: exact in every backend — pure sign-extension, every
    /// prior `dst` value overwritten. `kernel_parity` pins
    /// `i8::MIN`/`i8::MAX` lanes and widen-chunk boundary tails.
    pub fn copy_widen_i8(src: &[i8], dst: &mut [i64]) {
        assert_eq!(src.len(), dst.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::copy_widen_i8(src, dst) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: lengths checked; NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::copy_widen_i8(src, dst) },
            _ => scalar::copy_widen_i8(src, dst),
        }
    }

    /// Fused multi-rank i8 fold through an i16 intermediate (spec:
    /// [`scalar::sum_ranks_i8`]). Panics if `msgs.len() >`
    /// [`SUM_RANKS_MAX`] or any message length mismatches `acc`.
    ///
    /// Contract: exact in every backend. The i16 intermediate cannot
    /// saturate: `128 ranks * 127 = 16256 < i16::MAX`, so the fused fold
    /// equals the one-rank-at-a-time widen-and-add bit for bit.
    /// `kernel_parity` pins the worst case — [`SUM_RANKS_MAX`] ranks of
    /// all-`i8::MIN` lanes (`128 * -128 = -16384`, still in range) —
    /// plus empty `msgs` and chunk-straddling lengths.
    pub fn sum_ranks_i8(msgs: &[&[i8]], acc: &mut [i64]) {
        assert!(
            msgs.len() <= SUM_RANKS_MAX,
            "{} ranks exceed the fused i16-intermediate bound",
            msgs.len()
        );
        for m in msgs {
            assert_eq!(m.len(), acc.len());
        }
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: rank bound + lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::sum_ranks_i8(msgs, acc) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: rank bound + lengths checked; SSE2 baseline.
            Backend::Sse2 => unsafe { x86::sum_ranks_i8_sse2(msgs, acc) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: rank bound + lengths checked; NEON baseline.
            Backend::Neon => unsafe { neon::sum_ranks_i8(msgs, acc) },
            _ => scalar::sum_ranks_i8(msgs, acc),
        }
    }

    /// Decode fill `out[k] = (sum[k] as f64 * inv) as f32` (spec:
    /// [`scalar::decode_scale_i64`]).
    ///
    /// Contract: bit-identical to the scalar spec — per-lane i64→f64
    /// convert, one IEEE f64 mul, one f64→f32 round (no FMA). The AVX2
    /// path uses the 2^52 magic-number convert, exact for
    /// `|sum[k]| <= 2^51 - 1`, with a per-group guard that routes any
    /// lane outside that range (i64::MIN included) through the scalar
    /// spec — so extreme aggregates stay bit-identical too.
    /// `kernel_parity` pins lanes at the ±(2^51 - 1) guard edge and
    /// chunk-straddling lengths.
    pub fn decode_scale_i64(sum: &[i64], inv: f64, out: &mut [f32]) {
        assert_eq!(sum.len(), out.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::decode_scale_i64(sum, inv, out) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: lengths checked; NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::decode_scale_i64(sum, inv, out) },
            _ => scalar::decode_scale_i64(sum, inv, out),
        }
    }

    /// Striped squared L2 norm (spec: [`scalar::sq_norm`]).
    ///
    /// Contract: bit-identical to the scalar spec *by construction*,
    /// not by accident — f64 addition is non-associative, so every
    /// backend accumulates element `i` into stripe `i mod 8` and folds
    /// the 8 stripes through the one shared
    /// [`scalar::combine_stripes`]; scalar and vector evaluate the same
    /// expression tree. `kernel_parity` pins lengths straddling the
    /// 8-lane stripe period and catastrophic-cancellation inputs.
    pub fn sq_norm(v: &[f32]) -> f64 {
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2 detected; no other precondition.
            Backend::Avx2 => unsafe { x86::sq_norm(v) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::sq_norm(v) },
            _ => scalar::sq_norm(v),
        }
    }

    /// Striped squared distance (spec: [`scalar::sq_diff_norm`]).
    ///
    /// Contract: same stripe discipline as [`sq_norm`] — element `i` →
    /// stripe `i mod 8`, folded by the shared
    /// [`scalar::combine_stripes`] — with the per-lane difference
    /// computed as one f32 subtract before the f64 widen, exactly as
    /// the scalar spec writes it. `kernel_parity` sweeps
    /// stripe-boundary lengths against the spec bitwise.
    pub fn sq_diff_norm(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: lengths checked; AVX2 detected.
            Backend::Avx2 => unsafe { x86::sq_diff_norm(a, b) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: lengths checked; NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::sq_diff_norm(a, b) },
            _ => scalar::sq_diff_norm(a, b),
        }
    }

    /// Largest |lane| of an i8 buffer (spec: [`scalar::max_abs_i8`]).
    ///
    /// Contract: exact in every backend — lanes are widened before the
    /// abs, so `|i8::MIN| = 128` is returned exactly (a naive
    /// same-width `abs` would wrap it to -128). `kernel_parity` pins an
    /// all-`i8::MIN` buffer, the empty buffer (→ 0), and chunk tails.
    pub fn max_abs_i8(v: &[i8]) -> i64 {
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2 detected.
            Backend::Avx2 => unsafe { x86::max_abs_i8(v) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: SSE2 is x86_64 baseline.
            Backend::Sse2 => unsafe { x86::max_abs_i8_sse2(v) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::max_abs_i8(v) },
            _ => scalar::max_abs_i8(v),
        }
    }

    /// Largest |lane| of an i32 buffer (spec: [`scalar::max_abs_i32`]).
    ///
    /// Contract: exact in every backend — widen to i64 before the abs,
    /// so `|i32::MIN| = 2^31` is exact. `kernel_parity` pins
    /// `i32::MIN` lanes, the empty buffer, and chunk-tail lengths.
    pub fn max_abs_i32(v: &[i32]) -> i64 {
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2 detected.
            Backend::Avx2 => unsafe { x86::max_abs_i32(v) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is aarch64 baseline.
            Backend::Neon => unsafe { neon::max_abs_i32(v) },
            _ => scalar::max_abs_i32(v),
        }
    }

    /// Largest |lane| of an i64 buffer, saturating at `i64::MIN` (spec:
    /// [`scalar::max_abs_i64`]). aarch64 keeps the scalar fold (NEON has
    /// no 64-bit max; the scalar loop is already one `csel` per lane).
    ///
    /// Contract: exact in every backend, including the one lane with no
    /// true answer — `|i64::MIN|` does not fit i64, and both spec and
    /// vector paths saturate it to `i64::MAX`. `kernel_parity` pins an
    /// `i64::MIN` lane, the empty buffer, and chunk-tail lengths.
    pub fn max_abs_i64(v: &[i64]) -> i64 {
        match backend() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: AVX2 detected.
            Backend::Avx2 => unsafe { x86::max_abs_i64(v) },
            _ => scalar::max_abs_i64(v),
        }
    }
}

#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub use dispatch::*;
