//! aarch64 NEON kernels. NEON is part of the aarch64 baseline, so no
//! runtime detection is needed — the dispatch table selects this module
//! whenever the `simd` feature is on and the target is aarch64.
//!
//! Bit-identity follows the same argument as the x86 module: integer
//! kernels are exact; float kernels use per-lane IEEE ops that mirror
//! the scalar operators (`fmul`/`fadd` = `*`/`+`, `frintm` =
//! `f32::floor`, `frintn` = `f32::round_ties_even`, `scvtf`/`fcvtn`
//! round to nearest-even under the default FPCR, matching Rust `as`),
//! no FMA contraction, and the f64 norm folds accumulate into the same
//! 8 stripes as the scalar spec. The stochastic uniforms are drawn from
//! the scalar `splitmix64_at` (NEON lacks a 64-bit lane multiply, so
//! vectorizing the mix buys nothing); only the u32→f32 convert and the
//! round itself are vectorized — the stream is the scalar stream.

use core::arch::aarch64::*;

use super::scalar;
use crate::util::rng::splitmix64_at;

/// Safety: NEON (aarch64 baseline); equal slice lengths (dispatch
/// wrapper).
pub(super) unsafe fn round_stoch(grad: &[f32], a: f32, base: u64, j0: u64, out: &mut [f32]) {
    let n4 = grad.len() / 4 * 4;
    let av = vdupq_n_f32(a);
    let scalev = vdupq_n_f32(scalar::UNIFORM_SCALE);
    let mut ubuf = [0u32; 4];
    let mut i = 0;
    while i < n4 {
        for (k, u) in ubuf.iter_mut().enumerate() {
            *u = (splitmix64_at(base, j0.wrapping_add((i + k) as u64)) >> 40) as u32;
        }
        let uf = vmulq_f32(vcvtq_f32_u32(vld1q_u32(ubuf.as_ptr())), scalev);
        let g = vld1q_f32(grad.as_ptr().add(i));
        let t = vaddq_f32(vmulq_f32(g, av), uf);
        vst1q_f32(out.as_mut_ptr().add(i), vrndmq_f32(t));
        i += 4;
    }
    scalar::round_stoch(&grad[n4..], a, base, j0.wrapping_add(n4 as u64), &mut out[n4..]);
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn round_determ(grad: &[f32], a: f32, out: &mut [f32]) {
    let n4 = grad.len() / 4 * 4;
    let av = vdupq_n_f32(a);
    let mut i = 0;
    while i < n4 {
        let g = vld1q_f32(grad.as_ptr().add(i));
        vst1q_f32(out.as_mut_ptr().add(i), vrndnq_f32(vmulq_f32(g, av)));
        i += 4;
    }
    scalar::round_determ(&grad[n4..], a, &mut out[n4..]);
}

/// Widen one i16x8 to 4 x i64x2 and add into `acc[0..8]`.
/// Safety: `acc` must be valid for 8 i64 writes.
#[inline]
unsafe fn add16x8_to_i64(acc: *mut i64, w: int16x8_t) {
    let lo32 = vmovl_s16(vget_low_s16(w));
    let hi32 = vmovl_s16(vget_high_s16(w));
    let q = [
        vmovl_s32(vget_low_s32(lo32)),
        vmovl_s32(vget_high_s32(lo32)),
        vmovl_s32(vget_low_s32(hi32)),
        vmovl_s32(vget_high_s32(hi32)),
    ];
    for (j, qv) in q.iter().enumerate() {
        let p = acc.add(2 * j);
        vst1q_s64(p, vaddq_s64(vld1q_s64(p), *qv));
    }
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn add_widen_i8(src: &[i8], acc: &mut [i64]) {
    let n16 = src.len() / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let x = vld1q_s8(src.as_ptr().add(i));
        add16x8_to_i64(acc.as_mut_ptr().add(i), vmovl_s8(vget_low_s8(x)));
        add16x8_to_i64(acc.as_mut_ptr().add(i + 8), vmovl_s8(vget_high_s8(x)));
        i += 16;
    }
    scalar::add_widen_i8(&src[n16..], &mut acc[n16..]);
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn add_widen_i32(src: &[i32], acc: &mut [i64]) {
    let n4 = src.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        let x = vld1q_s32(src.as_ptr().add(i));
        let p0 = acc.as_mut_ptr().add(i);
        let p1 = acc.as_mut_ptr().add(i + 2);
        vst1q_s64(p0, vaddq_s64(vld1q_s64(p0), vmovl_s32(vget_low_s32(x))));
        vst1q_s64(p1, vaddq_s64(vld1q_s64(p1), vmovl_s32(vget_high_s32(x))));
        i += 4;
    }
    scalar::add_widen_i32(&src[n4..], &mut acc[n4..]);
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn add_i64(src: &[i64], acc: &mut [i64]) {
    let n2 = src.len() / 2 * 2;
    let mut i = 0;
    while i < n2 {
        let p = acc.as_mut_ptr().add(i);
        vst1q_s64(p, vaddq_s64(vld1q_s64(p), vld1q_s64(src.as_ptr().add(i))));
        i += 2;
    }
    scalar::add_i64(&src[n2..], &mut acc[n2..]);
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn copy_widen_i8(src: &[i8], dst: &mut [i64]) {
    let n16 = src.len() / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let x = vld1q_s8(src.as_ptr().add(i));
        for (off, half) in [(0, vget_low_s8(x)), (8, vget_high_s8(x))] {
            let w = vmovl_s8(half);
            let lo32 = vmovl_s16(vget_low_s16(w));
            let hi32 = vmovl_s16(vget_high_s16(w));
            let base = dst.as_mut_ptr().add(i + off);
            vst1q_s64(base, vmovl_s32(vget_low_s32(lo32)));
            vst1q_s64(base.add(2), vmovl_s32(vget_high_s32(lo32)));
            vst1q_s64(base.add(4), vmovl_s32(vget_low_s32(hi32)));
            vst1q_s64(base.add(6), vmovl_s32(vget_high_s32(hi32)));
        }
        i += 16;
    }
    scalar::copy_widen_i8(&src[n16..], &mut dst[n16..]);
}

/// Safety: the dispatch wrapper checks the rank bound and lengths.
pub(super) unsafe fn sum_ranks_i8(msgs: &[&[i8]], acc: &mut [i64]) {
    let d = acc.len();
    let n16 = d / 16 * 16;
    let mut i = 0;
    while i < n16 {
        let mut s_lo = vdupq_n_s16(0);
        let mut s_hi = vdupq_n_s16(0);
        for m in msgs {
            let x = vld1q_s8(m.as_ptr().add(i));
            s_lo = vaddq_s16(s_lo, vmovl_s8(vget_low_s8(x)));
            s_hi = vaddq_s16(s_hi, vmovl_s8(vget_high_s8(x)));
        }
        add16x8_to_i64(acc.as_mut_ptr().add(i), s_lo);
        add16x8_to_i64(acc.as_mut_ptr().add(i + 8), s_hi);
        i += 16;
    }
    for m in msgs {
        scalar::add_widen_i8(&m[n16..], &mut acc[n16..]);
    }
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn decode_scale_i64(sum: &[i64], inv: f64, out: &mut [f32]) {
    let n4 = sum.len() / 4 * 4;
    let invv = vdupq_n_f64(inv);
    let mut i = 0;
    while i < n4 {
        // scvtf and fcvtn both round to nearest-even (default FPCR),
        // matching `as f64` / `as f32` exactly
        let d0 = vcvtq_f64_s64(vld1q_s64(sum.as_ptr().add(i)));
        let d1 = vcvtq_f64_s64(vld1q_s64(sum.as_ptr().add(i + 2)));
        let f0 = vcvt_f32_f64(vmulq_f64(d0, invv));
        let f1 = vcvt_f32_f64(vmulq_f64(d1, invv));
        vst1q_f32(out.as_mut_ptr().add(i), vcombine_f32(f0, f1));
        i += 4;
    }
    scalar::decode_scale_i64(&sum[n4..], inv, &mut out[n4..]);
}

/// Horizontal fold of the 4 f64x2 stripe accumulators plus the
/// remainder, via the shared stripe combiner.
///
/// Safety: NEON (aarch64 baseline).
#[inline]
unsafe fn finish_stripes(acc: [float64x2_t; 4], tail: impl Iterator<Item = f64>) -> f64 {
    let mut s = [0.0f64; 8];
    for (j, a) in acc.iter().enumerate() {
        s[2 * j] = vgetq_lane_f64(*a, 0);
        s[2 * j + 1] = vgetq_lane_f64(*a, 1);
    }
    for (sj, d) in s.iter_mut().zip(tail) {
        *sj += d * d;
    }
    scalar::combine_stripes(&s)
}

/// Safety: NEON.
pub(super) unsafe fn sq_norm(v: &[f32]) -> f64 {
    let n8 = v.len() / 8 * 8;
    let mut acc = [vdupq_n_f64(0.0); 4]; // acc[j] = stripes 2j, 2j+1
    let mut i = 0;
    while i < n8 {
        let x = vld1q_f32(v.as_ptr().add(i));
        let y = vld1q_f32(v.as_ptr().add(i + 4));
        for (j, half) in [
            vget_low_f32(x),
            vget_high_f32(x),
            vget_low_f32(y),
            vget_high_f32(y),
        ]
        .into_iter()
        .enumerate()
        {
            let d = vcvt_f64_f32(half);
            acc[j] = vaddq_f64(acc[j], vmulq_f64(d, d));
        }
        i += 8;
    }
    finish_stripes(acc, v[n8..].iter().map(|&x| x as f64))
}

/// Safety: NEON; equal slice lengths (dispatch wrapper).
pub(super) unsafe fn sq_diff_norm(a: &[f32], b: &[f32]) -> f64 {
    let n8 = a.len() / 8 * 8;
    let mut acc = [vdupq_n_f64(0.0); 4];
    let mut i = 0;
    while i < n8 {
        let dx = vsubq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
        let dy = vsubq_f32(vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4)));
        for (j, half) in [
            vget_low_f32(dx),
            vget_high_f32(dx),
            vget_low_f32(dy),
            vget_high_f32(dy),
        ]
        .into_iter()
        .enumerate()
        {
            let d = vcvt_f64_f32(half);
            acc[j] = vaddq_f64(acc[j], vmulq_f64(d, d));
        }
        i += 8;
    }
    finish_stripes(acc, a[n8..].iter().zip(&b[n8..]).map(|(&x, &y)| (x - y) as f64))
}

/// Safety: NEON.
pub(super) unsafe fn max_abs_i8(v: &[i8]) -> i64 {
    let n16 = v.len() / 16 * 16;
    let mut m = vdupq_n_s16(0);
    let mut i = 0;
    while i < n16 {
        let x = vld1q_s8(v.as_ptr().add(i));
        // widen before abs so |-128| = 128 is exact in i16
        m = vmaxq_s16(m, vabsq_s16(vmovl_s8(vget_low_s8(x))));
        m = vmaxq_s16(m, vabsq_s16(vmovl_s8(vget_high_s8(x))));
        i += 16;
    }
    let mut best = vmaxvq_s16(m) as i64;
    for &x in &v[n16..] {
        best = best.max((x as i32).abs() as i64);
    }
    best
}

/// Safety: NEON.
pub(super) unsafe fn max_abs_i32(v: &[i32]) -> i64 {
    let n4 = v.len() / 4 * 4;
    let mut m = vdupq_n_u32(0);
    let mut i = 0;
    while i < n4 {
        let x = vld1q_s32(v.as_ptr().add(i));
        // sabs(i32::MIN) wraps to 0x80000000 = |i32::MIN| under the
        // unsigned max
        m = vmaxq_u32(m, vreinterpretq_u32_s32(vabsq_s32(x)));
        i += 4;
    }
    let mut best = vmaxvq_u32(m) as i64;
    for &x in &v[n4..] {
        best = best.max((x as i64).abs());
    }
    best
}
