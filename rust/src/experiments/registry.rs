//! Experiment registry: maps experiment ids to drivers (DESIGN.md §4).
//! Each driver writes `results/<id>_*.csv` and prints a paper-style
//! summary. All knobs are `key=value` config entries.

use anyhow::{anyhow, Result};

use crate::config::Config;

pub fn list() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig1", "IntSGD vs Heuristic IntSGD vs SGD convergence (classifier + LM)"),
        ("fig2", "FP32 vs Int8 all-reduce time vs message size (cost model)"),
        ("fig3", "convergence curves, all algorithms, classifier task"),
        ("fig4", "convergence curves, all algorithms, LM task"),
        ("fig5", "IntSGD sensitivity to beta and epsilon"),
        ("fig6", "logistic regression: IntGD vs IntDIANA vs VR-IntDIANA"),
        ("table2", "test accuracy + time breakdown, classifier task"),
        ("table3", "test loss + time breakdown, LM task"),
        ("ablation", "IntSGD design ablations (scaling rule, switch, block)"),
        ("all", "run every experiment with current config"),
    ]
}

pub fn run(id: &str, cfg: &Config) -> Result<()> {
    match id {
        "fig1" => super::fig1::run(cfg),
        "fig2" => super::fig2::run(cfg),
        "fig3" => super::fig3_4::run(3, cfg),
        "fig4" => super::fig3_4::run(4, cfg),
        "fig5" => super::fig5::run(cfg),
        "fig6" => super::fig6::run(cfg),
        "table2" => super::table2_3::run(2, cfg),
        "table3" => super::table2_3::run(3, cfg),
        "ablation" => super::ablation::run(cfg),
        "all" => {
            for (eid, _) in list() {
                if eid == "all" {
                    continue;
                }
                println!("=== {eid} ===");
                run(eid, cfg)?;
            }
            Ok(())
        }
        other => Err(anyhow!(
            "unknown experiment {other:?}; `repro list` shows the index"
        )),
    }
}
