//! `repro train` — the generic launcher: train any model with any
//! algorithm, with checkpointing. A thin wrapper over
//! `common::task_session` / the `api::Session` front door (experiment
//! drivers are canned protocols on top of the same API); the algorithm id
//! parses through `api::CompressorSpec`, so an unknown id fails with a
//! suggestion before any worker spawns.
//!
//!   repro train model=classifier algo=intsgd_random8 rounds=200 \
//!        workers=8 lr=0.1 save=ckpt/cls.intsgd

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::metrics::Csv;
use crate::runtime::{Checkpoint, Runtime};

use super::common::{run_task, setup, Task};

pub fn run(cfg: &Config) -> Result<()> {
    let model = cfg.str_or("model", "classifier");
    let task = match model {
        "classifier" => Task::Classifier,
        "lm" => Task::Lm,
        "transformer" => Task::Transformer,
        other => return Err(anyhow!("unknown model {other:?}")),
    };
    let algo = cfg.str_or("algo", "intsgd_random8");
    let default_lr = if task == Task::Classifier { 0.1 } else { 1.25 };
    let s = setup(cfg, 200, default_lr);
    let beta = cfg.f64_or("beta", 0.9);
    let eps = cfg.f64_or("eps", 1e-8);
    let seed = cfg.u64_or("seed", 0);

    eprintln!("[train] {model} / {algo} / {} workers / {} rounds", s.workers, s.rounds);
    let out = run_task(task, algo, &s, beta, eps, seed, cfg)?;

    // training log
    let log_path = format!("{}/train_{model}_{algo}.csv", s.out_dir);
    let mut csv = Csv::create(
        &log_path,
        &["round", "train_loss", "lr", "alpha", "wire_bytes", "comm_ms"],
    )?;
    for r in &out.result.records {
        csv.rowf(&[
            r.round as f64,
            r.train_loss,
            r.lr as f64,
            r.alpha,
            r.wire_bytes_per_worker as f64,
            r.comm_seconds * 1e3,
        ])?;
    }
    csv.flush()?;
    println!("final train loss {:.4}; test (loss, acc) = ({:.4}, {:.4})",
        out.result.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN),
        out.test.0, out.test.1);
    println!("wrote {log_path}");

    // checkpoint
    if let Some(path) = cfg.get("save") {
        let rt = Runtime::open(&s.artifact_dir)?;
        let meta = rt
            .meta(&format!("{model}_train_step"))
            .ok_or_else(|| anyhow!("missing artifact meta"))?;
        let layout: Vec<(String, u64)> = meta
            .params
            .iter()
            .map(|p| (p.name.clone(), p.numel() as u64))
            .collect();
        let ck = Checkpoint::new(
            s.rounds as u64,
            layout,
            out.result.final_params.clone(),
        )?;
        ck.save(path)?;
        println!("saved checkpoint {path} ({} params)", out.result.final_params.len());
    }
    Ok(())
}
