//! Figure 2 (Appendix C.2): all-reduce time of FP32 vs Int8 messages as a
//! function of message size, from the network cost model.
//!
//! Shape to reproduce: Int8 ~4x cheaper at large sizes; both flat (latency
//! dominated) at small sizes.

use anyhow::Result;

use crate::compress::Primitive;
use crate::config::Config;
use crate::metrics::Csv;
use crate::netsim::Network;

pub fn run(cfg: &Config) -> Result<()> {
    let out_dir = cfg.str_or("out_dir", "results");
    let n = cfg.usize_or("workers", 16);
    let net = Network::paper_cluster();
    let path = format!("{out_dir}/fig2_comm_times.csv");
    let mut csv = Csv::create(
        &path,
        &["num_coords", "fp32_ms", "int8_ms", "speedup"],
    )?;
    println!("{:>12} {:>12} {:>12} {:>9}", "coords", "fp32 (ms)", "int8 (ms)", "ratio");
    for log2 in 12..=27 {
        let d = 1usize << log2;
        let t32 = net.primitive_seconds(Primitive::AllReduce, 4 * d, n);
        let t8 = net.primitive_seconds(Primitive::AllReduce, d, n);
        csv.rowf(&[d as f64, t32 * 1e3, t8 * 1e3, t32 / t8])?;
        println!(
            "{:>12} {:>12.4} {:>12.4} {:>9.2}",
            d,
            t32 * 1e3,
            t8 * 1e3,
            t32 / t8
        );
    }
    csv.flush()?;
    println!("wrote {path}");
    Ok(())
}
