//! Figures 3 & 4 (Appendix C.3): full convergence curves for all
//! algorithms on the classification (Fig. 3) and LM (Fig. 4) tasks.
//!
//! Shape to reproduce: IntSGD variants track SGD; PowerSGD (EF) converges
//! visibly slower in the early epochs of the classifier (non-smooth
//! activations); all-gather baselines match statistically but cost more
//! time per round (captured in the time column).

use anyhow::Result;

use crate::config::Config;
use crate::metrics::Csv;

use super::common::{run_task, setup, Task};
use super::table2_3::ALGOS;

pub fn run(fig: u32, cfg: &Config) -> Result<()> {
    let task = if fig == 3 { Task::Classifier } else { Task::Lm };
    let default_lr = if fig == 3 { 0.1 } else { 1.25 };
    let s = setup(cfg, 240, default_lr);
    let path = format!("{}/fig{fig}_{}_curves.csv", s.out_dir, task.model_name());
    let mut csv = Csv::create(
        &path,
        &[
            "algo", "seed", "round", "train_loss", "eval_loss", "eval_acc",
            "cum_time_ms",
        ],
    )?;
    for algo in ALGOS {
        for &seed in &s.seeds {
            eprintln!("[fig{fig}] {algo} / seed {seed}");
            let out = run_task(task, algo, &s, 0.9, 1e-8, seed, cfg)?;
            let mut cum = 0.0f64;
            let mut evals = out.result.evals.iter().peekable();
            for r in &out.result.records {
                cum += r.compute_seconds + r.overhead_seconds + r.comm_seconds;
                let (el, ea) = match evals.peek() {
                    Some(&&(er, l, a)) if er == r.round => {
                        evals.next();
                        (l, a)
                    }
                    _ => (f64::NAN, f64::NAN),
                };
                csv.row(&[
                    algo.to_string(),
                    seed.to_string(),
                    r.round.to_string(),
                    format!("{:.6}", r.train_loss),
                    format!("{el:.6}"),
                    format!("{ea:.6}"),
                    format!("{:.3}", cum * 1e3),
                ])?;
            }
        }
    }
    csv.flush()?;
    println!("wrote {path}");
    Ok(())
}
