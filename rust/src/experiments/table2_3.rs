//! Tables 2 & 3: test metric + per-iteration time breakdown (computation
//! overhead / communication / total) for the seven algorithms, on the
//! classification (Table 2) and language-modeling (Table 3) tasks.
//!
//! Shape to reproduce (who wins, roughly by how much):
//!   - all-gather SGD/QSGD/NatSGD are an order of magnitude slower than
//!     ring all-reduce SGD;
//!   - PowerSGD and both IntSGD variants beat all-reduce SGD end-to-end;
//!   - IntSGD's compression overhead < PowerSGD's;
//!   - IntSGD (Random) ~matches SGD's test metric, IntSGD (Determ.) may
//!     lag on the LM task.
//!
//! "Computation" = measured straggler PJRT step time on this box;
//! "overhead" = measured compression encode+decode; "communication" = the
//! netsim model at the paper's cluster parameters. Absolute numbers thus
//! mix measured and modeled time — shapes, not milliseconds, are the
//! reproduction target (DESIGN.md §2).

use anyhow::Result;

use crate::config::Config;
use crate::metrics::{ms, pm, Csv};
use crate::util::stats::mean;

use super::common::{paper_name, run_task, setup, Task};

pub const ALGOS: &[&str] = &[
    "sgd_ag", "qsgd", "natsgd", "sgd_ar", "powersgd", "intsgd_determ8",
    "intsgd_random8",
];

pub fn run(table: u32, cfg: &Config) -> Result<()> {
    let task = if table == 2 { Task::Classifier } else { Task::Lm };
    let default_lr = if table == 2 { 0.1 } else { 1.25 };
    let s = setup(cfg, 160, default_lr);
    let path = format!("{}/table{table}_{}.csv", s.out_dir, task.model_name());
    let mut csv = Csv::create(
        &path,
        &[
            "algo", "paper_name", "seed", "test_loss", "test_acc",
            "overhead_ms", "comm_ms", "compute_ms", "total_ms", "wire_bytes",
        ],
    )?;

    struct Row {
        algo: String,
        metric: Vec<f64>,
        overhead: Vec<f64>,
        comm: Vec<f64>,
        total: Vec<f64>,
    }
    let mut rows: Vec<Row> = Vec::new();

    for algo in ALGOS {
        let mut row = Row {
            algo: algo.to_string(),
            metric: vec![],
            overhead: vec![],
            comm: vec![],
            total: vec![],
        };
        for &seed in &s.seeds {
            eprintln!("[table{table}] {algo} / seed {seed}");
            let out = run_task(task, algo, &s, 0.9, 1e-8, seed, cfg)?;
            // per-iteration averages over the steady state (skip warmup)
            let recs = &out.result.records[out.result.records.len() / 4..];
            let overhead = mean(&recs.iter().map(|r| r.overhead_seconds).collect::<Vec<_>>());
            let comm = mean(&recs.iter().map(|r| r.comm_seconds).collect::<Vec<_>>());
            let compute = mean(&recs.iter().map(|r| r.compute_seconds).collect::<Vec<_>>());
            let bytes = mean(
                &recs.iter().map(|r| r.wire_bytes_per_worker as f64).collect::<Vec<_>>(),
            );
            let total = overhead + comm + compute;
            let metric = if table == 2 { out.test.1 * 100.0 } else { out.test.0 };
            csv.row(&[
                algo.to_string(),
                paper_name(algo).to_string(),
                seed.to_string(),
                format!("{:.4}", out.test.0),
                format!("{:.4}", out.test.1),
                ms(overhead),
                ms(comm),
                ms(compute),
                ms(total),
                format!("{bytes:.0}"),
            ])?;
            row.metric.push(metric);
            row.overhead.push(overhead * 1e3);
            row.comm.push(comm * 1e3);
            row.total.push(total * 1e3);
        }
        rows.push(row);
    }
    csv.flush()?;

    // paper-style table
    let metric_name = if table == 2 { "Test Accuracy (%)" } else { "Test Loss" };
    println!("\nTable {table} ({}, this testbed):", task.model_name());
    println!(
        "{:<28} {:>18} {:>16} {:>16} {:>16}",
        "Algorithm", metric_name, "Overhead (ms)", "Comm (ms)", "Total (ms)"
    );
    for r in &rows {
        println!(
            "{:<28} {:>18} {:>16} {:>16} {:>16}",
            paper_name(&r.algo),
            pm(&r.metric),
            pm(&r.overhead),
            pm(&r.comm),
            pm(&r.total),
        );
    }
    println!("wrote {path}");
    Ok(())
}
