//! Ablations over IntSGD design choices called out in DESIGN.md:
//! scaling rule (moving-average vs Prop. 3 vs per-block), transport
//! (ring all-reduce vs INA switch), and rounding mode — all on the
//! classifier task.

use anyhow::Result;

use crate::config::Config;
use crate::metrics::Csv;
use crate::util::stats::mean;

use super::common::{run_task, setup, Task};

pub const VARIANTS: &[&str] = &[
    "intsgd_random8",   // Alg. 1 default (moving average, eps safeguard)
    "intsgd_prop3_32",  // Prop. 3 scale (beta=0, eps=0) — needs 32-bit head-room
    "intsgd_block8",    // Alg. 2 per-block scales
    "intsgd_switch8",   // INA switch transport with saturating adders
    "intsgd_determ8",   // deterministic rounding
];

pub fn run(cfg: &Config) -> Result<()> {
    let s = setup(cfg, 160, 0.1);
    let path = format!("{}/ablation_intsgd.csv", s.out_dir);
    let mut csv = Csv::create(
        &path,
        &["variant", "seed", "test_loss", "test_acc", "mean_alpha", "max_int"],
    )?;
    println!("{:<20} {:>10} {:>10} {:>12} {:>10}", "variant", "loss", "acc", "alpha", "max_int");
    for v in VARIANTS {
        for &seed in &s.seeds {
            eprintln!("[ablation] {v} / seed {seed}");
            let out = run_task(Task::Classifier, v, &s, 0.9, 1e-8, seed, cfg)?;
            let alphas: Vec<f64> = out
                .result
                .records
                .iter()
                .filter(|r| r.alpha > 0.0 && r.alpha.is_finite())
                .map(|r| r.alpha)
                .collect();
            let max_int = out.result.records.iter().map(|r| r.max_abs_int).max().unwrap_or(0);
            csv.row(&[
                v.to_string(),
                seed.to_string(),
                format!("{:.4}", out.test.0),
                format!("{:.4}", out.test.1),
                format!("{:.4e}", mean(&alphas)),
                max_int.to_string(),
            ])?;
            println!(
                "{:<20} {:>10.4} {:>10.4} {:>12.3e} {:>10}",
                v, out.test.0, out.test.1, mean(&alphas), max_int
            );
        }
    }
    csv.flush()?;
    println!("wrote {path}");
    Ok(())
}
