//! Figure 6 (Appendix C.5): l2-regularized logistic regression with
//! heterogeneous shards — IntGD vs IntDIANA vs VR-IntDIANA on the four
//! LibSVM-geometry datasets.
//!
//! Shape to reproduce:
//!   - IntGD's aggregated integers blow up as x -> x* (alpha ~ 1/||dx||
//!     against nonvanishing local gradients);
//!   - IntDIANA keeps them small (<~3 bits/coordinate);
//!   - VR-IntDIANA reaches the same gap with fewer gradient oracles.

use anyhow::Result;

use crate::config::Config;
use crate::data::{synth_dataset, DATASETS};
use crate::metrics::Csv;
use crate::optim::{Estimator, IntDiana};

pub fn run(cfg: &Config) -> Result<()> {
    let out_dir = cfg.str_or("out_dir", "results");
    let workers = cfg.usize_or("workers", 12);
    let rounds = cfg.usize_or("rounds", 400);
    let seeds = cfg.usize_or("seeds", 3) as u64;
    let only = cfg.get("dataset").map(|s| s.to_string());

    let path = format!("{out_dir}/fig6_logreg.csv");
    let mut csv = Csv::create(
        &path,
        &[
            "dataset", "algo", "seed", "round", "objective_gap", "max_abs_int",
            "agg_bits", "oracle_calls",
        ],
    )?;

    for spec in DATASETS {
        if let Some(ref o) = only {
            if o != spec.name {
                continue;
            }
        }
        // real-sim at full scale is slow on one core; subsample rounds
        let rounds = if spec.dim > 10_000 { rounds.min(150) } else { rounds };
        eprintln!("[fig6] dataset {} (N={}, d={})", spec.name, spec.n_examples, spec.dim);
        let ds = synth_dataset(spec, 11);
        let shards = ds.shards(workers);
        let global = ds.global();
        let d = spec.dim;

        // f* by full GD on the pooled problem
        let mut x = vec![0.0f32; d];
        let fstar_iters = cfg.usize_or("fstar_iters", 2000);
        for _ in 0..fstar_iters {
            let g = global.grad(&x);
            for (xi, &gi) in x.iter_mut().zip(&g) {
                *xi -= 1.0 * gi;
            }
        }
        let f_star = global.loss(&x);

        let m = shards[0].examples();
        let tau = (m / 20).max(1);
        let eta = cfg.f64_or("eta", 0.5);
        let algos: Vec<(&str, Estimator, bool, usize)> = vec![
            ("IntGD", Estimator::Gd, false, 0),
            ("IntDIANA", Estimator::Gd, true, 0),
            ("VR-IntDIANA", Estimator::LSvrg { p: tau as f64 / m as f64 }, true, tau),
        ];
        for (name, est, shifts, mb) in algos {
            for seed in 0..seeds {
                let mut opt = IntDiana::new(workers, d, eta, est, shifts, 500 + seed);
                let (_, recs) = opt.run(
                    &shards,
                    vec![0.0f32; d],
                    rounds,
                    mb,
                    &global,
                    f_star,
                    (rounds / 40).max(1),
                );
                for r in &recs {
                    csv.row(&[
                        spec.name.to_string(),
                        name.to_string(),
                        seed.to_string(),
                        r.round.to_string(),
                        format!("{:.6e}", r.objective.max(1e-16)),
                        r.max_abs_int.to_string(),
                        format!("{:.2}", r.agg_bits_per_coord),
                        r.oracle_calls.to_string(),
                    ])?;
                }
                let last = recs.last().unwrap();
                eprintln!(
                    "[fig6]   {name} seed {seed}: gap {:.2e}, max int {}, bits {:.1}",
                    last.objective, last.max_abs_int, last.agg_bits_per_coord
                );
            }
        }
    }
    csv.flush()?;
    println!("wrote {path}");
    Ok(())
}
