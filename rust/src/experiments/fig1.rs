//! Figure 1: IntSGD (8/32-bit) vs Heuristic IntSGD (8/32-bit) vs
//! full-precision SGD on the classification and LM tasks.
//!
//! Paper claim to reproduce: adaptive IntSGD matches full-precision SGD at
//! both widths, while Heuristic IntSGD (notably the 8-bit wire) fails to
//! match test performance.

use anyhow::Result;

use crate::config::Config;
use crate::metrics::Csv;

use super::common::{run_task, setup, Task};

pub const ALGOS: &[&str] =
    &["sgd_ar", "intsgd_random8", "intsgd_random32", "heuristic8", "heuristic32"];

pub fn run(cfg: &Config) -> Result<()> {
    let s = setup(cfg, 240, 0.1);
    let tasks: Vec<Task> = match cfg.str_or("task", "both") {
        "classifier" => vec![Task::Classifier],
        "lm" => vec![Task::Lm],
        _ => vec![Task::Classifier, Task::Lm],
    };
    for task in tasks {
        let lr = if task == Task::Lm { cfg.f32_or("lr", 1.25) } else { s.lr };
        let s = super::common::Setup { lr, ..setup(cfg, 240, 0.1) };
        let path = format!("{}/fig1_{}.csv", s.out_dir, task.model_name());
        let mut csv = Csv::create(
            &path,
            &["algo", "seed", "round", "train_loss", "eval_loss", "eval_acc", "alpha"],
        )?;
        for algo in ALGOS {
            for &seed in &s.seeds {
                eprintln!("[fig1] {} / {algo} / seed {seed}", task.model_name());
                let out = run_task(task, algo, &s, 0.9, 1e-8, seed, cfg)?;
                let mut evals = out.result.evals.iter().peekable();
                for r in &out.result.records {
                    let (el, ea) = match evals.peek() {
                        Some(&&(er, l, a)) if er == r.round => {
                            evals.next();
                            (l, a)
                        }
                        _ => (f64::NAN, f64::NAN),
                    };
                    csv.row(&[
                        algo.to_string(),
                        seed.to_string(),
                        r.round.to_string(),
                        format!("{:.6}", r.train_loss),
                        format!("{el:.6}"),
                        format!("{ea:.6}"),
                        format!("{:.4e}", r.alpha),
                    ])?;
                }
                eprintln!(
                    "[fig1]   final test: loss {:.4} acc {:.4}",
                    out.test.0, out.test.1
                );
            }
        }
        csv.flush()?;
        println!("wrote {path}");
    }
    Ok(())
}
