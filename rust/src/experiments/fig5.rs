//! Figure 5 (Appendix C.4): sensitivity of IntSGD to the moving-average
//! factor beta and the safeguard epsilon.
//!
//! Shape to reproduce: performance is flat across beta in {0, .3, .6, .9}
//! and eps in {1e-4, 1e-6, 1e-8}; beta=0.9, eps=1e-8 is a good default.

use anyhow::Result;

use crate::config::Config;
use crate::metrics::Csv;

use super::common::{run_task, setup, Task};

pub fn run(cfg: &Config) -> Result<()> {
    let betas = [0.0, 0.3, 0.6, 0.9];
    let epss = [1e-4, 1e-6, 1e-8];
    let tasks: Vec<Task> = match cfg.str_or("task", "classifier") {
        "lm" => vec![Task::Lm],
        "both" => vec![Task::Classifier, Task::Lm],
        _ => vec![Task::Classifier],
    };
    for task in tasks {
        let default_lr = if task == Task::Lm { 1.25 } else { 0.1 };
        let s = setup(cfg, 160, default_lr);
        let path = format!("{}/fig5_{}.csv", s.out_dir, task.model_name());
        let mut csv = Csv::create(
            &path,
            &["beta", "eps", "seed", "test_loss", "test_acc"],
        )?;
        println!("beta\\eps sensitivity ({}):", task.model_name());
        for &beta in &betas {
            for &eps in &epss {
                for &seed in &s.seeds {
                    eprintln!("[fig5] beta={beta} eps={eps:.0e} seed={seed}");
                    let out =
                        run_task(task, "intsgd_random8", &s, beta, eps, seed, cfg)?;
                    csv.rowf(&[beta, eps, seed as f64, out.test.0, out.test.1])?;
                    println!(
                        "  beta={beta:.1} eps={eps:.0e}: loss {:.4} acc {:.4}",
                        out.test.0, out.test.1
                    );
                }
            }
        }
        csv.flush()?;
        println!("wrote {path}");
    }
    Ok(())
}
